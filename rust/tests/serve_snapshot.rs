//! Crash-recovery determinism: snapshot at minute *T*, kill, restore,
//! continue — byte-identical to the uninterrupted run.
//!
//! The pin is exact, not statistical: the JSONL event stream of
//! (prefix-run-up-to-*T*) ++ (restored-run-to-completion) must equal the
//! uninterrupted run's stream line for line, and the final records,
//! metrics, makespan, and live-set accounting must match — under chaos
//! scenario scripts (node failures, drains, resizes, cancellations,
//! reclassifications), across both drive engines, all preemptive
//! policies, and several arrival-lookahead windows. The harness style
//! mirrors `victim_index_chaos.rs`.

use fitgpp::cluster::{ClusterSpec, NodeId};
use fitgpp::job::{JobClass, JobId};
use fitgpp::resources::ResourceVec;
use fitgpp::sched::control::{event_jsonl_line, EventSubscriber, SchedulerCommand, SchedulerEvent};
use fitgpp::sched::policy::PolicyKind;
use fitgpp::serve::snapshot;
use fitgpp::sim::scenario::ScenarioScript;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, SimSession};
use fitgpp::stats::rng::Pcg64;
use fitgpp::testkit::{check, gen, PropConfig};
use fitgpp::workload::source::WorkloadSource;
use fitgpp::workload::Workload;
use std::cell::RefCell;
use std::rc::Rc;

/// Captures the event stream in the exact wire/JSONL line format.
struct CollectLines(Rc<RefCell<Vec<String>>>);

impl EventSubscriber for CollectLines {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        self.0.borrow_mut().push(event_jsonl_line(ev));
    }
}

fn preemptive_policies(rng: &mut Pcg64) -> PolicyKind {
    match rng.below(8) {
        0 => PolicyKind::Lrtp,
        1 => PolicyKind::Rand,
        2 => PolicyKind::Srtf,
        3 => PolicyKind::Youngest,
        4 => PolicyKind::PSrtf,
        5 => PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        6 => PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
        _ => PolicyKind::FitGpp { s: 2.0, p_max: None },
    }
}

/// Random control-plane chaos over the first 300 minutes, with every
/// node restored at minute 400 so the backlog can drain.
fn chaos_script(rng: &mut Pcg64, nodes: usize, n_jobs: usize) -> ScenarioScript {
    let mut script = ScenarioScript::new();
    for _ in 0..2 + rng.below(5) {
        let node = NodeId(rng.below(nodes as u64) as u32);
        let at = 1 + rng.below(300);
        let cmd = match rng.below(6) {
            0 => SchedulerCommand::NodeDown { node },
            1 => SchedulerCommand::Drain { node },
            2 => SchedulerCommand::NodeUp { node },
            3 => SchedulerCommand::Resize {
                node,
                capacity: ResourceVec::new(
                    32.0 + rng.below(32) as f64,
                    256.0 + rng.below(256) as f64,
                    8.0 + rng.below(8) as f64,
                ),
            },
            4 => SchedulerCommand::Cancel {
                job: JobId(rng.below(n_jobs as u64) as u32),
            },
            _ => SchedulerCommand::Reclassify {
                job: JobId(rng.below(n_jobs as u64) as u32),
                class: if rng.chance(0.5) { JobClass::Te } else { JobClass::Be },
            },
        };
        script = script.at(at, cmd);
    }
    for node in 0..nodes {
        script = script.at(400, SchedulerCommand::NodeUp { node: NodeId(node as u32) });
    }
    script
}

fn collector() -> (Rc<RefCell<Vec<String>>>, Vec<Box<dyn EventSubscriber>>) {
    let lines = Rc::new(RefCell::new(Vec::new()));
    let subs: Vec<Box<dyn EventSubscriber>> = vec![Box::new(CollectLines(lines.clone()))];
    (lines, subs)
}

/// The uninterrupted run: full event stream + final result.
fn baseline(cfg: &SimConfig, wl: &Workload) -> (Vec<String>, SimResult) {
    let (lines, subs) = collector();
    let mut src = WorkloadSource::new(wl);
    let mut sess = SimSession::new(cfg.clone(), subs);
    sess.run_to_completion(&mut src);
    let res = sess.finish(&mut src);
    (Rc::try_unwrap(lines).unwrap().into_inner(), res)
}

/// The interrupted run: run to `cut`, snapshot through the full file
/// envelope, drop everything, restore into a fresh session with a fresh
/// source, and continue to completion. Returns the *stitched* event
/// stream (prefix ++ suffix) and the final result.
fn killed_and_restored(cfg: &SimConfig, wl: &Workload, cut: u64) -> (Vec<String>, SimResult) {
    let bytes = {
        let (_pre_lines, subs) = collector();
        let mut src = WorkloadSource::new(wl);
        let mut sess = SimSession::new(cfg.clone(), subs);
        sess.run_until(&mut src, cut);
        snapshot::encode(&sess)
        // sess, src, and the prefix collector drop here: the "kill".
    };
    // The prefix stream must be re-derived the way a real operator
    // would have it — from the prefix process's own subscriber. Run the
    // prefix again with its own collector to materialize those lines.
    let mut prefix_lines = {
        let (lines, subs) = collector();
        let mut src = WorkloadSource::new(wl);
        let mut sess = SimSession::new(cfg.clone(), subs);
        sess.run_until(&mut src, cut);
        drop(sess);
        Rc::try_unwrap(lines).unwrap().into_inner()
    };
    let (suffix, subs) = collector();
    let mut src = WorkloadSource::new(wl);
    let mut sess = snapshot::decode(&bytes, cfg.clone(), subs, &mut src).expect("restore");
    sess.run_to_completion(&mut src);
    let res = sess.finish(&mut src);
    prefix_lines.extend(Rc::try_unwrap(suffix).unwrap().into_inner());
    (prefix_lines, res)
}

fn assert_identical(
    what: &str,
    full: &(Vec<String>, SimResult),
    stitched: &(Vec<String>, SimResult),
) -> Result<(), String> {
    if stitched.0 != full.0 {
        let n = full.0.len().min(stitched.0.len());
        let diverge = (0..n)
            .find(|&i| full.0[i] != stitched.0[i])
            .unwrap_or(n);
        return Err(format!(
            "{what}: event streams diverge at line {diverge}: full has {} lines ({:?}…), stitched has {} ({:?}…)",
            full.0.len(),
            full.0.get(diverge),
            stitched.0.len(),
            stitched.0.get(diverge),
        ));
    }
    if stitched.1.records != full.1.records {
        return Err(format!("{what}: final records diverge"));
    }
    if stitched.1.metrics != full.1.metrics {
        return Err(format!("{what}: streaming metrics diverge"));
    }
    if stitched.1.makespan != full.1.makespan || stitched.1.unfinished != full.1.unfinished {
        return Err(format!(
            "{what}: makespan/unfinished diverge: {}/{} vs {}/{}",
            stitched.1.makespan, stitched.1.unfinished, full.1.makespan, full.1.unfinished
        ));
    }
    if format!("{:?}", stitched.1.sched_stats) != format!("{:?}", full.1.sched_stats) {
        return Err(format!("{what}: scheduler stats diverge"));
    }
    Ok(())
}

#[test]
fn prop_restore_is_byte_identical_under_chaos() {
    let cases = PropConfig { cases: 14, ..Default::default() };
    check("serve-snapshot-chaos", cases, |rng| {
        let nodes = 2 + rng.below(3) as usize;
        let n = 20 + rng.below(40) as usize;
        let wl = gen::workload(rng, n, 30 + rng.below(60));
        let policy = preemptive_policies(rng);
        let script = chaos_script(rng, nodes, n);
        let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
        cfg.paranoid = true;
        cfg.seed = rng.next_u64();
        cfg.engine = if rng.chance(0.5) { SimEngine::EventHorizon } else { SimEngine::PerMinute };
        cfg.arrival_lookahead = [0u64, 7, 10_000][rng.below(3) as usize];
        cfg.max_ticks = 20_000;
        cfg.scenario = Some(script);
        let full = baseline(&cfg, &wl);
        // Several random cut points per case, including minute 0 (restore
        // before anything ran) — each must stitch back byte-identically.
        let mut cuts = vec![0u64, 1 + rng.below(120)];
        if rng.chance(0.5) {
            cuts.push(1 + rng.below(500));
        }
        for cut in cuts {
            let stitched = killed_and_restored(&cfg, &wl, cut);
            assert_identical(
                &format!("{policy:?} {:?} lookahead={} cut={cut}", cfg.engine, cfg.arrival_lookahead),
                &full,
                &stitched,
            )?;
        }
        Ok(())
    });
}

#[test]
fn restore_matrix_covers_every_policy_and_both_engines() {
    // Deterministic sweep: all 8 preemptive policies x both engines, one
    // mid-run cut each, under a fixed chaos script.
    let mut rng = Pcg64::new(0xF1F6_0001);
    let nodes = 3;
    let n = 36;
    let wl = gen::workload(&mut rng, n, 60);
    let script = chaos_script(&mut rng, nodes, n);
    let policies = [
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::Srtf,
        PolicyKind::Youngest,
        PolicyKind::PSrtf,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
        PolicyKind::FitGpp { s: 2.0, p_max: None },
    ];
    for policy in policies {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
            cfg.paranoid = true;
            cfg.seed = 11;
            cfg.engine = engine;
            cfg.max_ticks = 20_000;
            cfg.scenario = Some(script.clone());
            let full = baseline(&cfg, &wl);
            let stitched = killed_and_restored(&cfg, &wl, 25);
            if let Err(e) = assert_identical(&format!("{policy:?} {engine:?}"), &full, &stitched) {
                panic!("{e}");
            }
        }
    }
}

#[test]
fn restore_under_wrong_policy_is_refused() {
    let mut rng = Pcg64::new(42);
    let wl = gen::workload(&mut rng, 20, 40);
    let cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
    let mut src = WorkloadSource::new(&wl);
    let mut sess = SimSession::new(cfg.clone(), Vec::new());
    sess.run_until(&mut src, 10);
    let bytes = snapshot::encode(&sess);
    let other = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Lrtp);
    let mut src2 = WorkloadSource::new(&wl);
    let err = snapshot::decode(&bytes, other, Vec::new(), &mut src2)
        .err()
        .expect("config mismatch must be refused");
    assert!(
        format!("{err:#}").contains("different configuration"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn background_writer_persists_decodable_snapshots() {
    let dir = std::env::temp_dir().join(format!("fitgpp-snapwriter-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg64::new(9);
    let wl = gen::workload(&mut rng, 20, 40);
    let cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
    let mut src = WorkloadSource::new(&wl);
    let mut sess = SimSession::new(cfg.clone(), Vec::new());

    let writer = snapshot::SnapshotWriter::spawn();
    sess.run_until(&mut src, 5);
    assert!(writer.enqueue(dir.join("auto-000000000005-000000.snap"), snapshot::encode(&sess)));
    sess.run_until(&mut src, 12);
    let cut = sess.now();
    assert!(writer.enqueue(dir.join("auto-000000000012-000001.snap"), snapshot::encode(&sess)));
    // finish() joins the writer thread: both files are durable after it.
    assert_eq!(writer.finish().unwrap(), 2);

    let latest = snapshot::latest_in(&dir).unwrap().expect("two snapshots on disk");
    assert!(latest.ends_with("auto-000000000012-000001.snap"), "picked {}", latest.display());
    let bytes = snapshot::load(&latest).unwrap();
    let mut src2 = WorkloadSource::new(&wl);
    let restored = snapshot::decode(&bytes, cfg, Vec::new(), &mut src2).unwrap();
    assert_eq!(restored.now(), cut);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn torn_tmp_files_are_invisible_to_restore_and_to_later_saves() {
    let dir = std::env::temp_dir().join(format!("fitgpp-snaptmp-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg64::new(10);
    let wl = gen::workload(&mut rng, 10, 20);
    let cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Lrtp);
    let mut src = WorkloadSource::new(&wl);
    let mut sess = SimSession::new(cfg.clone(), Vec::new());
    sess.run_until(&mut src, 8);
    let good = dir.join("auto-000000000008-000000.snap");
    snapshot::save(&good, &snapshot::encode(&sess)).unwrap();

    // A kill -9 mid-write leaves a half-written `*.snap.tmp`. It must
    // never be selected for restore, no matter how fresh it is…
    std::thread::sleep(std::time::Duration::from_millis(20));
    std::fs::write(dir.join("auto-000000000099-000001.snap.tmp"), b"torn garbage").unwrap();
    let latest = snapshot::latest_in(&dir).unwrap().expect("a snapshot on disk");
    assert_eq!(latest, good, "restore must ignore *.snap.tmp orphans");

    // …and a later save to the same name must simply overwrite the
    // leftover tmp file on its way through.
    std::fs::write(dir.join("retry.snap.tmp"), b"stale tmp from a dead process").unwrap();
    let retry = dir.join("retry.snap");
    snapshot::save(&retry, &snapshot::encode(&sess)).unwrap();
    let bytes = snapshot::load(&retry).unwrap();
    let mut src2 = WorkloadSource::new(&wl);
    let restored = snapshot::decode(&bytes, cfg, Vec::new(), &mut src2).unwrap();
    assert_eq!(restored.now(), sess.now());
    let _ = std::fs::remove_dir_all(&dir);
}
