//! Victim-index consistency under transition chaos.
//!
//! The incrementally-maintained [`VictimIndex`] claims byte-equality with
//! a from-scratch rebuild after *any* interleaving of the transitions that
//! mutate it: place, preempt (signal + synchronous zero-GP vacate),
//! resume, finish, cancel, reclassify, drain, node-down/up, and resize.
//! Two attack angles:
//!
//! 1. **Paranoid simulation chaos** — randomized workloads under
//!    preemptive policies with randomized control-plane scripts, run with
//!    `paranoid = true` so *every tick* asserts
//!    [`VictimIndex::check_against`] (lists, all four ordered score sets
//!    byte-equal; aggregates within fp tolerance) — across both drive
//!    engines and several arrival-lookahead windows, with records compared
//!    pairwise so the index also stays observably invisible.
//! 2. **Direct control-plane chaos** — a [`ClusterController`] driven
//!    command-by-command, with an explicit `check_against` after every
//!    single command *and* step, catching corruption that a later tick's
//!    paranoid check might mask.

use fitgpp::cluster::{ClusterSpec, NodeId};
use fitgpp::job::{JobClass, JobId};
use fitgpp::prop_assert;
use fitgpp::resources::ResourceVec;
use fitgpp::sched::control::{ClusterController, SchedulerCommand};
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sched::SchedConfig;
use fitgpp::sim::scenario::ScenarioScript;
use fitgpp::sim::{SimConfig, SimEngine, Simulator};
use fitgpp::stats::rng::Pcg64;
use fitgpp::testkit::{check, gen, PropConfig};

fn preemptive_policies(rng: &mut Pcg64) -> PolicyKind {
    match rng.below(8) {
        0 => PolicyKind::Lrtp,
        1 => PolicyKind::Rand,
        2 => PolicyKind::Srtf,
        3 => PolicyKind::Youngest,
        4 => PolicyKind::PSrtf,
        5 => PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        6 => PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
        _ => PolicyKind::FitGpp { s: 2.0, p_max: None },
    }
}

/// A random chaos script: node failures, drains, restores, upward
/// resizes, cancellations, and reclassifications sprinkled through the
/// first 300 minutes, with every node brought back up (and restored to a
/// roomy capacity) at minute 400 so the backlog can drain.
fn chaos_script(rng: &mut Pcg64, nodes: usize, n_jobs: usize) -> ScenarioScript {
    let mut script = ScenarioScript::new();
    for _ in 0..2 + rng.below(5) {
        let node = NodeId(rng.below(nodes as u64) as u32);
        let at = 1 + rng.below(300);
        let cmd = match rng.below(6) {
            0 => SchedulerCommand::NodeDown { node },
            1 => SchedulerCommand::Drain { node },
            2 => SchedulerCommand::NodeUp { node },
            3 => SchedulerCommand::Resize {
                node,
                // Upward-only: shrinking could strand a job that no longer
                // fits anywhere, and rejection paths are exercised anyway.
                capacity: ResourceVec::new(
                    32.0 + rng.below(32) as f64,
                    256.0 + rng.below(256) as f64,
                    8.0 + rng.below(8) as f64,
                ),
            },
            4 => SchedulerCommand::Cancel {
                job: JobId(rng.below(n_jobs as u64) as u32),
            },
            _ => SchedulerCommand::Reclassify {
                job: JobId(rng.below(n_jobs as u64) as u32),
                class: if rng.chance(0.5) { JobClass::Te } else { JobClass::Be },
            },
        };
        script = script.at(at, cmd);
    }
    for node in 0..nodes {
        script = script.at(400, SchedulerCommand::NodeUp { node: NodeId(node as u32) });
    }
    script
}

#[test]
fn prop_victim_index_matches_rebuild_under_simulation_chaos() {
    // Angle 1: paranoid ticks assert `check_against` after every minute,
    // under both engines and several lookahead windows; record equality
    // across all combinations pins that the index never *changed* a
    // decision either.
    let cases = PropConfig { cases: 12, ..Default::default() };
    check("victim-index-chaos", cases, |rng| {
        let nodes = 2 + rng.below(3) as usize;
        let n = 20 + rng.below(50) as usize;
        let wl = gen::workload(rng, n, 30 + rng.below(80));
        let script = chaos_script(rng, nodes, n);
        let policy = preemptive_policies(rng);
        let seed = rng.next_u64();
        let mk = |engine: SimEngine, lookahead: u64| {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
            cfg.paranoid = true; // check_against every tick
            cfg.seed = seed;
            cfg.engine = engine;
            cfg.arrival_lookahead = lookahead;
            cfg.max_ticks = 20_000;
            cfg.scenario = Some(script.clone());
            Simulator::new(cfg).run(&wl)
        };
        let base = mk(SimEngine::EventHorizon, 0);
        prop_assert!(
            base.sched_stats.internal_errors == 0,
            "{policy:?}: internal errors under chaos"
        );
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            for lookahead in [0u64, 7, 10_000] {
                if engine == SimEngine::EventHorizon && lookahead == 0 {
                    continue; // that's `base`
                }
                let other = mk(engine, lookahead);
                prop_assert!(
                    other.records == base.records,
                    "{policy:?}: records diverge ({engine:?}, lookahead {lookahead})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_victim_index_matches_rebuild_after_every_command() {
    // Angle 2: drive the controller directly and cross-check the index
    // against a from-scratch rebuild after *each* command and step — no
    // tick in between to repair or mask a stale entry.
    check("victim-index-commands", PropConfig::default(), |rng| {
        let nodes = 2 + rng.below(3) as usize;
        let n = 15 + rng.below(30) as usize;
        let wl = gen::workload(rng, n, 40);
        let policy = preemptive_policies(rng);
        let mut ctl = ClusterController::new(
            &ClusterSpec::tiny(nodes),
            SchedConfig::new(policy),
        );
        for job in &wl.jobs {
            ctl.stage_arrival(job.clone());
        }
        let verify = |ctl: &ClusterController, what: &str| -> Result<(), String> {
            ctl.sched
                .victim_index()
                .check_against(&ctl.sched.cluster, &ctl.jobs)
                .map_err(|e| format!("{policy:?}: index diverged after {what}: {e}"))
        };
        for now in 0..300u64 {
            if rng.chance(0.25) {
                let node = NodeId(rng.below(nodes as u64) as u32);
                let cmd = match rng.below(6) {
                    0 => SchedulerCommand::NodeDown { node },
                    1 => SchedulerCommand::Drain { node },
                    2 => SchedulerCommand::NodeUp { node },
                    3 => SchedulerCommand::Resize {
                        node,
                        capacity: ResourceVec::new(
                            32.0 + rng.below(32) as f64,
                            256.0 + rng.below(256) as f64,
                            8.0 + rng.below(8) as f64,
                        ),
                    },
                    4 => SchedulerCommand::Cancel {
                        job: JobId(rng.below(n as u64) as u32),
                    },
                    _ => SchedulerCommand::Reclassify {
                        job: JobId(rng.below(n as u64) as u32),
                        class: if rng.chance(0.5) { JobClass::Te } else { JobClass::Be },
                    },
                };
                let what = format!("{cmd:?} at {now}");
                ctl.command(now, cmd);
                if let Err(e) = verify(&ctl, &what) {
                    return Err(e);
                }
            }
            ctl.step(now);
            if let Err(e) = verify(&ctl, &format!("step {now}")) {
                return Err(e);
            }
        }
        Ok(())
    });
}
