//! Equivalence properties of the streaming scale layer.
//!
//! 1. **Source equivalence** — a streamed [`ArrivalSource`] must
//!    reproduce the materialized [`Workload`] run byte-for-byte (records,
//!    makespan, simulated minutes) across every policy and both simulator
//!    drive modes: the §4.2 generator stream, the §4.4 institution stream,
//!    and the buffered CSV stream against their materialized twins.
//! 2. **Sketch accuracy** — the mergeable quantile sketch backing
//!    streamed (no-records) runs must stay within 1% relative error of the
//!    exact percentiles, both on raw heavy-tailed lognormal samples and on
//!    the TE/BE slowdown distributions of a ≥100k-job institution trace.
//! 3. **Closed loop** — the completion-fed source is deterministic,
//!    bounded by the user count (peak live set ≤ users), and identical
//!    under both drive modes — no fixed trace can express it, so the only
//!    oracle is the per-minute drive mode.

use fitgpp::cluster::ClusterSpec;
use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::stats::dist::{LogNormal, Sample};
use fitgpp::stats::rng::Pcg64;
use fitgpp::stats::sketch::QuantileSketch;
use fitgpp::stats::summary::percentile;
use fitgpp::workload::source::{ClosedLoopParams, ClosedLoopSource};
use fitgpp::workload::synthetic::SyntheticWorkload;
use fitgpp::workload::trace::{CsvStreamSource, InstitutionSource, Trace};

fn all_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::Srtf,
        PolicyKind::Youngest,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::PSrtf,
        PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
    ]
}

fn cfg(cluster: &ClusterSpec, policy: PolicyKind, engine: SimEngine) -> SimConfig {
    let mut cfg = SimConfig::new(cluster.clone(), policy);
    cfg.engine = engine;
    cfg.seed = 0xA11CE;
    cfg.paranoid = true;
    cfg
}

fn assert_identical(streamed: &SimResult, materialized: &SimResult, what: &str) {
    assert_eq!(streamed.makespan, materialized.makespan, "{what}: makespan");
    assert_eq!(
        streamed.records.len(),
        materialized.records.len(),
        "{what}: record count"
    );
    for (a, b) in streamed.records.iter().zip(&materialized.records) {
        assert_eq!(a, b, "{what}: record {:?}", a.id);
        assert_eq!(
            a.slowdown.to_bits(),
            b.slowdown.to_bits(),
            "{what}: slowdown bits of {:?}",
            a.id
        );
    }
    assert_eq!(
        streamed.sched_stats.ticks, materialized.sched_stats.ticks,
        "{what}: simulated minutes"
    );
    assert_eq!(streamed.unfinished, materialized.unfinished, "{what}: unfinished");
    assert_eq!(
        streamed.metrics, materialized.metrics,
        "{what}: streaming sinks diverge"
    );
}

#[test]
fn synthetic_stream_matches_materialized_run_for_all_policies() {
    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(300);
    let wl = params.generate();
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let materialized = Simulator::new(cfg(&cluster, policy, engine)).run(&wl);
            let streamed = Simulator::new(cfg(&cluster, policy, engine))
                .run_source(&mut params.stream());
            assert_identical(&streamed, &materialized, &format!("{policy:?}/{engine:?}"));
        }
    }
}

#[test]
fn institution_and_csv_streams_match_materialized_run() {
    let cluster = ClusterSpec::tiny(4);
    let wl = Trace::synthesize_institution(31, 600);
    let csv = Trace::to_csv(&wl);
    for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
        let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
        let materialized = Simulator::new(cfg(&cluster, policy, engine)).run(&wl);

        let mut inst = InstitutionSource::new(31, 600);
        let streamed = Simulator::new(cfg(&cluster, policy, engine)).run_source(&mut inst);
        assert_identical(&streamed, &materialized, &format!("institution/{engine:?}"));

        let mut csv_src =
            CsvStreamSource::from_reader(std::io::Cursor::new(csv.as_bytes())).unwrap();
        let streamed = Simulator::new(cfg(&cluster, policy, engine)).run_source(&mut csv_src);
        assert!(csv_src.error().is_none());
        assert_identical(&streamed, &materialized, &format!("csv/{engine:?}"));
    }
}

#[test]
fn stream_with_lookahead_matches_materialized_run() {
    let cluster = ClusterSpec::tiny(2);
    let params = SyntheticWorkload::paper_section_4_2(5)
        .with_cluster(cluster.clone())
        .with_num_jobs(200);
    let wl = params.generate();
    let policy = PolicyKind::Lrtp;
    let materialized = Simulator::new(cfg(&cluster, policy, SimEngine::EventHorizon)).run(&wl);
    for lookahead in [1u64, 32, 1 << 20] {
        let mut c = cfg(&cluster, policy, SimEngine::EventHorizon);
        c.arrival_lookahead = lookahead;
        let streamed = Simulator::new(c).run_source(&mut params.stream());
        assert_identical(&streamed, &materialized, &format!("lookahead {lookahead}"));
    }
}

#[test]
fn sketch_tracks_exact_percentiles_on_heavy_tailed_lognormals() {
    // Satellite property test: sketch p50/p95/p99 within 1% relative error
    // of exact stats::summary percentiles on heavy-tailed lognormal
    // samples (the BE slowdown regime), across seeds and tail weights.
    for (seed, median, p95) in [(1u64, 2.0, 20.0), (2, 3.0, 80.0), (3, 1.2, 400.0)] {
        let dist = LogNormal::from_median_p95(median, p95);
        let mut rng = Pcg64::new(seed);
        let mut sketch = QuantileSketch::new();
        let mut xs = Vec::with_capacity(100_000);
        for _ in 0..100_000 {
            let v = 1.0 + dist.sample(&mut rng);
            sketch.insert(v);
            xs.push(v);
        }
        for p in [50.0, 95.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = sketch.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(
                rel < 0.01,
                "seed {seed} p{p}: exact {exact}, sketch {est}, rel {rel}"
            );
        }
    }
}

#[test]
fn streamed_reports_within_one_percent_on_100k_job_trace() {
    // Acceptance: with record_jobs off, sketch-backed TE/BE p50/p95/p99
    // stay within 1% relative error of the exact values on a >= 100k-job
    // institution trace. The sink is identical with records on or off
    // (pinned in sim unit tests), so one records-on run provides both the
    // exact and the sketch values.
    let jobs = fitgpp::benchkit::env_usize("FITGPP_STREAM_TEST_JOBS", 100_000);
    let mut source = InstitutionSource::new(12, jobs);
    let mut c = cfg(&ClusterSpec::pfn(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        SimEngine::EventHorizon);
    c.paranoid = false; // full invariant sweeps are too slow at 100k jobs
    let res = Simulator::new(c).run_source(&mut source);
    assert_eq!(res.metrics.jobs_seen, jobs as u64);
    assert_eq!(res.unfinished, 0);
    assert!(
        res.peak_live < jobs / 2,
        "live set ({}) must stay well below total jobs ({jobs})",
        res.peak_live
    );

    let exact_te = fitgpp::metrics::Percentiles::of(&res.slowdowns(JobClass::Te));
    let exact_be = fitgpp::metrics::Percentiles::of(&res.slowdowns(JobClass::Be));
    let sketch = res.metrics.slowdown_report();
    for (what, exact, est) in [
        ("te.p50", exact_te.p50, sketch.te.p50),
        ("te.p95", exact_te.p95, sketch.te.p95),
        ("te.p99", exact_te.p99, sketch.te.p99),
        ("be.p50", exact_be.p50, sketch.be.p50),
        ("be.p95", exact_be.p95, sketch.be.p95),
        ("be.p99", exact_be.p99, sketch.be.p99),
    ] {
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.01, "{what}: exact {exact}, sketch {est}, rel {rel}");
    }
}

#[test]
fn protocol_layer_is_invisible_without_a_scenario() {
    // Acceptance pin for the control-plane redesign: routing every run
    // through the ClusterController command/event protocol — with an
    // *empty* scenario attached and an event subscriber observing — must
    // leave records, counters, simulated minutes, and the metrics sink
    // byte-identical to the plain driver across all 7 policies and both
    // engines.
    use fitgpp::sched::control::SharedEventLog;
    use fitgpp::sim::scenario::ScenarioScript;
    use fitgpp::workload::source::WorkloadSource;

    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(300);
    let wl = params.generate();
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let plain = Simulator::new(cfg(&cluster, policy, engine)).run(&wl);

            let mut scripted_cfg = cfg(&cluster, policy, engine);
            scripted_cfg.scenario = Some(ScenarioScript::new());
            let log = SharedEventLog::new();
            let scripted = Simulator::new(scripted_cfg)
                .run_with(&mut WorkloadSource::new(&wl), vec![Box::new(log.clone())]);

            assert_identical(&scripted, &plain, &format!("{policy:?}/{engine:?} empty scenario"));
            assert_eq!(
                scripted.sched_stats.fast_forwarded_ticks, plain.sched_stats.fast_forwarded_ticks,
                "{policy:?}/{engine:?}: the empty scenario must not break fast-forwarding"
            );
            // The observer saw the whole run: one submitted + one
            // finished event per job at minimum, and observing changed
            // nothing (asserted above).
            let events = log.events();
            let submitted = events.iter().filter(|e| e.kind() == "submitted").count();
            let finished = events.iter().filter(|e| e.kind() == "finished").count();
            assert_eq!(submitted, wl.len(), "{policy:?}/{engine:?}");
            assert_eq!(finished, wl.len(), "{policy:?}/{engine:?}");
        }
    }
}

/// Strip tenant identity from a result so tenant-tagged runs can be
/// compared byte-for-byte against untagged baselines (tenant assignment is
/// pure metadata under the `fifo` discipline — nothing else may move).
fn strip_tenants(res: &SimResult) -> SimResult {
    let mut out = res.clone();
    for r in &mut out.records {
        r.tenant = fitgpp::job::TenantId::DEFAULT;
    }
    out.metrics.tenants.clear();
    out
}

#[test]
fn fifo_discipline_with_tenant_identity_is_byte_identical() {
    // The refactor's safety net: an explicit `--discipline fifo` run over
    // a tenant-tagged workload must be byte-identical (records, makespan,
    // simulated minutes, global metrics) to the pre-refactor default for
    // every policy, both engines, and both generator source types.
    use fitgpp::sched::admission::DisciplineKind;
    use fitgpp::workload::source::TenantAssigner;
    use fitgpp::workload::trace::InstitutionSource;

    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(300);
    let tagged_params = params
        .clone()
        .with_tenant_assigner(TenantAssigner::round_robin(5).with_burst(3, 200, 40));
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let base = Simulator::new(cfg(&cluster, policy, engine))
                .run_source(&mut params.stream());
            let mut tagged_cfg = cfg(&cluster, policy, engine);
            tagged_cfg.discipline = DisciplineKind::Fifo;
            let tagged = Simulator::new(tagged_cfg).run_source(&mut tagged_params.stream());
            assert!(
                tagged.metrics.tenants.len() == 5,
                "{policy:?}/{engine:?}: expected 5 tenants, saw {}",
                tagged.metrics.tenants.len()
            );
            assert_identical(
                &strip_tenants(&tagged),
                &strip_tenants(&base),
                &format!("{policy:?}/{engine:?} fifo+tenants"),
            );
        }
    }

    // Institution stream: same pin on the other generator.
    let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
    let base = Simulator::new(cfg(&cluster, policy, SimEngine::EventHorizon))
        .run_source(&mut InstitutionSource::new(31, 400));
    let tagged = Simulator::new(cfg(&cluster, policy, SimEngine::EventHorizon)).run_source(
        &mut InstitutionSource::new(31, 400).with_tenants(TenantAssigner::round_robin(7)),
    );
    assert_identical(&strip_tenants(&tagged), &strip_tenants(&base), "institution fifo+tenants");
}

#[test]
fn weighted_fair_with_one_tenant_is_byte_identical_to_fifo() {
    // With a single tenant, weighted round-robin degenerates to the exact
    // FIFO order (one sub-queue, head-gated by the same outcomes), so the
    // whole run must be byte-identical — a strong pin that the discipline
    // protocol itself (round/report bookkeeping) adds no drift.
    use fitgpp::sched::admission::DisciplineKind;
    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(29)
        .with_cluster(cluster.clone())
        .with_num_jobs(250);
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let base = Simulator::new(cfg(&cluster, policy, engine))
                .run_source(&mut params.stream());
            let mut wf_cfg = cfg(&cluster, policy, engine);
            wf_cfg.discipline = DisciplineKind::WeightedFair;
            let wf = Simulator::new(wf_cfg).run_source(&mut params.stream());
            assert_identical(&wf, &base, &format!("{policy:?}/{engine:?} wf-single-tenant"));
        }
    }
}

#[test]
fn tenant_disciplines_agree_across_engines_and_lookahead() {
    // The tenant-aware acceptance pin: weighted-fair and quota-gate runs
    // with 8 tenants and a mid-run quota squeeze must produce identical
    // records, metrics (including the per-tenant map), and makespans
    // under both drive modes and every lookahead window — i.e. the
    // disciplines respect the frozen-state contract the event-horizon
    // engine depends on.
    use fitgpp::job::TenantId;
    use fitgpp::sched::admission::DisciplineKind;
    use fitgpp::sched::control::SchedulerCommand;
    use fitgpp::sim::scenario::ScenarioScript;
    use fitgpp::workload::source::TenantAssigner;

    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(41)
        .with_cluster(cluster.clone())
        .with_num_jobs(300)
        .with_tenant_assigner(TenantAssigner::round_robin(8));
    let scenario = ScenarioScript::new()
        .at(20, SchedulerCommand::SetQuota { tenant: TenantId(3), size: 0.2 })
        .at(25, SchedulerCommand::SetWeight { tenant: TenantId(1), weight: 4 })
        .at(300, SchedulerCommand::SetQuota { tenant: TenantId(3), size: 1e9 });
    for discipline in [
        DisciplineKind::WeightedFair,
        DisciplineKind::QuotaGate { backfill: 2 },
    ] {
        let mk = |engine: SimEngine, lookahead: u64| {
            let mut c = cfg(
                &cluster,
                PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
                engine,
            );
            c.discipline = discipline;
            c.arrival_lookahead = lookahead;
            c.scenario = Some(scenario.clone());
            Simulator::new(c).run_source(&mut params.stream())
        };
        let base = mk(SimEngine::PerMinute, 0);
        assert_eq!(base.unfinished, 0, "{discipline:?}: quota squeeze was lifted, run drains");
        assert_eq!(base.metrics.tenants.len(), 8, "{discipline:?}");
        for engine in [SimEngine::PerMinute, SimEngine::EventHorizon] {
            for lookahead in [0u64, 1, 32, 1 << 20] {
                let other = mk(engine, lookahead);
                assert_identical(&other, &base, &format!("{discipline:?}/{engine:?}/{lookahead}"));
            }
        }
    }
}

#[test]
fn closed_loop_is_deterministic_and_bounded_by_users() {
    let cluster = ClusterSpec::tiny(3);
    let params = ClosedLoopParams::demo(12, 6);
    let run = |engine: SimEngine| {
        let mut source = ClosedLoopSource::new(params.clone(), 42);
        Simulator::new(cfg(&cluster, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, engine))
            .run_source(&mut source)
    };
    let a = run(SimEngine::EventHorizon);
    let b = run(SimEngine::EventHorizon);
    assert_eq!(a.records, b.records, "closed loop must be deterministic");

    // The per-minute drive mode is the only oracle for a feedback source.
    let pm = run(SimEngine::PerMinute);
    assert_identical(&a, &pm, "closed-loop engines");

    assert_eq!(a.metrics.jobs_seen, 12 * 6, "every trial ran");
    assert_eq!(a.unfinished, 0);
    assert!(
        a.peak_live <= 12,
        "each user has at most one job in flight (peak {})",
        a.peak_live
    );
    // Think time really separates a user's trials: with 12 users and think
    // ~10 min the run must span well past the ramp window.
    assert!(a.makespan > params.ramp, "makespan {} vs ramp {}", a.makespan, params.ramp);
}

#[test]
fn closed_loop_clamps_arrival_lookahead() {
    // A feedback-driven source must never be pulled ahead of `now`: a
    // completion can schedule a resubmission *earlier* than an already
    // visible arrival. The simulator clamps the lookahead to zero for
    // such sources, so any configured window changes nothing.
    let cluster = ClusterSpec::tiny(3);
    let run = |lookahead: u64| {
        let mut source = ClosedLoopSource::new(ClosedLoopParams::demo(6, 3), 9);
        let mut c = cfg(
            &cluster,
            PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            SimEngine::EventHorizon,
        );
        c.arrival_lookahead = lookahead;
        Simulator::new(c).run_source(&mut source)
    };
    let base = run(0);
    for lookahead in [1u64, 64, 1 << 20] {
        assert_eq!(base.records, run(lookahead).records, "lookahead {lookahead}");
    }
}

#[test]
fn closed_loop_source_seed_changes_schedule() {
    let cluster = ClusterSpec::tiny(3);
    let run = |seed: u64| {
        let mut source = ClosedLoopSource::new(ClosedLoopParams::demo(8, 4), seed);
        Simulator::new(cfg(&cluster, PolicyKind::FastLane, SimEngine::EventHorizon))
            .run_source(&mut source)
    };
    let a = run(1);
    let b = run(2);
    assert_ne!(a.records, b.records, "different seeds, different trials");
}
