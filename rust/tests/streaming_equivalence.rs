//! Equivalence properties of the streaming scale layer.
//!
//! 1. **Source equivalence** — a streamed [`ArrivalSource`] must
//!    reproduce the materialized [`Workload`] run byte-for-byte (records,
//!    makespan, simulated minutes) across every policy and both simulator
//!    drive modes: the §4.2 generator stream, the §4.4 institution stream,
//!    and the buffered CSV stream against their materialized twins.
//! 2. **Sketch accuracy** — the mergeable quantile sketch backing
//!    streamed (no-records) runs must stay within 1% relative error of the
//!    exact percentiles, both on raw heavy-tailed lognormal samples and on
//!    the TE/BE slowdown distributions of a ≥100k-job institution trace.
//! 3. **Closed loop** — the completion-fed source is deterministic,
//!    bounded by the user count (peak live set ≤ users), and identical
//!    under both drive modes — no fixed trace can express it, so the only
//!    oracle is the per-minute drive mode.

use fitgpp::cluster::ClusterSpec;
use fitgpp::job::JobClass;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::stats::dist::{LogNormal, Sample};
use fitgpp::stats::rng::Pcg64;
use fitgpp::stats::sketch::QuantileSketch;
use fitgpp::stats::summary::percentile;
use fitgpp::workload::source::{ClosedLoopParams, ClosedLoopSource};
use fitgpp::workload::synthetic::SyntheticWorkload;
use fitgpp::workload::trace::{CsvStreamSource, InstitutionSource, Trace};

fn all_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::Srtf,
        PolicyKind::Youngest,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    ]
}

fn cfg(cluster: &ClusterSpec, policy: PolicyKind, engine: SimEngine) -> SimConfig {
    let mut cfg = SimConfig::new(cluster.clone(), policy);
    cfg.engine = engine;
    cfg.seed = 0xA11CE;
    cfg.paranoid = true;
    cfg
}

fn assert_identical(streamed: &SimResult, materialized: &SimResult, what: &str) {
    assert_eq!(streamed.makespan, materialized.makespan, "{what}: makespan");
    assert_eq!(
        streamed.records.len(),
        materialized.records.len(),
        "{what}: record count"
    );
    for (a, b) in streamed.records.iter().zip(&materialized.records) {
        assert_eq!(a, b, "{what}: record {:?}", a.id);
        assert_eq!(
            a.slowdown.to_bits(),
            b.slowdown.to_bits(),
            "{what}: slowdown bits of {:?}",
            a.id
        );
    }
    assert_eq!(
        streamed.sched_stats.ticks, materialized.sched_stats.ticks,
        "{what}: simulated minutes"
    );
    assert_eq!(streamed.unfinished, materialized.unfinished, "{what}: unfinished");
    assert_eq!(
        streamed.metrics, materialized.metrics,
        "{what}: streaming sinks diverge"
    );
}

#[test]
fn synthetic_stream_matches_materialized_run_for_all_policies() {
    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(300);
    let wl = params.generate();
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let materialized = Simulator::new(cfg(&cluster, policy, engine)).run(&wl);
            let streamed = Simulator::new(cfg(&cluster, policy, engine))
                .run_source(&mut params.stream());
            assert_identical(&streamed, &materialized, &format!("{policy:?}/{engine:?}"));
        }
    }
}

#[test]
fn institution_and_csv_streams_match_materialized_run() {
    let cluster = ClusterSpec::tiny(4);
    let wl = Trace::synthesize_institution(31, 600);
    let csv = Trace::to_csv(&wl);
    for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
        let policy = PolicyKind::FitGpp { s: 4.0, p_max: Some(1) };
        let materialized = Simulator::new(cfg(&cluster, policy, engine)).run(&wl);

        let mut inst = InstitutionSource::new(31, 600);
        let streamed = Simulator::new(cfg(&cluster, policy, engine)).run_source(&mut inst);
        assert_identical(&streamed, &materialized, &format!("institution/{engine:?}"));

        let mut csv_src =
            CsvStreamSource::from_reader(std::io::Cursor::new(csv.as_bytes())).unwrap();
        let streamed = Simulator::new(cfg(&cluster, policy, engine)).run_source(&mut csv_src);
        assert!(csv_src.error().is_none());
        assert_identical(&streamed, &materialized, &format!("csv/{engine:?}"));
    }
}

#[test]
fn stream_with_lookahead_matches_materialized_run() {
    let cluster = ClusterSpec::tiny(2);
    let params = SyntheticWorkload::paper_section_4_2(5)
        .with_cluster(cluster.clone())
        .with_num_jobs(200);
    let wl = params.generate();
    let policy = PolicyKind::Lrtp;
    let materialized = Simulator::new(cfg(&cluster, policy, SimEngine::EventHorizon)).run(&wl);
    for lookahead in [1u64, 32, 1 << 20] {
        let mut c = cfg(&cluster, policy, SimEngine::EventHorizon);
        c.arrival_lookahead = lookahead;
        let streamed = Simulator::new(c).run_source(&mut params.stream());
        assert_identical(&streamed, &materialized, &format!("lookahead {lookahead}"));
    }
}

#[test]
fn sketch_tracks_exact_percentiles_on_heavy_tailed_lognormals() {
    // Satellite property test: sketch p50/p95/p99 within 1% relative error
    // of exact stats::summary percentiles on heavy-tailed lognormal
    // samples (the BE slowdown regime), across seeds and tail weights.
    for (seed, median, p95) in [(1u64, 2.0, 20.0), (2, 3.0, 80.0), (3, 1.2, 400.0)] {
        let dist = LogNormal::from_median_p95(median, p95);
        let mut rng = Pcg64::new(seed);
        let mut sketch = QuantileSketch::new();
        let mut xs = Vec::with_capacity(100_000);
        for _ in 0..100_000 {
            let v = 1.0 + dist.sample(&mut rng);
            sketch.insert(v);
            xs.push(v);
        }
        for p in [50.0, 95.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = sketch.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(
                rel < 0.01,
                "seed {seed} p{p}: exact {exact}, sketch {est}, rel {rel}"
            );
        }
    }
}

#[test]
fn streamed_reports_within_one_percent_on_100k_job_trace() {
    // Acceptance: with record_jobs off, sketch-backed TE/BE p50/p95/p99
    // stay within 1% relative error of the exact values on a >= 100k-job
    // institution trace. The sink is identical with records on or off
    // (pinned in sim unit tests), so one records-on run provides both the
    // exact and the sketch values.
    let jobs = fitgpp::benchkit::env_usize("FITGPP_STREAM_TEST_JOBS", 100_000);
    let mut source = InstitutionSource::new(12, jobs);
    let mut c = cfg(&ClusterSpec::pfn(), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        SimEngine::EventHorizon);
    c.paranoid = false; // full invariant sweeps are too slow at 100k jobs
    let res = Simulator::new(c).run_source(&mut source);
    assert_eq!(res.metrics.jobs_seen, jobs as u64);
    assert_eq!(res.unfinished, 0);
    assert!(
        res.peak_live < jobs / 2,
        "live set ({}) must stay well below total jobs ({jobs})",
        res.peak_live
    );

    let exact_te = fitgpp::metrics::Percentiles::of(&res.slowdowns(JobClass::Te));
    let exact_be = fitgpp::metrics::Percentiles::of(&res.slowdowns(JobClass::Be));
    let sketch = res.metrics.slowdown_report();
    for (what, exact, est) in [
        ("te.p50", exact_te.p50, sketch.te.p50),
        ("te.p95", exact_te.p95, sketch.te.p95),
        ("te.p99", exact_te.p99, sketch.te.p99),
        ("be.p50", exact_be.p50, sketch.be.p50),
        ("be.p95", exact_be.p95, sketch.be.p95),
        ("be.p99", exact_be.p99, sketch.be.p99),
    ] {
        let rel = (est - exact).abs() / exact;
        assert!(rel < 0.01, "{what}: exact {exact}, sketch {est}, rel {rel}");
    }
}

#[test]
fn protocol_layer_is_invisible_without_a_scenario() {
    // Acceptance pin for the control-plane redesign: routing every run
    // through the ClusterController command/event protocol — with an
    // *empty* scenario attached and an event subscriber observing — must
    // leave records, counters, simulated minutes, and the metrics sink
    // byte-identical to the plain driver across all 7 policies and both
    // engines.
    use fitgpp::sched::control::SharedEventLog;
    use fitgpp::sim::scenario::ScenarioScript;
    use fitgpp::workload::source::WorkloadSource;

    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(300);
    let wl = params.generate();
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let plain = Simulator::new(cfg(&cluster, policy, engine)).run(&wl);

            let mut scripted_cfg = cfg(&cluster, policy, engine);
            scripted_cfg.scenario = Some(ScenarioScript::new());
            let log = SharedEventLog::new();
            let scripted = Simulator::new(scripted_cfg)
                .run_with(&mut WorkloadSource::new(&wl), vec![Box::new(log.clone())]);

            assert_identical(&scripted, &plain, &format!("{policy:?}/{engine:?} empty scenario"));
            assert_eq!(
                scripted.sched_stats.fast_forwarded_ticks, plain.sched_stats.fast_forwarded_ticks,
                "{policy:?}/{engine:?}: the empty scenario must not break fast-forwarding"
            );
            // The observer saw the whole run: one submitted + one
            // finished event per job at minimum, and observing changed
            // nothing (asserted above).
            let events = log.events();
            let submitted = events.iter().filter(|e| e.kind() == "submitted").count();
            let finished = events.iter().filter(|e| e.kind() == "finished").count();
            assert_eq!(submitted, wl.len(), "{policy:?}/{engine:?}");
            assert_eq!(finished, wl.len(), "{policy:?}/{engine:?}");
        }
    }
}

#[test]
fn closed_loop_is_deterministic_and_bounded_by_users() {
    let cluster = ClusterSpec::tiny(3);
    let params = ClosedLoopParams::demo(12, 6);
    let run = |engine: SimEngine| {
        let mut source = ClosedLoopSource::new(params.clone(), 42);
        Simulator::new(cfg(&cluster, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, engine))
            .run_source(&mut source)
    };
    let a = run(SimEngine::EventHorizon);
    let b = run(SimEngine::EventHorizon);
    assert_eq!(a.records, b.records, "closed loop must be deterministic");

    // The per-minute drive mode is the only oracle for a feedback source.
    let pm = run(SimEngine::PerMinute);
    assert_identical(&a, &pm, "closed-loop engines");

    assert_eq!(a.metrics.jobs_seen, 12 * 6, "every trial ran");
    assert_eq!(a.unfinished, 0);
    assert!(
        a.peak_live <= 12,
        "each user has at most one job in flight (peak {})",
        a.peak_live
    );
    // Think time really separates a user's trials: with 12 users and think
    // ~10 min the run must span well past the ramp window.
    assert!(a.makespan > params.ramp, "makespan {} vs ramp {}", a.makespan, params.ramp);
}

#[test]
fn closed_loop_clamps_arrival_lookahead() {
    // A feedback-driven source must never be pulled ahead of `now`: a
    // completion can schedule a resubmission *earlier* than an already
    // visible arrival. The simulator clamps the lookahead to zero for
    // such sources, so any configured window changes nothing.
    let cluster = ClusterSpec::tiny(3);
    let run = |lookahead: u64| {
        let mut source = ClosedLoopSource::new(ClosedLoopParams::demo(6, 3), 9);
        let mut c = cfg(
            &cluster,
            PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            SimEngine::EventHorizon,
        );
        c.arrival_lookahead = lookahead;
        Simulator::new(c).run_source(&mut source)
    };
    let base = run(0);
    for lookahead in [1u64, 64, 1 << 20] {
        assert_eq!(base.records, run(lookahead).records, "lookahead {lookahead}");
    }
}

#[test]
fn closed_loop_source_seed_changes_schedule() {
    let cluster = ClusterSpec::tiny(3);
    let run = |seed: u64| {
        let mut source = ClosedLoopSource::new(ClosedLoopParams::demo(8, 4), seed);
        Simulator::new(cfg(&cluster, PolicyKind::FastLane, SimEngine::EventHorizon))
            .run_source(&mut source)
    };
    let a = run(1);
    let b = run(2);
    assert_ne!(a.records, b.records, "different seeds, different trials");
}
