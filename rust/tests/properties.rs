//! Property-based tests over the coordinator invariants, using the
//! in-tree `testkit` (proptest substitute). Each failure reports a
//! replayable seed.

use fitgpp::cluster::ClusterSpec;
use fitgpp::job::JobClass;
use fitgpp::prop_assert;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::stats::rng::Pcg64;
use fitgpp::testkit::{check, gen, PropConfig};

fn policies(rng: &mut Pcg64) -> PolicyKind {
    match rng.below(6) {
        0 => PolicyKind::Fifo,
        1 => PolicyKind::FastLane,
        2 => PolicyKind::Lrtp,
        3 => PolicyKind::Rand,
        4 => PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        _ => PolicyKind::FitGpp { s: 2.0, p_max: None },
    }
}

fn run_random(rng: &mut Pcg64, policy: PolicyKind) -> fitgpp::sim::SimResult {
    let nodes = 1 + rng.below(4) as usize;
    let n = 20 + rng.below(60) as usize;
    let span = 30 + rng.below(100);
    let wl = gen::workload(rng, n, span);
    let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
    cfg.paranoid = true; // cluster invariants checked every tick
    cfg.seed = rng.next_u64();
    Simulator::new(cfg).run(&wl)
}

#[test]
fn prop_all_jobs_complete_and_slowdowns_valid() {
    check("complete+slowdown", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        prop_assert!(res.unfinished == 0, "{policy:?}: {} unfinished", res.unfinished);
        for r in &res.records {
            prop_assert!(r.finished_at.is_some(), "{:?} unfinished", r.id);
            prop_assert!(
                r.slowdown >= 1.0 - 1e-9,
                "{:?} slowdown {} < 1",
                r.id,
                r.slowdown
            );
        }
        Ok(())
    });
}

#[test]
fn prop_te_jobs_never_preempted() {
    check("te-never-preempted", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        for r in &res.records {
            if r.class == JobClass::Te {
                prop_assert!(r.preemptions == 0, "TE {:?} preempted {}", r.id, r.preemptions);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_p_cap_is_hard() {
    // The paper's no-starvation guarantee: with P = p, no BE job is
    // preempted more than p times — including through the random fallback.
    check("p-cap", PropConfig::default(), |rng| {
        let p = 1 + rng.below(3) as u32;
        let res = run_random(rng, PolicyKind::FitGpp { s: 4.0, p_max: Some(p) });
        for r in &res.records {
            prop_assert!(
                r.preemptions <= p,
                "{:?} preempted {} > P={}",
                r.id,
                r.preemptions,
                p
            );
        }
        Ok(())
    });
}

#[test]
fn prop_non_preemptive_policies_never_preempt() {
    check("fifo-no-preempt", PropConfig::default(), |rng| {
        for policy in [PolicyKind::Fifo, PolicyKind::FastLane] {
            let res = run_random(rng, policy);
            prop_assert!(
                res.sched_stats.preemption_signals == 0,
                "{policy:?} preempted"
            );
            for r in &res.records {
                prop_assert!(r.preemptions == 0, "{:?}", r.id);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_resched_intervals_match_preemption_counts() {
    // Every vacated job eventually restarts (runs drain), so each
    // preemption produces exactly one re-scheduling interval.
    check("intervals-count", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        for r in &res.records {
            prop_assert!(
                r.resched_intervals.len() == r.preemptions as usize,
                "{:?}: {} intervals for {} preemptions",
                r.id,
                r.resched_intervals.len(),
                r.preemptions
            );
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_starts_in_submission_order() {
    // Vanilla FIFO admits strictly head-first, so first-start times are
    // non-decreasing in submission (= id) order.
    check("fifo-order", PropConfig::default(), |rng| {
        let res = run_random(rng, PolicyKind::Fifo);
        let mut last = 0;
        for r in &res.records {
            let s = r.first_start.unwrap();
            prop_assert!(s >= last, "{:?} started {} before predecessor {}", r.id, s, last);
            last = s;
        }
        Ok(())
    });
}

#[test]
fn prop_determinism() {
    check("determinism", PropConfig { cases: 16, ..Default::default() }, |rng| {
        let policy = policies(rng);
        let nodes = 1 + rng.below(3) as usize;
        let wl = gen::workload(rng, 40, 60);
        let seed = rng.next_u64();
        let mk = || {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
            cfg.seed = seed;
            Simulator::new(cfg).run(&wl)
        };
        let (a, b) = (mk(), mk());
        prop_assert!(a.makespan == b.makespan, "makespan differs");
        for (x, y) in a.records.iter().zip(&b.records) {
            prop_assert!(
                x.finished_at == y.finished_at && x.preemptions == y.preemptions,
                "{:?} differs",
                x.id
            );
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_at_least_critical_path() {
    // Makespan is bounded below by (a) the longest single job and (b) the
    // total-work / capacity ratio on the dominant axis.
    check("makespan-lb", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let nodes = 1 + rng.below(3) as usize;
        let wl = gen::workload(rng, 30, 40);
        let cap = ClusterSpec::tiny(nodes).total_capacity();
        let work = wl.total_work();
        let lb_work = work.dominant_share(&cap).floor() as u64;
        let lb_job = wl.jobs.iter().map(|j| j.submit + j.exec_time).max().unwrap_or(0);
        let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
        cfg.seed = rng.next_u64();
        let res = Simulator::new(cfg).run(&wl);
        prop_assert!(
            res.makespan >= lb_work.max(lb_job),
            "makespan {} below bound {}",
            res.makespan,
            lb_work.max(lb_job)
        );
        Ok(())
    });
}

#[test]
fn prop_parsers_never_panic_on_garbage() {
    // Failure injection: the JSON and trace parsers must reject (not
    // panic on) arbitrary byte soup, including truncations of valid input.
    check("parser-fuzz", PropConfig { cases: 200, ..Default::default() }, |rng| {
        let len = rng.below(200) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(96) + 32) as u8).collect();
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = fitgpp::util::json::Json::parse(&s); // Result, must not panic
        let _ = fitgpp::workload::trace::Trace::from_csv(&s);
        // Truncations of valid documents.
        let valid = r#"{"cluster":{"nodes":4},"policy":"lrtp","workload":{"kind":"synthetic","jobs":16}}"#;
        let cut = rng.below(valid.len() as u64) as usize;
        let _ = fitgpp::config::ExperimentConfig::from_json(&valid[..cut]);
        Ok(())
    });
}

#[test]
fn prop_checkpoint_parser_never_panics_on_bitflips() {
    use fitgpp::runtime::Checkpoint;
    check("checkpoint-fuzz", PropConfig { cases: 100, ..Default::default() }, |rng| {
        let ckpt = Checkpoint::new(
            rng.next_u64() % 1000,
            vec![(vec![4, 4], (0..16).map(|i| i as f32).collect())],
        );
        let mut bytes = ckpt.to_bytes();
        // Corrupt 1-4 random bytes and/or truncate.
        for _ in 0..=rng.below(4) {
            let i = rng.below(bytes.len() as u64) as usize;
            bytes[i] ^= (1 << rng.below(8)) as u8;
        }
        if rng.chance(0.3) {
            let cut = rng.below(bytes.len() as u64) as usize;
            bytes.truncate(cut);
        }
        let _ = Checkpoint::from_bytes(&bytes); // Result, must not panic
        Ok(())
    });
}

#[test]
fn prop_slowdown_percentiles_monotone() {
    // p50 ≤ p95 ≤ p99 for both classes under every policy.
    check("percentiles-monotone", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        let rep = res.slowdown_report();
        for p in [rep.te, rep.be] {
            if p.p50.is_nan() {
                continue; // class absent from this random workload
            }
            prop_assert!(p.p50 <= p.p95 + 1e-9 && p.p95 <= p.p99 + 1e-9, "{p:?}");
        }
        Ok(())
    });
}
