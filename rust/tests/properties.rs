//! Property-based tests over the coordinator invariants, using the
//! in-tree `testkit` (proptest substitute). Each failure reports a
//! replayable seed.

use fitgpp::cluster::{Cluster, ClusterSpec, NodeId};
use fitgpp::job::JobId;
use fitgpp::job::TenantId;
use fitgpp::queue::JobQueue;
use fitgpp::resources::ResourceVec;
use fitgpp::job::JobClass;
use fitgpp::prop_assert;
use fitgpp::sched::admission::{
    build_discipline, AdmissionCtx, AdmitOutcome, DisciplineKind, QueueDiscipline, TenantDirectory,
};
use fitgpp::sched::control::SchedulerCommand;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::scenario::ScenarioScript;
use fitgpp::sim::{SimConfig, SimEngine, Simulator};
use fitgpp::stats::rng::Pcg64;
use fitgpp::testkit::{check, gen, PropConfig};
use fitgpp::workload::source::TenantAssigner;

fn policies(rng: &mut Pcg64) -> PolicyKind {
    match rng.below(8) {
        0 => PolicyKind::Fifo,
        1 => PolicyKind::FastLane,
        2 => PolicyKind::Lrtp,
        3 => PolicyKind::Rand,
        4 => PolicyKind::Srtf,
        5 => PolicyKind::Youngest,
        6 => PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        _ => PolicyKind::FitGpp { s: 2.0, p_max: None },
    }
}

fn run_random(rng: &mut Pcg64, policy: PolicyKind) -> fitgpp::sim::SimResult {
    let nodes = 1 + rng.below(4) as usize;
    let n = 20 + rng.below(60) as usize;
    let span = 30 + rng.below(100);
    let wl = gen::workload(rng, n, span);
    let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
    cfg.paranoid = true; // cluster invariants checked every tick
    cfg.seed = rng.next_u64();
    Simulator::new(cfg).run(&wl)
}

#[test]
fn prop_all_jobs_complete_and_slowdowns_valid() {
    check("complete+slowdown", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        prop_assert!(res.unfinished == 0, "{policy:?}: {} unfinished", res.unfinished);
        for r in &res.records {
            prop_assert!(r.finished_at.is_some(), "{:?} unfinished", r.id);
            prop_assert!(
                r.slowdown >= 1.0 - 1e-9,
                "{:?} slowdown {} < 1",
                r.id,
                r.slowdown
            );
        }
        Ok(())
    });
}

#[test]
fn prop_te_jobs_never_preempted() {
    check("te-never-preempted", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        for r in &res.records {
            if r.class == JobClass::Te {
                prop_assert!(r.preemptions == 0, "TE {:?} preempted {}", r.id, r.preemptions);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_p_cap_is_hard() {
    // The paper's no-starvation guarantee: with P = p, no BE job is
    // preempted more than p times — including through the random fallback.
    check("p-cap", PropConfig::default(), |rng| {
        let p = 1 + rng.below(3) as u32;
        let res = run_random(rng, PolicyKind::FitGpp { s: 4.0, p_max: Some(p) });
        for r in &res.records {
            prop_assert!(
                r.preemptions <= p,
                "{:?} preempted {} > P={}",
                r.id,
                r.preemptions,
                p
            );
        }
        Ok(())
    });
}

#[test]
fn prop_non_preemptive_policies_never_preempt() {
    check("fifo-no-preempt", PropConfig::default(), |rng| {
        for policy in [PolicyKind::Fifo, PolicyKind::FastLane] {
            let res = run_random(rng, policy);
            prop_assert!(
                res.sched_stats.preemption_signals == 0,
                "{policy:?} preempted"
            );
            for r in &res.records {
                prop_assert!(r.preemptions == 0, "{:?}", r.id);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_resched_intervals_match_preemption_counts() {
    // Every vacated job eventually restarts (runs drain), so each
    // preemption produces exactly one re-scheduling interval.
    check("intervals-count", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        for r in &res.records {
            prop_assert!(
                r.resched_intervals.len() == r.preemptions as usize,
                "{:?}: {} intervals for {} preemptions",
                r.id,
                r.resched_intervals.len(),
                r.preemptions
            );
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_starts_in_submission_order() {
    // Vanilla FIFO admits strictly head-first, so first-start times are
    // non-decreasing in submission (= id) order.
    check("fifo-order", PropConfig::default(), |rng| {
        let res = run_random(rng, PolicyKind::Fifo);
        let mut last = 0;
        for r in &res.records {
            let s = r.first_start.unwrap();
            prop_assert!(s >= last, "{:?} started {} before predecessor {}", r.id, s, last);
            last = s;
        }
        Ok(())
    });
}

#[test]
fn prop_determinism() {
    check("determinism", PropConfig { cases: 16, ..Default::default() }, |rng| {
        let policy = policies(rng);
        let nodes = 1 + rng.below(3) as usize;
        let wl = gen::workload(rng, 40, 60);
        let seed = rng.next_u64();
        let mk = || {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
            cfg.seed = seed;
            Simulator::new(cfg).run(&wl)
        };
        let (a, b) = (mk(), mk());
        prop_assert!(a.makespan == b.makespan, "makespan differs");
        for (x, y) in a.records.iter().zip(&b.records) {
            prop_assert!(
                x.finished_at == y.finished_at && x.preemptions == y.preemptions,
                "{:?} differs",
                x.id
            );
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_at_least_critical_path() {
    // Makespan is bounded below by (a) the longest single job and (b) the
    // total-work / capacity ratio on the dominant axis.
    check("makespan-lb", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let nodes = 1 + rng.below(3) as usize;
        let wl = gen::workload(rng, 30, 40);
        let cap = ClusterSpec::tiny(nodes).total_capacity();
        let work = wl.total_work();
        let lb_work = work.dominant_share(&cap).floor() as u64;
        let lb_job = wl.jobs.iter().map(|j| j.submit + j.exec_time).max().unwrap_or(0);
        let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
        cfg.seed = rng.next_u64();
        let res = Simulator::new(cfg).run(&wl);
        prop_assert!(
            res.makespan >= lb_work.max(lb_job),
            "makespan {} below bound {}",
            res.makespan,
            lb_work.max(lb_job)
        );
        Ok(())
    });
}

#[test]
fn prop_parsers_never_panic_on_garbage() {
    // Failure injection: the JSON and trace parsers must reject (not
    // panic on) arbitrary byte soup, including truncations of valid input.
    check("parser-fuzz", PropConfig { cases: 200, ..Default::default() }, |rng| {
        let len = rng.below(200) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| (rng.below(96) + 32) as u8).collect();
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = fitgpp::util::json::Json::parse(&s); // Result, must not panic
        let _ = fitgpp::workload::trace::Trace::from_csv(&s);
        // Truncations of valid documents.
        let valid = r#"{"cluster":{"nodes":4},"policy":"lrtp","workload":{"kind":"synthetic","jobs":16}}"#;
        let cut = rng.below(valid.len() as u64) as usize;
        let _ = fitgpp::config::ExperimentConfig::from_json(&valid[..cut]);
        Ok(())
    });
}

#[test]
fn prop_checkpoint_parser_never_panics_on_bitflips() {
    use fitgpp::runtime::Checkpoint;
    check("checkpoint-fuzz", PropConfig { cases: 100, ..Default::default() }, |rng| {
        let ckpt = Checkpoint::new(
            rng.next_u64() % 1000,
            vec![(vec![4, 4], (0..16).map(|i| i as f32).collect())],
        );
        let mut bytes = ckpt.to_bytes();
        // Corrupt 1-4 random bytes and/or truncate.
        for _ in 0..=rng.below(4) {
            let i = rng.below(bytes.len() as u64) as usize;
            bytes[i] ^= (1 << rng.below(8)) as u8;
        }
        if rng.chance(0.3) {
            let cut = rng.below(bytes.len() as u64) as usize;
            bytes.truncate(cut);
        }
        let _ = Checkpoint::from_bytes(&bytes); // Result, must not panic
        Ok(())
    });
}

#[test]
fn prop_slowdown_percentiles_monotone() {
    // p50 ≤ p95 ≤ p99 for both classes under every policy.
    check("percentiles-monotone", PropConfig::default(), |rng| {
        let policy = policies(rng);
        let res = run_random(rng, policy);
        let rep = res.slowdown_report();
        for p in [rep.te, rep.be] {
            if p.p50.is_nan() {
                continue; // class absent from this random workload
            }
            prop_assert!(p.p50 <= p.p95 + 1e-9 && p.p95 <= p.p99 + 1e-9, "{p:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_fair_never_starves_a_nonempty_tenant() {
    // Satellite property: under the weighted-fair discipline, every
    // non-empty tenant's head is attempted at least once per admission
    // round regardless of other tenants' backlogs or weights, and with
    // any per-round admission capacity ≥ 1 every queued job is admitted
    // within a bounded number of rounds (no starvation). Driven directly
    // against the discipline protocol with an adversarial random
    // capacity, so the bound is the discipline's own, not the cluster's.
    check("wf-no-starvation", PropConfig::default(), |rng| {
        let tenants = 2 + rng.below(6) as u32;
        let mut dir = TenantDirectory::new(None);
        for t in 0..tenants {
            dir.set_weight(TenantId(t), 1 + rng.below(4) as u32);
        }
        let mut d = build_discipline(&DisciplineKind::WeightedFair);
        let mut tenant_of: Vec<u32> = Vec::new();
        for id in 0..(10 + rng.below(40)) as u32 {
            let t = rng.below(tenants as u64) as u32;
            d.submit(JobId(id), TenantId(t));
            tenant_of.push(t);
        }
        let total = d.len();
        let mut admitted = 0usize;
        let mut rounds = 0usize;
        while admitted < total {
            rounds += 1;
            prop_assert!(
                rounds <= total + 1,
                "{admitted}/{total} admitted after {rounds} rounds — starvation"
            );
            // Adversarial per-round capacity: 1..=3 placements, everything
            // else reports NoFit.
            let mut capacity = 1 + rng.below(3);
            let mut attempted: Vec<u32> = Vec::new();
            d.begin_round();
            while let Some(id) = d.next_candidate(&AdmissionCtx { tenants: &dir }) {
                let t = TenantId(tenant_of[id.0 as usize]);
                if !attempted.contains(&t.0) {
                    attempted.push(t.0);
                }
                if capacity > 0 {
                    capacity -= 1;
                    prop_assert!(d.remove(id), "{id} offered but not queued");
                    admitted += 1;
                    d.report(id, t, AdmitOutcome::Placed, &AdmissionCtx { tenants: &dir });
                } else {
                    d.report(id, t, AdmitOutcome::NoFit, &AdmissionCtx { tenants: &dir });
                }
            }
            // Every tenant with a queued job got at least one attempt.
            let mut queued: Vec<u32> = Vec::new();
            d.for_each(&mut |id| queued.push(tenant_of[id.0 as usize]));
            for t in queued {
                prop_assert!(
                    attempted.contains(&t),
                    "tenant {t} had queued work but was never attempted this round"
                );
            }
        }
        prop_assert!(d.is_empty(), "all jobs admitted");
        Ok(())
    });
}

#[test]
fn prop_quota_gate_conserves_jobs_under_chaos() {
    // Satellite property: with the quota-gate discipline under randomized
    // quota-squeeze chaos scripts (random caps applied mid-run, lifted
    // later), every skipped head is eventually admitted or cancelled —
    // the run drains, nothing is lost, and both simulator drive modes
    // agree on every record.
    let cases = PropConfig { cases: 12, ..Default::default() };
    check("quota-gate-conservation", cases, |rng| {
        let nodes = 1 + rng.below(3) as usize;
        let tenants = 2 + rng.below(4) as u32;
        let n = 20 + rng.below(50) as usize;
        let mut wl = gen::workload(rng, n, 30 + rng.below(80));
        wl.assign_tenants(&TenantAssigner::round_robin(tenants));

        // Random squeeze: tight caps on a couple of tenants early, a few
        // cancellations, everything lifted at minute 500 so the backlog
        // can drain (a cap below one job's Size is a full stop while it
        // lasts).
        let mut script = ScenarioScript::new();
        for _ in 0..1 + rng.below(3) {
            let t = TenantId(rng.below(tenants as u64) as u32);
            let at = rng.below(60);
            let size = rng.below(100) as f64 / 100.0;
            script = script.at(at, SchedulerCommand::SetQuota { tenant: t, size });
        }
        if rng.chance(0.5) {
            script = script.at(
                10 + rng.below(40),
                SchedulerCommand::Cancel { job: JobId(rng.below(n as u64) as u32) },
            );
        }
        for t in 0..tenants {
            script = script.at(500, SchedulerCommand::SetQuota { tenant: TenantId(t), size: 1e9 });
        }

        let policy = policies(rng);
        let seed = rng.next_u64();
        let backfill = 1 + rng.below(8) as usize;
        let mk = |engine: SimEngine| {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
            cfg.paranoid = true;
            cfg.seed = seed;
            cfg.engine = engine;
            cfg.discipline = DisciplineKind::QuotaGate { backfill };
            cfg.scenario = Some(script.clone());
            Simulator::new(cfg).run(&wl)
        };
        let res = mk(SimEngine::EventHorizon);
        prop_assert!(res.unfinished == 0, "{} jobs lost by the quota gate", res.unfinished);
        let cancelled = res.metrics.cancelled_total();
        for r in &res.records {
            prop_assert!(
                r.finished_at.is_some() || r.cancelled,
                "{:?} neither finished nor cancelled",
                r.id
            );
        }
        prop_assert!(
            res.metrics.jobs_seen + cancelled == n as u64,
            "seen {} + cancelled {cancelled} != {n}",
            res.metrics.jobs_seen
        );
        // Engine equivalence holds under quota chaos too.
        let pm = mk(SimEngine::PerMinute);
        prop_assert!(pm.records == res.records, "engines diverge under quota chaos");
        prop_assert!(pm.metrics == res.metrics, "sinks diverge under quota chaos");
        Ok(())
    });
}

#[test]
fn prop_node_free_equals_capacity_minus_allocations() {
    // The Node conservation invariant — free == capacity − Σ allocations —
    // and the capacity-index consistency must survive arbitrary
    // alloc/release/reserve/unreserve interleavings.
    check("node-conservation", PropConfig::default(), |rng| {
        let nodes = 1 + rng.below(4) as usize;
        let mut cluster = Cluster::new(&ClusterSpec::tiny(nodes));
        // Live allocations (job id -> (node, demand)) and per-node reserve
        // tallies, mirrored outside the cluster as the ground truth.
        let mut live: Vec<(u32, NodeId, ResourceVec)> = Vec::new();
        let mut reserved: Vec<ResourceVec> = vec![ResourceVec::ZERO; nodes];
        let mut next_id = 0u32;
        for _ in 0..120 {
            match rng.below(4) {
                0 => {
                    // Allocate a random demand on a random node if it fits.
                    let demand = ResourceVec::new(
                        1.0 + rng.below(16) as f64,
                        1.0 + rng.below(128) as f64,
                        rng.below(5) as f64,
                    );
                    let node = NodeId(rng.below(nodes as u64) as u32);
                    if demand.fits_in(&cluster.node(node).free) {
                        cluster.bind(JobId(next_id), demand, node);
                        live.push((next_id, node, demand));
                        next_id += 1;
                    }
                }
                1 => {
                    // Release a random live allocation.
                    if let Some(i) = rng.pick_index(live.len()) {
                        let (id, node, _) = live.swap_remove(i);
                        let got = cluster.unbind(JobId(id));
                        prop_assert!(got == Ok(node), "unbind returned {got:?}");
                    }
                }
                2 => {
                    // Reserve space (may exceed free — that is legal).
                    let node = rng.below(nodes as u64) as usize;
                    let amount = ResourceVec::new(
                        rng.below(20) as f64,
                        rng.below(100) as f64,
                        rng.below(6) as f64,
                    );
                    cluster.reserve(NodeId(node as u32), amount);
                    reserved[node] += amount;
                }
                _ => {
                    // Unreserve up to what we reserved.
                    let node = rng.below(nodes as u64) as usize;
                    let amount = reserved[node].scale(0.5);
                    cluster.unreserve(NodeId(node as u32), amount);
                    reserved[node] = reserved[node].saturating_sub(&amount);
                }
            }
            // Ground truth: free == capacity − Σ live allocations per node.
            for n in &cluster.nodes {
                let allocated = live
                    .iter()
                    .filter(|(_, node, _)| *node == n.id)
                    .fold(ResourceVec::ZERO, |acc, (_, _, d)| acc + *d);
                let expect = n.capacity - allocated;
                let diff = n.free - expect;
                prop_assert!(
                    diff.cpu.abs() < 1e-6 && diff.ram_gb.abs() < 1e-6 && diff.gpu.abs() < 1e-6,
                    "{}: free {} != capacity - allocations {}",
                    n.id,
                    n.free,
                    expect
                );
            }
            if let Err(e) = cluster.check_invariants() {
                return Err(format!("invariants: {e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_queue_reinsertion_is_most_recent_preemption_first() {
    // The documented re-insertion rule: preempted jobs return to the *top*
    // of the queue, and when several victims vacate in one tick the most
    // recently vacated sits closest to the head (LIFO among themselves),
    // with previously queued jobs behind them in unchanged order.
    check("queue-reinsertion", PropConfig::default(), |rng| {
        let mut q = JobQueue::new();
        let base = rng.below(6);
        for i in 0..base {
            q.submit(JobId(i as u32));
        }
        let before: Vec<JobId> = q.iter().collect();
        // One tick's victim batch, vacating in this order.
        let victims: Vec<JobId> =
            (0..1 + rng.below(5)).map(|i| JobId(1000 + i as u32)).collect();
        for v in &victims {
            q.reinsert_front(*v);
        }
        let got: Vec<JobId> = q.iter().collect();
        let mut want: Vec<JobId> = victims.iter().rev().copied().collect();
        want.extend(before.iter().copied());
        prop_assert!(
            got == want,
            "queue order {got:?} != most-recent-preemption-first {want:?}"
        );
        // Head is always the most recent preemption.
        prop_assert!(
            q.head() == victims.last().copied(),
            "head {:?} is not the last vacated victim",
            q.head()
        );
        Ok(())
    });
}

#[test]
fn prop_capacity_index_never_hides_a_fitting_node() {
    // Soundness of the free-capacity index, independently of any engine:
    // for arbitrary cluster states (allocations + reservation holds) and
    // arbitrary demands, (a) `fits_nowhere` may only say "nowhere" when a
    // linear scan agrees no node fits, and (b) every node whose effective
    // free space fits the demand appears among `fit_candidates`. Either
    // failure would change placements identically in BOTH simulator drive
    // modes, so the engine-equivalence suite cannot catch it — this
    // property is the index's dedicated safety net.
    check("index-soundness", PropConfig::default(), |rng| {
        let nodes = 1 + rng.below(6) as usize;
        let mut cluster = Cluster::new(&ClusterSpec::tiny(nodes));
        let mut next_id = 0u32;
        for _ in 0..rng.below(40) {
            match rng.below(3) {
                0 => {
                    let demand = ResourceVec::new(
                        1.0 + rng.below(24) as f64,
                        1.0 + rng.below(200) as f64,
                        rng.below(9) as f64,
                    );
                    let node = NodeId(rng.below(nodes as u64) as u32);
                    if demand.fits_in(&cluster.node(node).free) {
                        cluster.bind(JobId(next_id), demand, node);
                        next_id += 1;
                    }
                }
                1 => {
                    let node = NodeId(rng.below(nodes as u64) as u32);
                    let amount = ResourceVec::new(
                        rng.below(20) as f64,
                        rng.below(150) as f64,
                        rng.below(6) as f64,
                    );
                    cluster.reserve(node, amount);
                }
                _ => {
                    let node = NodeId(rng.below(nodes as u64) as u32);
                    let amount = ResourceVec::new(
                        rng.below(10) as f64,
                        rng.below(60) as f64,
                        rng.below(3) as f64,
                    );
                    cluster.unreserve(node, amount);
                }
            }
        }
        for _ in 0..16 {
            let demand = ResourceVec::new(
                rng.below(40) as f64,
                rng.below(300) as f64,
                rng.below(12) as f64,
            );
            let fitting: Vec<u32> = cluster
                .nodes
                .iter()
                .filter(|n| demand.fits_in(&n.effective_free()))
                .map(|n| n.id.0)
                .collect();
            if cluster.fits_nowhere(&demand) {
                prop_assert!(
                    fitting.is_empty(),
                    "fits_nowhere lied: {demand} fits nodes {fitting:?}"
                );
            }
            let candidates: Vec<u32> = cluster.fit_candidates(&demand).map(|n| n.0).collect();
            for id in &fitting {
                prop_assert!(
                    candidates.contains(id),
                    "fit_candidates hid node-{id} which fits {demand}"
                );
            }
        }
        Ok(())
    });
}
