//! Control-plane protocol properties: event-stream conservation, fault &
//! cancellation scenarios end-to-end, and the golden JSONL event log.
//!
//! 1. **Conservation** — in any run, every occupancy opened by
//!    `Started`/`Resumed` is closed by exactly one of `Vacated`,
//!    `Finished`, `Cancelled`, or membership in a `NodeLost` eviction
//!    list; every job is `Submitted` exactly once and reaches at most one
//!    terminal (`Finished` xor `Cancelled`) — exactly one in a drained
//!    run. Node-resource conservation under `NodeDown`/`NodeUp`/`Drain`
//!    sequences is enforced *inside* the runs: `paranoid` mode re-checks
//!    `free + Σ allocations == capacity`, hold bookkeeping, and capacity-
//!    index consistency on every tick, and `internal_errors` must stay 0.
//! 2. **Determinism** — a scenario run's full event stream is
//!    byte-identical across both engines and every `arrival_lookahead`
//!    setting; a seeded scenario's JSONL log is pinned by a golden file
//!    (regenerate with `FITGPP_BLESS=1 cargo test golden`).
//! 3. **End-to-end** — a node-failure + TE-patience-cancellation scenario
//!    behaves as §2's interactive-user story demands: impatient TE kills
//!    are counted per class and excluded from slowdown percentiles,
//!    evicted jobs resume with priority, and the run still drains.

use fitgpp::cluster::{ClusterSpec, NodeId};
use fitgpp::job::{JobClass, JobId, JobSpec};
use fitgpp::resources::ResourceVec;
use fitgpp::sched::control::{
    JsonlEventLog, SchedulerCommand, SchedulerEvent, SharedBuf, SharedEventLog,
};
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::scenario::ScenarioScript;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::testkit::{check, gen, PropConfig};
use fitgpp::workload::source::WorkloadSource;
use fitgpp::workload::Workload;
use std::collections::{HashMap, HashSet};

fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
    ResourceVec::new(c, r, g)
}

fn run_with_events(
    mut cfg: SimConfig,
    wl: &Workload,
    scenario: ScenarioScript,
) -> (SimResult, Vec<SchedulerEvent>) {
    cfg.scenario = Some(scenario);
    let log = SharedEventLog::new();
    let res = Simulator::new(cfg)
        .run_with(&mut WorkloadSource::new(wl), vec![Box::new(log.clone())]);
    (res, log.events())
}

/// The conservation checker: replays the event stream against the
/// protocol's state machine and fails on any violation.
fn assert_conservation(events: &[SchedulerEvent], drained: bool) -> Result<(), String> {
    let mut submitted: HashSet<u32> = HashSet::new();
    let mut first_started: HashSet<u32> = HashSet::new();
    let mut terminal: HashMap<u32, &'static str> = HashMap::new();
    let mut open: HashSet<u32> = HashSet::new(); // jobs occupying a node
    for ev in events {
        match ev {
            SchedulerEvent::Submitted { job, .. } => {
                if !submitted.insert(job.0) {
                    return Err(format!("{job} submitted twice"));
                }
            }
            SchedulerEvent::Started { job, .. } => {
                if !submitted.contains(&job.0) {
                    return Err(format!("{job} started before submission"));
                }
                if !first_started.insert(job.0) {
                    return Err(format!("{job} 'Started' twice (restart must be 'Resumed')"));
                }
                if !open.insert(job.0) {
                    return Err(format!("{job} started while already occupying"));
                }
            }
            SchedulerEvent::Resumed { job, .. } => {
                if !first_started.contains(&job.0) {
                    return Err(format!("{job} resumed before its first start"));
                }
                if !open.insert(job.0) {
                    return Err(format!("{job} resumed while already occupying"));
                }
            }
            SchedulerEvent::Preempted { job, .. } => {
                if !open.contains(&job.0) {
                    return Err(format!("{job} preempted while not occupying"));
                }
            }
            SchedulerEvent::Vacated { job, .. } => {
                if !open.remove(&job.0) {
                    return Err(format!("{job} vacated without occupancy"));
                }
            }
            SchedulerEvent::Finished { job, record, .. } => {
                if !open.remove(&job.0) {
                    return Err(format!("{job} finished without occupancy"));
                }
                if record.finished_at.is_none() || record.cancelled {
                    return Err(format!("{job} finished with a non-finished record"));
                }
                if terminal.insert(job.0, "finished").is_some() {
                    return Err(format!("{job} reached two terminals"));
                }
            }
            SchedulerEvent::Cancelled { job, record, .. } => {
                // A queued job cancels without occupancy; a running or
                // draining one releases its seat.
                open.remove(&job.0);
                if !record.cancelled || record.finished_at.is_some() {
                    return Err(format!("{job} cancelled with a non-cancelled record"));
                }
                if terminal.insert(job.0, "cancelled").is_some() {
                    return Err(format!("{job} reached two terminals"));
                }
            }
            SchedulerEvent::NodeLost { lost, .. } => {
                for job in lost {
                    if !open.remove(&job.0) {
                        return Err(format!("{job} evicted by node loss while not occupying"));
                    }
                }
            }
            _ => {}
        }
    }
    if !open.is_empty() {
        return Err(format!("occupancies never closed: {open:?}"));
    }
    if drained {
        for id in &submitted {
            if !terminal.contains_key(id) {
                return Err(format!("job-{id} submitted but reached no terminal"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_event_stream_conservation_under_chaos() {
    // Random workloads under random fault/cancel scenarios, both engines:
    // the conservation state machine must hold, the cluster invariants
    // must survive every tick (paranoid), the run must drain, and the two
    // engines must produce identical event streams.
    let policies = [
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    ];
    let cases = PropConfig { cases: 16, ..Default::default() };
    check("event-stream conservation", cases, |rng| {
        let wl = gen::workload(rng, 50, 120);
        let nodes = 3u32;
        let mut script = ScenarioScript::new();
        if rng.chance(0.5) {
            script = script.with_te_patience(gen::int(rng, 1, 30));
        }
        for node in 0..nodes {
            if rng.chance(0.5) {
                // Fail/restore pair; windows may overlap across nodes.
                let down = gen::int(rng, 1, 160);
                script = script
                    .at(down, SchedulerCommand::NodeDown { node: NodeId(node) })
                    .at(
                        down + gen::int(rng, 1, 120),
                        SchedulerCommand::NodeUp { node: NodeId(node) },
                    );
            } else if rng.chance(0.4) {
                let start = gen::int(rng, 1, 160);
                script = script
                    .at(start, SchedulerCommand::Drain { node: NodeId(node) })
                    .at(
                        start + gen::int(rng, 1, 120),
                        SchedulerCommand::NodeUp { node: NodeId(node) },
                    );
            }
        }
        for _ in 0..4 {
            if rng.chance(0.7) {
                script = script.at(
                    gen::int(rng, 0, 250),
                    SchedulerCommand::Cancel { job: JobId(gen::int(rng, 0, 49) as u32) },
                );
            }
        }
        let policy = policies[gen::int(rng, 0, policies.len() as u64 - 1) as usize];

        let mk = |engine: SimEngine| {
            let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes as usize), policy);
            cfg.engine = engine;
            cfg.paranoid = true;
            cfg.seed = 0xC0FFEE;
            run_with_events(cfg, &wl, script.clone())
        };
        let (res_pm, ev_pm) = mk(SimEngine::PerMinute);
        let (res_eh, ev_eh) = mk(SimEngine::EventHorizon);

        fitgpp::prop_assert!(
            res_pm.unfinished == 0,
            "{policy:?}: scenario run failed to drain ({} unfinished)",
            res_pm.unfinished
        );
        fitgpp::prop_assert!(
            res_pm.sched_stats.internal_errors == 0 && res_eh.sched_stats.internal_errors == 0,
            "{policy:?}: internal errors surfaced"
        );
        assert_conservation(&ev_pm, true).map_err(|e| format!("{policy:?}/PerMinute: {e}"))?;
        fitgpp::prop_assert!(
            ev_pm == ev_eh,
            "{policy:?}: engines produced different event streams ({} vs {} events)",
            ev_pm.len(),
            ev_eh.len()
        );
        fitgpp::prop_assert!(
            res_pm.records == res_eh.records && res_pm.metrics == res_eh.metrics,
            "{policy:?}: engines disagree on records/metrics"
        );
        Ok(())
    });
}

#[test]
fn node_failure_plus_te_cancellation_end_to_end() {
    // The acceptance scenario: two full-node BE hogs, an impatient TE user
    // (patience 5), a node failure with a later repair. FIFO (no bypass)
    // guarantees the TE job waits past its patience.
    let wl = Workload::new(vec![
        JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 100, 0),
        JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 100, 0),
        JobSpec::new(2, JobClass::Te, rv(4.0, 32.0, 1.0), 10, 5, 0),
        JobSpec::new(3, JobClass::Be, rv(4.0, 32.0, 1.0), 20, 10, 0),
    ]);
    let script = ScenarioScript::new()
        .with_te_patience(5)
        .at(30, SchedulerCommand::NodeDown { node: NodeId(0) })
        .at(50, SchedulerCommand::NodeUp { node: NodeId(0) });
    let mut cfg = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo);
    cfg.paranoid = true;
    let (res, events) = run_with_events(cfg, &wl, script);

    // The impatient TE job was killed after exactly its patience.
    assert_eq!(res.cancelled(), (1, 0));
    let cancel = events
        .iter()
        .find(|e| e.kind() == "cancelled")
        .expect("a TE cancellation");
    assert_eq!(cancel.at(), 15, "submitted at 10, patience 5");
    assert_eq!(cancel.job(), Some(JobId(2)));

    // The node failure evicted the hog on node 0; it resumed after repair
    // with its progress intact and still finished.
    let lost = events.iter().find(|e| e.kind() == "node_lost").expect("a node loss");
    match lost {
        SchedulerEvent::NodeLost { at, lost, .. } => {
            assert_eq!(*at, 30);
            assert_eq!(lost, &vec![JobId(0)]);
        }
        _ => unreachable!(),
    }
    let resumed_at_repair = events.iter().any(|e| {
        matches!(e, SchedulerEvent::Resumed { job, at, .. } if *job == JobId(0) && *at == 50)
    });
    assert!(resumed_at_repair, "evicted hog resumes the minute the node returns");
    let hog = &res.records[0];
    assert_eq!(hog.evictions, 1);
    assert_eq!(hog.preemptions, 0, "a node failure is not a policy preemption");
    assert!(hog.finished_at.is_some());

    // The cancelled job is excluded from percentiles but keeps a record.
    assert!(res.records[2].cancelled && res.records[2].finished_at.is_none());
    assert_eq!(res.slowdowns(JobClass::Te).len(), 0);
    assert_eq!(res.metrics.jobs_seen, 3, "three jobs ran to an outcome");

    // Everything else drained; conservation holds.
    assert_eq!(res.unfinished, 0);
    assert_eq!(res.sched_stats.internal_errors, 0);
    assert_conservation(&events, true).unwrap();
}

#[test]
fn scenario_reclassification_promotes_a_blocked_job() {
    // FastLane: a blocked BE job promoted to TE takes the fragmented free
    // space at once (the "user promotes their trial" story).
    let wl = Workload::new(vec![
        JobSpec::new(0, JobClass::Be, rv(30.0, 200.0, 7.0), 0, 50, 0),
        JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 1, 10, 0),
        JobSpec::new(2, JobClass::Be, rv(2.0, 16.0, 1.0), 1, 5, 0),
    ]);
    let script = ScenarioScript::new().at(
        5,
        SchedulerCommand::Reclassify { job: JobId(2), class: JobClass::Te },
    );
    let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::FastLane);
    cfg.paranoid = true;
    let (res, events) = run_with_events(cfg, &wl, script);
    assert!(events.iter().any(|e| e.kind() == "reclassified"));
    assert_eq!(
        res.records[2].first_start,
        Some(5),
        "promoted job starts the minute it enters the TE lane"
    );
    assert_eq!(res.records[2].class, JobClass::Te, "record carries the final class");
    assert_eq!(res.unfinished, 0);
    assert_conservation(&events, true).unwrap();
}

#[test]
fn scenario_resize_opens_capacity_mid_run() {
    // A queued job that cannot fit the node starts the minute an elastic
    // resize grows it.
    let wl = Workload::new(vec![
        JobSpec::new(0, JobClass::Be, rv(16.0, 128.0, 4.0), 0, 30, 0),
        JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 1, 5, 0),
    ]);
    let script = ScenarioScript::new().at(
        5,
        SchedulerCommand::Resize { node: NodeId(0), capacity: rv(64.0, 512.0, 16.0) },
    );
    let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::Fifo);
    cfg.paranoid = true;
    let (res, events) = run_with_events(cfg, &wl, script);
    assert!(events.iter().any(|e| e.kind() == "node_resized"));
    assert_eq!(res.records[1].first_start, Some(5));
    assert_eq!(res.unfinished, 0);
    assert_conservation(&events, true).unwrap();
}

/// The golden scenario: one seeded workload, every command type, the
/// patience rule — the JSONL log must be byte-identical across engines
/// and lookahead settings, and must match the checked-in golden file.
fn golden_log(engine: SimEngine, lookahead: u64) -> String {
    let wl = Workload::new(vec![
        JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 60, 2),
        JobSpec::new(1, JobClass::Be, rv(16.0, 128.0, 4.0), 0, 40, 0),
        JobSpec::new(2, JobClass::Te, rv(8.0, 64.0, 2.0), 4, 6, 0),
        JobSpec::new(3, JobClass::Te, rv(4.0, 32.0, 1.0), 12, 8, 0),
        JobSpec::new(4, JobClass::Be, rv(2.0, 16.0, 1.0), 15, 20, 1),
        JobSpec::new(5, JobClass::Be, rv(24.0, 192.0, 6.0), 30, 25, 3),
        JobSpec::new(6, JobClass::Te, rv(6.0, 48.0, 2.0), 55, 5, 0),
    ]);
    let script = ScenarioScript::new()
        .with_te_patience(4)
        .at(8, SchedulerCommand::Drain { node: NodeId(1) })
        .at(20, SchedulerCommand::NodeUp { node: NodeId(1) })
        .at(25, SchedulerCommand::NodeDown { node: NodeId(0) })
        .at(45, SchedulerCommand::NodeUp { node: NodeId(0) })
        .at(16, SchedulerCommand::Reclassify { job: JobId(4), class: JobClass::Te })
        .at(35, SchedulerCommand::Cancel { job: JobId(0) })
        .at(2, SchedulerCommand::Resize { node: NodeId(1), capacity: rv(48.0, 384.0, 12.0) })
        // Stale by the time its target finished / premature for a job not
        // yet arrived: exercises both deferral paths deterministically.
        .at(1, SchedulerCommand::Cancel { job: JobId(6) });
    let mut cfg = SimConfig::new(
        ClusterSpec::tiny(2),
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    );
    cfg.paranoid = true;
    cfg.engine = engine;
    cfg.arrival_lookahead = lookahead;
    cfg.scenario = Some(script);
    let buf = SharedBuf::new();
    let res = Simulator::new(cfg).run_with(
        &mut WorkloadSource::new(&wl),
        vec![Box::new(JsonlEventLog::new(buf.clone()))],
    );
    assert_eq!(res.sched_stats.internal_errors, 0);
    buf.contents()
}

#[test]
fn golden_jsonl_event_log_pins_the_scenario() {
    let reference = golden_log(SimEngine::EventHorizon, 0);
    assert!(!reference.is_empty());
    for (engine, lookahead) in [
        (SimEngine::PerMinute, 0),
        (SimEngine::PerMinute, 7),
        (SimEngine::EventHorizon, 1),
        (SimEngine::EventHorizon, 1 << 20),
    ] {
        assert_eq!(
            golden_log(engine, lookahead),
            reference,
            "JSONL log diverged under {engine:?}/lookahead {lookahead}"
        );
    }
    // The log must witness the whole command vocabulary.
    for kind in [
        "submitted",
        "started",
        "finished",
        "cancelled",
        "node_lost",
        "node_restored",
        "node_draining",
        "node_resized",
        "reclassified",
    ] {
        assert!(
            reference.contains(&format!("\"type\":\"{kind}\"")),
            "golden scenario never produced a {kind:?} event:\n{reference}"
        );
    }

    // Golden-file pin. Regenerate with FITGPP_BLESS=1 after an intended
    // protocol change; a missing file (first run) self-blesses.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/scenario_events.jsonl");
    let bless = std::env::var("FITGPP_BLESS").is_ok();
    if bless || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, &reference).unwrap();
        eprintln!("blessed golden event log at {}", path.display());
        return;
    }
    let golden = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        reference,
        golden,
        "JSONL event log diverged from the golden file {} — rerun with \
         FITGPP_BLESS=1 if the protocol change is intended",
        path.display()
    );
}

/// Every constructible event, aimed at the encoder edge cases: optional
/// `finished_at`/`slowdown` fields, NaN slowdown on unfinished records,
/// fractional and integer-valued floats, empty and multi-element
/// eviction lists, and reason strings that need escaping.
fn encoder_sweep_events() -> Vec<SchedulerEvent> {
    use fitgpp::job::TenantId;
    use fitgpp::sim::JobRecord;
    let record = |finished_at: Option<u64>, slowdown: f64, cancelled: bool| JobRecord {
        id: JobId(7),
        class: JobClass::Te,
        demand: rv(4.0, 16.5, 1.0),
        submit: 3,
        exec_time: 120,
        grace_period: 10,
        first_start: Some(5),
        finished_at,
        preemptions: 2,
        evictions: 1,
        resched_intervals: vec![4, 9],
        slowdown,
        cancelled,
        tenant: TenantId(3),
    };
    vec![
        SchedulerEvent::Submitted { at: 0, job: JobId(1), class: JobClass::Be },
        SchedulerEvent::Submitted { at: u64::MAX / 2, job: JobId(u32::MAX), class: JobClass::Te },
        SchedulerEvent::Started { at: 1, job: JobId(2), node: NodeId(0) },
        SchedulerEvent::Resumed { at: 2, job: JobId(3), node: NodeId(41) },
        SchedulerEvent::Preempted { at: 3, job: JobId(4) },
        SchedulerEvent::Vacated { at: 4, job: JobId(5) },
        SchedulerEvent::Finished { at: 130, job: JobId(7), record: record(Some(130), 1.25, false) },
        SchedulerEvent::Finished { at: 130, job: JobId(7), record: record(Some(130), 1.0, false) },
        // Unfinished-at-cutoff shape: no finished_at/slowdown keys at all.
        SchedulerEvent::Finished { at: 200, job: JobId(7), record: record(None, f64::NAN, false) },
        SchedulerEvent::Cancelled { at: 50, job: JobId(7), record: record(None, 0.0, true) },
        SchedulerEvent::Reclassified { at: 6, job: JobId(8), class: JobClass::Be },
        SchedulerEvent::NodeLost { at: 7, node: NodeId(2), lost: vec![] },
        SchedulerEvent::NodeLost {
            at: 8,
            node: NodeId(3),
            lost: vec![JobId(1), JobId(9), JobId(100)],
        },
        SchedulerEvent::NodeRestored { at: 9, node: NodeId(2) },
        SchedulerEvent::NodeDraining { at: 10, node: NodeId(4) },
        SchedulerEvent::NodeResized {
            at: 11,
            node: NodeId(5),
            capacity: rv(96.0, 1536.5, 8.0),
        },
        SchedulerEvent::QuotaChanged { at: 12, tenant: fitgpp::job::TenantId(1), size: 2.75 },
        SchedulerEvent::QuotaChanged {
            at: 13,
            tenant: fitgpp::job::TenantId(2),
            size: f64::INFINITY,
        },
        SchedulerEvent::WeightChanged { at: 14, tenant: fitgpp::job::TenantId(1), weight: 3 },
        SchedulerEvent::AdmissionSkipped { at: 15, job: JobId(11), tenant: fitgpp::job::TenantId(2) },
        SchedulerEvent::CommandRejected { at: 16, reason: String::new() },
        SchedulerEvent::CommandRejected {
            at: 17,
            reason: "bad \"spec\": tab\there, newline\nthere, ctrl \u{1}, unicode üñï".into(),
        },
    ]
}

#[test]
fn direct_encoder_matches_value_tree_for_every_event_variant() {
    use fitgpp::sched::control::{event_jsonl_line, JsonLineEncoder};
    let events = encoder_sweep_events();
    // The sweep must actually cover every variant kind.
    let kinds: HashSet<&str> = events.iter().map(|e| e.kind()).collect();
    for kind in [
        "submitted",
        "started",
        "resumed",
        "preempted",
        "vacated",
        "finished",
        "cancelled",
        "reclassified",
        "node_lost",
        "node_restored",
        "node_draining",
        "node_resized",
        "quota_changed",
        "weight_changed",
        "admission_skipped",
        "command_rejected",
    ] {
        assert!(kinds.contains(kind), "sweep is missing a {kind:?} event");
    }
    let mut enc = JsonLineEncoder::new();
    for ev in &events {
        assert_eq!(
            enc.event(ev),
            event_jsonl_line(ev),
            "direct encoding diverged from the value tree for {ev:?}"
        );
    }
}
