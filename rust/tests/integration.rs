//! Cross-module integration tests: config → workload → simulator →
//! metrics → serialization, plus trace round-trips through the CLI-facing
//! API surface.

use fitgpp::cluster::ClusterSpec;
use fitgpp::config::ExperimentConfig;
use fitgpp::job::JobClass;
use fitgpp::metrics::slowdown_table;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, Simulator};
use fitgpp::util::json::Json;
use fitgpp::workload::{synthetic::SyntheticWorkload, trace::Trace};

#[test]
fn config_to_results_pipeline() {
    let cfg = ExperimentConfig::from_json(
        r#"{
            "cluster": {"nodes": 4},
            "policy": "fitgpp:s=4,p=1",
            "seed": 3,
            "workload": {"kind": "synthetic", "jobs": 400, "seed": 3}
        }"#,
    )
    .unwrap();
    let wl = cfg.build_workload().unwrap();
    assert_eq!(wl.len(), 400);
    let res = Simulator::new(cfg.sim_config()).run(&wl);
    assert_eq!(res.unfinished, 0);
    // JSON dump round-trips and has the right fields.
    let dump = res.to_json().to_pretty();
    let back = Json::parse(&dump).unwrap();
    assert!(back.get("slowdown").get("te").get("p95").as_f64().is_some());
    assert!(back.get("preemption").get("fraction_preempted").as_f64().is_some());
}

#[test]
fn trace_file_workload_roundtrip() {
    let dir = std::env::temp_dir().join("fitgpp-itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    let wl = Trace::synthesize_institution(5, 300);
    Trace::write_csv(&wl, &path).unwrap();

    let cfg = ExperimentConfig::from_json(&format!(
        r#"{{
            "cluster": {{"nodes": 4}},
            "policy": "lrtp",
            "workload": {{"kind": "trace", "path": "{}"}}
        }}"#,
        path.display()
    ))
    .unwrap();
    let wl2 = cfg.build_workload().unwrap();
    assert_eq!(wl2.len(), wl.len());
    let res = Simulator::new(cfg.sim_config()).run(&wl2);
    assert_eq!(res.unfinished, 0);
}

#[test]
fn four_policy_comparison_has_paper_shape() {
    // A scaled-down Table 1: the orderings the paper reports must hold.
    let cluster = ClusterSpec::tiny(6);
    let wl = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(1500)
        .generate();
    let run = |p: PolicyKind| {
        let mut cfg = SimConfig::new(cluster.clone(), p);
        cfg.seed = 1;
        Simulator::new(cfg).run(&wl)
    };
    let fifo = run(PolicyKind::Fifo);
    let lrtp = run(PolicyKind::Lrtp);
    let rand = run(PolicyKind::Rand);
    let fg = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });

    let te = |r: &fitgpp::sim::SimResult| r.slowdown_report().te;
    let be = |r: &fitgpp::sim::SimResult| r.slowdown_report().be;

    // All preemptive policies crush FIFO's TE tail.
    for (name, r) in [("lrtp", &lrtp), ("rand", &rand), ("fitgpp", &fg)] {
        assert!(
            te(r).p95 < te(&fifo).p95 * 0.6,
            "{name} TE p95 {} vs FIFO {}",
            te(r).p95,
            te(&fifo).p95
        );
    }
    // FitGpp's BE slowdown beats (or matches) LRTP's and RAND's.
    assert!(
        be(&fg).p95 <= be(&lrtp).p95 * 1.05,
        "fitgpp BE p95 {} vs lrtp {}",
        be(&fg).p95,
        be(&lrtp).p95
    );
    assert!(
        be(&fg).p95 <= be(&rand).p95 * 1.05,
        "fitgpp BE p95 {} vs rand {}",
        be(&fg).p95,
        be(&rand).p95
    );
    // FitGpp preempts fewer jobs than the node-blind baselines. (The
    // paper's order-of-magnitude gap needs the full 84-node scale — the
    // table3_preempted bench reproduces it; at this test's 6-node scale
    // the direction still holds.)
    assert!(fg.sched_stats.preemption_signals < rand.sched_stats.preemption_signals);

    // The table renderer produces all four rows.
    let rows = [
        ("FIFO", fifo.slowdown_report()),
        ("LRTP", lrtp.slowdown_report()),
        ("RAND", rand.slowdown_report()),
        ("FitGpp", fg.slowdown_report()),
    ];
    let t = slowdown_table("Table 1 (scaled)", &rows);
    let text = t.to_text();
    for name in ["FIFO", "LRTP", "RAND", "FitGpp"] {
        assert!(text.contains(name));
    }
}

#[test]
fn gp_scale_raises_te_wait_under_lrtp() {
    // Fig. 7's mechanism: longer grace periods make LRTP's TE latency
    // worse (its victims' GPs gate the TE start).
    let cluster = ClusterSpec::tiny(4);
    let mk = |scale: f64| {
        SyntheticWorkload::paper_section_4_2(31)
            .with_cluster(cluster.clone())
            .with_num_jobs(800)
            .with_gp_scale(scale)
            .generate()
    };
    let run = |wl: &fitgpp::workload::Workload| {
        let mut cfg = SimConfig::new(cluster.clone(), PolicyKind::Lrtp);
        cfg.seed = 2;
        Simulator::new(cfg).run(wl).slowdown_report().te.p95
    };
    let base = run(&mk(1.0));
    let scaled = run(&mk(8.0));
    assert!(
        scaled > base,
        "8× GPs must raise LRTP TE p95: {base} → {scaled}"
    );
}

#[test]
fn te_fraction_sweep_is_monotone_under_fifo() {
    // Fig. 6's x-axis: more TE jobs ⇒ the TE percentile under FIFO can
    // only stay or worsen mildly... we assert the sweep *runs* and yields
    // finite numbers for every fraction (shape assertions live in the
    // bench, which prints the full series).
    let cluster = ClusterSpec::tiny(4);
    for frac in [0.1, 0.3, 0.5, 0.7] {
        let wl = SyntheticWorkload::paper_section_4_2(41)
            .with_cluster(cluster.clone())
            .with_num_jobs(400)
            .with_te_fraction(frac)
            .generate();
        let res = Simulator::new(SimConfig::new(cluster.clone(), PolicyKind::Fifo)).run(&wl);
        let te = res.slowdowns(JobClass::Te);
        assert!(!te.is_empty());
        assert!(te.iter().all(|x| x.is_finite()));
    }
}

#[test]
fn progress_during_grace_lets_short_victims_finish() {
    // Ablation (DESIGN.md): with progress-during-grace, a victim whose
    // remaining work is shorter than its grace period completes during the
    // drain instead of being suspended and re-queued.
    use fitgpp::job::JobSpec;
    use fitgpp::resources::ResourceVec;
    let specs = vec![
        // Victim: 4 minutes of work left when preempted, GP 10.
        JobSpec::new(0, JobClass::Be, ResourceVec::new(32.0, 256.0, 8.0), 0, 5, 10),
        JobSpec::new(1, JobClass::Te, ResourceVec::new(8.0, 64.0, 2.0), 1, 5, 0),
    ];
    let run = |progress: bool| {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(1), PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        cfg.progress_during_grace = progress;
        Simulator::new(cfg).run(&fitgpp::workload::Workload::new(specs.clone()))
    };
    let with = run(true);
    assert_eq!(with.records[0].preemptions, 0, "finished during drain");
    assert_eq!(with.records[0].finished_at, Some(5));
    let without = run(false);
    assert_eq!(without.records[0].preemptions, 1, "suspended and resumed");
    assert!(without.records[0].finished_at.unwrap() > 5);
}
