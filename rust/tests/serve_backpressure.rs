//! Backpressure fidelity under batched fan-out: a slow subscriber is
//! told exactly what it missed, and never at the expense of fast ones.
//!
//! Three subscribers watch the same run; one of them sleeps through the
//! whole burst. The properties pinned here:
//!
//! 1. **Fast subscribers are unaffected** — both fast streams carry the
//!    complete event sequence, byte-identical to each other, with zero
//!    `lagged` notices.
//! 2. **Drop accounting conserves lines** — for the slow subscriber,
//!    `delivered event lines + Σ lagged.dropped` equals the full event
//!    count, so every dropped line is reported exactly once.
//! 3. **Notices precede newer lines** — the slow stream is an in-order
//!    subsequence of the fast stream, so nothing newer than a gap is
//!    ever delivered before the `lagged` notice covering that gap (a
//!    gap in the subsequence without a notice would break property 2).
//!
//! Every client socket carries a read timeout, so a lost line or a lost
//! notice fails the test loudly instead of hanging it.

#![cfg(unix)]

use fitgpp::cluster::ClusterSpec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::serve::server::{run, ServeConfig};
use fitgpp::sim::SimConfig;
use fitgpp::util::json::Json;
use fitgpp::workload::source::WorkloadSource;
use fitgpp::workload::Workload;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Barrier};
use std::thread;
use std::time::Duration;

/// Enough jobs that the slow subscriber's socket buffer fills during the
/// burst (its server-side writer blocks, its queue hits the cap, lines
/// drop) while the fast subscribers never feel it.
const JOBS: u32 = 4000;

fn connect(sock: &std::path::Path) -> UnixStream {
    let mut tries = 0;
    loop {
        match UnixStream::connect(sock) {
            Ok(s) => {
                s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
                return s;
            }
            Err(_) if tries < 500 => {
                tries += 1;
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("server socket never came up: {e}"),
        }
    }
}

/// Subscribe and consume the handshake (hello + subscribe ack) so the
/// driver can start the burst knowing every subscriber is attached.
fn subscribe(sock: &std::path::Path) -> (BufReader<UnixStream>, UnixStream) {
    let stream = connect(sock);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("type").as_str(), Some("hello"));
    writeln!(writer, r#"{{"cmd":"subscribe","seq":1}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("type").as_str(), Some("ack"));
    (reader, writer)
}

/// Read one subscriber's stream until `finished` events for all of
/// [`JOBS`] have been seen, panicking on any `lagged` notice — the fast
/// subscriber's contract is the complete stream, nothing dropped.
fn read_complete_stream(reader: &mut BufReader<UnixStream>) -> Vec<String> {
    let mut events = Vec::new();
    let mut finished = 0u32;
    let mut line = String::new();
    while finished < JOBS {
        assert!(reader.read_line(&mut line).unwrap() > 0, "server closed early");
        let v = Json::parse(&line).unwrap();
        match v.get("type").as_str() {
            Some("lagged") => panic!("fast subscriber lagged: {line}"),
            Some("hello") | Some("ack") | Some("error") | Some("pong") | Some("snapshot") => {}
            Some(t) => {
                if t == "finished" {
                    finished += 1;
                }
                events.push(line.trim_end().to_string());
            }
            None => panic!("line without a type: {line}"),
        }
        line.clear();
    }
    events
}

/// True when `needle` appears in `haystack` in order (not necessarily
/// contiguously).
fn is_subsequence(needle: &[String], haystack: &[String]) -> bool {
    let mut it = haystack.iter();
    needle.iter().all(|n| it.any(|h| h == n))
}

#[test]
fn slow_subscriber_gets_exact_drop_accounting_fast_ones_lose_nothing() {
    let sock = std::env::temp_dir().join(format!("fitgpp-bp-test-{}.sock", std::process::id()));
    let mut cfg = ServeConfig::new(SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo));
    cfg.uds = Some(sock.clone());
    // Small enough that a sleeping consumer overflows once its socket
    // buffer fills, large enough that a reading one never queues it.
    cfg.queue_cap = 64;
    let server = thread::spawn(move || {
        let workload = Workload::new(vec![]);
        let mut source = WorkloadSource::new(&workload);
        run(cfg, &mut source).unwrap()
    });

    let ready = Arc::new(Barrier::new(4));
    let burst_done = Arc::new(AtomicBool::new(false));
    // Carries the full event count from the controller to the slow
    // subscriber, which reads until its own accounting balances.
    let (target_tx, target_rx) = mpsc::channel::<u64>();

    // Fast subscriber #1 doubles as the controller: once it has seen the
    // whole run it tells the slow subscriber what "complete" means and
    // stops the server (which force-delivers any still-owed notice).
    let fast1 = {
        let sock = sock.clone();
        let ready = ready.clone();
        let burst_done = burst_done.clone();
        thread::spawn(move || {
            let (mut reader, mut writer) = subscribe(&sock);
            ready.wait();
            let events = read_complete_stream(&mut reader);
            burst_done.store(true, Ordering::SeqCst);
            target_tx.send(events.len() as u64).unwrap();
            writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
            events
        })
    };

    // Fast subscriber #2 just reads everything as it comes.
    let fast2 = {
        let sock = sock.clone();
        let ready = ready.clone();
        thread::spawn(move || {
            let (mut reader, _writer) = subscribe(&sock);
            ready.wait();
            read_complete_stream(&mut reader)
        })
    };

    // The slow subscriber sleeps through the burst, then drains until
    // every line is accounted for: delivered, or covered by a notice.
    let slow = {
        let sock = sock.clone();
        let ready = ready.clone();
        let burst_done = burst_done.clone();
        thread::spawn(move || {
            let (mut reader, _writer) = subscribe(&sock);
            ready.wait();
            while !burst_done.load(Ordering::SeqCst) {
                thread::sleep(Duration::from_millis(25));
            }
            let target = target_rx.recv().unwrap();
            let mut events = Vec::new();
            let mut lagged: Vec<u64> = Vec::new();
            let mut line = String::new();
            while (events.len() as u64) + lagged.iter().sum::<u64>() < target {
                assert!(
                    reader.read_line(&mut line).unwrap() > 0,
                    "stream ended before the accounting balanced"
                );
                let v = Json::parse(&line).unwrap();
                match v.get("type").as_str() {
                    Some("lagged") => {
                        let dropped = v.get("dropped").as_u64().expect("lagged without a count");
                        assert!(dropped > 0, "lagged notice claiming zero drops: {line}");
                        lagged.push(dropped);
                    }
                    Some("hello") | Some("ack") | Some("error") | Some("pong")
                    | Some("snapshot") => {}
                    Some(_) => events.push(line.trim_end().to_string()),
                    None => panic!("line without a type: {line}"),
                }
                line.clear();
            }
            (events, lagged, target)
        })
    };

    // The driver submits the burst, paced by acks so no single session
    // iteration stages more lines than a reading subscriber's queue cap.
    let driver = {
        let sock = sock.clone();
        let ready = ready.clone();
        thread::spawn(move || {
            let stream = connect(&sock);
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let mut line = String::new();
            reader.read_line(&mut line).unwrap(); // hello
            ready.wait();
            for id in 0..JOBS {
                writeln!(
                    writer,
                    r#"{{"cmd":"submit","id":{id},"class":"BE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":1,"seq":{}}}"#,
                    u64::from(id) + 1
                )
                .unwrap();
                loop {
                    line.clear();
                    assert!(reader.read_line(&mut line).unwrap() > 0, "server closed early");
                    if Json::parse(&line).unwrap().get("type").as_str() == Some("ack") {
                        break;
                    }
                }
            }
        })
    };

    driver.join().unwrap();
    let fast1_events = fast1.join().unwrap();
    let fast2_events = fast2.join().unwrap();
    let (slow_events, slow_lagged, target) = slow.join().unwrap();
    let outcome = server.join().unwrap();

    // Fast subscribers saw the identical, complete stream.
    assert_eq!(fast1_events, fast2_events, "fast subscribers diverged");
    assert_eq!(
        fast1_events.iter().filter(|l| l.contains("\"type\":\"finished\"")).count(),
        JOBS as usize
    );

    // The slow subscriber lagged, was told so, and the accounting is
    // exact: every line is either delivered or counted in a notice.
    assert!(!slow_lagged.is_empty(), "slow subscriber never got a lagged notice");
    let dropped: u64 = slow_lagged.iter().sum();
    assert_eq!(
        slow_events.len() as u64 + dropped,
        target,
        "delivered + dropped must equal the full event count"
    );
    assert!(
        is_subsequence(&slow_events, &fast1_events),
        "slow stream is not an in-order subsequence of the fast stream"
    );

    // And the server-side counter agrees someone dropped lines.
    assert!(outcome.stats.events_dropped >= dropped);
    assert_eq!(outcome.result.metrics.completed, u64::from(JOBS));
}
