//! Equivalence properties of the layered event-core.
//!
//! 1. **Drive-mode equivalence** — the event-horizon drive mode must
//!    produce byte-identical results to the per-minute reference mode —
//!    same `SlowdownReport`, same `PreemptionReport`, same per-job records,
//!    same makespan — on §4.2 synthetic workloads across seeds, all seven
//!    policies, and the progress-during-grace ablation, plus randomized
//!    workloads from the in-tree property kit. Because the refactored core
//!    routes every placement through the cluster's capacity index and every
//!    completion/expiry through the event clock, this suite also pins
//!    *those* layers: any index prune that hides a fitting node or clock
//!    prediction that misses an event diverges the two modes (paranoid mode
//!    cross-checks every skipped scan).
//! 2. **Policy-oracle equivalence** — the trait-based policies
//!    ([`build_policy`]) must plan identically to verbatim copies of the
//!    pre-refactor per-policy planning loops, kept in this file as the
//!    oracle, across randomized cluster states — so FitGpp/LRTP/RAND
//!    results are unchanged by the `PreemptionPolicy` refactor.

use fitgpp::cluster::{Cluster, ClusterSpec, NodeId};
use fitgpp::job::{Job, JobClass, JobId, JobSpec};
use fitgpp::job_table::JobTable;
use fitgpp::prop_assert;
use fitgpp::resources::ResourceVec;
use fitgpp::sched::policy::{build_policy, PlanScratch, PolicyCtx, PolicyKind, PreemptionPlan};
use fitgpp::sched::victim_index::VictimIndex;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::stats::rng::Pcg64;
use fitgpp::testkit::{check, gen, PropConfig};
use fitgpp::workload::synthetic::SyntheticWorkload;
use fitgpp::workload::Workload;

/// All policy kinds (the §4.1 four, the FastLane ablation, the
/// trait-demonstration ablations, and the prediction-aware pair), FitGpp
/// in two parameterizations.
fn all_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::Srtf,
        PolicyKind::Youngest,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::FitGpp { s: 2.0, p_max: None },
        PolicyKind::PSrtf,
        PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
    ]
}

fn run(
    engine: SimEngine,
    wl: &Workload,
    cluster: &ClusterSpec,
    policy: PolicyKind,
    seed: u64,
    progress: bool,
) -> SimResult {
    let mut cfg = SimConfig::new(cluster.clone(), policy);
    cfg.engine = engine;
    cfg.seed = seed;
    cfg.progress_during_grace = progress;
    cfg.paranoid = true;
    Simulator::new(cfg).run(wl)
}

/// Byte-identical comparison: debug strings (covers every float bit via
/// `{:?}` and dodges NaN != NaN) plus structural record equality.
fn assert_identical(eh: &SimResult, pm: &SimResult, what: &str) {
    assert_eq!(eh.makespan, pm.makespan, "{what}: makespan");
    assert_eq!(
        format!("{:?}", eh.slowdown_report()),
        format!("{:?}", pm.slowdown_report()),
        "{what}: SlowdownReport"
    );
    assert_eq!(
        format!("{:?}", eh.preemption_report()),
        format!("{:?}", pm.preemption_report()),
        "{what}: PreemptionReport"
    );
    assert_eq!(
        format!("{:?}", eh.intervals_report()),
        format!("{:?}", pm.intervals_report()),
        "{what}: IntervalsReport"
    );
    assert_eq!(eh.unfinished, pm.unfinished, "{what}: unfinished");
    assert_eq!(eh.records.len(), pm.records.len());
    for (a, b) in eh.records.iter().zip(&pm.records) {
        assert_eq!(a, b, "{what}: record {:?}", a.id);
        assert_eq!(
            a.slowdown.to_bits(),
            b.slowdown.to_bits(),
            "{what}: slowdown bits of {:?}",
            a.id
        );
    }
    assert_eq!(
        eh.sched_stats.ticks, pm.sched_stats.ticks,
        "{what}: simulated minutes"
    );
    assert_eq!(
        eh.sched_stats.preemption_signals, pm.sched_stats.preemption_signals,
        "{what}: signals"
    );
    assert_eq!(eh.sched_stats.internal_errors, 0, "{what}: internal errors");
    assert_eq!(pm.sched_stats.internal_errors, 0, "{what}: internal errors");
}

#[test]
fn event_horizon_matches_per_minute_on_section_4_2_workloads() {
    // ≥ 3 seeds on §4.2 synthetic workloads, byte-identical reports across
    // every implemented policy.
    let cluster = ClusterSpec::tiny(3);
    let mut fast_forwarded_somewhere = false;
    for seed in [11u64, 29, 47] {
        let wl = SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(cluster.clone())
            .with_num_jobs(400)
            .generate();
        for policy in all_policies() {
            let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, false);
            let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, false);
            assert_identical(&eh, &pm, &format!("seed {seed}, {policy:?}"));
            fast_forwarded_somewhere |= eh.sched_stats.fast_forwards > 0;
            assert_eq!(pm.sched_stats.fast_forwards, 0, "oracle never bulk-burns");
        }
    }
    assert!(
        fast_forwarded_somewhere,
        "the event-horizon engine never skipped a span — it is not exercising its fast path"
    );
}

#[test]
fn equivalence_holds_under_progress_during_grace() {
    let cluster = ClusterSpec::tiny(2);
    for seed in [3u64, 13, 31] {
        let wl = SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(cluster.clone())
            .with_num_jobs(250)
            .with_gp_scale(4.0) // long drains: grace-expiry horizons matter
            .generate();
        for policy in [
            PolicyKind::Lrtp,
            PolicyKind::Rand,
            PolicyKind::Srtf,
            PolicyKind::Youngest,
            PolicyKind::FitGpp { s: 4.0, p_max: Some(2) },
        ] {
            let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, true);
            let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, true);
            assert_identical(&eh, &pm, &format!("pdg seed {seed}, {policy:?}"));
        }
    }
}

#[test]
fn equivalence_holds_without_draining_the_backlog() {
    // Cut-off runs exercise the tail/max-tick clamps of the fast-forward.
    let cluster = ClusterSpec::tiny(2);
    let wl = SyntheticWorkload::paper_section_4_2(17)
        .with_cluster(cluster.clone())
        .with_num_jobs(200)
        .generate();
    for policy in [PolicyKind::Fifo, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }] {
        for (drain, tail, max) in [(false, 25, u64::MAX / 2), (true, 0, 500)] {
            let mk = |engine| {
                let mut cfg = SimConfig::new(cluster.clone(), policy);
                cfg.engine = engine;
                cfg.seed = 17;
                cfg.drain = drain;
                cfg.tail_ticks = tail;
                cfg.max_ticks = max;
                cfg.paranoid = true;
                Simulator::new(cfg).run(&wl)
            };
            let eh = mk(SimEngine::EventHorizon);
            let pm = mk(SimEngine::PerMinute);
            assert_identical(&eh, &pm, &format!("{policy:?} drain={drain}"));
        }
    }
}

#[test]
fn prop_engines_agree_on_random_workloads() {
    // Randomized breadth: arbitrary demands, grace periods, and arrival
    // patterns from the property kit, paranoid invariants on.
    check("engine-equivalence", PropConfig::default(), |rng| {
        let policies = all_policies();
        let policy = policies[rng.below(policies.len() as u64) as usize];
        let cluster = ClusterSpec::tiny(1 + rng.below(3) as usize);
        let wl = gen::workload(rng, 20 + rng.below(50) as usize, 30 + rng.below(80));
        let seed = rng.next_u64();
        let progress = rng.chance(0.3);
        let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, progress);
        let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, progress);
        prop_assert!(eh.makespan == pm.makespan, "{policy:?}: makespan {} vs {}", eh.makespan, pm.makespan);
        prop_assert!(
            eh.records == pm.records,
            "{policy:?}: records diverge (seed {seed:#x})"
        );
        prop_assert!(
            eh.sched_stats.ticks == pm.sched_stats.ticks,
            "{policy:?}: ticks {} vs {}",
            eh.sched_stats.ticks,
            pm.sched_stats.ticks
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Policy-oracle equivalence: verbatim pre-refactor planning loops.
//
// The seed repository implemented LRTP and RAND as self-contained loops
// (no shared greedy helper) dispatched through a `plan_preemption` match.
// The copies below preserve those loops exactly as they were before the
// `PreemptionPolicy` refactor; the property test drives both the oracle
// and the trait-built policy over randomized cluster states with cloned
// RNGs and demands bit-identical plans.
// ---------------------------------------------------------------------

mod pre_refactor_oracle {
    use super::*;

    fn fit_node(te: &JobSpec, proj: &[ResourceVec]) -> Option<NodeId> {
        proj.iter()
            .enumerate()
            .find(|(_, f)| te.demand.fits_in(f))
            .map(|(i, _)| NodeId(i as u32))
    }

    fn infeasible(te: &JobSpec, ctx: &PolicyCtx<'_>) -> bool {
        let max_node_cap = ctx
            .cluster
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, n| acc.max(&n.capacity));
        !te.demand.fits_in(&max_node_cap)
    }

    /// Pre-refactor `lrtp::plan`, verbatim modulo formatting.
    pub fn lrtp(te: &JobSpec, ctx: &PolicyCtx<'_>) -> Option<PreemptionPlan> {
        if infeasible(te, ctx) {
            return None;
        }
        let mut pool = ctx.running_be();
        pool.sort_by_key(|id| (std::cmp::Reverse((ctx.oracle_remaining)(*id)), id.0));
        let mut pool = pool.into_iter();

        let mut projected: Vec<ResourceVec> = ctx.effective_free.to_vec();
        let total_cap = ctx.cluster.total_capacity();
        let mut victims = Vec::new();
        loop {
            if let Some(node) = fit_node(te, &projected) {
                return Some(PreemptionPlan { node, victims, fallback: false });
            }
            if !victims.is_empty() {
                let aggregate = projected
                    .iter()
                    .fold(ResourceVec::ZERO, |acc, f| acc + *f);
                if te.demand.fits_in(&aggregate) {
                    let node = projected
                        .iter()
                        .enumerate()
                        .max_by(|(_, a), (_, b)| {
                            a.size(&total_cap).partial_cmp(&b.size(&total_cap)).unwrap()
                        })
                        .map(|(i, _)| NodeId(i as u32))
                        .unwrap();
                    return Some(PreemptionPlan { node, victims, fallback: false });
                }
            }
            let Some(id) = pool.next() else {
                return None;
            };
            let j = &ctx.jobs[id];
            let node = j.node.expect("running");
            projected[node.0 as usize] += j.spec.demand;
            victims.push(id);
        }
    }

    /// Pre-refactor `rand_policy::plan`, verbatim modulo formatting.
    pub fn rand(
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        rng: &mut Pcg64,
        p_max: Option<u32>,
    ) -> Option<PreemptionPlan> {
        if infeasible(te, ctx) {
            return None;
        }
        let mut pool = ctx.running_be();
        if let Some(p) = p_max {
            pool.retain(|id| ctx.jobs[*id].preemptions < p);
        }

        let mut projected: Vec<ResourceVec> = ctx.effective_free.to_vec();
        let total_cap = ctx.cluster.total_capacity();
        let mut victims = Vec::new();
        loop {
            if let Some(node) = fit_node(te, &projected) {
                return Some(PreemptionPlan { node, victims, fallback: false });
            }
            if !victims.is_empty() {
                let aggregate = projected
                    .iter()
                    .fold(ResourceVec::ZERO, |acc, f| acc + *f);
                if te.demand.fits_in(&aggregate) {
                    let node = projected
                        .iter()
                        .enumerate()
                        .max_by(|(_, a), (_, b)| {
                            a.size(&total_cap).partial_cmp(&b.size(&total_cap)).unwrap()
                        })
                        .map(|(i, _)| NodeId(i as u32))
                        .unwrap();
                    return Some(PreemptionPlan { node, victims, fallback: false });
                }
            }
            let Some(i) = rng.pick_index(pool.len()) else {
                return None;
            };
            let id = pool.swap_remove(i);
            let j = &ctx.jobs[id];
            let node = j.node.expect("running");
            projected[node.0 as usize] += j.spec.demand;
            victims.push(id);
        }
    }
}

/// Build a random cluster state: `n` running BE jobs packed onto a tiny
/// cluster, with randomized preemption counts. Returns (cluster, jobs).
fn random_cluster_state(rng: &mut Pcg64) -> (Cluster, Vec<Job>) {
    let nodes = 1 + rng.below(4) as usize;
    let spec = ClusterSpec::tiny(nodes);
    let mut cluster = Cluster::new(&spec);
    let mut jobs = Vec::new();
    let target = rng.below(12) as usize;
    while jobs.len() < target {
        let demand = ResourceVec::new(
            1.0 + rng.below(16) as f64,
            8.0 + rng.below(128) as f64,
            rng.below(5) as f64,
        );
        let node = NodeId(rng.below(nodes as u64) as u32);
        if !demand.fits_in(&cluster.node(node).free) {
            break; // keep states irregular: stop at first failed pack
        }
        let id = jobs.len() as u32;
        let mut job = Job::new(JobSpec::new(
            id,
            JobClass::Be,
            demand,
            rng.below(50),
            1 + rng.below(200),
            rng.below(15),
        ));
        // A common start minute (≥ every submit, which is < 50): the
        // scheduler only ever compares remaining times of co-running jobs
        // at a shared `now`, and the victim index exploits exactly that
        // (its completion keys order `remaining_at(now)` for any common
        // now). Per-job start minutes would compare stored remainings at
        // *different* sync points — a state no scheduler run produces.
        job.start(node, 50);
        job.preemptions = rng.below(3) as u32;
        cluster.bind(JobId(id), demand, node);
        jobs.push(job);
    }
    (cluster, jobs)
}

#[test]
fn prop_trait_policies_match_pre_refactor_oracle() {
    check("policy-oracle", PropConfig::default(), |rng| {
        let (cluster, jobs) = random_cluster_state(rng);
        let free: Vec<ResourceVec> = cluster.nodes.iter().map(|n| n.free).collect();
        let remaining: Vec<u64> = jobs.iter().map(|j| j.remaining).collect();
        let jobs = JobTable::from_jobs(jobs);
        let oracle = |id: JobId| remaining[id.0 as usize];
        let predicted = |id: JobId| remaining[id.0 as usize] as f64;
        let vidx = VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx {
            cluster: &cluster,
            jobs: &jobs,
            effective_free: &free,
            oracle_remaining: &oracle,
            predicted_remaining: &predicted,
            victims: &vidx,
        };
        let te = JobSpec::new(
            999,
            JobClass::Te,
            ResourceVec::new(
                1.0 + rng.below(32) as f64,
                8.0 + rng.below(256) as f64,
                rng.below(10) as f64,
            ),
            0,
            5,
            0,
        );
        let seed = rng.next_u64();

        // LRTP: deterministic — trait plan must equal the verbatim oracle.
        let mut rng_a = Pcg64::new(seed);
        let got = build_policy(&PolicyKind::Lrtp).plan(&te, &ctx, &mut PlanScratch::default(), &mut rng_a);
        let want = pre_refactor_oracle::lrtp(&te, &ctx);
        prop_assert!(got == want, "LRTP diverged: {got:?} vs {want:?}");

        // RAND: both sides consume an identically-seeded RNG.
        let mut rng_a = Pcg64::new(seed);
        let mut rng_b = Pcg64::new(seed);
        let got = build_policy(&PolicyKind::Rand).plan(&te, &ctx, &mut PlanScratch::default(), &mut rng_a);
        let want = pre_refactor_oracle::rand(&te, &ctx, &mut rng_b, None);
        prop_assert!(got == want, "RAND diverged: {got:?} vs {want:?}");
        prop_assert!(
            rng_a.next_u64() == rng_b.next_u64(),
            "RAND consumed different amounts of randomness"
        );

        // P-SRTF: with predictions exactly equal to the oracle (as built
        // above), the prediction-aware ordering must reproduce SRTF's
        // plan bit-for-bit.
        let mut rng_a = Pcg64::new(seed);
        let got = build_policy(&PolicyKind::PSrtf).plan(&te, &ctx, &mut PlanScratch::default(), &mut rng_a);
        let want = fitgpp::sched::policy::srtf::plan(&te, &ctx, &mut PlanScratch::default());
        prop_assert!(got == want, "P-SRTF with oracle predictions diverged from SRTF");

        // FitGpp: the trait object delegates to the (unchanged) Eq. 1-4
        // implementation; pin the delegation including the RNG fallback.
        for p_max in [Some(1), None] {
            let mut rng_a = Pcg64::new(seed);
            let mut rng_b = Pcg64::new(seed);
            let got = build_policy(&PolicyKind::FitGpp { s: 4.0, p_max }).plan(
                &te,
                &ctx,
                &mut PlanScratch::default(),
                &mut rng_a,
            );
            let want = fitgpp::sched::policy::fitgpp::plan(
                &te,
                &ctx,
                &mut PlanScratch::default(),
                4.0,
                p_max,
                &mut rng_b,
            );
            prop_assert!(got == want, "FitGpp({p_max:?}) diverged");
            prop_assert!(
                rng_a.next_u64() == rng_b.next_u64(),
                "FitGpp consumed different amounts of randomness"
            );
        }
        Ok(())
    });
}

/// Satellite audit (perf PR): every `SchedStats` counter that describes
/// *scheduling work* — signals, plans, placements, completions, skips,
/// replans, simulated minutes — must be drive-mode invariant. Counters
/// that were ever bumped per-minute in one engine and per-burn in the
/// other would double-count under exactly one of them; this pin turns any
/// such drift into a test failure. `fast_forwards` /
/// `fast_forwarded_ticks` are *engine descriptors* (how the minutes were
/// covered, not what happened in them) and are excluded by design: the
/// per-minute oracle is instead pinned to never bulk-burn at all.
#[test]
fn sched_stats_counters_are_drive_mode_invariant() {
    let cluster = ClusterSpec::tiny(3);
    for seed in [13u64, 101] {
        let wl = SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(cluster.clone())
            .with_num_jobs(300)
            .generate();
        for policy in all_policies() {
            let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, false);
            let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, false);
            let what = format!("seed {seed}, {policy:?}");
            let (a, b) = (&eh.sched_stats, &pm.sched_stats);
            assert_eq!(a.preemption_signals, b.preemption_signals, "{what}: signals");
            assert_eq!(a.fallback_plans, b.fallback_plans, "{what}: fallback_plans");
            assert_eq!(a.plans, b.plans, "{what}: plans");
            assert_eq!(a.placements, b.placements, "{what}: placements");
            assert_eq!(a.completions, b.completions, "{what}: completions");
            assert_eq!(a.te_no_preemption, b.te_no_preemption, "{what}: te_no_preemption");
            assert_eq!(a.ticks, b.ticks, "{what}: simulated minutes");
            assert_eq!(a.replans, b.replans, "{what}: replans");
            assert_eq!(a.internal_errors, 0, "{what}: internal errors");
            assert_eq!(b.internal_errors, 0, "{what}: internal errors");
            assert_eq!(a.admission_skips, b.admission_skips, "{what}: admission_skips");
            // Completions must also agree with ground truth: every job in
            // the workload finished (these runs drain).
            assert_eq!(a.completions, wl.jobs.len() as u64, "{what}: all jobs completed");
            assert_eq!(b.fast_forwards, 0, "{what}: oracle never bulk-burns");
            assert_eq!(b.fast_forwarded_ticks, 0, "{what}: oracle never bulk-burns");
        }
    }
}
