//! Equivalence property: the event-horizon engine must produce
//! byte-identical results to the per-minute reference loop — same
//! `SlowdownReport`, same `PreemptionReport`, same per-job records, same
//! makespan — on §4.2 synthetic workloads across seeds, policies, and the
//! progress-during-grace ablation, plus randomized workloads from the
//! in-tree property kit.

use fitgpp::cluster::ClusterSpec;
use fitgpp::prop_assert;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::testkit::{check, gen, PropConfig};
use fitgpp::workload::synthetic::SyntheticWorkload;
use fitgpp::workload::Workload;

fn paper_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::FitGpp { s: 2.0, p_max: None },
    ]
}

fn run(
    engine: SimEngine,
    wl: &Workload,
    cluster: &ClusterSpec,
    policy: PolicyKind,
    seed: u64,
    progress: bool,
) -> SimResult {
    let mut cfg = SimConfig::new(cluster.clone(), policy);
    cfg.engine = engine;
    cfg.seed = seed;
    cfg.progress_during_grace = progress;
    cfg.paranoid = true;
    Simulator::new(cfg).run(wl)
}

/// Byte-identical comparison: debug strings (covers every float bit via
/// `{:?}` and dodges NaN != NaN) plus structural record equality.
fn assert_identical(eh: &SimResult, pm: &SimResult, what: &str) {
    assert_eq!(eh.makespan, pm.makespan, "{what}: makespan");
    assert_eq!(
        format!("{:?}", eh.slowdown_report()),
        format!("{:?}", pm.slowdown_report()),
        "{what}: SlowdownReport"
    );
    assert_eq!(
        format!("{:?}", eh.preemption_report()),
        format!("{:?}", pm.preemption_report()),
        "{what}: PreemptionReport"
    );
    assert_eq!(
        format!("{:?}", eh.intervals_report()),
        format!("{:?}", pm.intervals_report()),
        "{what}: IntervalsReport"
    );
    assert_eq!(eh.unfinished, pm.unfinished, "{what}: unfinished");
    assert_eq!(eh.records.len(), pm.records.len());
    for (a, b) in eh.records.iter().zip(&pm.records) {
        assert_eq!(a, b, "{what}: record {:?}", a.id);
        assert_eq!(
            a.slowdown.to_bits(),
            b.slowdown.to_bits(),
            "{what}: slowdown bits of {:?}",
            a.id
        );
    }
    assert_eq!(
        eh.sched_stats.ticks, pm.sched_stats.ticks,
        "{what}: simulated minutes"
    );
    assert_eq!(
        eh.sched_stats.preemption_signals, pm.sched_stats.preemption_signals,
        "{what}: signals"
    );
}

#[test]
fn event_horizon_matches_per_minute_on_section_4_2_workloads() {
    // The satellite requirement: ≥ 3 seeds on §4.2 synthetic workloads,
    // byte-identical SlowdownReport / PreemptionReport.
    let cluster = ClusterSpec::tiny(3);
    let mut fast_forwarded_somewhere = false;
    for seed in [11u64, 29, 47] {
        let wl = SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(cluster.clone())
            .with_num_jobs(400)
            .generate();
        for policy in paper_policies() {
            let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, false);
            let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, false);
            assert_identical(&eh, &pm, &format!("seed {seed}, {policy:?}"));
            fast_forwarded_somewhere |= eh.sched_stats.fast_forwards > 0;
            assert_eq!(pm.sched_stats.fast_forwards, 0, "oracle never bulk-burns");
        }
    }
    assert!(
        fast_forwarded_somewhere,
        "the event-horizon engine never skipped a span — it is not exercising its fast path"
    );
}

#[test]
fn equivalence_holds_under_progress_during_grace() {
    let cluster = ClusterSpec::tiny(2);
    for seed in [3u64, 13, 31] {
        let wl = SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(cluster.clone())
            .with_num_jobs(250)
            .with_gp_scale(4.0) // long drains: grace-expiry horizons matter
            .generate();
        for policy in [
            PolicyKind::Lrtp,
            PolicyKind::Rand,
            PolicyKind::FitGpp { s: 4.0, p_max: Some(2) },
        ] {
            let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, true);
            let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, true);
            assert_identical(&eh, &pm, &format!("pdg seed {seed}, {policy:?}"));
        }
    }
}

#[test]
fn equivalence_holds_without_draining_the_backlog() {
    // Cut-off runs exercise the tail/max-tick clamps of the fast-forward.
    let cluster = ClusterSpec::tiny(2);
    let wl = SyntheticWorkload::paper_section_4_2(17)
        .with_cluster(cluster.clone())
        .with_num_jobs(200)
        .generate();
    for policy in [PolicyKind::Fifo, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }] {
        for (drain, tail, max) in [(false, 25, u64::MAX / 2), (true, 0, 500)] {
            let mk = |engine| {
                let mut cfg = SimConfig::new(cluster.clone(), policy);
                cfg.engine = engine;
                cfg.seed = 17;
                cfg.drain = drain;
                cfg.tail_ticks = tail;
                cfg.max_ticks = max;
                cfg.paranoid = true;
                Simulator::new(cfg).run(&wl)
            };
            let eh = mk(SimEngine::EventHorizon);
            let pm = mk(SimEngine::PerMinute);
            assert_identical(&eh, &pm, &format!("{policy:?} drain={drain}"));
        }
    }
}

#[test]
fn prop_engines_agree_on_random_workloads() {
    // Randomized breadth: arbitrary demands, grace periods, and arrival
    // patterns from the property kit, paranoid invariants on.
    check("engine-equivalence", PropConfig::default(), |rng| {
        let policy = match rng.below(6) {
            0 => PolicyKind::Fifo,
            1 => PolicyKind::FastLane,
            2 => PolicyKind::Lrtp,
            3 => PolicyKind::Rand,
            4 => PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            _ => PolicyKind::FitGpp { s: 8.0, p_max: None },
        };
        let cluster = ClusterSpec::tiny(1 + rng.below(3) as usize);
        let wl = gen::workload(rng, 20 + rng.below(50) as usize, 30 + rng.below(80));
        let seed = rng.next_u64();
        let progress = rng.chance(0.3);
        let eh = run(SimEngine::EventHorizon, &wl, &cluster, policy, seed, progress);
        let pm = run(SimEngine::PerMinute, &wl, &cluster, policy, seed, progress);
        prop_assert!(eh.makespan == pm.makespan, "{policy:?}: makespan {} vs {}", eh.makespan, pm.makespan);
        prop_assert!(
            eh.records == pm.records,
            "{policy:?}: records diverge (seed {seed:#x})"
        );
        prop_assert!(
            eh.sched_stats.ticks == pm.sched_stats.ticks,
            "{policy:?}: ticks {} vs {}",
            eh.sched_stats.ticks,
            pm.sched_stats.ticks
        );
        Ok(())
    });
}
