//! End-to-end runtime tests: rust loads and executes the python-AOT HLO
//! artifacts. Skips (prints a note) when `make artifacts` has not run.

use fitgpp::runtime::{self, Checkpoint, Engine, Manifest, Trainer};
use fitgpp::xla;

fn manifest_or_skip() -> Option<(Engine, Manifest)> {
    if !runtime::artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    if !runtime::backend_available() {
        eprintln!("skipping: PJRT backend stubbed in this build (see rust/src/xla.rs)");
        return None;
    }
    let engine = Engine::cpu().expect("PJRT CPU client");
    let manifest = Manifest::load(&runtime::artifacts_dir()).expect("manifest");
    Some((engine, manifest))
}

#[test]
fn probe_round_trip_matches_known_values() {
    let Some((engine, manifest)) = manifest_or_skip() else { return };
    let probe = manifest.probe.clone().expect("probe artifact");
    let exe = engine
        .load_hlo_text(&manifest.artifact_path(&probe))
        .expect("compile probe");
    let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
    let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
    let out = exe.run(&[x, y]).expect("run probe");
    assert_eq!(out.len(), 1);
    let vals = out[0].to_vec::<f32>().unwrap();
    // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert_eq!(vals, vec![5., 5., 9., 9.]);
}

#[test]
fn manifest_lists_tiny_and_small() {
    let Some((_, manifest)) = manifest_or_skip() else { return };
    let tiny = manifest.variant("tiny").unwrap();
    let small = manifest.variant("small").unwrap();
    assert!(tiny.param_count() > 10_000);
    assert!(small.param_count() > tiny.param_count());
    assert_eq!(tiny.tokens.dtype, "s32");
}

#[test]
fn tiny_train_step_loss_decreases() {
    let Some((engine, manifest)) = manifest_or_skip() else { return };
    let mut t = Trainer::new(&engine, &manifest, "tiny", 42).expect("trainer");
    let first = t.step_synthetic().expect("step");
    assert!(first.is_finite());
    // Random init ⇒ loss ≈ ln(vocab) = ln(256) ≈ 5.55.
    assert!((first - 5.55).abs() < 1.0, "initial loss {first}");
    let mut last = first;
    for _ in 0..40 {
        last = t.step_synthetic().expect("step");
    }
    assert!(
        last < first * 0.9,
        "loss must decrease: first {first}, last {last}"
    );
    assert_eq!(t.step, 41);
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let Some((engine, manifest)) = manifest_or_skip() else { return };
    let mut t = Trainer::new(&engine, &manifest, "tiny", 7).expect("trainer");
    for _ in 0..3 {
        t.step_synthetic().unwrap();
    }
    let ckpt = t.checkpoint().unwrap();
    assert_eq!(ckpt.step, 3);
    // Serialize → parse → identical tensors.
    let bytes = ckpt.to_bytes();
    let back = Checkpoint::from_bytes(&bytes).unwrap();
    assert_eq!(back, ckpt);
    // Restore into a new trainer: same params (norms match exactly), and
    // training continues from the recorded step.
    let t2 = Trainer::from_checkpoint(&engine, &manifest, "tiny", &back, 7).unwrap();
    assert_eq!(t2.step, 3);
    let n1 = t.param_norm().unwrap();
    let n2 = t2.param_norm().unwrap();
    assert!((n1 - n2).abs() < 1e-9, "{n1} vs {n2}");
}

#[test]
fn restored_trainer_keeps_learning() {
    let Some((engine, manifest)) = manifest_or_skip() else { return };
    let mut t = Trainer::new(&engine, &manifest, "tiny", 3).unwrap();
    let mut before = f32::INFINITY;
    for _ in 0..20 {
        before = t.step_synthetic().unwrap();
    }
    let ckpt = t.checkpoint().unwrap();
    let mut t2 = Trainer::from_checkpoint(&engine, &manifest, "tiny", &ckpt, 3).unwrap();
    let mut after = f32::INFINITY;
    for _ in 0..20 {
        after = t2.step_synthetic().unwrap();
    }
    assert!(after < before, "resumed training regressed: {before} → {after}");
}

#[test]
fn wrong_token_count_is_rejected() {
    let Some((engine, manifest)) = manifest_or_skip() else { return };
    let mut t = Trainer::new(&engine, &manifest, "tiny", 1).unwrap();
    assert!(t.step_with(&[0i32; 3]).is_err());
}

#[test]
fn checkpoint_variant_mismatch_rejected() {
    let Some((engine, manifest)) = manifest_or_skip() else { return };
    let t = Trainer::new(&engine, &manifest, "tiny", 1).unwrap();
    let ckpt = t.checkpoint().unwrap();
    // A tiny checkpoint cannot restore a small model.
    assert!(Trainer::from_checkpoint(&engine, &manifest, "small", &ckpt, 1).is_err());
}
