//! Acceptance tests for the prediction subsystem.
//!
//! 1. **Convergence** — `ClassEwma` learns per-(tenant, class) runtime
//!    means from `Finished` events and keeps buckets isolated.
//! 2. **Cold start** — with zero completions observed, `ClassEwma` falls
//!    back to the declared runtime, so predicted-SRTF degrades to plain
//!    SRTF byte-for-byte over a whole run.
//! 3. **Zero-noise control** — `Noisy(sigma = 0)` is byte-identical to
//!    `Oracle` across both engines and every policy in the suite.
//! 4. **Engine invariance** — estimator state after a run is identical
//!    under the per-minute and event-horizon engines at every arrival
//!    lookahead, because `Finished` events fire at the same simulated
//!    minute in both.

use fitgpp::cluster::ClusterSpec;
use fitgpp::job::{JobClass, JobSpec, TenantId};
use fitgpp::resources::ResourceVec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sched::predict::{ClassEwma, EstimatorKind, RuntimeEstimator, SharedEstimator};
use fitgpp::sim::{JobRecord, SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::workload::source::{TenantAssigner, WorkloadSource};
use fitgpp::workload::synthetic::SyntheticWorkload;

fn all_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::Srtf,
        PolicyKind::Youngest,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::PSrtf,
        PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
    ]
}

fn cfg(cluster: &ClusterSpec, policy: PolicyKind, engine: SimEngine) -> SimConfig {
    let mut cfg = SimConfig::new(cluster.clone(), policy);
    cfg.engine = engine;
    cfg.seed = 0xA11CE;
    cfg.paranoid = true;
    cfg
}

fn assert_identical(a: &SimResult, b: &SimResult, what: &str) {
    assert_eq!(a.makespan, b.makespan, "{what}: makespan");
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x, y, "{what}: record {:?}", x.id);
        assert_eq!(
            x.slowdown.to_bits(),
            y.slowdown.to_bits(),
            "{what}: slowdown bits of {:?}",
            x.id
        );
    }
    assert_eq!(a.sched_stats.ticks, b.sched_stats.ticks, "{what}: simulated minutes");
    assert_eq!(a.unfinished, b.unfinished, "{what}: unfinished");
    assert_eq!(a.metrics, b.metrics, "{what}: streaming sinks diverge");
}

fn spec(id: u32, class: JobClass, exec: u64, tenant: u32) -> JobSpec {
    JobSpec::new(id, class, ResourceVec::new(4.0, 32.0, 1.0), 0, exec, 5)
        .with_tenant(TenantId(tenant))
}

/// A completed-job record with the given declared-and-actual runtime.
fn record(id: u32, class: JobClass, exec: u64, tenant: u32) -> JobRecord {
    let mut j = fitgpp::job::Job::new(spec(id, class, exec, tenant));
    j.start(fitgpp::cluster::NodeId(0), 0);
    j.complete(exec);
    JobRecord::from_job(&j)
}

#[test]
fn class_ewma_converges_and_keeps_buckets_isolated() {
    let mut est = ClassEwma::new(0.2);
    // Constant runtimes converge exactly: the EWMA of a constant is the
    // constant after the first observation.
    for i in 0..50 {
        est.observe(&record(i, JobClass::Be, 40, 0));
        est.observe(&record(1000 + i, JobClass::Te, 90, 1));
    }
    assert_eq!(est.predict_total(&spec(9000, JobClass::Be, 777, 0)), 40.0);
    assert_eq!(est.predict_total(&spec(9001, JobClass::Te, 777, 1)), 90.0);
    // Buckets are keyed by (tenant, class): the unobserved combinations
    // stay cold and fall back to the declared runtime.
    assert_eq!(est.predict_total(&spec(9002, JobClass::Te, 777, 0)), 777.0);
    assert_eq!(est.predict_total(&spec(9003, JobClass::Be, 777, 1)), 777.0);

    // A mixed stream settles inside the observed range and tracks the
    // recency-weighted mean, not the declared runtime.
    let mut est = ClassEwma::new(0.2);
    for i in 0..200 {
        let x = if i % 2 == 0 { 30 } else { 50 };
        est.observe(&record(i, JobClass::Be, x, 0));
    }
    let p = est.predict_total(&spec(9004, JobClass::Be, 999, 0));
    assert!(p > 30.0 && p < 50.0, "EWMA must land inside the observed range, got {p}");
    assert!((p - 40.0).abs() < 8.0, "EWMA should hover near the mean, got {p}");
    assert_eq!(est.updates(), 200);
}

#[test]
fn cold_start_falls_back_to_declared_runtime() {
    let est = SharedEstimator::new(&EstimatorKind::ClassEwma { alpha: 0.2 }, 7);
    assert_eq!(est.updates(), 0);
    for (id, class, exec, tenant) in
        [(0u32, JobClass::Be, 1u64, 0u32), (1, JobClass::Te, 40, 2), (2, JobClass::Be, 100_000, 9)]
    {
        let s = spec(id, class, exec, tenant);
        assert_eq!(
            est.predict_total(&s).to_bits(),
            (exec as f64).to_bits(),
            "zero completions observed => prediction is the declared runtime"
        );
    }
}

#[test]
fn cold_psrtf_degrades_to_srtf_byte_for_byte() {
    // Every job gets a unique tenant, so every (tenant, class) bucket is
    // still cold when its only job runs: the EWMA estimator falls back to
    // the declared runtime for the entire run, and predicted-SRTF must
    // reproduce SRTF's schedule bit-for-bit under both engines.
    let cluster = ClusterSpec::tiny(3);
    let jobs = 300;
    let params = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(jobs)
        .with_tenant_assigner(TenantAssigner::round_robin(jobs as u32));
    let wl = params.generate();
    for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
        let srtf = Simulator::new(cfg(&cluster, PolicyKind::Srtf, engine)).run(&wl);
        let mut pc = cfg(&cluster, PolicyKind::PSrtf, engine);
        pc.estimator = EstimatorKind::ClassEwma { alpha: 0.2 };
        let psrtf = Simulator::new(pc).run(&wl);
        assert_identical(&psrtf, &srtf, &format!("cold P-SRTF vs SRTF / {engine:?}"));
        // The estimator still observed every completion — it was cold for
        // *decisions*, not disconnected.
        assert_eq!(psrtf.prediction_updates, jobs as u64, "{engine:?}");
    }
}

#[test]
fn noisy_sigma_zero_is_byte_identical_to_oracle_everywhere() {
    // The acceptance pin: Noisy(sigma = 0) multiplies every prediction by
    // exactly 1.0, so runs must match the Oracle estimator byte-for-byte
    // across both engines and all 9 policies — including the two
    // prediction-aware ones, where the estimator actually steers plans.
    let cluster = ClusterSpec::tiny(3);
    let wl = SyntheticWorkload::paper_section_4_2(23)
        .with_cluster(cluster.clone())
        .with_num_jobs(300)
        .generate();
    for policy in all_policies() {
        for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
            let mut oc = cfg(&cluster, policy, engine);
            oc.estimator = EstimatorKind::Oracle;
            let oracle = Simulator::new(oc).run(&wl);

            let mut nc = cfg(&cluster, policy, engine);
            nc.estimator = EstimatorKind::Noisy { sigma: 0.0 };
            let noisy = Simulator::new(nc).run(&wl);

            assert_identical(&noisy, &oracle, &format!("{policy:?}/{engine:?} noisy(0)"));
            assert_eq!(
                noisy.prediction_updates, oracle.prediction_updates,
                "{policy:?}/{engine:?}: update counts"
            );
        }
    }
}

#[test]
fn estimator_state_is_engine_invariant() {
    // Attach an external EWMA estimator as an event subscriber (exactly
    // how the scheduler feeds its internal one) and run the same workload
    // under both engines and every arrival lookahead. Because `Finished`
    // events fire at the same simulated minute in all drive modes, the
    // estimator must end in bit-identical state: same update count, same
    // prediction for every probe spec.
    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(41)
        .with_cluster(cluster.clone())
        .with_num_jobs(300)
        .with_tenant_assigner(TenantAssigner::round_robin(4));
    let wl = params.generate();
    let probes: Vec<JobSpec> = (0..4)
        .flat_map(|t| {
            [spec(8000 + t, JobClass::Be, 60, t), spec(8100 + t, JobClass::Te, 60, t)]
        })
        .collect();

    let observe = |engine: SimEngine, lookahead: u64| {
        let est = SharedEstimator::new(&EstimatorKind::ClassEwma { alpha: 0.2 }, 0);
        let mut c = cfg(&cluster, PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) }, engine);
        c.estimator = EstimatorKind::ClassEwma { alpha: 0.2 };
        c.arrival_lookahead = lookahead;
        let res = Simulator::new(c)
            .run_with(&mut WorkloadSource::new(&wl), vec![Box::new(est.clone())]);
        let preds: Vec<u64> = probes.iter().map(|s| est.predict_total(s).to_bits()).collect();
        (res, est.updates(), preds)
    };

    let (base_res, base_updates, base_preds) = observe(SimEngine::PerMinute, 0);
    assert_eq!(base_updates, 300, "every completion reaches the estimator");
    for engine in [SimEngine::PerMinute, SimEngine::EventHorizon] {
        for lookahead in [0u64, 1, 32, 1 << 20] {
            let (res, updates, preds) = observe(engine, lookahead);
            assert_identical(&res, &base_res, &format!("{engine:?}/{lookahead}"));
            assert_eq!(updates, base_updates, "{engine:?}/{lookahead}: update count");
            assert_eq!(
                preds, base_preds,
                "{engine:?}/{lookahead}: estimator state diverged (probe predictions)"
            );
            assert_eq!(
                res.prediction_updates, base_res.prediction_updates,
                "{engine:?}/{lookahead}: internal estimator update count"
            );
        }
    }
}

#[test]
fn noisy_predictions_actually_spread_with_sigma() {
    // Guard against a stub: at sigma > 0 the noisy estimator must produce
    // per-job spread (different ids, different multipliers) while staying
    // deterministic for a fixed seed.
    let a = SharedEstimator::new(&EstimatorKind::Noisy { sigma: 0.5 }, 7);
    let b = SharedEstimator::new(&EstimatorKind::Noisy { sigma: 0.5 }, 7);
    let mut distinct = std::collections::BTreeSet::new();
    for id in 0..64 {
        let s = spec(id, JobClass::Be, 100, 0);
        let pa = a.predict_total(&s);
        assert_eq!(pa.to_bits(), b.predict_total(&s).to_bits(), "same seed, same prediction");
        assert!(pa > 0.0 && pa.is_finite());
        distinct.insert(pa.to_bits());
    }
    assert!(distinct.len() > 32, "log-normal error must vary per job, saw {}", distinct.len());
}

#[test]
fn predicted_srtf_with_exact_predictions_matches_srtf_on_shared_tenants() {
    // Complement to the cold-start pin: with the *Oracle* estimator (exact
    // totals), predicted remaining equals true remaining even after
    // completions accumulate, so P-SRTF tracks SRTF on a workload where
    // tenants share buckets and an EWMA would diverge.
    let cluster = ClusterSpec::tiny(3);
    let params = SyntheticWorkload::paper_section_4_2(31)
        .with_cluster(cluster.clone())
        .with_num_jobs(250)
        .with_tenant_assigner(TenantAssigner::round_robin(2));
    let wl = params.generate();
    for engine in [SimEngine::EventHorizon, SimEngine::PerMinute] {
        let srtf = Simulator::new(cfg(&cluster, PolicyKind::Srtf, engine)).run(&wl);
        let psrtf = Simulator::new(cfg(&cluster, PolicyKind::PSrtf, engine)).run(&wl);
        assert_identical(&psrtf, &srtf, &format!("oracle P-SRTF vs SRTF / {engine:?}"));
    }
}
