//! Scenario tests: the qualitative behaviours §3 claims for FitGpp,
//! demonstrated on crafted workloads.

use fitgpp::cluster::ClusterSpec;
use fitgpp::job::{JobClass, JobSpec};
use fitgpp::resources::ResourceVec;
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sim::{SimConfig, SimResult, Simulator};
use fitgpp::workload::Workload;

fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
    ResourceVec::new(c, r, g)
}

fn run(policy: PolicyKind, nodes: usize, specs: Vec<JobSpec>) -> SimResult {
    let mut cfg = SimConfig::new(ClusterSpec::tiny(nodes), policy);
    cfg.paranoid = true;
    Simulator::new(cfg).run(&Workload::new(specs))
}

/// A full node of BE jobs: one big (long GP), several small (short GP).
fn mixed_node_workload() -> Vec<JobSpec> {
    let mut specs = vec![
        // Big BE job: 24 CPUs, GP 15.
        JobSpec::new(0, JobClass::Be, rv(24.0, 192.0, 6.0), 0, 200, 15),
    ];
    // Two small BE jobs: 4 CPUs each, GP 1.
    for i in 1..=2 {
        specs.push(JobSpec::new(i, JobClass::Be, rv(4.0, 32.0, 1.0), 0, 200, 1));
    }
    // TE job arrives once the node is saturated.
    specs.push(JobSpec::new(3, JobClass::Te, rv(4.0, 32.0, 1.0), 5, 10, 0));
    specs
}

#[test]
fn fitgpp_picks_small_short_gp_victim() {
    let res = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, 1, mixed_node_workload());
    let big = &res.records[0];
    assert_eq!(big.preemptions, 0, "big/long-GP job must be spared");
    let small_preempted: u32 = res.records[1..=2].iter().map(|r| r.preemptions).sum();
    assert_eq!(small_preempted, 1, "exactly one small victim (Eq. 2)");
    // TE waits only the short GP: signal at t=5, GP 1 ⇒ start t=6.
    assert_eq!(res.records[3].first_start, Some(6));
}

#[test]
fn lrtp_picks_longest_remaining_regardless_of_gp() {
    // Make the big job also the longest-remaining: LRTP evicts it and the
    // TE job eats its 15-minute grace period.
    let res = run(PolicyKind::Lrtp, 1, mixed_node_workload());
    assert_eq!(res.records[0].preemptions, 1, "LRTP evicts the longest job");
    assert_eq!(res.records[3].first_start, Some(20), "TE waits the 15-min GP");
}

#[test]
fn te_slowdown_fitgpp_beats_fifo_on_contended_cluster() {
    // Synthetic contention: FIFO's TE tail must collapse under FitGpp —
    // the paper's headline claim, in miniature.
    let wl = fitgpp::workload::synthetic::SyntheticWorkload::paper_section_4_2(11)
        .with_cluster(ClusterSpec::tiny(4))
        .with_num_jobs(800)
        .generate();
    let mut fifo_cfg = SimConfig::new(ClusterSpec::tiny(4), PolicyKind::Fifo);
    fifo_cfg.seed = 1;
    let fifo = Simulator::new(fifo_cfg).run(&wl);
    let mut fg_cfg = SimConfig::new(
        ClusterSpec::tiny(4),
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    );
    fg_cfg.seed = 1;
    let fg = Simulator::new(fg_cfg).run(&wl);
    let fifo_te = fifo.slowdown_report().te;
    let fg_te = fg.slowdown_report().te;
    assert!(
        fg_te.p95 < fifo_te.p95 * 0.5,
        "FitGpp TE p95 {} must be well below FIFO {}",
        fg_te.p95,
        fifo_te.p95
    );
    // BE jobs are not destroyed in the process (within 2× of FIFO median).
    let fifo_be = fifo.slowdown_report().be;
    let fg_be = fg.slowdown_report().be;
    assert!(
        fg_be.p50 < fifo_be.p50 * 2.0,
        "FitGpp BE p50 {} vs FIFO {}",
        fg_be.p50,
        fifo_be.p50
    );
}

#[test]
fn fitgpp_preempts_fewer_jobs_than_rand() {
    let wl = fitgpp::workload::synthetic::SyntheticWorkload::paper_section_4_2(13)
        .with_cluster(ClusterSpec::tiny(4))
        .with_num_jobs(800)
        .generate();
    let run_policy = |p: PolicyKind| {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(4), p);
        cfg.seed = 5;
        Simulator::new(cfg).run(&wl)
    };
    let fg = run_policy(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
    let rand = run_policy(PolicyKind::Rand);
    assert!(
        fg.preempted_fraction() < rand.preempted_fraction(),
        "FitGpp {} !< RAND {}",
        fg.preempted_fraction(),
        rand.preempted_fraction()
    );
}

#[test]
fn fastlane_explains_part_of_the_gain() {
    // Ablation: TE bypass alone already helps vs FIFO, but preemption
    // (FitGpp) helps more under saturation.
    let wl = fitgpp::workload::synthetic::SyntheticWorkload::paper_section_4_2(17)
        .with_cluster(ClusterSpec::tiny(4))
        .with_num_jobs(600)
        .generate();
    let run_policy = |p: PolicyKind| {
        let mut cfg = SimConfig::new(ClusterSpec::tiny(4), p);
        cfg.seed = 9;
        Simulator::new(cfg).run(&wl).slowdown_report().te.p95
    };
    let fifo = run_policy(PolicyKind::Fifo);
    let lane = run_policy(PolicyKind::FastLane);
    let fg = run_policy(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
    assert!(lane < fifo, "bypass alone must beat FIFO ({lane} vs {fifo})");
    assert!(fg <= lane, "preemption must not hurt vs bypass ({fg} vs {lane})");
}

#[test]
fn zero_gp_means_zero_te_wait() {
    // Every BE job rewindable (GP 0): the TE job starts the minute it
    // arrives (§2's rewinding remark).
    let specs = vec![
        JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 100, 0),
        JobSpec::new(1, JobClass::Te, rv(8.0, 64.0, 2.0), 7, 10, 0),
    ];
    let res = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, 1, specs);
    assert_eq!(res.records[1].first_start, Some(7));
    assert!((res.records[1].slowdown - 1.0).abs() < 1e-9);
}

#[test]
fn victim_requeued_at_top_restarts_before_queue() {
    // After preemption, the victim must re-enter service before BE jobs
    // that were already queued (the paper's "top of the queue" rule).
    let specs = vec![
        JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0), // victim
        JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 1, 50, 0), // queued
        JobSpec::new(2, JobClass::Be, rv(32.0, 256.0, 8.0), 2, 50, 0), // queued
        JobSpec::new(3, JobClass::Te, rv(8.0, 64.0, 2.0), 5, 5, 0),
    ];
    let res = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, 1, specs);
    let victim = &res.records[0];
    assert_eq!(victim.preemptions, 1);
    let restart = victim.first_start.unwrap() + victim.resched_intervals[0] + 1;
    assert!(
        restart <= res.records[1].first_start.unwrap(),
        "victim restarts at {restart}, queued job started {}",
        res.records[1].first_start.unwrap()
    );
}

#[test]
fn sensitivity_larger_s_prefers_shorter_gp_victims() {
    // Two candidate victims: small-with-long-GP vs large-with-zero-GP.
    // s = 0 picks the small one (size only); s = 8 flips to the zero-GP one.
    let specs_base = vec![
        JobSpec::new(0, JobClass::Be, rv(6.0, 48.0, 2.0), 0, 200, 20), // small, GP 20
        JobSpec::new(1, JobClass::Be, rv(20.0, 160.0, 5.0), 0, 200, 0), // large, GP 0
        JobSpec::new(2, JobClass::Te, rv(8.0, 64.0, 2.0), 5, 10, 0),
    ];
    let low_s = run(PolicyKind::FitGpp { s: 0.0, p_max: Some(1) }, 1, specs_base.clone());
    assert_eq!(low_s.records[0].preemptions, 1, "s=0 ⇒ smallest Size wins");
    let high_s = run(PolicyKind::FitGpp { s: 8.0, p_max: Some(1) }, 1, specs_base);
    assert_eq!(high_s.records[1].preemptions, 1, "s=8 ⇒ zero-GP wins");
}
