//! The control plane: a typed command/event protocol over the scheduler.
//!
//! The paper's premise is that TE jobs are *interactive*: users watch
//! their runs, kill the ones that misbehave, promote the ones that work,
//! and the cluster underneath them loses nodes, drains machines for
//! maintenance, and grows. The bare [`Scheduler::tick`] loop can express
//! exactly one of those things (arrivals in, completions out); everything
//! else — cancellation, reclassification, node failure/restore, drains,
//! capacity changes — arrives here, as a [`SchedulerCommand`], and every
//! observable state change leaves as a [`SchedulerEvent`] delivered to
//! pluggable [`EventSubscriber`]s.
//!
//! ## The facade
//!
//! [`ClusterController`] owns the scheduler *and* the resident
//! [`JobTable`] and exposes exactly three verbs:
//!
//! * [`stage_arrival`](ClusterController::stage_arrival) — a job becomes
//!   known (inserted into the table, its submit minute registered with the
//!   [`EventClock`](crate::sched::EventClock));
//! * [`command`](ClusterController::command) — a control-plane command is
//!   applied *between* scheduling rounds;
//! * [`step`](ClusterController::step) — one scheduling round runs: due
//!   arrivals pop, [`Scheduler::tick`] decides, completed jobs retire.
//!
//! Both drivers — the simulator's
//! [`run_core`](crate::sim::Simulator::run_with) and the live executor
//! ([`live::LiveCluster::run`](crate::live::LiveCluster::run)) — speak
//! only these verbs, so a scenario that holds in simulation is expressed
//! in exactly the language the live cluster runs.
//!
//! ## Events and subscribers
//!
//! The built-in [`StreamingMetrics`] sink is itself a subscriber (it folds
//! [`SchedulerEvent::Finished`] and [`SchedulerEvent::Cancelled`] records
//! in); additional subscribers bolt on without touching the scheduler:
//! [`JsonlEventLog`] serializes every event as one deterministic JSON line
//! (the golden-file tests pin a seeded scenario's whole log byte-for-byte
//! across engines and lookahead settings), and [`SharedEventLog`] collects
//! events in memory for tests and the live report.
//!
//! Within one step, event order is normalized: `Submitted` (arrival
//! order), then `Finished`, `Preempted`, `Vacated`, `Started`/`Resumed`,
//! and finally `AdmissionSkipped` (each in [`TickStats`] order).
//! Command-derived events precede the step they were applied before. The per-tick interleaving inside the
//! scheduler is not observable through [`TickStats`]; the normalized
//! order is part of the protocol contract and what the JSONL golden files
//! pin.

use crate::cluster::{ClusterSpec, NodeAvailability, NodeId};
use crate::job::{Job, JobClass, JobId, JobSpec, TenantId};
use crate::job_table::JobTable;
use crate::metrics::StreamingMetrics;
use crate::resources::ResourceVec;
use crate::sched::{SchedConfig, Scheduler, TickStats};
use crate::sim::JobRecord;
use crate::util::bin::{BinReader, BinWriter};
use crate::util::json::Json;
use crate::Minutes;
use std::fmt;
use std::io::Write;
use std::sync::{Arc, Mutex};

/// A control-plane command. Commands are applied between scheduling
/// rounds ([`ClusterController::command`]); invalid ones degrade into a
/// [`SchedulerEvent::CommandRejected`] instead of corrupting state, so a
/// hostile or stale scenario file cannot abort a run.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerCommand {
    /// Make a job known to the scheduler. Its arrival is staged on the
    /// clock and pops at `spec.submit` like any source-pulled job. (The
    /// simulator's [`ArrivalSource`](crate::workload::source::ArrivalSource)
    /// pulls stage arrivals directly; `Submit` serves live/manual driving.)
    Submit(JobSpec),
    /// Kill a queued, running, or draining job. It retires immediately as
    /// [`Cancelled`](crate::job::JobState::Cancelled), its resources (if
    /// any) return to the cluster, and it is excluded from slowdown
    /// statistics.
    Cancel {
        /// The job to kill.
        job: JobId,
    },
    /// Change a job's TE/BE class mid-flight (promote a trial to a full
    /// run, or demote one). Queued jobs re-enqueue at the tail of the lane
    /// their new class routes to; running jobs flip in place.
    Reclassify {
        /// The job whose class changes.
        job: JobId,
        /// The class it becomes.
        class: JobClass,
    },
    /// A node fails: hosted jobs are evicted with no grace period and
    /// re-queued at the top of their lane; the node stops accepting
    /// placements until [`SchedulerCommand::NodeUp`].
    NodeDown {
        /// The failing node.
        node: NodeId,
    },
    /// A failed or draining node returns to service.
    NodeUp {
        /// The node restored.
        node: NodeId,
    },
    /// Drain a node for maintenance: tenants run to completion, no new
    /// placement lands there.
    Drain {
        /// The node to drain.
        node: NodeId,
    },
    /// Change a node's capacity (elastic resize). Rejected if current
    /// allocations would no longer fit.
    Resize {
        /// The node resized.
        node: NodeId,
        /// Its new capacity vector.
        capacity: ResourceVec,
    },
    /// Cap a tenant's occupied Size (Eq. 1 `Size` of its Running +
    /// Draining demand against the cluster's construction-time total
    /// capacity). Checked before admission by the queue disciplines'
    /// quota gate; `0` is a full stop. Rejected for non-finite or
    /// negative sizes.
    SetQuota {
        /// The tenant capped.
        tenant: TenantId,
        /// The occupied-Size cap.
        size: f64,
    },
    /// Set a tenant's weighted-fair share (how many consecutive
    /// admissions its turn is worth under the `WeightedFair` discipline).
    /// Rejected for weight 0.
    SetWeight {
        /// The tenant whose share changes.
        tenant: TenantId,
        /// The new share (≥ 1).
        weight: u32,
    },
}

/// An observable scheduler state change. Every event carries the minute
/// it happened at; `Finished`/`Cancelled` carry the job's full final
/// [`JobRecord`] so subscribers (metrics sinks, logs) need no access to
/// the job table.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerEvent {
    /// A job's arrival was processed (it entered a queue).
    Submitted {
        /// Minute of the event.
        at: Minutes,
        /// The job submitted.
        job: JobId,
        /// Its class at submission.
        class: JobClass,
    },
    /// A job started running for the first time.
    Started {
        /// Minute of the event.
        at: Minutes,
        /// The job placed.
        job: JobId,
        /// The node hosting it.
        node: NodeId,
    },
    /// A previously interrupted (preempted or evicted) job restarted.
    Resumed {
        /// Minute of the event.
        at: Minutes,
        /// The job placed again.
        job: JobId,
        /// The node hosting it.
        node: NodeId,
    },
    /// A job received the preemption signal (its grace period begins).
    Preempted {
        /// Minute of the event.
        at: Minutes,
        /// The signalled victim.
        job: JobId,
    },
    /// A draining job's grace period elapsed and it released its node
    /// (re-queued at the top).
    Vacated {
        /// Minute of the event.
        at: Minutes,
        /// The job that vacated.
        job: JobId,
    },
    /// A job completed.
    Finished {
        /// Minute of the event.
        at: Minutes,
        /// The completed job.
        job: JobId,
        /// Its final record.
        record: JobRecord,
    },
    /// A job was cancelled by the control plane.
    Cancelled {
        /// Minute of the event.
        at: Minutes,
        /// The cancelled job.
        job: JobId,
        /// Its final record (`finished_at` is `None`, `cancelled` is set).
        record: JobRecord,
    },
    /// A job's class changed.
    Reclassified {
        /// Minute of the event.
        at: Minutes,
        /// The reclassified job.
        job: JobId,
        /// Its new class.
        class: JobClass,
    },
    /// A node failed; `lost` lists the jobs evicted with it (allocation
    /// order), each re-queued at the top of its lane.
    NodeLost {
        /// Minute of the event.
        at: Minutes,
        /// The failed node.
        node: NodeId,
        /// Jobs evicted with the node.
        lost: Vec<JobId>,
    },
    /// A node returned to service.
    NodeRestored {
        /// Minute of the event.
        at: Minutes,
        /// The restored node.
        node: NodeId,
    },
    /// A node began draining for maintenance.
    NodeDraining {
        /// Minute of the event.
        at: Minutes,
        /// The draining node.
        node: NodeId,
    },
    /// A node's capacity changed.
    NodeResized {
        /// Minute of the event.
        at: Minutes,
        /// The resized node.
        node: NodeId,
        /// Its new capacity.
        capacity: ResourceVec,
    },
    /// A tenant's occupied-Size quota changed.
    QuotaChanged {
        /// Minute of the event.
        at: Minutes,
        /// The tenant capped.
        tenant: TenantId,
        /// Its new occupied-Size cap.
        size: f64,
    },
    /// A tenant's weighted-fair share changed.
    WeightChanged {
        /// Minute of the event.
        at: Minutes,
        /// The tenant whose share changed.
        tenant: TenantId,
        /// Its new share.
        weight: u32,
    },
    /// A queued job was newly skipped by quota gating (one event per
    /// transition into the skipped state, not per round — the stream is
    /// identical under both simulator drive modes).
    AdmissionSkipped {
        /// Minute of the event.
        at: Minutes,
        /// The skipped job.
        job: JobId,
        /// Its over-quota tenant.
        tenant: TenantId,
    },
    /// A command could not be applied; the run continues.
    CommandRejected {
        /// Minute of the event.
        at: Minutes,
        /// Why the command was declined.
        reason: String,
    },
}

impl SchedulerEvent {
    /// The minute this event occurred at.
    pub fn at(&self) -> Minutes {
        match self {
            SchedulerEvent::Submitted { at, .. }
            | SchedulerEvent::Started { at, .. }
            | SchedulerEvent::Resumed { at, .. }
            | SchedulerEvent::Preempted { at, .. }
            | SchedulerEvent::Vacated { at, .. }
            | SchedulerEvent::Finished { at, .. }
            | SchedulerEvent::Cancelled { at, .. }
            | SchedulerEvent::Reclassified { at, .. }
            | SchedulerEvent::NodeLost { at, .. }
            | SchedulerEvent::NodeRestored { at, .. }
            | SchedulerEvent::NodeDraining { at, .. }
            | SchedulerEvent::NodeResized { at, .. }
            | SchedulerEvent::QuotaChanged { at, .. }
            | SchedulerEvent::WeightChanged { at, .. }
            | SchedulerEvent::AdmissionSkipped { at, .. }
            | SchedulerEvent::CommandRejected { at, .. } => *at,
        }
    }

    /// Snake-case discriminant (the `"type"` field of the JSONL form).
    pub fn kind(&self) -> &'static str {
        match self {
            SchedulerEvent::Submitted { .. } => "submitted",
            SchedulerEvent::Started { .. } => "started",
            SchedulerEvent::Resumed { .. } => "resumed",
            SchedulerEvent::Preempted { .. } => "preempted",
            SchedulerEvent::Vacated { .. } => "vacated",
            SchedulerEvent::Finished { .. } => "finished",
            SchedulerEvent::Cancelled { .. } => "cancelled",
            SchedulerEvent::Reclassified { .. } => "reclassified",
            SchedulerEvent::NodeLost { .. } => "node_lost",
            SchedulerEvent::NodeRestored { .. } => "node_restored",
            SchedulerEvent::NodeDraining { .. } => "node_draining",
            SchedulerEvent::NodeResized { .. } => "node_resized",
            SchedulerEvent::QuotaChanged { .. } => "quota_changed",
            SchedulerEvent::WeightChanged { .. } => "weight_changed",
            SchedulerEvent::AdmissionSkipped { .. } => "admission_skipped",
            SchedulerEvent::CommandRejected { .. } => "command_rejected",
        }
    }

    /// The job this event concerns, when it concerns exactly one.
    pub fn job(&self) -> Option<JobId> {
        match self {
            SchedulerEvent::Submitted { job, .. }
            | SchedulerEvent::Started { job, .. }
            | SchedulerEvent::Resumed { job, .. }
            | SchedulerEvent::Preempted { job, .. }
            | SchedulerEvent::Vacated { job, .. }
            | SchedulerEvent::Finished { job, .. }
            | SchedulerEvent::Cancelled { job, .. }
            | SchedulerEvent::Reclassified { job, .. }
            | SchedulerEvent::AdmissionSkipped { job, .. } => Some(*job),
            _ => None,
        }
    }

    /// One deterministic JSON object per event (keys sorted; the JSONL
    /// log is one such object per line).
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("type", Json::str(self.kind())),
            ("at", Json::num(self.at() as f64)),
        ];
        match self {
            SchedulerEvent::Submitted { job, class, .. } => {
                fields.push(("job", Json::num(job.0 as f64)));
                fields.push(("class", Json::str(class.as_str())));
            }
            SchedulerEvent::Started { job, node, .. }
            | SchedulerEvent::Resumed { job, node, .. } => {
                fields.push(("job", Json::num(job.0 as f64)));
                fields.push(("node", Json::num(node.0 as f64)));
            }
            SchedulerEvent::Preempted { job, .. } | SchedulerEvent::Vacated { job, .. } => {
                fields.push(("job", Json::num(job.0 as f64)));
            }
            SchedulerEvent::Finished { job, record, .. }
            | SchedulerEvent::Cancelled { job, record, .. } => {
                fields.push(("job", Json::num(job.0 as f64)));
                fields.push(("tenant", Json::num(record.tenant.0 as f64)));
                fields.push(("class", Json::str(record.class.as_str())));
                fields.push(("preemptions", Json::num(record.preemptions as f64)));
                fields.push(("evictions", Json::num(record.evictions as f64)));
                if let Some(fin) = record.finished_at {
                    fields.push(("slowdown", Json::num(record.slowdown)));
                    fields.push(("finished_at", Json::num(fin as f64)));
                }
            }
            SchedulerEvent::Reclassified { job, class, .. } => {
                fields.push(("job", Json::num(job.0 as f64)));
                fields.push(("class", Json::str(class.as_str())));
            }
            SchedulerEvent::NodeLost { node, lost, .. } => {
                fields.push(("node", Json::num(node.0 as f64)));
                fields.push((
                    "lost",
                    Json::arr(lost.iter().map(|j| Json::num(j.0 as f64))),
                ));
            }
            SchedulerEvent::NodeRestored { node, .. }
            | SchedulerEvent::NodeDraining { node, .. } => {
                fields.push(("node", Json::num(node.0 as f64)));
            }
            SchedulerEvent::NodeResized { node, capacity, .. } => {
                fields.push(("node", Json::num(node.0 as f64)));
                fields.push(("cpu", Json::num(capacity.cpu)));
                fields.push(("ram_gb", Json::num(capacity.ram_gb)));
                fields.push(("gpu", Json::num(capacity.gpu)));
            }
            SchedulerEvent::QuotaChanged { tenant, size, .. } => {
                fields.push(("tenant", Json::num(tenant.0 as f64)));
                fields.push(("size", Json::num(*size)));
            }
            SchedulerEvent::WeightChanged { tenant, weight, .. } => {
                fields.push(("tenant", Json::num(tenant.0 as f64)));
                fields.push(("weight", Json::num(*weight as f64)));
            }
            SchedulerEvent::AdmissionSkipped { job, tenant, .. } => {
                fields.push(("job", Json::num(job.0 as f64)));
                fields.push(("tenant", Json::num(tenant.0 as f64)));
            }
            SchedulerEvent::CommandRejected { reason, .. } => {
                fields.push(("reason", Json::str(reason)));
            }
        }
        Json::obj(fields)
    }
}

/// The canonical one-line JSONL form of an event: the deterministic
/// sorted-key JSON object, no trailing newline. Shared by
/// [`JsonlEventLog`] and the wire protocol's event fan-out
/// ([`crate::serve`]), so a byte comparison between a logged run and a
/// served run's event stream is meaningful.
pub fn event_jsonl_line(ev: &SchedulerEvent) -> String {
    ev.to_json().to_string()
}

/// Direct single-pass JSONL encoder: serializes each event straight into
/// a reusable scratch buffer, skipping the [`Json`] value tree (and its
/// `BTreeMap` + per-node `String` allocations) entirely. Steady state is
/// allocation-free — the scratch grows to the longest line seen and is
/// reused thereafter (`rust/benches/serve.rs` pins 0 allocs/op).
///
/// The output is byte-identical to [`event_jsonl_line`] for every
/// variant: keys are emitted in the sorted order the `BTreeMap` would
/// produce, and numbers/strings go through the exact same
/// [`crate::util::json`] formatting routines. `rust/tests/control_events.rs`
/// sweeps every constructor against the value-tree form, and the golden
/// scenario log pins the serve fan-out + [`JsonlEventLog`] output.
#[derive(Default)]
pub struct JsonLineEncoder {
    buf: String,
}

impl JsonLineEncoder {
    /// A fresh encoder with a line-sized scratch buffer.
    pub fn new() -> Self {
        JsonLineEncoder { buf: String::with_capacity(256) }
    }

    /// Encode one event; the returned line (no trailing newline) is valid
    /// until the next call.
    pub fn event(&mut self, ev: &SchedulerEvent) -> &str {
        use crate::util::json::{write_escaped as esc, write_num as num};
        self.buf.clear();
        let b = &mut self.buf;
        b.push_str("{\"at\":");
        num(b, ev.at() as f64);
        match ev {
            SchedulerEvent::Submitted { job, class, .. } => {
                b.push_str(",\"class\":");
                esc(b, class.as_str());
                b.push_str(",\"job\":");
                num(b, job.0 as f64);
                b.push_str(",\"type\":\"submitted\"}");
            }
            SchedulerEvent::Started { job, node, .. }
            | SchedulerEvent::Resumed { job, node, .. } => {
                b.push_str(",\"job\":");
                num(b, job.0 as f64);
                b.push_str(",\"node\":");
                num(b, node.0 as f64);
                b.push_str(",\"type\":");
                esc(b, ev.kind());
                b.push('}');
            }
            SchedulerEvent::Preempted { job, .. } | SchedulerEvent::Vacated { job, .. } => {
                b.push_str(",\"job\":");
                num(b, job.0 as f64);
                b.push_str(",\"type\":");
                esc(b, ev.kind());
                b.push('}');
            }
            SchedulerEvent::Finished { job, record, .. }
            | SchedulerEvent::Cancelled { job, record, .. } => {
                b.push_str(",\"class\":");
                esc(b, record.class.as_str());
                b.push_str(",\"evictions\":");
                num(b, record.evictions as f64);
                if let Some(fin) = record.finished_at {
                    b.push_str(",\"finished_at\":");
                    num(b, fin as f64);
                }
                b.push_str(",\"job\":");
                num(b, job.0 as f64);
                b.push_str(",\"preemptions\":");
                num(b, record.preemptions as f64);
                if record.finished_at.is_some() {
                    b.push_str(",\"slowdown\":");
                    num(b, record.slowdown);
                }
                b.push_str(",\"tenant\":");
                num(b, record.tenant.0 as f64);
                b.push_str(",\"type\":");
                esc(b, ev.kind());
                b.push('}');
            }
            SchedulerEvent::Reclassified { job, class, .. } => {
                b.push_str(",\"class\":");
                esc(b, class.as_str());
                b.push_str(",\"job\":");
                num(b, job.0 as f64);
                b.push_str(",\"type\":\"reclassified\"}");
            }
            SchedulerEvent::NodeLost { node, lost, .. } => {
                b.push_str(",\"lost\":[");
                for (i, j) in lost.iter().enumerate() {
                    if i > 0 {
                        b.push(',');
                    }
                    num(b, j.0 as f64);
                }
                b.push_str("],\"node\":");
                num(b, node.0 as f64);
                b.push_str(",\"type\":\"node_lost\"}");
            }
            SchedulerEvent::NodeRestored { node, .. }
            | SchedulerEvent::NodeDraining { node, .. } => {
                b.push_str(",\"node\":");
                num(b, node.0 as f64);
                b.push_str(",\"type\":");
                esc(b, ev.kind());
                b.push('}');
            }
            SchedulerEvent::NodeResized { node, capacity, .. } => {
                b.push_str(",\"cpu\":");
                num(b, capacity.cpu);
                b.push_str(",\"gpu\":");
                num(b, capacity.gpu);
                b.push_str(",\"node\":");
                num(b, node.0 as f64);
                b.push_str(",\"ram_gb\":");
                num(b, capacity.ram_gb);
                b.push_str(",\"type\":\"node_resized\"}");
            }
            SchedulerEvent::QuotaChanged { tenant, size, .. } => {
                b.push_str(",\"size\":");
                num(b, *size);
                b.push_str(",\"tenant\":");
                num(b, tenant.0 as f64);
                b.push_str(",\"type\":\"quota_changed\"}");
            }
            SchedulerEvent::WeightChanged { tenant, weight, .. } => {
                b.push_str(",\"tenant\":");
                num(b, tenant.0 as f64);
                b.push_str(",\"type\":\"weight_changed\",\"weight\":");
                num(b, *weight as f64);
                b.push('}');
            }
            SchedulerEvent::AdmissionSkipped { job, tenant, .. } => {
                b.push_str(",\"job\":");
                num(b, job.0 as f64);
                b.push_str(",\"tenant\":");
                num(b, tenant.0 as f64);
                b.push_str(",\"type\":\"admission_skipped\"}");
            }
            SchedulerEvent::CommandRejected { reason, .. } => {
                b.push_str(",\"reason\":");
                esc(b, reason);
                b.push_str(",\"type\":\"command_rejected\"}");
            }
        }
        &self.buf
    }
}

/// A consumer of the scheduler's event stream. Subscribers observe; they
/// never mutate scheduler state, and they must be deterministic given the
/// event sequence (the sequence itself is deterministic per
/// `(source, config, scenario, seed)`).
pub trait EventSubscriber {
    /// Deliver one event. Called in emission order, synchronously, within
    /// the scheduling round the event belongs to.
    fn on_event(&mut self, ev: &SchedulerEvent);
}

/// The metrics sink is the canonical first subscriber: retiring jobs fold
/// into it exactly as the pre-protocol simulator did, so scenario-free
/// runs stay byte-identical.
impl EventSubscriber for StreamingMetrics {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        match ev {
            SchedulerEvent::Finished { record, .. } => self.observe(record),
            SchedulerEvent::Cancelled { record, .. } => self.observe_cancelled(record),
            _ => {}
        }
    }
}

/// A subscriber serializing each event as one JSON line. The output is
/// fully deterministic (sorted keys, normalized in-step order), which is
/// what lets a golden file pin a whole scenario run.
///
/// Write failures do not abort the run: logging stops at the first error,
/// which is recorded in a cloneable [`JsonlErrorFlag`] — take one with
/// [`error_flag`](JsonlEventLog::error_flag) *before* boxing the log, so
/// the caller can still fail loudly after the run instead of shipping a
/// silently truncated log. Dropping the log flushes the writer and
/// records any flush error in the same flag.
pub struct JsonlEventLog<W: Write> {
    w: W,
    enc: JsonLineEncoder,
    lines: u64,
    error: JsonlErrorFlag,
}

/// Which event-log operation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventLogOp {
    /// Writing one event line.
    Write,
    /// Flushing buffered lines (at drop).
    Flush,
}

impl fmt::Display for EventLogOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EventLogOp::Write => "write",
            EventLogOp::Flush => "flush",
        })
    }
}

/// A typed event-log failure: which operation failed, how many complete
/// lines made it out first, and the underlying I/O message. The same type
/// reports wire-serializer write failures in [`crate::serve`], so a
/// truncated log and a dropped connection surface identically.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EventLogError {
    /// The failed operation.
    pub op: EventLogOp,
    /// Complete lines written before the failure.
    pub lines: u64,
    /// The underlying I/O error message.
    pub message: String,
}

impl fmt::Display for EventLogError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "event log {} failed after {} lines: {}",
            self.op, self.lines, self.message
        )
    }
}

impl std::error::Error for EventLogError {}

/// Cloneable observer of a [`JsonlEventLog`]'s first write/flush error,
/// readable after the log itself has been boxed into a controller and
/// dropped.
#[derive(Clone, Default)]
pub struct JsonlErrorFlag(Arc<Mutex<Option<EventLogError>>>);

impl JsonlErrorFlag {
    /// The first recorded error, if any.
    pub fn get(&self) -> Option<EventLogError> {
        self.0.lock().unwrap().clone()
    }

    fn set(&self, err: EventLogError) {
        let mut slot = self.0.lock().unwrap();
        if slot.is_none() {
            *slot = Some(err);
        }
    }
}

impl<W: Write> JsonlEventLog<W> {
    /// Log into `w` (a file, a [`SharedBuf`], any writer).
    pub fn new(w: W) -> Self {
        JsonlEventLog {
            w,
            enc: JsonLineEncoder::new(),
            lines: 0,
            error: JsonlErrorFlag::default(),
        }
    }

    /// Lines written so far.
    pub fn lines(&self) -> u64 {
        self.lines
    }

    /// The first write error, if any (logging stops at the first failure;
    /// the run itself continues).
    pub fn error(&self) -> Option<EventLogError> {
        self.error.get()
    }

    /// A cloneable handle to this log's error slot (see the type docs).
    pub fn error_flag(&self) -> JsonlErrorFlag {
        self.error.clone()
    }
}

impl<W: Write> EventSubscriber for JsonlEventLog<W> {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        if self.error.get().is_some() {
            return;
        }
        // Direct encode into the reused scratch — same bytes as
        // `event_jsonl_line`, none of its per-event value tree.
        let line = self.enc.event(ev);
        let io = self
            .w
            .write_all(line.as_bytes())
            .and_then(|()| self.w.write_all(b"\n"));
        match io {
            Ok(()) => self.lines += 1,
            Err(e) => self.error.set(EventLogError {
                op: EventLogOp::Write,
                lines: self.lines,
                message: e.to_string(),
            }),
        }
    }
}

impl<W: Write> Drop for JsonlEventLog<W> {
    fn drop(&mut self) {
        // Surface buffered-writer flush failures (a BufWriter's own Drop
        // would swallow them).
        if let Err(e) = self.w.flush() {
            self.error.set(EventLogError {
                op: EventLogOp::Flush,
                lines: self.lines,
                message: e.to_string(),
            });
        }
    }
}

/// An in-memory, handle-cloneable event collector: attach one clone as a
/// subscriber, keep the other to read the events back after the run
/// (tests, the live report).
#[derive(Clone, Default)]
pub struct SharedEventLog(Arc<Mutex<Vec<SchedulerEvent>>>);

impl SharedEventLog {
    /// An empty log.
    pub fn new() -> Self {
        SharedEventLog::default()
    }

    /// Snapshot of all events observed so far.
    pub fn events(&self) -> Vec<SchedulerEvent> {
        self.0.lock().unwrap().clone()
    }

    /// Number of events observed so far.
    pub fn len(&self) -> usize {
        self.0.lock().unwrap().len()
    }

    /// True when nothing has been observed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl EventSubscriber for SharedEventLog {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        self.0.lock().unwrap().push(ev.clone());
    }
}

/// A handle-cloneable in-memory byte sink implementing [`Write`] — pair it
/// with [`JsonlEventLog`] to capture the JSONL text of a run (golden
/// tests, diagnostics).
#[derive(Clone, Default)]
pub struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl SharedBuf {
    /// An empty buffer.
    pub fn new() -> Self {
        SharedBuf::default()
    }

    /// The buffered bytes as UTF-8 text.
    pub fn contents(&self) -> String {
        String::from_utf8_lossy(&self.0.lock().unwrap()).into_owned()
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// What one scheduling round produced, in protocol terms. `finished` and
/// `cancelled` carry final records (the jobs are already retired from the
/// table); the driver forwards both to its
/// [`ArrivalSource`](crate::workload::source::ArrivalSource) so
/// closed-loop users schedule their next trial after kills exactly as
/// after completions.
#[derive(Debug, Default)]
pub struct StepOutcome {
    /// Arrivals whose submission was processed this round (id order within
    /// the minute).
    pub arrivals: Vec<JobId>,
    /// The raw per-tick outcome from the scheduler core.
    pub tick: TickStats,
    /// Jobs that completed this round, retired, in completion order.
    pub finished: Vec<JobRecord>,
    /// Jobs cancelled by commands applied since the previous round.
    pub cancelled: Vec<JobRecord>,
}

/// The control-plane facade: owns the [`Scheduler`] and the resident
/// [`JobTable`], consumes [`SchedulerCommand`]s, and emits
/// [`SchedulerEvent`]s to the built-in metrics sink and every attached
/// subscriber. See the module docs for the protocol.
pub struct ClusterController {
    /// The scheduler under control (public: drivers read clock/queue state
    /// directly — e.g. the event-horizon engine's burn-target peeks).
    pub sched: Scheduler,
    /// Resident jobs (queued + active + staged arrivals inside the
    /// lookahead window).
    pub jobs: JobTable,
    metrics: StreamingMetrics,
    subs: Vec<Box<dyn EventSubscriber>>,
    cancelled_buf: Vec<JobRecord>,
}

impl ClusterController {
    /// Build a controller for `spec` under `cfg`. The scheduler's runtime
    /// estimator is subscribed to the event stream here, so every
    /// `Finished` record feeds it — identically under both engines.
    pub fn new(spec: &ClusterSpec, cfg: SchedConfig) -> Self {
        let sched = Scheduler::new(spec, cfg);
        let estimator = sched.estimator();
        ClusterController {
            sched,
            jobs: JobTable::new(),
            metrics: StreamingMetrics::new(),
            subs: vec![Box::new(estimator)],
            cancelled_buf: Vec::new(),
        }
    }

    /// Attach a subscriber; it receives every event emitted from now on.
    pub fn subscribe(&mut self, sub: Box<dyn EventSubscriber>) {
        self.subs.push(sub);
    }

    /// The built-in metrics sink (read-only view).
    pub fn metrics(&self) -> &StreamingMetrics {
        &self.metrics
    }

    /// Make a job known: insert it into the table and stage its arrival on
    /// the clock. The `Submitted` event fires when the arrival is
    /// *processed* (at `spec.submit`), not here — staging is driver
    /// plumbing (lookahead pulls), not an observable scheduling act.
    pub fn stage_arrival(&mut self, spec: JobSpec) {
        self.sched.clock.push_arrival(spec.submit, spec.id);
        self.jobs.insert(Job::new(spec));
    }

    /// Apply one command between scheduling rounds. Invalid commands emit
    /// [`SchedulerEvent::CommandRejected`] and change nothing.
    pub fn command(&mut self, now: Minutes, cmd: SchedulerCommand) {
        match cmd {
            SchedulerCommand::Submit(spec) => {
                if spec.submit < now {
                    self.reject(now, format!("submit {}: submit minute is in the past", spec.id));
                } else if self.jobs.seen(spec.id) {
                    // `seen`, not `contains`: a retired id must be rejected
                    // too — job ids are never reused, and the slab's
                    // RETIRED sentinel would (rightly) refuse the insert.
                    self.reject(now, format!("submit {}: id already used", spec.id));
                } else {
                    self.stage_arrival(spec);
                }
            }
            SchedulerCommand::Cancel { job } => {
                if !self.sched.discard(job, &mut self.jobs) {
                    self.reject(now, format!("cancel {job}: not under scheduler management"));
                    return;
                }
                self.jobs[job].cancel(now);
                let rec = JobRecord::from_job(&self.jobs.remove(job));
                let ev = SchedulerEvent::Cancelled { at: now, job, record: rec };
                self.emit(&ev);
                let SchedulerEvent::Cancelled { record, .. } = ev else {
                    unreachable!()
                };
                self.cancelled_buf.push(record);
            }
            SchedulerCommand::Reclassify { job, class } => {
                match self.sched.reclassify(job, class, &mut self.jobs) {
                    // Valid no-op (already that class): nothing changed, so
                    // nothing is emitted — the event stream stays truthful.
                    Ok(changed) => {
                        if changed {
                            self.emit(&SchedulerEvent::Reclassified { at: now, job, class });
                        }
                    }
                    Err(e) => self.reject(now, format!("reclassify {job}: {e}")),
                }
            }
            SchedulerCommand::NodeDown { node } => {
                let Some(availability) = self.availability(node) else {
                    self.reject(now, format!("node_down: {node} does not exist"));
                    return;
                };
                if availability == NodeAvailability::Down {
                    self.reject(now, format!("node_down: {node} is already down"));
                    return;
                }
                let lost = self.sched.fail_node(node, now, &mut self.jobs);
                self.emit(&SchedulerEvent::NodeLost { at: now, node, lost });
            }
            SchedulerCommand::NodeUp { node } => {
                let Some(availability) = self.availability(node) else {
                    self.reject(now, format!("node_up: {node} does not exist"));
                    return;
                };
                if availability == NodeAvailability::Up {
                    self.reject(now, format!("node_up: {node} is already up"));
                    return;
                }
                self.sched.restore_node(node, &self.jobs);
                self.emit(&SchedulerEvent::NodeRestored { at: now, node });
            }
            SchedulerCommand::Drain { node } => {
                let Some(availability) = self.availability(node) else {
                    self.reject(now, format!("drain: {node} does not exist"));
                    return;
                };
                if availability != NodeAvailability::Up {
                    self.reject(now, format!("drain: {node} is not up"));
                    return;
                }
                self.sched.drain_node(node);
                self.emit(&SchedulerEvent::NodeDraining { at: now, node });
            }
            SchedulerCommand::Resize { node, capacity } => {
                if self.availability(node).is_none() {
                    self.reject(now, format!("resize: {node} does not exist"));
                    return;
                }
                match self.sched.resize_node(node, capacity, &self.jobs) {
                    Ok(()) => self.emit(&SchedulerEvent::NodeResized { at: now, node, capacity }),
                    Err(e) => self.reject(now, format!("resize: {e}")),
                }
            }
            SchedulerCommand::SetQuota { tenant, size } => {
                if !size.is_finite() || size < 0.0 {
                    self.reject(
                        now,
                        format!("set_quota {tenant}: size must be a finite non-negative number"),
                    );
                    return;
                }
                self.sched.set_quota(tenant, size);
                self.emit(&SchedulerEvent::QuotaChanged { at: now, tenant, size });
            }
            SchedulerCommand::SetWeight { tenant, weight } => {
                if weight == 0 {
                    self.reject(now, format!("set_weight {tenant}: weight must be at least 1"));
                    return;
                }
                self.sched.set_weight(tenant, weight);
                self.emit(&SchedulerEvent::WeightChanged { at: now, tenant, weight });
            }
        }
    }

    /// One scheduling round: pop due arrivals, emit their `Submitted`
    /// events, run [`Scheduler::tick`], emit the round's events in
    /// normalized order, retire completed jobs into records, and hand back
    /// any cancellations applied since the previous round.
    pub fn step(&mut self, now: Minutes) -> StepOutcome {
        let mut arrivals = Vec::new();
        while let Some(id) = self.sched.clock.pop_arrival_due(now) {
            arrivals.push(id);
        }
        for id in &arrivals {
            let class = self.jobs[*id].spec.class;
            self.emit(&SchedulerEvent::Submitted { at: now, job: *id, class });
        }

        let tick = self.sched.tick(now, &mut self.jobs, &arrivals);

        let mut finished = Vec::with_capacity(tick.completed.len());
        for id in &tick.completed {
            let job = self.jobs.remove(*id);
            let ev = SchedulerEvent::Finished {
                at: now,
                job: *id,
                record: JobRecord::from_job(&job),
            };
            self.emit(&ev);
            // Recover the record rather than cloning one per job — this is
            // the million-job streaming hot path.
            let SchedulerEvent::Finished { record, .. } = ev else {
                unreachable!()
            };
            finished.push(record);
        }
        for id in &tick.preempted {
            self.emit(&SchedulerEvent::Preempted { at: now, job: *id });
        }
        for id in &tick.vacated {
            self.emit(&SchedulerEvent::Vacated { at: now, job: *id });
        }
        for id in &tick.started {
            let (node, first_start) = {
                let j = &self.jobs[*id];
                (j.node.expect("started job has a node"), j.first_start)
            };
            let ev = if first_start == Some(now) {
                SchedulerEvent::Started { at: now, job: *id, node }
            } else {
                SchedulerEvent::Resumed { at: now, job: *id, node }
            };
            self.emit(&ev);
        }
        for (id, tenant) in &tick.skipped {
            self.emit(&SchedulerEvent::AdmissionSkipped { at: now, job: *id, tenant: *tenant });
        }

        StepOutcome {
            arrivals,
            tick,
            finished,
            cancelled: std::mem::take(&mut self.cancelled_buf),
        }
    }

    /// All work done and nothing queued?
    pub fn idle(&self) -> bool {
        self.sched.idle()
    }

    /// Forwarded [`Scheduler::quiescent`] on the owned table.
    pub fn quiescent(&self) -> bool {
        self.sched.quiescent(&self.jobs)
    }

    /// Forwarded [`Scheduler::next_internal_at`] on the owned table.
    pub fn next_internal_at(&mut self) -> Option<Minutes> {
        self.sched.clock.next_internal_at(&self.jobs)
    }

    /// Bulk-burn a quiescent span (the event-horizon engine's fast path).
    pub fn burn_many(&mut self, dt: Minutes) {
        self.sched.burn_many(dt);
    }

    /// Tear down into the pieces result assembly needs.
    pub fn into_parts(self) -> (Scheduler, JobTable, StreamingMetrics) {
        (self.sched, self.jobs, self.metrics)
    }

    /// Serialize the controller's full state — job table, scheduler,
    /// metrics sink — for a snapshot. Must be taken at a round boundary
    /// (between `step` calls): cancellations applied since the previous
    /// round are handed back by `step`, so the pending buffer is empty
    /// there by construction.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        debug_assert!(
            self.cancelled_buf.is_empty(),
            "controller snapshot must be taken at a round boundary"
        );
        self.jobs.snapshot_bin(w);
        self.sched.snapshot_bin(w);
        self.metrics.snapshot_bin(w);
    }

    /// Restore state written by [`ClusterController::snapshot_bin`] into a
    /// controller freshly built from the same spec and config. Attached
    /// subscribers are kept as-is (the caller re-attaches its own); the
    /// estimator subscription installed by [`ClusterController::new`]
    /// observes the restored estimator state through its shared handle.
    pub fn restore_bin(&mut self, r: &mut BinReader) -> anyhow::Result<()> {
        self.jobs = JobTable::restore_bin(r)?;
        self.sched.restore_bin(r, &self.jobs)?;
        self.metrics = StreamingMetrics::restore_bin(r)?;
        self.cancelled_buf.clear();
        Ok(())
    }

    fn availability(&self, node: NodeId) -> Option<NodeAvailability> {
        self.sched
            .cluster
            .nodes
            .get(node.0 as usize)
            .map(|n| n.availability)
    }

    fn reject(&mut self, now: Minutes, reason: String) {
        self.emit(&SchedulerEvent::CommandRejected { at: now, reason });
    }

    /// Broadcast one event: the built-in metrics sink first, then every
    /// attached subscriber. By reference, so the hot retire path can
    /// recover the `Finished`/`Cancelled` record from the event afterwards
    /// instead of cloning one per job.
    fn emit(&mut self, ev: &SchedulerEvent) {
        EventSubscriber::on_event(&mut self.metrics, ev);
        for s in &mut self.subs {
            s.on_event(ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::PolicyKind;

    fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    fn controller(policy: PolicyKind, nodes: usize) -> (ClusterController, SharedEventLog) {
        let mut ctl = ClusterController::new(&ClusterSpec::tiny(nodes), SchedConfig::new(policy));
        ctl.sched.paranoid = true;
        let log = SharedEventLog::new();
        ctl.subscribe(Box::new(log.clone()));
        (ctl, log)
    }

    fn spec(id: u32, class: JobClass, submit: Minutes, exec: Minutes) -> JobSpec {
        JobSpec::new(id, class, rv(4.0, 32.0, 1.0), submit, exec, 0)
    }

    #[test]
    fn submit_start_finish_event_sequence() {
        let (mut ctl, log) = controller(PolicyKind::Fifo, 1);
        ctl.stage_arrival(spec(0, JobClass::Be, 0, 2));
        ctl.step(0);
        ctl.step(1);
        let out = ctl.step(2);
        assert_eq!(out.finished.len(), 1);
        assert!(ctl.idle());
        let kinds: Vec<&str> = log.events().iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, vec!["submitted", "started", "finished"]);
        assert_eq!(ctl.metrics().completed, 1);
    }

    #[test]
    fn cancel_running_job_frees_its_seat() {
        let (mut ctl, log) = controller(PolicyKind::Fifo, 1);
        ctl.stage_arrival(JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 100, 0));
        ctl.stage_arrival(JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 5, 0));
        ctl.step(0);
        // Job 0 hogs the node; kill it and job 1 starts next round.
        ctl.command(1, SchedulerCommand::Cancel { job: JobId(0) });
        let out = ctl.step(1);
        assert_eq!(out.cancelled.len(), 1);
        assert!(out.cancelled[0].cancelled);
        assert_eq!(out.tick.started, vec![JobId(1)]);
        assert_eq!(ctl.metrics().cancelled.be, 1);
        assert_eq!(ctl.metrics().jobs_seen, 0, "cancelled jobs stay out of the stats pool");
        assert!(log.events().iter().any(|e| e.kind() == "cancelled"));
        // The record is excluded from slowdown percentiles by construction:
        // no finished_at.
        assert!(out.cancelled[0].finished_at.is_none());
    }

    #[test]
    fn cancel_unknown_job_is_rejected_not_fatal() {
        let (mut ctl, log) = controller(PolicyKind::Fifo, 1);
        ctl.command(0, SchedulerCommand::Cancel { job: JobId(9) });
        assert_eq!(log.events().len(), 1);
        assert_eq!(log.events()[0].kind(), "command_rejected");
        ctl.step(0);
        assert!(ctl.idle());
    }

    #[test]
    fn node_down_emits_lost_jobs_and_up_restores() {
        let (mut ctl, log) = controller(PolicyKind::Fifo, 2);
        ctl.stage_arrival(spec(0, JobClass::Be, 0, 50));
        ctl.step(0);
        let host = ctl.jobs[JobId(0)].node.unwrap();
        ctl.command(1, SchedulerCommand::NodeDown { node: host });
        let out = ctl.step(1);
        // The evicted job restarts immediately on the surviving node.
        assert_eq!(out.tick.started, vec![JobId(0)]);
        let evs = log.events();
        let lost = evs.iter().find(|e| e.kind() == "node_lost").unwrap();
        match lost {
            SchedulerEvent::NodeLost { lost, .. } => assert_eq!(lost, &vec![JobId(0)]),
            _ => unreachable!(),
        }
        let resumed = evs
            .iter()
            .any(|e| matches!(e, SchedulerEvent::Resumed { job, .. } if *job == JobId(0)));
        assert!(resumed, "an eviction restart is a resume, not a first start");
        // Double-down is rejected; up restores.
        ctl.command(2, SchedulerCommand::NodeDown { node: host });
        assert!(log.events().iter().any(|e| e.kind() == "command_rejected"));
        ctl.command(2, SchedulerCommand::NodeUp { node: host });
        assert!(log.events().iter().any(|e| e.kind() == "node_restored"));
    }

    #[test]
    fn resize_rejects_below_use_and_applies_otherwise() {
        let (mut ctl, log) = controller(PolicyKind::Fifo, 1);
        ctl.stage_arrival(JobSpec::new(0, JobClass::Be, rv(16.0, 128.0, 4.0), 0, 50, 0));
        ctl.step(0);
        ctl.command(1, SchedulerCommand::Resize { node: NodeId(0), capacity: rv(8.0, 64.0, 2.0) });
        assert_eq!(log.events().last().unwrap().kind(), "command_rejected");
        let bigger = rv(64.0, 512.0, 16.0);
        ctl.command(1, SchedulerCommand::Resize { node: NodeId(0), capacity: bigger });
        assert_eq!(log.events().last().unwrap().kind(), "node_resized");
        ctl.step(1);
    }

    #[test]
    fn quota_and_weight_commands_emit_events_and_gate_admission() {
        use crate::sched::admission::DisciplineKind;
        let mut cfg = SchedConfig::new(PolicyKind::Fifo);
        cfg.discipline = DisciplineKind::WeightedFair;
        let mut ctl = ClusterController::new(&ClusterSpec::tiny(1), cfg);
        ctl.sched.paranoid = true;
        let log = SharedEventLog::new();
        ctl.subscribe(Box::new(log.clone()));

        // Full-stop quota on tenant 1 before its job arrives; a weight
        // change for good measure; and two invalid forms.
        ctl.command(0, SchedulerCommand::SetQuota { tenant: TenantId(1), size: 0.0 });
        ctl.command(0, SchedulerCommand::SetWeight { tenant: TenantId(0), weight: 2 });
        ctl.command(0, SchedulerCommand::SetQuota { tenant: TenantId(1), size: -1.0 });
        ctl.command(0, SchedulerCommand::SetWeight { tenant: TenantId(1), weight: 0 });

        ctl.stage_arrival(spec(0, JobClass::Be, 0, 2).with_tenant(TenantId(0)));
        ctl.stage_arrival(spec(1, JobClass::Be, 0, 2).with_tenant(TenantId(1)));
        let out = ctl.step(0);
        assert_eq!(out.tick.started, vec![JobId(0)], "tenant 0 runs");
        assert_eq!(out.tick.skipped, vec![(JobId(1), TenantId(1))], "tenant 1 gated");
        let kinds: Vec<&str> = log.events().iter().map(|e| e.kind()).collect();
        assert!(kinds.contains(&"quota_changed"));
        assert!(kinds.contains(&"weight_changed"));
        assert!(kinds.contains(&"admission_skipped"));
        assert_eq!(kinds.iter().filter(|k| **k == "command_rejected").count(), 2);

        // Steady-state skips are not re-reported (fresh transitions only).
        let before = log.events().len();
        ctl.step(1);
        let re_skips = log.events()[before..]
            .iter()
            .filter(|e| e.kind() == "admission_skipped")
            .count();
        assert_eq!(re_skips, 0, "a head that stays gated is reported once");

        // Lifting the quota admits the gated job.
        ctl.command(2, SchedulerCommand::SetQuota { tenant: TenantId(1), size: 100.0 });
        let out = ctl.step(2);
        assert_eq!(out.tick.started, vec![JobId(1)]);
        let ev = log
            .events()
            .iter()
            .find(|e| e.kind() == "admission_skipped")
            .unwrap()
            .to_json()
            .to_string();
        assert!(ev.contains("\"tenant\":1"), "{ev}");
    }

    #[test]
    fn jsonl_log_is_one_object_per_line() {
        let buf = SharedBuf::new();
        let mut ctl = ClusterController::new(
            &ClusterSpec::tiny(1),
            SchedConfig::new(PolicyKind::Fifo),
        );
        ctl.subscribe(Box::new(JsonlEventLog::new(buf.clone())));
        ctl.stage_arrival(spec(0, JobClass::Te, 0, 1));
        ctl.step(0);
        ctl.step(1);
        let text = buf.contents();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "submitted, started, finished: {text}");
        for line in lines {
            let v = Json::parse(line).expect("every line parses");
            assert!(v.get("type").as_str().is_some());
            assert!(v.get("at").as_u64().is_some());
        }
        assert!(text.contains("\"type\":\"finished\""));
    }

    #[test]
    fn event_json_kinds_are_stable() {
        let ev = SchedulerEvent::NodeLost { at: 3, node: NodeId(1), lost: vec![JobId(2)] };
        assert_eq!(ev.kind(), "node_lost");
        assert_eq!(ev.at(), 3);
        let j = ev.to_json().to_string();
        assert!(j.contains("\"lost\":[2]"), "{j}");
    }
}
