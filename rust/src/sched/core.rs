//! The scheduler core: per-minute FIFO admission with grace-period
//! preemption (§2–3 of the paper).
//!
//! ## Tick semantics (one call = one simulated minute)
//!
//! 1. **Completions** — running jobs whose remaining time reached zero
//!    release their resources.
//! 2. **Grace expirations** — draining jobs whose grace period elapsed
//!    vacate and are re-queued at the *top* of the BE queue
//!    (`PreemptionCount += 1`).
//! 3. **Arrivals** — submitted jobs enter a queue: under preemptive
//!    policies TE jobs enter the TE fast lane (the paper allocates surplus
//!    directly to TE jobs, §2); under vanilla FIFO everything shares one
//!    queue.
//! 4. **Admission** — TE lane first (per-arrival): place if some node
//!    fits; otherwise consult the preemption policy, signal the victims,
//!    and *reserve* the target node's space so the drained resources are
//!    "allocated to the TE job" rather than grabbed by other admissions.
//!    Then one round of the shared/BE queue's [`QueueDiscipline`] (strict
//!    head-gated FIFO by default; no preemption on behalf of this queue).
//!
//! There is no per-minute "burn" step: progress, grace burn-down, and
//! queue waiting are accounted *lazily* (see [`Job::sync`]) — each
//! lifecycle transition settles the whole span since the job's last
//! transition in one arithmetic step, so a tick costs O(due events +
//! admission work), not O(active + queued). The tick's steady-state
//! allocations are likewise zero: candidate lists, the due-event set,
//! effective-free snapshots, and skip sets live in round-scratch buffers
//! on the scheduler and are reused every round (`BENCH_hotpath.json`
//! pins allocs/op = 0 for the steady-state cases).
//!
//! Zero-GP victims vacate synchronously inside the admission step, so a TE
//! job whose victim permits rewinding starts in the same minute.
//!
//! ## Layering
//!
//! The core is deliberately thin; each concern lives one layer down:
//!
//! * **Admission** — *which queued job to try next* is behind the
//!   [`QueueDiscipline`] trait ([`crate::sched::admission`]): the default
//!   [`Fifo`](crate::sched::admission::Fifo) reproduces the paper's
//!   head-only loop byte-for-byte; `WeightedFair` and `QuotaGate` make the
//!   shared queue tenant-aware without touching the policy layer.
//! * **Policy** — *whom to evict* is behind the
//!   [`PreemptionPolicy`] trait, built once per run from the plain-data
//!   [`PolicyKind`](crate::sched::policy::PolicyKind) config.
//! * **Clock** — *when anything happens next* is answered by the
//!   [`EventClock`]: steps 1–2 scan the active set only on minutes where
//!   the clock says a completion/expiry is actually due, and the
//!   event-horizon engine reads [`Scheduler::next_internal_at`] (a heap
//!   peek, not a job-table rescan) to size its bulk burns.
//! * **Cluster** — *where space exists* is answered by the incremental
//!   free-capacity index in [`Cluster`] (updated on bind/unbind/reserve),
//!   so fits-anywhere checks and best-fit search stop scanning every node.

use crate::cluster::{Cluster, ClusterSpec, Node, NodeAvailability, NodeId, Placement};
use crate::job::{Job, JobClass, JobId, JobState, TenantId};
use crate::job_table::JobTable;
use crate::queue::JobQueue;
use crate::resources::{ResourceVec, EPS};
use crate::sched::admission::{
    build_discipline, AdmissionCtx, AdmitOutcome, DisciplineKind, QueueDiscipline,
    TenantDirectory, TenantUsage,
};
use crate::sched::clock::EventClock;
use crate::sched::policy::{build_policy, PlanScratch, PolicyCtx, PolicyKind, PreemptionPolicy};
use crate::sched::predict::{EstimatorKind, SharedEstimator};
use crate::sched::victim_index::VictimIndex;
use crate::stats::rng::Pcg64;
use crate::Minutes;

/// Scheduler configuration (everything §4 varies is here).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Scheduling/preemption policy (plain data; behaviour is built from it
    /// once, at scheduler construction).
    pub policy: PolicyKind,
    /// Admission queue discipline for the shared/BE queue (plain data,
    /// like `policy`). Default [`DisciplineKind::Fifo`] — byte-identical
    /// to the pre-admission-layer scheduler.
    pub discipline: DisciplineKind,
    /// Node-selection rule for placements (paper does not pin one; best-fit
    /// is the default — see the `placement_ablation` bench).
    pub placement: Placement,
    /// Whether a draining job keeps making progress during its grace
    /// period. Default `false` (suspension processing is overhead).
    pub progress_during_grace: bool,
    /// Seed for the policy RNG (RAND victims, FitGpp fallback).
    pub seed: u64,
    /// Occupied-Size quota applied to every tenant with no explicit
    /// `SetQuota` entry (`None` = unlimited, the default).
    pub default_quota: Option<f64>,
    /// Runtime estimator feeding the prediction-aware policies (plain
    /// data, like `policy`). Default [`EstimatorKind::Oracle`] —
    /// byte-identical to the pre-prediction scheduler for every policy
    /// that ignores predictions.
    pub estimator: EstimatorKind,
}

impl SchedConfig {
    /// Paper-default configuration for `policy`.
    pub fn new(policy: PolicyKind) -> Self {
        SchedConfig {
            policy,
            discipline: DisciplineKind::Fifo,
            placement: Placement::BestFit,
            progress_during_grace: false,
            seed: 0x5EED,
            default_quota: None,
            estimator: EstimatorKind::Oracle,
        }
    }
}

/// A reservation pins an incoming TE job to the node whose victims are
/// draining: the drained space is *held* (invisible to other placements)
/// until the TE job starts or finds a seat elsewhere.
#[derive(Debug, Clone)]
pub struct Reservation {
    /// The TE job this reservation belongs to.
    pub te: JobId,
    /// The node whose space is held.
    pub node: NodeId,
    /// Amount held = the TE job's demand.
    pub hold: ResourceVec,
    /// Victims signalled for this reservation (bookkeeping/event log).
    pub victims: Vec<JobId>,
}

/// Aggregate counters across the run.
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Preemption signals issued (one per victim).
    pub preemption_signals: u64,
    /// Plans that used FitGpp's random escape hatch.
    pub fallback_plans: u64,
    /// Preemption plans issued (one per TE trigger).
    pub plans: u64,
    /// Jobs placed.
    pub placements: u64,
    /// Completed jobs.
    pub completions: u64,
    /// TE jobs that found room with no preemption at all.
    pub te_no_preemption: u64,
    /// Simulated minutes advanced (per-minute ticks plus bulk-burned
    /// minutes — always equal to simulated time, whichever engine ran).
    pub ticks: u64,
    /// Reservations dropped and re-planned because the drained space did
    /// not materialize on a single node (aggregate baseline plans).
    pub replans: u64,
    /// Quiescent spans fast-forwarded in bulk ([`Scheduler::burn_many`]
    /// calls — only the event-horizon engine issues them).
    pub fast_forwards: u64,
    /// Simulated minutes covered by those bulk burns (a subset of `ticks`).
    pub fast_forwarded_ticks: u64,
    /// Internal inconsistencies survived in release builds (debug builds
    /// panic instead). Always 0 in a healthy run.
    pub internal_errors: u64,
    /// Queued jobs newly skipped by quota gating (one per transition into
    /// the skipped state, not per round — so the counter, like the
    /// `AdmissionSkipped` event stream, is identical under both simulator
    /// drive modes).
    pub admission_skips: u64,
}

/// Per-tick outcome (used by tests, the live executor, and the
/// event-horizon engine's skip-eligibility check).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TickStats {
    /// Jobs that completed this tick.
    pub completed: Vec<JobId>,
    /// Jobs that vacated their node this tick (grace period elapsed).
    pub vacated: Vec<JobId>,
    /// Jobs placed (started or resumed) this tick.
    pub started: Vec<JobId>,
    /// Jobs signalled for preemption this tick.
    pub preempted: Vec<JobId>,
    /// Queued jobs newly skipped by quota gating this tick (fresh
    /// transitions only — a head that stays skipped is reported once).
    pub skipped: Vec<(JobId, TenantId)>,
}

impl TickStats {
    /// Reset every list for reuse as a round-scratch buffer: capacity is
    /// retained, so a caller that drives [`Scheduler::tick_into`] with one
    /// long-lived `TickStats` keeps steady-state ticks allocation-free.
    pub fn clear(&mut self) {
        self.completed.clear();
        self.vacated.clear();
        self.started.clear();
        self.preempted.clear();
        self.skipped.clear();
    }
}

/// The scheduler. Owns cluster + queues; the job table lives outside (the
/// simulator or live executor owns it) and is passed to `tick`.
pub struct Scheduler {
    /// The configuration this scheduler was built with.
    pub cfg: SchedConfig,
    /// Live cluster state (node capacities, allocations, holds, index).
    pub cluster: Cluster,
    /// The shared admission queue (all jobs under vanilla FIFO; BE jobs
    /// under preemptive policies), driven through the pluggable
    /// [`QueueDiscipline`] built from [`SchedConfig::discipline`].
    pub be_queue: Box<dyn QueueDiscipline>,
    /// TE fast lane (unused under vanilla FIFO). Per-arrival — no head to
    /// discipline — and never quota-gated (TE latency is the objective).
    pub te_queue: JobQueue,
    /// Live reservations pinning incoming TE jobs to draining nodes.
    pub reservations: Vec<Reservation>,
    /// Future completions / grace expiries / arrivals (see
    /// [`crate::sched::clock`]). Shared by both simulator drive modes.
    pub clock: EventClock,
    /// Per-tenant weights and quotas (mutated by `SetQuota`/`SetWeight`
    /// commands between rounds).
    pub tenants: TenantDirectory,
    /// Jobs currently occupying resources (Running or Draining).
    active: Vec<JobId>,
    /// Per-tenant occupied Size, maintained at bind/unbind points.
    usage: TenantUsage,
    /// Reference capacity for Eq. 1 `Size` in quota accounting: the
    /// cluster's total capacity at construction (fixed, so quota meanings
    /// do not drift under resizes mid-run).
    quota_ref: ResourceVec,
    /// Job ids reported skipped by the previous admission round (the
    /// dedup set behind [`TickStats::skipped`]).
    prev_skipped: Vec<u32>,
    /// Round scratch: due event ids from [`EventClock::take_due_into`].
    due_scratch: Vec<u32>,
    /// Round scratch: snapshot of the TE lane for the admission walk.
    scratch_te: Vec<JobId>,
    /// Round scratch: per-node effective free space for [`PolicyCtx`].
    scratch_eff: Vec<ResourceVec>,
    /// Round scratch: this round's quota skips.
    scratch_skipped: Vec<(JobId, TenantId)>,
    /// Round scratch: deduped skips inside [`Scheduler::note_skips`].
    scratch_dedup: Vec<(JobId, TenantId)>,
    /// Incrementally-maintained preemption-candidate index: per-node
    /// running-BE lists plus the ordered score sets every policy ranks by,
    /// updated only at lifecycle transitions (see
    /// [`crate::sched::victim_index`]). Policies read it through
    /// [`PolicyCtx::victims`]; planning never rescans the job table.
    victim_index: VictimIndex,
    /// Reusable plan-path scratch (greedy projections, victim pools, sort
    /// keys), handed to the policy on every plan so steady-state planning
    /// allocates nothing.
    plan_scratch: PlanScratch,
    /// Behaviour built from `cfg.policy` at construction (one build per
    /// run, per the [`PreemptionPolicy`] contract).
    policy: Box<dyn PreemptionPolicy>,
    /// Runtime-estimator handle built from `cfg.estimator` at
    /// construction. The controller subscribes a clone to the event stream
    /// so `Finished` records feed the estimator; the policy view reads
    /// predictions through it.
    estimator: SharedEstimator,
    rng: Pcg64,
    /// Aggregate counters across the run.
    pub stats: SchedStats,
    /// Run `Cluster::check_invariants` every tick (tests; ~2× slower).
    pub paranoid: bool,
}

impl Scheduler {
    /// Build a scheduler for `spec` under `cfg`.
    pub fn new(spec: &ClusterSpec, cfg: SchedConfig) -> Self {
        Scheduler {
            rng: Pcg64::new(cfg.seed),
            policy: build_policy(&cfg.policy),
            estimator: SharedEstimator::new(&cfg.estimator, cfg.seed),
            be_queue: build_discipline(&cfg.discipline),
            tenants: TenantDirectory::new(cfg.default_quota),
            cfg,
            cluster: Cluster::new(spec),
            te_queue: JobQueue::new(),
            reservations: Vec::new(),
            clock: EventClock::new(),
            active: Vec::new(),
            usage: TenantUsage::default(),
            quota_ref: spec.total_capacity(),
            prev_skipped: Vec::new(),
            due_scratch: Vec::new(),
            scratch_te: Vec::new(),
            scratch_eff: Vec::new(),
            scratch_skipped: Vec::new(),
            scratch_dedup: Vec::new(),
            victim_index: VictimIndex::new(spec.nodes.len()),
            plan_scratch: PlanScratch::default(),
            stats: SchedStats::default(),
            paranoid: false,
        }
    }

    /// A clone of the runtime-estimator handle (shared state): the
    /// controller subscribes one to the event stream; diagnostics read
    /// update counts through another.
    pub fn estimator(&self) -> SharedEstimator {
        self.estimator.clone()
    }

    /// Placement preference key for the residual-based rules: strictly
    /// smaller is better, ties break to the lower node id (matching the
    /// pre-index linear scan exactly). FirstFit never reaches this — it
    /// takes its own id-order early-exit branch in
    /// [`Self::find_node_effective`].
    fn placement_key(&self, free: &ResourceVec, demand: &ResourceVec, node: &Node) -> f64 {
        match self.cfg.placement {
            Placement::FirstFit => unreachable!("FirstFit uses the id-order scan"),
            Placement::BestFit => (*free - *demand).size(&node.capacity),
            Placement::WorstFit => -(*free - *demand).size(&node.capacity),
        }
    }

    /// Find a node where `demand` fits in *effective* free space, honouring
    /// `own`'s reservation, under the configured placement rule.
    ///
    /// Hot path (28% of a full-scale simulation before optimization). The
    /// cluster's capacity index prunes it twice over: an O(1)
    /// [`fits_nowhere`](Cluster::fits_nowhere) reject covers the saturated
    /// common case, and [`fit_candidates`](Cluster::fit_candidates) visits
    /// only nodes whose effective free `Size` can cover the demand. The
    /// node holding `own`'s reservation is evaluated directly with its
    /// hold credited back — the index cannot know about the credit.
    fn find_node_effective(&self, demand: &ResourceVec, own: Option<JobId>) -> Option<NodeId> {
        let own_res: Option<(NodeId, ResourceVec)> = own.and_then(|te| {
            self.reservations
                .iter()
                .find(|r| r.te == te)
                .map(|r| (r.node, r.hold))
        });

        // FirstFit keeps its id-order early exit: size-ordered candidates
        // cannot stop at the first hit, a plain id-order walk can. The O(1)
        // saturation reject still skips hopeless non-credited nodes.
        if self.cfg.placement == Placement::FirstFit {
            let nowhere = self.cluster.fits_nowhere(demand);
            if nowhere && own_res.is_none() {
                return None; // saturated cluster, no credit to consider
            }
            for node in &self.cluster.nodes {
                let free = match own_res {
                    Some((rnode, hold)) if rnode == node.id => {
                        if !node.is_schedulable() {
                            // Defensive: reservations are dropped when a
                            // node drains or fails, so the credit should
                            // never point at a non-Up node.
                            continue;
                        }
                        let held = node.hold().saturating_sub(&hold);
                        node.free.saturating_sub(&held)
                    }
                    _ => {
                        if nowhere {
                            continue;
                        }
                        node.effective_free()
                    }
                };
                if demand.fits_in(&free) {
                    return Some(node.id);
                }
            }
            return None;
        }

        let mut best: Option<(f64, NodeId)> = None;

        if let Some((rnode, hold)) = own_res {
            let node = self.cluster.node(rnode);
            if node.is_schedulable() {
                let held = node.hold().saturating_sub(&hold);
                let free = node.free.saturating_sub(&held);
                if demand.fits_in(&free) {
                    best = Some((self.placement_key(&free, demand, node), rnode));
                }
            }
        }

        if !self.cluster.fits_nowhere(demand) {
            let own_node = own_res.map(|(rnode, _)| rnode);
            for id in self.cluster.fit_candidates(demand) {
                if own_node == Some(id) {
                    continue; // already evaluated with its credit above
                }
                let node = self.cluster.node(id);
                let free = node.effective_free();
                if !demand.fits_in(&free) {
                    continue;
                }
                let key = self.placement_key(&free, demand, node);
                let better = match best {
                    None => true,
                    Some((k, bid)) => key < k || (key == k && id < bid),
                };
                if better {
                    best = Some((key, id));
                }
            }
        }
        best.map(|(_, id)| id)
    }

    /// Does `job` hold an active reservation?
    fn has_reservation(&self, job: JobId) -> bool {
        self.reservations.iter().any(|r| r.te == job)
    }

    fn release_reservation(&mut self, job: JobId) {
        if let Some(i) = self.reservations.iter().position(|r| r.te == job) {
            let r = self.reservations.remove(i);
            self.cluster.unreserve(r.node, r.hold);
        }
    }

    /// Release `id`'s resources. A missing binding is a scheduler-internal
    /// inconsistency: fatal in debug builds, counted and skipped in release
    /// builds (a corrupt input must degrade one decision, not abort a whole
    /// sweep).
    fn unbind_checked(&mut self, id: JobId, jobs: &JobTable) {
        if let Err(e) = self.cluster.unbind(id) {
            if cfg!(debug_assertions) {
                panic!("scheduler inconsistency: {e} ({:?})", jobs.get(id).map(|j| j.state));
            }
            self.stats.internal_errors += 1;
        }
    }

    /// Submit a job into the right queue.
    pub fn submit(&mut self, job: &Job) {
        debug_assert_eq!(job.state, JobState::Pending);
        if self.cfg.policy.te_bypass() && job.is_te() {
            self.te_queue.submit(job.id());
        } else {
            self.be_queue.submit(job.id(), job.spec.tenant);
        }
    }

    /// Number of queued + active jobs (for load metrics / drain detection).
    pub fn in_flight(&self) -> usize {
        self.be_queue.len() + self.te_queue.len() + self.active.len()
    }

    /// Total demand of queued + active jobs (the "cluster load" numerator
    /// used by the §4.2 arrival calibration). Sums in queue order — the
    /// `Fifo` discipline preserves the exact pre-refactor order, keeping
    /// the calibration's f64 accumulation bit-identical.
    pub fn outstanding_demand(&self, jobs: &JobTable) -> ResourceVec {
        let mut d = ResourceVec::ZERO;
        self.be_queue.for_each(&mut |id| d += *jobs.demand_of(id));
        for id in self.te_queue.iter() {
            d += *jobs.demand_of(id);
        }
        for id in &self.active {
            d += *jobs.demand_of(*id);
        }
        d
    }

    /// Eq. 1 `Size` of one job's demand against the quota reference
    /// capacity (the cluster total at construction). Column reads — the
    /// `Job` record stays untouched on this path.
    fn quota_size(&self, jobs: &JobTable, id: JobId) -> (TenantId, f64) {
        (jobs.tenant_of(id), jobs.demand_of(id).size(&self.quota_ref))
    }

    /// Record that `id` started occupying resources.
    fn occupy_usage(&mut self, jobs: &JobTable, id: JobId) {
        let (tenant, size) = self.quota_size(jobs, id);
        self.usage.add(tenant, size);
    }

    /// Record that `id` released its resources (complete, vacate, cancel,
    /// evict). Must pair every [`Scheduler::occupy_usage`].
    fn release_usage(&mut self, jobs: &JobTable, id: JobId) {
        let (tenant, size) = self.quota_size(jobs, id);
        self.usage.sub(tenant, size);
    }

    /// Is `tenant` at or over its occupied-Size quota? Checked *before*
    /// admission: a tenant strictly under its cap may overshoot by one
    /// job, so every queued job stays admissible once the tenant drains
    /// (the conservation property `rust/tests/properties.rs` pins).
    fn over_quota(&self, tenant: TenantId) -> bool {
        match self.tenants.quota(tenant) {
            None => false,
            Some(q) => self.usage.occupied_size(tenant) >= q - EPS,
        }
    }

    /// The tenant's currently occupied Size (diagnostics/tests).
    pub fn tenant_occupied_size(&self, tenant: TenantId) -> f64 {
        self.usage.occupied_size(tenant)
    }

    /// Set `tenant`'s occupied-Size quota (the `SetQuota` command).
    pub fn set_quota(&mut self, tenant: TenantId, size: f64) {
        self.tenants.set_quota(tenant, size);
    }

    /// Set `tenant`'s weighted-fair share (the `SetWeight` command).
    pub fn set_weight(&mut self, tenant: TenantId, weight: u32) {
        self.tenants.set_weight(tenant, weight);
    }

    /// One simulated minute. `arrivals` must be sorted by submission order.
    /// Convenience wrapper over [`Scheduler::tick_into`] that allocates a
    /// fresh [`TickStats`]; hot drivers hold one and reuse it.
    pub fn tick(&mut self, now: Minutes, jobs: &mut JobTable, arrivals: &[JobId]) -> TickStats {
        let mut out = TickStats::default();
        self.tick_into(now, jobs, arrivals, &mut out);
        out
    }

    /// One simulated minute, writing the outcome into a caller-owned
    /// `out` (cleared here; see [`TickStats::clear`]). With a reused
    /// `out`, steady-state ticks perform zero heap allocations.
    pub fn tick_into(
        &mut self,
        now: Minutes,
        jobs: &mut JobTable,
        arrivals: &[JobId],
        out: &mut TickStats,
    ) {
        out.clear();
        self.stats.ticks += 1;

        // -- 1+2: completions and grace expirations ----------------------
        // The clock hands over exactly the jobs with a live event due this
        // minute; event-free minutes skip the whole active-set scan. When
        // a scan does run it walks `active` in insertion order, exactly
        // like the pre-clock core, so multi-event ticks process in the
        // identical order (the due set only *guards* the walk — live
        // events are exact, so a guarded walk transitions the same jobs
        // the old exhaustive scan did, with the same swap_remove order).
        self.clock.take_due_into(now, jobs, &mut self.due_scratch);
        if !self.due_scratch.is_empty() {
            let mut i = 0;
            while i < self.active.len() {
                let id = self.active[i];
                if self.due_scratch.binary_search(&id.0).is_err() {
                    i += 1;
                    continue;
                }
                let job = &mut jobs[id];
                job.sync(now);
                match job.state {
                    JobState::Running if job.remaining == 0 => {
                        job.complete(now);
                        jobs.bump_epoch(id);
                        self.victim_index.remove(id);
                        self.unbind_checked(id, jobs);
                        self.release_usage(jobs, id);
                        self.active.swap_remove(i);
                        self.stats.completions += 1;
                        out.completed.push(id);
                    }
                    JobState::Draining if job.remaining == 0 && self.cfg.progress_during_grace => {
                        job.complete(now);
                        jobs.bump_epoch(id);
                        self.victim_index.remove(id);
                        self.unbind_checked(id, jobs);
                        self.release_usage(jobs, id);
                        self.active.swap_remove(i);
                        self.stats.completions += 1;
                        out.completed.push(id);
                    }
                    JobState::Draining if job.grace_left == 0 => {
                        let tenant = job.spec.tenant;
                        job.vacate(now);
                        jobs.bump_epoch(id);
                        self.unbind_checked(id, jobs);
                        self.release_usage(jobs, id);
                        self.active.swap_remove(i);
                        self.be_queue.reinsert_front(id, tenant);
                        out.vacated.push(id);
                    }
                    _ => i += 1,
                }
            }
        } else if self.paranoid {
            // Cross-check the skip: no active job may have a due transition
            // the clock failed to predict.
            for id in &self.active {
                let job = &jobs[*id];
                let due = match job.state {
                    JobState::Running => job.remaining_at(now) == 0,
                    JobState::Draining => {
                        job.grace_left_at(now) == 0
                            || (self.cfg.progress_during_grace && job.remaining_at(now) == 0)
                    }
                    _ => false,
                };
                assert!(!due, "{} has a due transition the clock missed", job.id());
            }
        }

        // -- 3: arrivals --------------------------------------------------
        for id in arrivals {
            debug_assert_eq!(jobs[*id].spec.submit, now, "arrival at wrong tick");
            self.submit(&jobs[*id]);
        }

        // -- 4: admission --------------------------------------------------
        if self.cfg.policy.te_bypass() {
            self.admit_te_lane(now, jobs, out);
        }
        self.admit_be_queue(now, jobs, out);

        if self.paranoid {
            self.cluster.check_invariants().expect("cluster invariants");
            self.check_hold_invariants();
            self.victim_index
                .check_against(&self.cluster, jobs)
                .expect("victim index matches a from-scratch rebuild");
        }

        // No step 5: progress, grace burn-down, and queue waiting are
        // settled lazily at each job's next transition (see [`Job::sync`]).
    }

    /// TE fast lane admission. Per-arrival, not head-gated: the paper
    /// triggers preemption "when a TE job arrives at a job queue", and a
    /// TE job whose victims drained may start while an earlier TE job is
    /// still waiting out a longer grace period. Order is still FIFO among
    /// TE jobs for placement attempts.
    fn admit_te_lane(&mut self, now: Minutes, jobs: &mut JobTable, out: &mut TickStats) {
        // Snapshot the lane into a reused scratch buffer (admission
        // mutates the queue as it places).
        let mut waiting = std::mem::take(&mut self.scratch_te);
        waiting.clear();
        waiting.extend(self.te_queue.iter());
        for &head in &waiting {
            let demand = *jobs.demand_of(head);
            // (a) Fits somewhere (own reservation credited)?
            if let Some(node) = self.find_node_effective(&demand, Some(head)) {
                if !self.has_reservation(head) {
                    self.stats.te_no_preemption += 1;
                }
                self.place(head, node, now, jobs, out);
                continue;
            }
            // (b) Waiting on an existing reservation? Hold while any of its
            // victims is still draining. If the drains landed and the job
            // *still* does not fit (the baselines' aggregate plans can
            // under-deliver on a single node), drop the reservation and
            // re-plan — the paper's "continue the preemption process until
            // they can prepare enough resource".
            if self.has_reservation(head) {
                // `get`, not indexing: a victim may have been retired from
                // the table (completed under progress-during-grace, or
                // cancelled by the control plane) — a retired victim is
                // simply "no longer draining".
                let still_draining = self
                    .reservations
                    .iter()
                    .find(|r| r.te == head)
                    .map(|r| {
                        r.victims
                            .iter()
                            .any(|v| jobs.get(*v).is_some_and(|j| j.state == JobState::Draining))
                    })
                    .unwrap_or(false);
                if still_draining {
                    continue;
                }
                self.release_reservation(head);
                self.stats.replans += 1;
            }
            // (c) Ask the policy for victims.
            let plan = {
                let mut eff = std::mem::take(&mut self.scratch_eff);
                eff.clear();
                eff.extend(self.cluster.nodes.iter().map(Node::effective_free));
                let est = &self.estimator;
                let ctx = PolicyCtx {
                    cluster: &self.cluster,
                    jobs,
                    effective_free: &eff,
                    oracle_remaining: &|id: JobId| jobs[id].remaining_at(now),
                    predicted_remaining: &|id: JobId| est.predicted_remaining(&jobs[id], now),
                    victims: &self.victim_index,
                };
                let plan =
                    self.policy
                        .plan(&jobs[head].spec, &ctx, &mut self.plan_scratch, &mut self.rng);
                self.scratch_eff = eff;
                plan
            };
            let Some(plan) = plan else {
                continue; // nothing to preempt (or non-preemptive policy)
            };
            self.stats.plans += 1;
            if plan.fallback {
                self.stats.fallback_plans += 1;
            }
            // Signal victims; zero-GP victims vacate synchronously. A
            // signalled victim leaves the preemptible pool either way
            // (Draining jobs are not re-preemptible), so it exits the
            // index here, not at its eventual vacate/complete.
            let mut victims = Vec::new();
            for v in &plan.victims {
                self.victim_index.remove(*v);
                let job = &mut jobs[*v];
                let tenant = job.spec.tenant;
                job.signal_preemption(now, self.cfg.progress_during_grace);
                self.stats.preemption_signals += 1;
                out.preempted.push(*v);
                if job.grace_left == 0 {
                    job.vacate(now);
                    jobs.bump_epoch(*v);
                    self.unbind_checked(*v, jobs);
                    self.release_usage(jobs, *v);
                    if let Some(i) = self.active.iter().position(|a| a == v) {
                        self.active.swap_remove(i);
                    }
                    self.be_queue.reinsert_front(*v, tenant);
                    out.vacated.push(*v);
                } else {
                    let grace_left = job.grace_left;
                    let remaining = job.remaining;
                    let epoch = jobs.bump_epoch(*v);
                    self.clock
                        .push_grace_expiry(now.saturating_add(grace_left), *v, epoch);
                    if self.cfg.progress_during_grace {
                        self.clock
                            .push_completion(now.saturating_add(remaining), *v, epoch);
                    }
                    victims.push(*v);
                }
            }
            self.reservations.push(Reservation {
                te: head,
                node: plan.node,
                hold: demand,
                victims,
            });
            self.cluster.reserve(plan.node, demand);
            // Retry immediately: zero-GP victims may have freed the seat.
            if let Some(node) = self.find_node_effective(&demand, Some(head)) {
                self.place(head, node, now, jobs, out);
            }
        }
        self.scratch_te = waiting;
    }

    /// Shared/BE queue admission: one round of the configured
    /// [`QueueDiscipline`]. Under the default `Fifo` discipline this is
    /// the paper's strict head-gated loop, byte-identical to the
    /// pre-admission-layer scheduler: try the head; a job that vacated
    /// this very round ("the scheduler decides resource allocation at
    /// every simulated minute" — a suspend and a restart cannot share one
    /// decision), an over-quota head, or a head that fits nowhere ends
    /// the round. Tenant-aware disciplines instead *skip* such heads per
    /// their own rules; no preemption ever happens on behalf of this
    /// queue.
    fn admit_be_queue(&mut self, now: Minutes, jobs: &mut JobTable, out: &mut TickStats) {
        self.be_queue.begin_round();
        let mut skipped = std::mem::take(&mut self.scratch_skipped);
        skipped.clear();
        loop {
            let Some(head) = self
                .be_queue
                .next_candidate(&AdmissionCtx { tenants: &self.tenants })
            else {
                break;
            };
            let tenant = jobs.tenant_of(head);
            let outcome = if jobs[head].last_vacated == Some(now) {
                AdmitOutcome::VacatedNow
            } else if self.over_quota(tenant) {
                skipped.push((head, tenant));
                AdmitOutcome::OverQuota
            } else {
                let demand = *jobs.demand_of(head);
                match self.find_node_effective(&demand, Some(head)) {
                    Some(node) => {
                        self.place(head, node, now, jobs, out);
                        AdmitOutcome::Placed
                    }
                    None => AdmitOutcome::NoFit,
                }
            };
            self.be_queue
                .report(head, tenant, outcome, &AdmissionCtx { tenants: &self.tenants });
        }
        self.note_skips(&skipped, out);
        self.scratch_skipped = skipped;
    }

    /// Fold one round's quota skips into the dedup set, surfacing only
    /// fresh transitions in [`TickStats::skipped`]. A head that stays
    /// skipped round after round is reported once — which also keeps the
    /// skip stream identical under both simulator drive modes (a quiescent
    /// span's elided rounds would have re-skipped the identical set).
    fn note_skips(&mut self, skipped: &[(JobId, TenantId)], out: &mut TickStats) {
        if skipped.is_empty() {
            if !self.prev_skipped.is_empty() {
                self.prev_skipped.clear();
            }
            return;
        }
        // One round can report the same head several times (a quota-gate
        // scan restarts from the front after every placement): dedupe
        // before diffing against the previous round.
        let mut deduped = std::mem::take(&mut self.scratch_dedup);
        deduped.clear();
        for (id, tenant) in skipped {
            if !deduped.iter().any(|(j, _)| j == id) {
                deduped.push((*id, *tenant));
            }
        }
        for (id, tenant) in &deduped {
            if !self.prev_skipped.contains(&id.0) {
                out.skipped.push((*id, *tenant));
                self.stats.admission_skips += 1;
            }
        }
        self.prev_skipped.clear();
        self.prev_skipped.extend(deduped.iter().map(|(id, _)| id.0));
        self.scratch_dedup = deduped;
    }

    fn place(&mut self, id: JobId, node: NodeId, now: Minutes, jobs: &mut JobTable, out: &mut TickStats) {
        // Remove from whichever queue holds it (TE lane admission is
        // per-arrival, so the job may not be at the head). A job that is in
        // neither queue is an internal inconsistency (it may already be
        // placed); release builds skip this one decision rather than
        // risking a double-bind that would corrupt cluster accounting.
        let removed = self.te_queue.remove(id) || self.be_queue.remove(id);
        debug_assert!(removed, "{id} placed but not queued");
        if !removed {
            self.stats.internal_errors += 1;
            return;
        }
        self.release_reservation(id);
        let job = &mut jobs[id];
        job.start(node, now);
        let remaining = job.remaining;
        let demand = job.spec.demand;
        let epoch = jobs.bump_epoch(id);
        self.clock.push_completion(now.saturating_add(remaining), id, epoch);
        self.cluster.bind(id, demand, node);
        if jobs[id].is_be() {
            let capacity = self.cluster.node(node).capacity;
            self.victim_index.insert(&jobs[id], &capacity);
        }
        self.active.push(id);
        self.occupy_usage(jobs, id);
        self.stats.placements += 1;
        out.started.push(id);
    }

    /// Debug check: cluster holds match live reservations.
    fn check_hold_invariants(&self) {
        let mut expect = vec![ResourceVec::ZERO; self.cluster.nodes.len()];
        for r in &self.reservations {
            expect[r.node.0 as usize] += r.hold;
        }
        for (i, (a, n)) in expect.iter().zip(&self.cluster.nodes).enumerate() {
            let d = *a - n.hold();
            assert!(
                d.cpu.abs() < 1e-6 && d.ram_gb.abs() < 1e-6 && d.gpu.abs() < 1e-6,
                "hold mismatch on node {i}: {a} vs {}",
                n.hold()
            );
        }
    }

    /// All jobs done and nothing queued?
    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.be_queue.is_empty() && self.te_queue.is_empty()
    }

    // ------------------------------------------------------------------
    // Event-horizon support: the three methods below let the simulator
    // fast-forward quiescent spans in O(1) ticks instead of calling `tick`
    // once per simulated minute. See `sim::SimEngine::EventHorizon`.
    // ------------------------------------------------------------------

    /// True when no scheduling *decision* can change before the next event
    /// (arrival, completion, or grace expiry):
    ///
    /// * every queued TE job is pinned to a reservation with at least one
    ///   still-draining victim, so its admission pass is a deterministic
    ///   no-op (it neither replans — which would consume policy RNG — nor
    ///   places, since the cluster's free/hold state cannot change without
    ///   an event), and
    /// * a shared-queue admission round is a pure function of frozen
    ///   (cluster, queue, tenant-usage) state that mutates nothing when it
    ///   places nothing — the [`QueueDiscipline`] frozen-state contract —
    ///   so a round that just ended blocked stays a no-op for the whole
    ///   span, whatever the discipline (a quota-gated tenant's usage can
    ///   only change at a completion/vacate event, which ends the span).
    ///
    /// The caller must additionally rule out the one same-tick rule that
    /// is *not* visible from this state: a job that vacated in the tick
    /// just executed becomes admittable one tick later
    /// (check [`TickStats::vacated`]).
    pub fn quiescent(&self, jobs: &JobTable) -> bool {
        self.te_queue.iter().all(|id| {
            self.reservations.iter().any(|r| {
                r.te == id
                    && r.victims
                        .iter()
                        .any(|v| jobs.get(*v).is_some_and(|j| j.state == JobState::Draining))
            })
        })
    }

    /// Absolute minute of the next scheduler-internal event — a running job
    /// completing, a draining job's grace period expiring, or (under
    /// progress-during-grace) a draining job finishing — or `None` when no
    /// job occupies resources. A lazy heap peek on the [`EventClock`], not
    /// a job-table scan.
    pub fn next_internal_at(&mut self, jobs: &JobTable) -> Option<Minutes> {
        self.clock.next_internal_at(jobs)
    }

    /// Advance `dt` quiescent simulated minutes in one step — exactly what
    /// `dt` calls to [`Scheduler::tick`] would have done given that no
    /// completion, grace expiry, arrival, or admission can occur inside
    /// the span. Under lazy accounting (see [`Job::sync`]) that is O(1):
    /// running, draining, and queued jobs all settle the elapsed span at
    /// their next transition, so only the time counters advance here. The
    /// event-horizon engine establishes the quiescence precondition via
    /// [`Scheduler::quiescent`] and [`Scheduler::next_internal_at`]; the
    /// engine-equivalence suite pins the byte-identity of the two drive
    /// modes.
    pub fn burn_many(&mut self, dt: Minutes) {
        if dt == 0 {
            return;
        }
        self.stats.ticks += dt;
        self.stats.fast_forwards += 1;
        self.stats.fast_forwarded_ticks += dt;
    }

    // ------------------------------------------------------------------
    // Control-plane support: the operations behind
    // [`SchedulerCommand`](crate::sched::control::SchedulerCommand).
    // The [`ClusterController`](crate::sched::control::ClusterController)
    // facade calls these and emits the corresponding events; nothing here
    // runs on the scenario-free hot path.
    // ------------------------------------------------------------------

    /// Is `id` under this scheduler's management — queued in either lane or
    /// occupying resources? False for jobs whose arrival has not been
    /// processed yet (staged in the clock's arrival heap) and for retired
    /// jobs; the scenario driver uses this to defer cancellations until
    /// the target actually exists scheduler-side.
    pub fn tracks(&self, id: JobId) -> bool {
        self.active.contains(&id)
            || self.te_queue.position(id).is_some()
            || self.be_queue.contains(id)
    }

    /// Withdraw `id` from the scheduler entirely (cancellation): remove it
    /// from whichever queue holds it or release its resources if active,
    /// and drop any reservation it owns. Returns false when the job is not
    /// tracked (the caller turns that into a rejected command). Job-side
    /// state is untouched — the controller applies [`Job::cancel`] and
    /// retires the record.
    pub fn discard(&mut self, id: JobId, jobs: &mut JobTable) -> bool {
        if self.te_queue.remove(id) || self.be_queue.remove(id) {
            self.release_reservation(id);
            return true;
        }
        if let Some(i) = self.active.iter().position(|a| *a == id) {
            self.active.swap_remove(i);
            self.victim_index.remove(id);
            self.unbind_checked(id, jobs);
            self.release_usage(jobs, id);
            return true;
        }
        false
    }

    /// Change a job's class mid-flight (the user promotes a trial run to a
    /// full training job, or demotes one). Queued jobs move to the tail of
    /// the lane their new class routes to (their reservation, if any, is
    /// dropped — the TE lane will re-plan); running jobs flip in place,
    /// which changes their preemption eligibility from the next decision
    /// on. Draining jobs cannot be reclassified (the preemption signal is
    /// already out), nor can jobs the scheduler does not track.
    ///
    /// Every failure mode returns the *same* message: whether a missing
    /// target is "not yet pulled" or "staged but not arrived" depends on
    /// the driver's `arrival_lookahead`, and the rejection text ends up in
    /// the deterministic event log — it must not leak that distinction.
    ///
    /// Returns `Ok(true)` when the class actually changed and `Ok(false)`
    /// for a valid no-op (the job already has that class), so the
    /// controller only emits a `Reclassified` event for real transitions.
    pub fn reclassify(
        &mut self,
        id: JobId,
        class: JobClass,
        jobs: &mut JobTable,
    ) -> Result<bool, &'static str> {
        const REJECT: &str = "only a queued or running job can be reclassified";
        let Some(state) = jobs.get(id).map(|j| j.state) else {
            return Err(REJECT);
        };
        match state {
            JobState::Pending => {
                if !self.tracks(id) {
                    return Err(REJECT); // staged pre-arrival
                }
                if jobs[id].spec.class == class {
                    return Ok(false);
                }
                let queued = self.te_queue.remove(id) || self.be_queue.remove(id);
                debug_assert!(queued, "tracked pending job must be queued");
                self.release_reservation(id);
                jobs[id].spec.class = class;
                self.submit(&jobs[id]);
                Ok(true)
            }
            JobState::Running => {
                if jobs[id].spec.class == class {
                    return Ok(false);
                }
                jobs[id].spec.class = class;
                // A BE↔TE flip changes preemption eligibility: rebuild the
                // hosting node's index slice (insertion order = allocation
                // order, same as a from-scratch build).
                if let Some(node) = jobs[id].node {
                    self.victim_index.rebuild_node(node, &self.cluster, jobs);
                }
                Ok(true)
            }
            _ => Err(REJECT),
        }
    }

    /// Node failure: drop every reservation pinned to `node` (their TE
    /// jobs re-plan on the remaining nodes), evict every hosted job with
    /// **no** grace period — the node is gone — and mark the node `Down`.
    /// Evicted jobs re-queue at the top of their lane (like preempted
    /// jobs, but without counting a policy preemption) and may restart in
    /// the very next scheduling round. Returns the evicted jobs in
    /// allocation order.
    pub fn fail_node(&mut self, node: NodeId, now: Minutes, jobs: &mut JobTable) -> Vec<JobId> {
        self.drop_reservations_on(node);
        self.victim_index.remove_node(node);
        let lost = self.cluster.evict_all(node);
        for id in &lost {
            match self.active.iter().position(|a| a == id) {
                Some(i) => {
                    self.active.swap_remove(i);
                }
                None => {
                    debug_assert!(false, "{id} hosted but not active");
                    self.stats.internal_errors += 1;
                }
            }
            self.release_usage(jobs, *id);
            let (is_te, tenant) = {
                let job = &mut jobs[*id];
                job.fail_over(now);
                (job.is_te(), job.spec.tenant)
            };
            jobs.bump_epoch(*id);
            if self.cfg.policy.te_bypass() && is_te {
                self.te_queue.reinsert_front(*id);
            } else {
                self.be_queue.reinsert_front(*id, tenant);
            }
        }
        self.cluster.set_availability(node, NodeAvailability::Down);
        lost
    }

    /// Maintenance drain: no new placements land on `node`, hosted jobs
    /// run to completion. Reservations pinned here are dropped so their TE
    /// jobs re-plan elsewhere.
    pub fn drain_node(&mut self, node: NodeId) {
        self.drop_reservations_on(node);
        // Hosted jobs keep running but stop being preemption candidates
        // (the index holds Up-node jobs only, like the scan it replaced).
        self.victim_index.remove_node(node);
        self.cluster.set_availability(node, NodeAvailability::Draining);
    }

    /// Bring a node (back) into service: `Down → Up` after a repair —
    /// the node returns empty at full capacity — or `Draining → Up` to
    /// abort a maintenance drain with its tenants intact (they re-enter
    /// the preemptible pool, hence the index rebuild).
    pub fn restore_node(&mut self, node: NodeId, jobs: &JobTable) {
        self.cluster.set_availability(node, NodeAvailability::Up);
        self.victim_index.rebuild_node(node, &self.cluster, jobs);
    }

    /// Resize a node's capacity (the `Resize` command). Size keys are
    /// normalized by the hosting node's capacity, so every hosted victim's
    /// ranking changes with it: the node's index slice is rebuilt after the
    /// cluster applies the resize.
    pub fn resize_node(
        &mut self,
        node: NodeId,
        capacity: ResourceVec,
        jobs: &JobTable,
    ) -> Result<(), crate::cluster::ClusterError> {
        self.cluster.resize(node, capacity)?;
        self.victim_index.rebuild_node(node, &self.cluster, jobs);
        Ok(())
    }

    /// The live victim index (tests, benches, diagnostics).
    pub fn victim_index(&self) -> &VictimIndex {
        &self.victim_index
    }

    /// Serialize the scheduler's run state for a snapshot. Taken at a
    /// round boundary (between ticks), so round-scratch buffers and the
    /// disciplines' round-local cursors are excluded by construction.
    /// Derived structures — the victim index, the cluster's free-capacity
    /// index — are rebuilt on restore, not written. Config (`cfg`, the
    /// policy, estimator parameters) is also excluded: restore targets a
    /// scheduler freshly built from the identical config.
    pub fn snapshot_bin(&self, w: &mut crate::util::bin::BinWriter) {
        self.cluster.snapshot_bin(w);
        self.be_queue.snapshot_bin(w);
        self.te_queue.snapshot_bin(w);
        w.seq(self.reservations.len());
        for r in &self.reservations {
            w.u32(r.te.0);
            w.u32(r.node.0);
            r.hold.snapshot_bin(w);
            w.seq(r.victims.len());
            for v in &r.victims {
                w.u32(v.0);
            }
        }
        self.clock.snapshot_bin(w);
        self.tenants.snapshot_bin(w);
        // `active` order is behavioural: the due-event walk and swap_remove
        // pattern depend on it.
        w.seq(self.active.len());
        for id in &self.active {
            w.u32(id.0);
        }
        self.usage.snapshot_bin(w);
        self.quota_ref.snapshot_bin(w);
        w.seq(self.prev_skipped.len());
        for id in &self.prev_skipped {
            w.u32(*id);
        }
        let (state, inc) = self.rng.state_parts();
        w.u64(state);
        w.u64(inc);
        self.estimator.snapshot_bin(w);
        let s = &self.stats;
        for c in [
            s.preemption_signals,
            s.fallback_plans,
            s.plans,
            s.placements,
            s.completions,
            s.te_no_preemption,
            s.ticks,
            s.replans,
            s.fast_forwards,
            s.fast_forwarded_ticks,
            s.internal_errors,
            s.admission_skips,
        ] {
            w.u64(c);
        }
    }

    /// Restore state written by [`Scheduler::snapshot_bin`] into a
    /// scheduler freshly built from the same cluster spec and config.
    /// `jobs` must already hold the restored job table — the victim index
    /// is rebuilt from it (and cross-checked against the incremental
    /// invariants when [`Scheduler::paranoid`] is set).
    pub fn restore_bin(
        &mut self,
        r: &mut crate::util::bin::BinReader,
        jobs: &JobTable,
    ) -> anyhow::Result<()> {
        self.cluster = Cluster::restore_bin(r)?;
        self.be_queue.restore_bin(r)?;
        self.te_queue = JobQueue::restore_bin(r)?;
        self.reservations.clear();
        for _ in 0..r.seq()? {
            let te = JobId(r.u32()?);
            let node = NodeId(r.u32()?);
            let hold = ResourceVec::restore_bin(r)?;
            let mut victims = Vec::new();
            for _ in 0..r.seq()? {
                victims.push(JobId(r.u32()?));
            }
            self.reservations.push(Reservation { te, node, hold, victims });
        }
        self.clock = EventClock::restore_bin(r)?;
        self.tenants = TenantDirectory::restore_bin(r)?;
        self.active.clear();
        for _ in 0..r.seq()? {
            self.active.push(JobId(r.u32()?));
        }
        self.usage = TenantUsage::restore_bin(r)?;
        self.quota_ref = ResourceVec::restore_bin(r)?;
        self.prev_skipped.clear();
        for _ in 0..r.seq()? {
            self.prev_skipped.push(r.u32()?);
        }
        let state = r.u64()?;
        let inc = r.u64()?;
        self.rng = Pcg64::from_parts(state, inc);
        self.estimator.restore_bin(r)?;
        self.stats = SchedStats {
            preemption_signals: r.u64()?,
            fallback_plans: r.u64()?,
            plans: r.u64()?,
            placements: r.u64()?,
            completions: r.u64()?,
            te_no_preemption: r.u64()?,
            ticks: r.u64()?,
            replans: r.u64()?,
            fast_forwards: r.u64()?,
            fast_forwarded_ticks: r.u64()?,
            internal_errors: r.u64()?,
            admission_skips: r.u64()?,
        };
        // Derived state: rebuild the victim index from the restored
        // cluster + job table (PR 8's paranoid cross-check validates the
        // incremental invariants against exactly this rebuild).
        self.victim_index = VictimIndex::build(&self.cluster, jobs);
        if self.paranoid {
            self.victim_index
                .check_against(&self.cluster, jobs)
                .map_err(|e| anyhow::anyhow!("snapshot corrupt: victim index rebuild: {e}"))?;
        }
        Ok(())
    }

    /// Drop every reservation pinned to `node`, returning the TE jobs that
    /// owned them.
    fn drop_reservations_on(&mut self, node: NodeId) -> Vec<JobId> {
        let tes: Vec<JobId> = self
            .reservations
            .iter()
            .filter(|r| r.node == node)
            .map(|r| r.te)
            .collect();
        for te in &tes {
            self.release_reservation(*te);
        }
        tes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobClass, JobSpec};

    fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    /// Tiny driver: run the scheduler over `jobs` until idle (or 10k ticks).
    fn run_cfg(cfg: SchedConfig, spec: &ClusterSpec, jobs: &mut JobTable) -> (Scheduler, Minutes) {
        let mut sched = Scheduler::new(spec, cfg);
        sched.paranoid = true;
        let mut now = 0;
        loop {
            let mut arrivals: Vec<JobId> = jobs
                .iter()
                .filter(|j| j.spec.submit == now)
                .map(|j| j.id())
                .collect();
            arrivals.sort();
            sched.tick(now, jobs, &arrivals);
            now += 1;
            let all_submitted = jobs.iter().all(|j| j.spec.submit < now);
            if all_submitted && sched.idle() {
                return (sched, now);
            }
            assert!(now < 10_000, "runaway test simulation");
        }
    }

    fn run(policy: PolicyKind, spec: &ClusterSpec, jobs: &mut JobTable) -> (Scheduler, Minutes) {
        run_cfg(SchedConfig::new(policy), spec, jobs)
    }

    fn mkjobs(specs: Vec<JobSpec>) -> JobTable {
        JobTable::from_jobs(specs.into_iter().map(Job::new).collect())
    }

    #[test]
    fn single_job_runs_to_completion() {
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![JobSpec::new(0, JobClass::Be, rv(4.0, 32.0, 1.0), 0, 5, 0)]);
        let (_, end) = run(PolicyKind::Fifo, &spec, &mut jobs);
        assert_eq!(jobs[JobId(0)].finished_at, Some(5));
        assert!((jobs[JobId(0)].slowdown() - 1.0).abs() < 1e-12);
        assert_eq!(end, 6);
    }

    #[test]
    fn fifo_head_of_line_blocks_small_jobs() {
        // Node is full with job 0 (10 min). Job 1 (huge) blocks job 2
        // (tiny) even though job 2 would fit — the FIFO principle.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(30.0, 200.0, 8.0), 0, 10, 0),
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 1, 5, 0),
            JobSpec::new(2, JobClass::Be, rv(1.0, 1.0, 0.0), 1, 5, 0),
        ]);
        let (_, _) = run(PolicyKind::Fifo, &spec, &mut jobs);
        // Job 1 starts at 10 (after job 0), job 2 only after job 1 at 15.
        assert_eq!(jobs[JobId(1)].first_start, Some(10));
        assert_eq!(jobs[JobId(2)].first_start, Some(15));
    }

    #[test]
    fn te_bypass_lets_te_jump_blocked_queue() {
        // Same setup but a TE job instead of job 2: with FastLane (bypass,
        // no preemption) the TE job takes the fragmented free space at once.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(30.0, 200.0, 7.0), 0, 10, 0),
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 1, 5, 0),
            JobSpec::new(2, JobClass::Te, rv(1.0, 1.0, 1.0), 1, 5, 0),
        ]);
        let (sched, _) = run(PolicyKind::FastLane, &spec, &mut jobs);
        assert_eq!(jobs[JobId(2)].first_start, Some(1), "TE starts immediately");
        assert_eq!(sched.stats.preemption_signals, 0);
    }

    #[test]
    fn fitgpp_preempts_to_admit_te() {
        // Node full with two BE jobs; TE arrives; FitGpp must preempt the
        // small one (GP=2) and start the TE job after the drain.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(24.0, 192.0, 6.0), 0, 100, 2),
            JobSpec::new(1, JobClass::Be, rv(8.0, 64.0, 2.0), 0, 100, 2),
            JobSpec::new(2, JobClass::Te, rv(4.0, 32.0, 1.0), 1, 5, 0),
        ]);
        let (sched, _) = run(
            PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            &spec,
            &mut jobs,
        );
        assert_eq!(sched.stats.preemption_signals, 1);
        assert_eq!(jobs[JobId(1)].preemptions, 1, "small job is the victim");
        assert_eq!(jobs[JobId(0)].preemptions, 0);
        // Signal at t=1, GP 2 burns at t=1,2 ⇒ vacate at t=3, TE starts t=3.
        assert_eq!(jobs[JobId(2)].first_start, Some(3));
        // Victim re-queued at top and resumed once the TE job finished (it
        // needs 8 CPUs; TE holds 4 of the 0 free... it refits when space allows).
        assert!(jobs[JobId(1)].resched_intervals.len() == 1);
    }

    #[test]
    fn zero_gp_victim_vacates_same_tick() {
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 100, 0),
            JobSpec::new(1, JobClass::Te, rv(4.0, 32.0, 1.0), 1, 5, 0),
        ]);
        let (_, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, &spec, &mut jobs);
        assert_eq!(jobs[JobId(1)].first_start, Some(1), "rewind-OK victim frees seat instantly");
        assert_eq!(jobs[JobId(1)].slowdown(), 1.0);
    }

    #[test]
    fn preempted_job_goes_to_queue_top() {
        // Victim must restart before a BE job that was submitted earlier
        // but still queued.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 20, 0), // runs, victim
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 20, 0), // queued behind
            JobSpec::new(2, JobClass::Te, rv(16.0, 128.0, 4.0), 1, 5, 0),
        ]);
        let (_, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, &spec, &mut jobs);
        // Job 0 vacates at t=1 (GP 0), requeued at head, refits at t=6 once
        // the TE job is done (its 16 CPUs + 32-16 free = fits at TE end).
        assert!(jobs[JobId(0)].first_start.unwrap() < jobs[JobId(1)].first_start.unwrap(),
            "victim resumes before the younger queued job");
        assert_eq!(jobs[JobId(0)].preemptions, 1);
    }

    #[test]
    fn reservation_prevents_squatting() {
        // TE preempts a victim with GP 3 on a full node; while it drains, a
        // small BE job arrives — it must NOT grab the drained space.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 100, 3),
            JobSpec::new(1, JobClass::Te, rv(30.0, 250.0, 8.0), 1, 5, 0),
            JobSpec::new(2, JobClass::Be, rv(2.0, 2.0, 0.0), 2, 50, 0),
        ]);
        let (_, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: None }, &spec, &mut jobs);
        // Victim vacates at t=4 (signal t=1, GP 3). TE must start t=4.
        assert_eq!(jobs[JobId(1)].first_start, Some(4));
        // The small BE job fits beside the TE job (2 CPUs free) at t=4, not
        // before (node was full/draining with hold).
        assert!(jobs[JobId(2)].first_start.unwrap() >= 4);
    }

    #[test]
    fn te_never_preempted_and_te_does_not_preempt_te() {
        // Cluster saturated by TE jobs; another TE arrives — no preemption
        // possible, it waits for completion.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Te, rv(32.0, 256.0, 8.0), 0, 10, 0),
            JobSpec::new(1, JobClass::Te, rv(32.0, 256.0, 8.0), 1, 5, 0),
        ]);
        let (sched, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, &spec, &mut jobs);
        assert_eq!(sched.stats.preemption_signals, 0);
        assert_eq!(jobs[JobId(1)].first_start, Some(10));
        assert_eq!(jobs[JobId(0)].preemptions, 0);
    }

    #[test]
    fn p_cap_respected_end_to_end() {
        // One BE job; two TE waves try to preempt it. With P=1 the second
        // wave must not preempt it again.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 30, 0),
            JobSpec::new(1, JobClass::Te, rv(32.0, 256.0, 8.0), 1, 3, 0),
            JobSpec::new(2, JobClass::Te, rv(32.0, 256.0, 8.0), 10, 3, 0),
        ]);
        let (_, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, &spec, &mut jobs);
        assert_eq!(jobs[JobId(0)].preemptions, 1, "P=1 ⇒ at most one preemption");
        // Second TE waits for the BE job to finish instead.
        assert!(jobs[JobId(2)].first_start.unwrap() > 10);
    }

    #[test]
    fn srtf_preempts_shortest_remaining_victim() {
        // Two BE jobs fill the node; SRTF must evict the one closer to
        // completion (oracle-assisted), not the long one.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(16.0, 128.0, 4.0), 0, 100, 0),
            JobSpec::new(1, JobClass::Be, rv(16.0, 128.0, 4.0), 0, 8, 0),
            JobSpec::new(2, JobClass::Te, rv(16.0, 128.0, 4.0), 1, 5, 0),
        ]);
        let (sched, _) = run(PolicyKind::Srtf, &spec, &mut jobs);
        assert!(sched.stats.preemption_signals >= 1);
        assert_eq!(jobs[JobId(1)].preemptions, 1, "short-remaining job is the victim");
        assert_eq!(jobs[JobId(0)].preemptions, 0);
    }

    #[test]
    fn youngest_preempts_latest_submission() {
        // Jobs 0 (t=0) and 1 (t=1) fill the node; a TE at t=2 must evict
        // job 1 — the youngest — under the preempt-youngest ablation.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(16.0, 128.0, 4.0), 0, 100, 0),
            JobSpec::new(1, JobClass::Be, rv(16.0, 128.0, 4.0), 1, 100, 0),
            JobSpec::new(2, JobClass::Te, rv(16.0, 128.0, 4.0), 2, 5, 0),
        ]);
        let (sched, _) = run(PolicyKind::Youngest, &spec, &mut jobs);
        assert!(sched.stats.preemption_signals >= 1);
        assert_eq!(jobs[JobId(1)].preemptions, 1, "youngest job is the victim");
        assert_eq!(jobs[JobId(0)].preemptions, 0);
    }

    #[test]
    fn draining_job_finishing_early_completes() {
        // progress_during_grace = true: a victim whose remaining < GP
        // finishes during the drain instead of being suspended.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 3, 10),
            JobSpec::new(1, JobClass::Te, rv(32.0, 256.0, 8.0), 1, 5, 0),
        ]);
        let mut cfg = SchedConfig::new(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        cfg.progress_during_grace = true;
        let mut sched = Scheduler::new(&spec, cfg);
        sched.paranoid = true;
        let mut now = 0;
        while now < 100 {
            let mut arrivals: Vec<JobId> =
                jobs.iter().filter(|j| j.spec.submit == now).map(|j| j.id()).collect();
            arrivals.sort();
            sched.tick(now, &mut jobs, &arrivals);
            now += 1;
            if jobs.iter().all(|j| j.state == JobState::Done) {
                break;
            }
        }
        assert_eq!(jobs[JobId(0)].preemptions, 0, "finished during drain, never vacated");
        assert_eq!(jobs[JobId(0)].finished_at, Some(3));
    }

    #[test]
    fn burn_many_matches_repeated_ticks_on_quiescent_state() {
        // One running job, one queued job blocked behind it: burning 5
        // minutes in bulk must equal five per-minute ticks.
        let spec = ClusterSpec::tiny(1);
        let mk = || {
            mkjobs(vec![
                JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
                JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 20, 0),
            ])
        };
        let drive = |jobs: &mut JobTable| {
            let mut sched = Scheduler::new(&spec, SchedConfig::new(PolicyKind::Fifo));
            let mut arrivals: Vec<JobId> = jobs.iter().map(|j| j.id()).collect();
            arrivals.sort();
            sched.tick(0, jobs, &arrivals);
            sched
        };
        let mut a = mk();
        let mut sa = drive(&mut a);
        assert!(sa.quiescent(&a), "blocked BE head is quiescent");
        // Job 0 started at t=0 with 50 minutes ⇒ completion event at t=50.
        assert_eq!(sa.next_internal_at(&a), Some(50));
        sa.burn_many(5);

        let mut b = mk();
        let mut sb = drive(&mut b);
        for t in 1..=5 {
            sb.tick(t, &mut b, &[]);
        }
        assert_eq!(a[JobId(0)].remaining, b[JobId(0)].remaining);
        assert_eq!(a[JobId(1)].waiting, b[JobId(1)].waiting);
        assert_eq!(sa.stats.ticks, sb.stats.ticks);
        assert_eq!(sa.stats.fast_forwards, 1);
        assert_eq!(sa.stats.fast_forwarded_ticks, 5);
    }

    #[test]
    fn te_without_draining_reservation_blocks_quiescence() {
        // A queued TE job whose plan found nothing to preempt must force
        // per-minute stepping (its admission path replans every tick).
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            // TE job filling the node; a second TE cannot preempt it.
            JobSpec::new(0, JobClass::Te, rv(32.0, 256.0, 8.0), 0, 30, 0),
            JobSpec::new(1, JobClass::Te, rv(32.0, 256.0, 8.0), 0, 5, 0),
        ]);
        let mut sched = Scheduler::new(
            &spec,
            SchedConfig::new(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
        );
        let mut arrivals: Vec<JobId> = jobs.iter().map(|j| j.id()).collect();
        arrivals.sort();
        sched.tick(0, &mut jobs, &arrivals);
        assert_eq!(sched.te_queue.len(), 1);
        assert!(!sched.quiescent(&jobs));
    }

    #[test]
    fn stats_track_te_without_preemption() {
        let spec = ClusterSpec::tiny(2);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Te, rv(4.0, 32.0, 1.0), 0, 5, 0),
            JobSpec::new(1, JobClass::Te, rv(4.0, 32.0, 1.0), 0, 5, 0),
        ]);
        let (sched, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, &spec, &mut jobs);
        assert_eq!(sched.stats.te_no_preemption, 2);
        assert_eq!(sched.stats.plans, 0);
    }

    #[test]
    fn fail_node_evicts_and_requeues_with_priority() {
        // Two nodes; node 0 hosts job 0, node 1 hosts job 1; job 2 queued.
        let spec = ClusterSpec::tiny(2);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
            JobSpec::new(2, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
        ]);
        let mut sched = Scheduler::new(&spec, SchedConfig::new(PolicyKind::Fifo));
        sched.paranoid = true;
        sched.tick(0, &mut jobs, &[JobId(0), JobId(1), JobId(2)]);
        assert_eq!(jobs[JobId(0)].state, JobState::Running);
        assert_eq!(jobs[JobId(1)].state, JobState::Running);

        let lost = sched.fail_node(crate::cluster::NodeId(0), 1, &mut jobs);
        assert_eq!(lost, vec![JobId(0)]);
        assert_eq!(jobs[JobId(0)].state, JobState::Pending);
        assert_eq!(jobs[JobId(0)].evictions, 1);
        assert_eq!(jobs[JobId(0)].preemptions, 0);
        // The evicted job jumped the queue: it restarts before job 2 once
        // capacity returns.
        let mut order = Vec::new();
        sched.be_queue.for_each(&mut |id| order.push(id));
        assert_eq!(order.first(), Some(&JobId(0)));

        // With node 0 down, nothing can be placed on it; restoring brings
        // the evicted job back ahead of the queue.
        sched.tick(1, &mut jobs, &[]);
        assert_eq!(jobs[JobId(0)].state, JobState::Pending, "no capacity while down");
        sched.restore_node(crate::cluster::NodeId(0), &jobs);
        sched.tick(2, &mut jobs, &[]);
        assert_eq!(jobs[JobId(0)].state, JobState::Running);
        assert_eq!(jobs[JobId(2)].state, JobState::Pending, "priority preserved");
    }

    #[test]
    fn drain_node_blocks_placement_but_keeps_tenants() {
        let spec = ClusterSpec::tiny(2);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(4.0, 32.0, 1.0), 0, 5, 0),
            JobSpec::new(1, JobClass::Be, rv(4.0, 32.0, 1.0), 1, 5, 0),
        ]);
        let mut sched = Scheduler::new(&spec, SchedConfig::new(PolicyKind::Fifo));
        sched.paranoid = true;
        sched.tick(0, &mut jobs, &[JobId(0)]);
        let host = jobs[JobId(0)].node.unwrap();
        sched.drain_node(host);
        // Job 1 arrives: it must land on the other node.
        sched.tick(1, &mut jobs, &[JobId(1)]);
        assert_eq!(jobs[JobId(1)].state, JobState::Running);
        assert_ne!(jobs[JobId(1)].node.unwrap(), host);
        // The tenant runs to completion undisturbed.
        for t in 2..8 {
            sched.tick(t, &mut jobs, &[]);
        }
        assert_eq!(jobs[JobId(0)].state, JobState::Done);
    }

    #[test]
    fn discard_covers_queued_and_active_jobs() {
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
            JobSpec::new(1, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
        ]);
        let mut sched = Scheduler::new(&spec, SchedConfig::new(PolicyKind::Fifo));
        sched.paranoid = true;
        sched.tick(0, &mut jobs, &[JobId(0), JobId(1)]);
        assert!(sched.tracks(JobId(0)) && sched.tracks(JobId(1)));

        // Queued job: vanishes from the queue.
        assert!(sched.discard(JobId(1), &mut jobs));
        assert!(!sched.tracks(JobId(1)));
        // Active job: resources come back.
        assert!(sched.discard(JobId(0), &mut jobs));
        assert!(sched.idle());
        sched.cluster.check_invariants().unwrap();
        // Unknown job: declined.
        assert!(!sched.discard(JobId(7), &mut jobs));
    }

    #[test]
    fn reclassify_moves_queued_job_between_lanes() {
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 50, 0),
            JobSpec::new(1, JobClass::Be, rv(4.0, 32.0, 1.0), 0, 50, 0),
        ]);
        let mut sched = Scheduler::new(
            &spec,
            SchedConfig::new(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
        );
        sched.tick(0, &mut jobs, &[JobId(0), JobId(1)]);
        // Job 1 is stuck behind the full node in the BE queue.
        assert_eq!(jobs[JobId(1)].state, JobState::Pending);
        sched.reclassify(JobId(1), JobClass::Te, &mut jobs).unwrap();
        assert_eq!(sched.te_queue.len(), 1, "promoted into the TE lane");
        assert_eq!(sched.be_queue.len(), 0);
        // Running jobs flip in place; draining jobs are refused.
        sched.reclassify(JobId(0), JobClass::Te, &mut jobs).unwrap();
        assert_eq!(jobs[JobId(0)].spec.class, JobClass::Te);
        assert!(sched.reclassify(JobId(9), JobClass::Be, &mut jobs).is_err());
    }

    #[test]
    fn quota_gate_skips_over_quota_head_without_stalling_others() {
        use crate::sched::admission::DisciplineKind;
        // One node [32,256,8]; each job asks for half of everything, so
        // Size vs the cluster total is ~0.866. Tenant 0's quota of 0.5
        // admits one job (under-cap overshoot) and then gates the next;
        // tenant 1 must slip past the gated head.
        let spec = ClusterSpec::tiny(1);
        let half = rv(16.0, 128.0, 4.0);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, half, 0, 50, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(1, JobClass::Be, half, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(2, JobClass::Be, half, 0, 5, 0).with_tenant(crate::job::TenantId(1)),
        ]);
        let mut cfg = SchedConfig::new(PolicyKind::Fifo);
        cfg.discipline = DisciplineKind::QuotaGate { backfill: 8 };
        cfg.default_quota = Some(0.5);
        let (sched, _) = run_cfg(cfg, &spec, &mut jobs);
        assert_eq!(jobs[JobId(0)].first_start, Some(0));
        assert_eq!(
            jobs[JobId(2)].first_start,
            Some(0),
            "tenant 1 is not stalled by tenant 0's gated head"
        );
        // Job 1 waits for its own tenant's drain, then runs (conservation).
        assert_eq!(jobs[JobId(1)].first_start, Some(50));
        assert_eq!(jobs[JobId(1)].state, JobState::Done);
        // The skip was reported exactly once, despite ~50 gated rounds.
        assert_eq!(sched.stats.admission_skips, 1, "fresh transitions only");
        assert_eq!(sched.stats.internal_errors, 0);
    }

    #[test]
    fn weighted_fair_interleaves_tenants_on_a_serial_node() {
        use crate::sched::admission::DisciplineKind;
        // Node fits one job at a time. Tenant 0 queues three jobs, tenant
        // 1 queues one: under FIFO it would run last (t=15); weighted-fair
        // rotates it in right after tenant 0's first job.
        let spec = ClusterSpec::tiny(1);
        let full = rv(32.0, 256.0, 8.0);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(1, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(2, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(3, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(1)),
        ]);
        let mut cfg = SchedConfig::new(PolicyKind::Fifo);
        cfg.discipline = DisciplineKind::WeightedFair;
        let (_, _) = run_cfg(cfg, &spec, &mut jobs);
        assert_eq!(jobs[JobId(0)].first_start, Some(0));
        assert_eq!(jobs[JobId(3)].first_start, Some(5), "tenant 1's turn after one job");
        assert_eq!(jobs[JobId(1)].first_start, Some(10));
        assert_eq!(jobs[JobId(2)].first_start, Some(15));
    }

    #[test]
    fn set_weight_changes_the_rotation() {
        use crate::sched::admission::DisciplineKind;
        // Same serial node, but tenant 0 is worth two turns.
        let spec = ClusterSpec::tiny(1);
        let full = rv(32.0, 256.0, 8.0);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(1, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(2, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(0)),
            JobSpec::new(3, JobClass::Be, full, 0, 5, 0).with_tenant(crate::job::TenantId(1)),
        ]);
        let mut cfg = SchedConfig::new(PolicyKind::Fifo);
        cfg.discipline = DisciplineKind::WeightedFair;
        let mut sched = Scheduler::new(&spec, cfg);
        sched.paranoid = true;
        sched.set_weight(crate::job::TenantId(0), 2);
        let mut now = 0;
        loop {
            let arrivals: Vec<JobId> = if now == 0 {
                vec![JobId(0), JobId(1), JobId(2), JobId(3)]
            } else {
                Vec::new()
            };
            sched.tick(now, &mut jobs, &arrivals);
            now += 1;
            if sched.idle() {
                break;
            }
            assert!(now < 100);
        }
        assert_eq!(jobs[JobId(1)].first_start, Some(5), "second turn of the weight-2 tenant");
        assert_eq!(jobs[JobId(3)].first_start, Some(10), "tenant 1 after the double turn");
    }

    #[test]
    fn tenant_usage_tracks_occupancy_through_preemption() {
        use crate::job::TenantId;
        // FitGpp preempts tenant 0's BE job for a TE job; occupied size
        // must drop when the victim vacates and return when it resumes.
        let spec = ClusterSpec::tiny(1);
        let mut jobs = mkjobs(vec![
            JobSpec::new(0, JobClass::Be, rv(32.0, 256.0, 8.0), 0, 30, 0)
                .with_tenant(TenantId(0)),
            JobSpec::new(1, JobClass::Te, rv(32.0, 256.0, 8.0), 1, 3, 0)
                .with_tenant(TenantId(1)),
        ]);
        let mut sched = Scheduler::new(
            &spec,
            SchedConfig::new(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }),
        );
        sched.paranoid = true;
        sched.tick(0, &mut jobs, &[JobId(0)]);
        assert!(sched.tenant_occupied_size(TenantId(0)) > 1.0);
        sched.tick(1, &mut jobs, &[JobId(1)]);
        // Zero-GP victim vacated in the same tick; the TE job occupies.
        assert_eq!(sched.tenant_occupied_size(TenantId(0)), 0.0);
        assert!(sched.tenant_occupied_size(TenantId(1)) > 1.0);
        for t in 2..40 {
            sched.tick(t, &mut jobs, &[]);
        }
        assert!(sched.idle());
        assert_eq!(sched.tenant_occupied_size(TenantId(0)), 0.0);
        assert_eq!(sched.tenant_occupied_size(TenantId(1)), 0.0);
    }

    #[test]
    fn no_internal_errors_across_a_mixed_run() {
        let spec = ClusterSpec::tiny(2);
        let mut jobs = mkjobs(
            (0..24)
                .map(|i| {
                    JobSpec::new(
                        i,
                        if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                        rv(6.0 + (i % 4) as f64 * 8.0, 48.0, (i % 3) as f64),
                        (i as u64) / 2,
                        4 + (i as u64 % 11),
                        (i as u64) % 4,
                    )
                })
                .collect(),
        );
        let (sched, _) = run(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }, &spec, &mut jobs);
        assert_eq!(sched.stats.internal_errors, 0);
    }
}
