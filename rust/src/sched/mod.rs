//! The scheduler: FIFO admission core plus pluggable preemption policies
//! (§3 of the paper).

pub mod core;
pub mod policy;

pub use core::{SchedConfig, SchedStats, Scheduler, TickStats};
pub use policy::{PolicyKind, PreemptionPlan};
