//! The scheduler: tenant-aware admission, the FIFO core, the event clock,
//! pluggable preemption policies (§3 of the paper), and the control-plane
//! protocol.
//!
//! Six layers: [`admission`] decides *which queued job to try next*
//! (behind the object-safe [`QueueDiscipline`](admission::QueueDiscipline)
//! trait — FIFO, weighted-fair, quota-gated), [`policy`] decides *whom to
//! evict* (behind the [`PreemptionPolicy`](policy::PreemptionPolicy)
//! trait), [`predict`] estimates *how long jobs will run* (behind the
//! [`RuntimeEstimator`](predict::RuntimeEstimator) trait, feeding the
//! prediction-aware policies), [`clock`] knows *when anything happens
//! next* (min-heaps, no job-table rescans), [`victim_index`] keeps the
//! preemptible pool pre-sorted so planning never rescans the cluster, the
//! [`core`] ties them to the cluster's incremental capacity index, and
//! [`control`] is the public
//! face: a typed
//! [`SchedulerCommand`](control::SchedulerCommand) /
//! [`SchedulerEvent`](control::SchedulerEvent) protocol consumed by the
//! [`ClusterController`](control::ClusterController) facade that both the
//! simulator and the live executor drive.

// Perf-sensitive tree: silent copies and churny buffer idioms are bugs
// here, not style nits (the hot path is pinned allocation-free by the
// perf gate).
#![deny(
    clippy::redundant_clone,
    clippy::large_enum_variant,
    clippy::vec_init_then_push
)]

pub mod admission;
pub mod clock;
pub mod control;
pub mod core;
pub mod policy;
pub mod predict;
pub mod victim_index;

pub use admission::{DisciplineKind, QueueDiscipline, TenantDirectory};
pub use clock::EventClock;
pub use control::{
    ClusterController, EventSubscriber, JsonlErrorFlag, JsonlEventLog, SchedulerCommand,
    SchedulerEvent, SharedBuf, SharedEventLog, StepOutcome,
};
pub use core::{SchedConfig, SchedStats, Scheduler, TickStats};
pub use policy::{PolicyKind, PreemptionPlan, PreemptionPolicy};
pub use predict::{EstimatorKind, RuntimeEstimator, SharedEstimator};
pub use victim_index::VictimIndex;
