//! The scheduler: FIFO admission core, the event clock, and pluggable
//! preemption policies (§3 of the paper).
//!
//! Three layers: [`policy`] decides *whom to evict* (behind the
//! [`PreemptionPolicy`](policy::PreemptionPolicy) trait), [`clock`] knows
//! *when anything happens next* (min-heaps, no job-table rescans), and the
//! [`core`] ties them to the cluster's incremental capacity index.

pub mod clock;
pub mod core;
pub mod policy;

pub use clock::EventClock;
pub use core::{SchedConfig, SchedStats, Scheduler, TickStats};
pub use policy::{PolicyKind, PreemptionPlan, PreemptionPolicy};
