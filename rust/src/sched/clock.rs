//! The event clock: min-heaps over the scheduler's three future event
//! streams — job completions, grace-period expiries, and workload arrivals.
//!
//! Both simulator drive modes consume the same clock (see
//! [`sim`](crate::sim)): the per-minute mode uses it to skip the per-tick
//! job-table scan on event-free minutes, and the event-horizon mode
//! additionally reads [`EventClock::next_internal_at`] to know how far a
//! quiescent span may be fast-forwarded in one
//! [`burn_many`](crate::sched::Scheduler::burn_many) call. Either way the
//! scheduler no longer rescans the whole job table to answer "when does
//! anything happen next?" — that query is a heap peek.
//!
//! ## Lazy invalidation by epoch
//!
//! Events are predictions: "job `j` completes at minute `t`" is only true
//! while `j` keeps running every minute until `t`. Instead of deleting
//! entries from the middle of a heap when a prediction dies (a preempted
//! job no longer completes on schedule), every entry is stamped with the
//! job's epoch — a counter kept in the job table's struct-of-arrays epoch
//! column and bumped via [`JobTable::bump_epoch`] on every lifecycle
//! transition. An entry whose stamp no longer matches the job's current
//! epoch is *stale* and is discarded the first time it reaches the top of
//! its heap. A job that has been *retired* from the [`JobTable`]
//! (completed and folded into a metrics sink by the streaming simulator)
//! has no epoch at all — [`JobTable::epoch_of`] returns `None` — and any
//! leftover entries for it are likewise stale. Live entries are exact: the
//! scheduler pushes them only at transitions, and a job's lazily-accounted
//! counters (remaining time, grace left — see [`Job::sync`](crate::job::Job::sync))
//! reach zero exactly at the stamped minute, so the stamp is precisely
//! when the event fires.
//!
//! Arrivals need no epochs — submission times are immutable workload data.
//! Under the streaming simulator only arrivals inside the bounded
//! lookahead window are resident here; the earlier ones live in the
//! [`ArrivalSource`](crate::workload::source::ArrivalSource) until pulled.

use crate::job::JobId;
use crate::job_table::JobTable;
use crate::util::bin::{BinReader, BinWriter};
use crate::Minutes;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One prediction: `(minute, job, epoch-at-push)`. Ordered by minute, then
/// job id, so heap ties are deterministic.
type Entry = (Minutes, u32, u64);

/// Min-heaps over the scheduler's future events. See the module docs for
/// the staleness protocol.
///
/// The fourth heap, `controls`, carries **control-plane wakeups**: minutes
/// at which a [`ScenarioScript`](crate::sim::scenario::ScenarioScript)
/// injects a command (cancellation, node failure/restore, drain, resize)
/// or a deferred action (a TE patience deadline, a held-over cancel) may
/// fire. Entries are bare minutes — the scenario driver owns *what*
/// happens; the clock only answers *when next*, so the event-horizon
/// engine never fast-forwards across an injection point. Stale wakeups
/// (e.g. a patience deadline for a TE job that started in time) cost one
/// spurious per-minute tick and nothing else.
#[derive(Debug, Default)]
pub struct EventClock {
    /// Predicted completions of running (or, under progress-during-grace,
    /// draining) jobs.
    completions: BinaryHeap<Reverse<Entry>>,
    /// Predicted grace-period expiries of draining jobs.
    grace_expiries: BinaryHeap<Reverse<Entry>>,
    /// Workload arrivals `(submit minute, job)`; immutable, never stale.
    arrivals: BinaryHeap<Reverse<(Minutes, u32)>>,
    /// Control-plane wakeup minutes (scenario commands, patience
    /// deadlines, held-over cancellations).
    controls: BinaryHeap<Reverse<Minutes>>,
}

/// Is the entry's prediction still live? Retired jobs have no epoch.
fn is_live(jobs: &JobTable, id: u32, epoch: u64) -> bool {
    jobs.epoch_of(JobId(id)) == Some(epoch)
}

/// Discard stale heads, then report the head's minute without popping it.
fn live_peek(heap: &mut BinaryHeap<Reverse<Entry>>, jobs: &JobTable) -> Option<Minutes> {
    while let Some(Reverse((at, id, epoch))) = heap.peek().copied() {
        if is_live(jobs, id, epoch) {
            return Some(at);
        }
        heap.pop();
    }
    None
}

/// Pop every entry scheduled at or before `now`; true iff any was live.
fn drain_due(heap: &mut BinaryHeap<Reverse<Entry>>, now: Minutes, jobs: &JobTable) -> bool {
    let mut any = false;
    while let Some(Reverse((at, id, epoch))) = heap.peek().copied() {
        if at > now {
            break;
        }
        heap.pop();
        if is_live(jobs, id, epoch) {
            debug_assert_eq!(at, now, "live event for {id} missed its minute");
            any = true;
        }
    }
    any
}

impl EventClock {
    /// An empty clock.
    pub fn new() -> Self {
        EventClock::default()
    }

    /// Schedule a predicted completion of `job` at minute `at`, valid while
    /// the job stays in its current `epoch`.
    pub fn push_completion(&mut self, at: Minutes, job: JobId, epoch: u64) {
        self.completions.push(Reverse((at, job.0, epoch)));
    }

    /// Schedule a predicted grace-period expiry of `job` at minute `at`.
    pub fn push_grace_expiry(&mut self, at: Minutes, job: JobId, epoch: u64) {
        self.grace_expiries.push(Reverse((at, job.0, epoch)));
    }

    /// Register a workload arrival (the streaming simulator pushes each
    /// arrival when it pulls the job from its source).
    pub fn push_arrival(&mut self, at: Minutes, job: JobId) {
        self.arrivals.push(Reverse((at, job.0)));
    }

    /// Minute of the next pending arrival, if any.
    pub fn next_arrival_at(&self) -> Option<Minutes> {
        self.arrivals.peek().map(|Reverse((at, _))| *at)
    }

    /// Pop one arrival due exactly at `now` (submission order within the
    /// minute: ids are dense in submission order and break heap ties).
    pub fn pop_arrival_due(&mut self, now: Minutes) -> Option<JobId> {
        match self.arrivals.peek() {
            Some(Reverse((at, _))) if *at == now => {
                self.arrivals.pop().map(|Reverse((_, id))| JobId(id))
            }
            _ => None,
        }
    }

    /// Are any arrivals still pending?
    pub fn arrivals_pending(&self) -> bool {
        !self.arrivals.is_empty()
    }

    /// Register a control-plane wakeup at minute `at` (scenario command
    /// times, TE patience deadlines, held-over cancellations). Duplicates
    /// are harmless.
    pub fn push_control(&mut self, at: Minutes) {
        self.controls.push(Reverse(at));
    }

    /// Minute of the next control-plane wakeup, if any. The event-horizon
    /// engine includes this in its burn-target minimum so no quiescent
    /// span ever crosses a command injection point.
    pub fn next_control_at(&self) -> Option<Minutes> {
        self.controls.peek().map(|Reverse(at)| *at)
    }

    /// Discard every control wakeup at or before `now`; true iff any was
    /// due. The scenario driver calls this each tick it services, keeping
    /// the heap bounded by the not-yet-fired injection points.
    pub fn pop_controls_due(&mut self, now: Minutes) -> bool {
        let mut any = false;
        while let Some(Reverse(at)) = self.controls.peek().copied() {
            if at > now {
                break;
            }
            self.controls.pop();
            any = true;
        }
        any
    }

    /// Consume every internal event due at `now` (and discard stale
    /// leftovers). Returns true iff a *live* completion or grace expiry is
    /// due — i.e. the scheduler's completion/expiry scan has work to do
    /// this tick.
    pub fn take_due(&mut self, now: Minutes, jobs: &JobTable) -> bool {
        // `|` not `||`: both heaps must drain even when the first had work.
        drain_due(&mut self.completions, now, jobs) | drain_due(&mut self.grace_expiries, now, jobs)
    }

    /// Consume every internal event due at `now` into `due`: the sorted,
    /// deduplicated ids of jobs with a *live* completion or grace expiry
    /// due this minute (stale leftovers are discarded along the way).
    /// `due` is a caller-owned scratch buffer — cleared here and refilled
    /// in place, so steady-state rounds reuse its capacity instead of
    /// allocating. The heaps likewise only shrink, never reallocate.
    pub fn take_due_into(&mut self, now: Minutes, jobs: &JobTable, due: &mut Vec<u32>) {
        due.clear();
        for heap in [&mut self.completions, &mut self.grace_expiries] {
            while let Some(Reverse((at, id, epoch))) = heap.peek().copied() {
                if at > now {
                    break;
                }
                heap.pop();
                if is_live(jobs, id, epoch) {
                    debug_assert_eq!(at, now, "live event for {id} missed its minute");
                    due.push(id);
                }
            }
        }
        // A job can have both a completion and a grace expiry due on the
        // same minute (progress-during-grace): dedup so the applier visits
        // it once.
        due.sort_unstable();
        due.dedup();
    }

    /// Absolute minute of the next live internal event (completion or
    /// grace expiry), or `None` when nothing occupies resources. Stale
    /// heads are discarded on the way.
    pub fn next_internal_at(&mut self, jobs: &JobTable) -> Option<Minutes> {
        let c = live_peek(&mut self.completions, jobs);
        let g = live_peek(&mut self.grace_expiries, jobs);
        match (c, g) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (x, None) => x,
            (None, y) => y,
        }
    }

    /// Entries currently held across all heaps (diagnostics; includes
    /// stale entries awaiting lazy discard).
    pub fn len(&self) -> usize {
        self.completions.len()
            + self.grace_expiries.len()
            + self.arrivals.len()
            + self.controls.len()
    }

    /// True when no entries are held at all.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Serialize every heap for a snapshot. Entries are written in sorted
    /// order (heap-internal layout is arbitrary, but entry tuples have a
    /// total order, so the *multiset* fully determines future pop order) —
    /// this makes the snapshot bytes themselves deterministic. Stale
    /// (epoch-invalidated) entries are written verbatim: discarding them
    /// here would need a `JobTable` and would change nothing observable,
    /// since they are lazily dropped on either side of the snapshot.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        let mut entries: Vec<Entry> = self.completions.iter().map(|Reverse(e)| *e).collect();
        entries.sort_unstable();
        w.seq(entries.len());
        for (at, id, epoch) in &entries {
            w.u64(*at);
            w.u32(*id);
            w.u64(*epoch);
        }
        let mut entries: Vec<Entry> = self.grace_expiries.iter().map(|Reverse(e)| *e).collect();
        entries.sort_unstable();
        w.seq(entries.len());
        for (at, id, epoch) in &entries {
            w.u64(*at);
            w.u32(*id);
            w.u64(*epoch);
        }
        let mut arrivals: Vec<(Minutes, u32)> = self.arrivals.iter().map(|Reverse(e)| *e).collect();
        arrivals.sort_unstable();
        w.seq(arrivals.len());
        for (at, id) in &arrivals {
            w.u64(*at);
            w.u32(*id);
        }
        let mut controls: Vec<Minutes> = self.controls.iter().map(|Reverse(m)| *m).collect();
        controls.sort_unstable();
        w.seq(controls.len());
        for at in &controls {
            w.u64(*at);
        }
    }

    /// Rebuild a clock written by [`EventClock::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let mut clock = EventClock::new();
        let n = r.seq()?;
        for _ in 0..n {
            let entry = (r.u64()?, r.u32()?, r.u64()?);
            clock.completions.push(Reverse(entry));
        }
        let n = r.seq()?;
        for _ in 0..n {
            let entry = (r.u64()?, r.u32()?, r.u64()?);
            clock.grace_expiries.push(Reverse(entry));
        }
        let n = r.seq()?;
        for _ in 0..n {
            let entry = (r.u64()?, r.u32()?);
            clock.arrivals.push(Reverse(entry));
        }
        let n = r.seq()?;
        for _ in 0..n {
            let at = r.u64()?;
            clock.controls.push(Reverse(at));
        }
        Ok(clock)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{Job, JobClass, JobSpec};
    use crate::resources::ResourceVec;

    fn job(id: u32) -> Job {
        Job::new(JobSpec::new(id, JobClass::Be, ResourceVec::new(1.0, 1.0, 0.0), 0, 10, 2))
    }

    fn table(n: u32) -> JobTable {
        JobTable::from_jobs((0..n).map(job).collect())
    }

    #[test]
    fn arrivals_pop_in_time_then_id_order() {
        let mut c = EventClock::new();
        c.push_arrival(5, JobId(2));
        c.push_arrival(3, JobId(1));
        c.push_arrival(3, JobId(0));
        assert_eq!(c.next_arrival_at(), Some(3));
        assert_eq!(c.pop_arrival_due(3), Some(JobId(0)));
        assert_eq!(c.pop_arrival_due(3), Some(JobId(1)));
        assert_eq!(c.pop_arrival_due(3), None, "next arrival is at 5");
        assert!(c.arrivals_pending());
        assert_eq!(c.pop_arrival_due(5), Some(JobId(2)));
        assert!(!c.arrivals_pending());
    }

    #[test]
    fn stale_entries_are_discarded() {
        let mut c = EventClock::new();
        let mut jobs = table(1);
        c.push_completion(10, JobId(0), jobs.epoch_of(JobId(0)).unwrap());
        assert_eq!(c.next_internal_at(&jobs), Some(10));
        // A lifecycle transition invalidates the prediction.
        jobs.bump_epoch(JobId(0));
        assert_eq!(c.next_internal_at(&jobs), None);
        assert!(c.is_empty(), "stale head was discarded by the peek");
    }

    #[test]
    fn retired_jobs_entries_are_stale() {
        let mut c = EventClock::new();
        let mut jobs = table(1);
        c.push_completion(10, JobId(0), jobs.epoch_of(JobId(0)).unwrap());
        jobs.remove(JobId(0)); // streaming simulator retired it
        assert_eq!(c.next_internal_at(&jobs), None);
        assert!(c.is_empty());
    }

    #[test]
    fn take_due_reports_live_events_only() {
        let mut c = EventClock::new();
        let mut jobs = table(2);
        c.push_completion(4, JobId(0), jobs.epoch_of(JobId(0)).unwrap());
        c.push_grace_expiry(4, JobId(1), jobs.epoch_of(JobId(1)).unwrap());
        jobs.bump_epoch(JobId(1)); // grace prediction dies
        assert!(!c.take_due(3, &jobs), "nothing due before minute 4");
        assert!(c.take_due(4, &jobs), "live completion at 4");
        assert!(!c.take_due(4, &jobs), "events are consumed");
        assert!(c.is_empty());
    }

    #[test]
    fn take_due_into_collects_sorted_deduped_live_ids() {
        let mut c = EventClock::new();
        let mut jobs = table(3);
        let e0 = jobs.epoch_of(JobId(0)).unwrap();
        let e1 = jobs.epoch_of(JobId(1)).unwrap();
        let e2 = jobs.epoch_of(JobId(2)).unwrap();
        c.push_completion(4, JobId(2), e2);
        c.push_completion(4, JobId(0), e0);
        c.push_grace_expiry(4, JobId(0), e0); // duplicate id across heaps
        c.push_grace_expiry(4, JobId(1), e1);
        jobs.bump_epoch(JobId(1)); // this expiry is stale
        let mut due = Vec::new();
        c.take_due_into(3, &jobs, &mut due);
        assert!(due.is_empty(), "nothing due before minute 4");
        c.take_due_into(4, &jobs, &mut due);
        assert_eq!(due, vec![0, 2], "sorted, deduped, stale dropped");
        c.take_due_into(4, &jobs, &mut due);
        assert!(due.is_empty(), "events are consumed");
        assert!(c.is_empty());
    }

    #[test]
    fn control_wakeups_order_and_drain() {
        let mut c = EventClock::new();
        c.push_control(9);
        c.push_control(3);
        c.push_control(3); // duplicates are fine
        assert_eq!(c.next_control_at(), Some(3));
        assert!(!c.pop_controls_due(2), "nothing due yet");
        assert!(c.pop_controls_due(3), "both minute-3 entries drain");
        assert_eq!(c.next_control_at(), Some(9));
        assert!(c.pop_controls_due(100), "late drains catch up");
        assert_eq!(c.next_control_at(), None);
        assert!(c.is_empty());
    }

    #[test]
    fn next_internal_is_min_across_heaps() {
        let mut c = EventClock::new();
        let jobs = table(2);
        c.push_completion(9, JobId(0), jobs.epoch_of(JobId(0)).unwrap());
        c.push_grace_expiry(6, JobId(1), jobs.epoch_of(JobId(1)).unwrap());
        assert_eq!(c.next_internal_at(&jobs), Some(6));
    }
}
