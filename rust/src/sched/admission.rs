//! The admission layer: pluggable queue disciplines, tenant weights, and
//! running-size quotas.
//!
//! The paper's scheduler examines only the head of one global FIFO (§2) —
//! which is exactly the head-of-line blocking FitGpp mitigates. Fairness
//! and quota enforcement for a multi-tenant cluster live one layer *up*
//! from preemption: at admission, deciding **which queued job to try
//! next**, orthogonally to the policy's *whom to evict*. This module is
//! that layer.
//!
//! ## The discipline protocol
//!
//! [`QueueDiscipline`] is an object-safe trait the scheduler core drives
//! once per tick in an *admission round*:
//!
//! 1. [`begin_round`](QueueDiscipline::begin_round) resets round-local
//!    cursor state;
//! 2. [`next_candidate`](QueueDiscipline::next_candidate) yields the next
//!    queued job to attempt (or `None` — round over);
//! 3. the scheduler attempts it (quota check, node search, placement) and
//!    [`report`](QueueDiscipline::report)s the [`AdmitOutcome`], which the
//!    discipline turns into its blocking / skipping / rotation rule.
//!
//! **The frozen-state contract.** A round that places nothing must leave
//! all *persistent* discipline state untouched, and its candidate sequence
//! must be a pure function of (discipline state, job table, cluster
//! state, tenant directory). The event-horizon engine relies on this: a
//! quiescent span skips whole ticks, so a placement-free round replayed on
//! frozen state must reproduce itself exactly or the two simulator drive
//! modes would diverge. All round-local state (cursors, blocked sets,
//! backfill budgets) is reset by `begin_round`; persistent state (the
//! round-robin turn, queue contents) moves only on placements — which only
//! happen on ticks both engines execute.
//!
//! ## Disciplines
//!
//! * [`Fifo`] — verbatim port of the original [`JobQueue`] admission loop,
//!   byte-identical including the preemption re-insertion rule (§2:
//!   *"suspended BE jobs are placed back on the top of the job queue"*)
//!   and the blocked-head semantics. The default.
//! * [`WeightedFair`] — per-tenant sub-queues with weighted round-robin
//!   across tenants: the turn tenant admits up to `weight` jobs before the
//!   turn rotates, and a tenant whose head is blocked is skipped *for this
//!   round only*, so one tenant's blocked head no longer stalls the rest.
//!   Every non-empty tenant's head is attempted at least once per round —
//!   the starvation bound `rust/tests/properties.rs` pins.
//! * [`QuotaGate`] — the global FIFO order, but over-quota heads are
//!   *skipped* (not blocked), and up to `backfill` blocked (doesn't-fit)
//!   heads per scan are stepped over so small jobs behind a blocked head
//!   can backfill.
//!
//! ## Quotas and weights
//!
//! Per-tenant state lives in the scheduler-owned [`TenantDirectory`]
//! (mutated by the control plane's `SetQuota` / `SetWeight` commands), not
//! in the disciplines. A quota caps a tenant's **occupied Size** — the
//! Eq. 1 `Size` of all its Running + Draining demand, measured against the
//! cluster's total capacity at scheduler construction. The cap is checked
//! *before* admission: a tenant strictly below its cap may overshoot by at
//! most one job, which guarantees every queued job stays admissible once
//! the tenant drains (the conservation property). A quota of `0` is a full
//! stop for the tenant. The TE fast lane is *not* quota-gated: TE latency
//! is the paper's whole objective, and the lane is already per-arrival
//! (no head-of-line blocking to fix); tenant quotas gate the shared/BE
//! queue, while TE occupancy still *counts against* the tenant's usage.

use crate::job::{JobId, TenantId};
use crate::queue::JobQueue;
use crate::util::bin::{BinReader, BinWriter};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt;

/// Which queue discipline admits jobs. Plain data (config/CLI surface,
/// like [`PolicyKind`](crate::sched::policy::PolicyKind)); behaviour is
/// built once per run by [`build_discipline`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum DisciplineKind {
    /// The paper's single global FIFO: head-only admission, a blocked head
    /// blocks everything behind it. The default — byte-identical to the
    /// pre-admission-layer scheduler.
    #[default]
    Fifo,
    /// Weighted round-robin over per-tenant FIFO sub-queues.
    WeightedFair,
    /// Global FIFO with over-quota skip and a bounded backfill window.
    QuotaGate {
        /// How many blocked (doesn't-fit) heads one scan may step over
        /// before the round ends (≥ 1).
        backfill: usize,
    },
}

/// Default backfill window for [`DisciplineKind::QuotaGate`].
pub const DEFAULT_BACKFILL: usize = 8;

impl DisciplineKind {
    /// Human-readable name (tables, logs).
    pub fn name(&self) -> String {
        match self {
            DisciplineKind::Fifo => "fifo".to_string(),
            DisciplineKind::WeightedFair => "weighted_fair".to_string(),
            DisciplineKind::QuotaGate { backfill } => format!("quota_gate:w={backfill}"),
        }
    }

    /// Parse the CLI form: `fifo` | `weighted_fair` | `quota_gate` |
    /// `quota_gate:w=<n>`.
    pub fn parse(s: &str) -> Result<DisciplineKind> {
        let s = s.trim();
        match s {
            "fifo" => return Ok(DisciplineKind::Fifo),
            "weighted_fair" | "wfq" => return Ok(DisciplineKind::WeightedFair),
            "quota_gate" => return Ok(DisciplineKind::QuotaGate { backfill: DEFAULT_BACKFILL }),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("quota_gate:") {
            let Some(raw) = rest.strip_prefix("w=") else {
                bail!("bad discipline {s:?}: expected quota_gate:w=<n>");
            };
            let w: usize = raw
                .parse()
                .map_err(|e| anyhow::anyhow!("bad discipline {s:?}: {e}"))?;
            if w == 0 {
                bail!("bad discipline {s:?}: backfill window must be at least 1");
            }
            return Ok(DisciplineKind::QuotaGate { backfill: w });
        }
        bail!("unknown discipline {s:?} (expected fifo | weighted_fair | quota_gate[:w=<n>])")
    }
}

impl fmt::Display for DisciplineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

/// Build the discipline for `kind` (once per run, at scheduler
/// construction — mirroring
/// [`build_policy`](crate::sched::policy::build_policy)).
pub fn build_discipline(kind: &DisciplineKind) -> Box<dyn QueueDiscipline> {
    match kind {
        DisciplineKind::Fifo => Box::new(Fifo::new()),
        DisciplineKind::WeightedFair => Box::new(WeightedFair::new()),
        DisciplineKind::QuotaGate { backfill } => Box::new(QuotaGate::new(*backfill)),
    }
}

/// Per-tenant scheduling parameters: weights (weighted-fair shares) and
/// occupied-Size quotas. Owned by the scheduler, mutated between rounds by
/// the control plane (`SetQuota` / `SetWeight`), read by the admission
/// loop and the disciplines.
#[derive(Debug, Clone, Default)]
pub struct TenantDirectory {
    weights: BTreeMap<u32, u32>,
    quotas: BTreeMap<u32, f64>,
    /// Quota applied to tenants with no explicit entry (`None` =
    /// unlimited, the default).
    default_quota: Option<f64>,
}

impl TenantDirectory {
    /// A directory with every tenant at weight 1 and no quotas.
    pub fn new(default_quota: Option<f64>) -> Self {
        TenantDirectory { default_quota, ..TenantDirectory::default() }
    }

    /// The tenant's weighted-fair share (default 1).
    pub fn weight(&self, tenant: TenantId) -> u32 {
        self.weights.get(&tenant.0).copied().unwrap_or(1)
    }

    /// The tenant's occupied-Size cap, if any.
    pub fn quota(&self, tenant: TenantId) -> Option<f64> {
        self.quotas.get(&tenant.0).copied().or(self.default_quota)
    }

    /// Set the tenant's weighted-fair share (≥ 1; the controller rejects 0
    /// before it gets here).
    pub fn set_weight(&mut self, tenant: TenantId, weight: u32) {
        self.weights.insert(tenant.0, weight.max(1));
    }

    /// Set the tenant's occupied-Size cap.
    pub fn set_quota(&mut self, tenant: TenantId, size: f64) {
        self.quotas.insert(tenant.0, size.max(0.0));
    }

    /// Serialize weights, quotas, and the default quota for a snapshot.
    /// Quota `f64`s travel bit-exact: a restored run must make the same
    /// quota comparisons the uninterrupted run would.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.seq(self.weights.len());
        for (t, wt) in &self.weights {
            w.u32(*t);
            w.u32(*wt);
        }
        w.seq(self.quotas.len());
        for (t, q) in &self.quotas {
            w.u32(*t);
            w.f64(*q);
        }
        w.bool(self.default_quota.is_some());
        if let Some(q) = self.default_quota {
            w.f64(q);
        }
    }

    /// Rebuild a directory written by [`TenantDirectory::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> Result<Self> {
        let mut weights = BTreeMap::new();
        for _ in 0..r.seq()? {
            let t = r.u32()?;
            weights.insert(t, r.u32()?);
        }
        let mut quotas = BTreeMap::new();
        for _ in 0..r.seq()? {
            let t = r.u32()?;
            quotas.insert(t, r.f64()?);
        }
        let default_quota = if r.bool()? { Some(r.f64()?) } else { None };
        Ok(TenantDirectory { weights, quotas, default_quota })
    }
}

/// Per-tenant occupied Size (Eq. 1 `Size` of all Running + Draining
/// demand), maintained incrementally by the scheduler at bind/unbind
/// points. The job count rides along so a tenant whose last job releases
/// resets to exactly `0.0` — accumulated f64 round-off cannot drift a
/// quota decision, and the add/sub sequence is identical in both simulator
/// drive modes, so decisions stay engine-invariant.
#[derive(Debug, Clone, Default)]
pub struct TenantUsage {
    occupied: BTreeMap<u32, (f64, u32)>,
}

impl TenantUsage {
    /// A job of `size` started occupying resources for `tenant`.
    pub fn add(&mut self, tenant: TenantId, size: f64) {
        let slot = self.occupied.entry(tenant.0).or_insert((0.0, 0));
        slot.0 += size;
        slot.1 += 1;
    }

    /// A job of `size` released its resources.
    pub fn sub(&mut self, tenant: TenantId, size: f64) {
        let Some(slot) = self.occupied.get_mut(&tenant.0) else {
            debug_assert!(false, "{tenant} released without occupancy");
            return;
        };
        debug_assert!(slot.1 > 0, "{tenant} released more jobs than it held");
        slot.1 = slot.1.saturating_sub(1);
        if slot.1 == 0 {
            self.occupied.remove(&tenant.0);
        } else {
            slot.0 = (slot.0 - size).max(0.0);
        }
    }

    /// The tenant's currently occupied Size.
    pub fn occupied_size(&self, tenant: TenantId) -> f64 {
        self.occupied.get(&tenant.0).map(|(s, _)| *s).unwrap_or(0.0)
    }

    /// Number of jobs currently occupying resources for the tenant.
    pub fn occupied_jobs(&self, tenant: TenantId) -> u32 {
        self.occupied.get(&tenant.0).map(|(_, n)| *n).unwrap_or(0)
    }

    /// Serialize the occupied-Size ledger for a snapshot. The accumulated
    /// sizes travel bit-exact — recomputing them from the job table would
    /// lose the add/sub round-off history quota decisions depend on.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.seq(self.occupied.len());
        for (t, (size, n)) in &self.occupied {
            w.u32(*t);
            w.f64(*size);
            w.u32(*n);
        }
    }

    /// Rebuild a ledger written by [`TenantUsage::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> Result<Self> {
        let mut occupied = BTreeMap::new();
        for _ in 0..r.seq()? {
            let t = r.u32()?;
            let size = r.f64()?;
            let n = r.u32()?;
            occupied.insert(t, (size, n));
        }
        Ok(TenantUsage { occupied })
    }
}

/// Read-only context the scheduler hands the discipline on every
/// `next_candidate` / `report` call.
pub struct AdmissionCtx<'a> {
    /// Tenant weights and quotas (the disciplines read weights; the
    /// scheduler applies quotas before the attempt).
    pub tenants: &'a TenantDirectory,
}

/// Outcome of one admission attempt, reported back to the discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    /// The job was placed. The scheduler has already removed it from the
    /// discipline via [`QueueDiscipline::remove`]; cluster and quota state
    /// changed, so round-local blocked state must be forgotten.
    Placed,
    /// No node can host the job right now.
    NoFit,
    /// The job's tenant is at or over its occupied-Size quota.
    OverQuota,
    /// The job vacated in this same scheduling round and is not
    /// re-admittable until the next one (§2's one-decision-per-minute
    /// rule). Disciplines treat it like [`AdmitOutcome::NoFit`].
    VacatedNow,
}

/// An admission queue discipline. See the module docs for the round
/// protocol and the frozen-state contract.
pub trait QueueDiscipline: fmt::Debug + Send {
    /// New submission: tail (of the tenant's sub-queue, where one exists).
    fn submit(&mut self, id: JobId, tenant: TenantId);

    /// Preempted / evicted job returning: *top* of its queue, ahead of
    /// everything — the paper's re-insertion rule, applied per tenant
    /// under tenant-aware disciplines.
    fn reinsert_front(&mut self, id: JobId, tenant: TenantId);

    /// Remove a queued job (placement, cancellation, reclassification).
    /// Returns true when it was queued. Must be callable mid-round.
    fn remove(&mut self, id: JobId) -> bool;

    /// Queued job count.
    fn len(&self) -> usize;

    /// True when nothing is queued.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Is `id` queued?
    fn contains(&self, id: JobId) -> bool;

    /// Visit every queued id in a deterministic, implementation-defined
    /// order ([`Fifo`] preserves exact queue order — the synthetic
    /// generator's load calibration sums demands in that order).
    fn for_each(&self, f: &mut dyn FnMut(JobId));

    /// Begin an admission round: reset all round-local cursor state.
    fn begin_round(&mut self);

    /// The next queued job to attempt, or `None` when the round is over.
    /// Must not mutate persistent state.
    fn next_candidate(&mut self, ctx: &AdmissionCtx) -> Option<JobId>;

    /// Report the outcome of the attempt on `id`. Persistent state may
    /// move only on [`AdmitOutcome::Placed`].
    fn report(&mut self, id: JobId, tenant: TenantId, outcome: AdmitOutcome, ctx: &AdmissionCtx);

    /// Serialize *persistent* discipline state for a snapshot. Round-local
    /// state is excluded: snapshots are taken at round boundaries, where
    /// `begin_round` resets it anyway (the frozen-state contract).
    fn snapshot_bin(&self, w: &mut BinWriter);

    /// Restore state written by
    /// [`snapshot_bin`](QueueDiscipline::snapshot_bin) into a discipline
    /// freshly built from the same [`DisciplineKind`]. Round-local state is
    /// reset.
    fn restore_bin(&mut self, r: &mut BinReader) -> Result<()>;
}

// ---------------------------------------------------------------------
// Fifo
// ---------------------------------------------------------------------

/// The paper's single global FIFO as a discipline: head-only admission,
/// any non-placement outcome ends the round (a blocked head blocks
/// everything behind it). Byte-identical to the pre-refactor
/// `while let Some(head) = be_queue.head()` loop — pinned by
/// `rust/tests/streaming_equivalence.rs`.
#[derive(Debug, Default)]
pub struct Fifo {
    q: JobQueue,
    round_over: bool,
}

impl Fifo {
    /// An empty FIFO discipline.
    pub fn new() -> Self {
        Fifo::default()
    }
}

impl QueueDiscipline for Fifo {
    fn submit(&mut self, id: JobId, _tenant: TenantId) {
        self.q.submit(id);
    }

    fn reinsert_front(&mut self, id: JobId, _tenant: TenantId) {
        self.q.reinsert_front(id);
    }

    fn remove(&mut self, id: JobId) -> bool {
        self.q.remove(id)
    }

    fn len(&self) -> usize {
        self.q.len()
    }

    fn contains(&self, id: JobId) -> bool {
        self.q.position(id).is_some()
    }

    fn for_each(&self, f: &mut dyn FnMut(JobId)) {
        for id in self.q.iter() {
            f(id);
        }
    }

    fn begin_round(&mut self) {
        self.round_over = false;
    }

    fn next_candidate(&mut self, _ctx: &AdmissionCtx) -> Option<JobId> {
        if self.round_over {
            return None;
        }
        self.q.head()
    }

    fn report(
        &mut self,
        _id: JobId,
        _tenant: TenantId,
        outcome: AdmitOutcome,
        _ctx: &AdmissionCtx,
    ) {
        // Placed: the head was removed, the new head is the next candidate.
        // Anything else: the head blocks the queue for this round.
        if outcome != AdmitOutcome::Placed {
            self.round_over = true;
        }
    }

    fn snapshot_bin(&self, w: &mut BinWriter) {
        w.u8(0);
        self.q.snapshot_bin(w);
    }

    fn restore_bin(&mut self, r: &mut BinReader) -> Result<()> {
        if r.u8()? != 0 {
            bail!("snapshot corrupt: expected a fifo discipline");
        }
        self.q = JobQueue::restore_bin(r)?;
        self.round_over = false;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// WeightedFair
// ---------------------------------------------------------------------

/// Weighted round-robin over per-tenant FIFO sub-queues.
///
/// The *turn* tenant admits up to `weight(tenant)` jobs, then the turn
/// rotates to the next tenant (cyclic by tenant id). Within a round, a
/// tenant whose head is blocked (no fit, over quota, vacated-this-tick)
/// is skipped for the rest of the round — sound because placements only
/// consume capacity and grow usage, so a blocked verdict cannot flip
/// mid-round — so one tenant's blocked head never stalls the others, and
/// every non-empty tenant's head is attempted at least once per round
/// (the starvation bound).
///
/// Persistent state (`turn`, `served`) moves only on placements, per the
/// frozen-state contract.
#[derive(Debug, Default)]
pub struct WeightedFair {
    /// Tenant id → its FIFO sub-queue. Entries persist once created
    /// (bounded by the tenant count, not the job count).
    queues: BTreeMap<u32, JobQueue>,
    /// Queued job → its tenant, so [`QueueDiscipline::remove`] (the hot
    /// placement path: the candidate is its sub-queue's head) goes
    /// straight to the right sub-queue instead of scanning all of them.
    tenant_of: BTreeMap<u32, u32>,
    /// The tenant currently holding the turn.
    turn: u32,
    /// Placements the turn tenant has used of its weight.
    served: u32,
    /// Total queued jobs across all sub-queues.
    len: usize,
    /// Round-local: tenants whose head was blocked this round.
    round_blocked: Vec<u32>,
    /// Round-local: candidate handed out by the last `next_candidate`
    /// (the tenant whose verdict `report` settles).
    offered: Option<u32>,
}

impl WeightedFair {
    /// An empty weighted-fair discipline.
    pub fn new() -> Self {
        WeightedFair::default()
    }

    /// Tenants in cyclic id order starting from the turn holder.
    fn cyclic_tenants(&self) -> impl Iterator<Item = u32> + '_ {
        let turn = self.turn;
        self.queues
            .range(turn..)
            .map(|(t, _)| *t)
            .chain(self.queues.range(..turn).map(|(t, _)| *t))
    }

    /// The tenant id after `t` in cyclic order (among known tenants).
    fn tenant_after(&self, t: u32) -> u32 {
        use std::ops::Bound;
        self.queues
            .range((Bound::Excluded(t), Bound::Unbounded))
            .map(|(k, _)| *k)
            .next()
            .or_else(|| self.queues.keys().next().copied())
            .unwrap_or(t)
    }
}

impl QueueDiscipline for WeightedFair {
    fn submit(&mut self, id: JobId, tenant: TenantId) {
        self.queues.entry(tenant.0).or_default().submit(id);
        self.tenant_of.insert(id.0, tenant.0);
        self.len += 1;
    }

    fn reinsert_front(&mut self, id: JobId, tenant: TenantId) {
        self.queues.entry(tenant.0).or_default().reinsert_front(id);
        self.tenant_of.insert(id.0, tenant.0);
        self.len += 1;
    }

    fn remove(&mut self, id: JobId) -> bool {
        let Some(t) = self.tenant_of.get(&id.0).copied() else {
            return false;
        };
        let removed = self
            .queues
            .get_mut(&t)
            .map(|q| q.remove(id))
            .unwrap_or(false);
        debug_assert!(removed, "{id} tracked for tenant-{t} but not queued");
        if removed {
            self.tenant_of.remove(&id.0);
            self.len -= 1;
        }
        removed
    }

    fn len(&self) -> usize {
        self.len
    }

    fn contains(&self, id: JobId) -> bool {
        self.tenant_of.contains_key(&id.0)
    }

    fn for_each(&self, f: &mut dyn FnMut(JobId)) {
        for q in self.queues.values() {
            for id in q.iter() {
                f(id);
            }
        }
    }

    fn begin_round(&mut self) {
        self.round_blocked.clear();
        self.offered = None;
    }

    fn next_candidate(&mut self, _ctx: &AdmissionCtx) -> Option<JobId> {
        let mut pick: Option<(u32, JobId)> = None;
        for t in self.cyclic_tenants() {
            if self.round_blocked.contains(&t) {
                continue;
            }
            if let Some(head) = self.queues[&t].head() {
                pick = Some((t, head));
                break;
            }
        }
        let (t, head) = pick?;
        self.offered = Some(t);
        Some(head)
    }

    fn report(&mut self, _id: JobId, tenant: TenantId, outcome: AdmitOutcome, ctx: &AdmissionCtx) {
        debug_assert_eq!(self.offered, Some(tenant.0), "report for an unoffered tenant");
        self.offered = None;
        match outcome {
            AdmitOutcome::Placed => {
                // Blocked tenants stay blocked for the rest of the round:
                // within one round placements only *bind* capacity and
                // *grow* usage (BE candidates never hold reservations), so
                // a NoFit/OverQuota verdict can never flip — re-attempting
                // would just repeat the failed node search.
                //
                // Turn accounting: the placement belongs to `tenant` (the
                // turn holder, or the next tenant in order when the holder
                // was empty/blocked — then the turn passes to it).
                if self.turn != tenant.0 {
                    self.turn = tenant.0;
                    self.served = 0;
                }
                self.served += 1;
                if self.served >= ctx.tenants.weight(tenant) {
                    self.turn = self.tenant_after(tenant.0);
                    self.served = 0;
                }
            }
            AdmitOutcome::NoFit | AdmitOutcome::OverQuota | AdmitOutcome::VacatedNow => {
                self.round_blocked.push(tenant.0);
            }
        }
    }

    fn snapshot_bin(&self, w: &mut BinWriter) {
        w.u8(1);
        // Empty sub-queues are serialized too: known tenants shape the
        // cyclic rotation order, so they are behavioural state.
        w.seq(self.queues.len());
        for (t, q) in &self.queues {
            w.u32(*t);
            q.snapshot_bin(w);
        }
        w.seq(self.tenant_of.len());
        for (j, t) in &self.tenant_of {
            w.u32(*j);
            w.u32(*t);
        }
        w.u32(self.turn);
        w.u32(self.served);
        w.usize(self.len);
    }

    fn restore_bin(&mut self, r: &mut BinReader) -> Result<()> {
        if r.u8()? != 1 {
            bail!("snapshot corrupt: expected a weighted-fair discipline");
        }
        let mut queues = BTreeMap::new();
        for _ in 0..r.seq()? {
            let t = r.u32()?;
            queues.insert(t, JobQueue::restore_bin(r)?);
        }
        let mut tenant_of = BTreeMap::new();
        for _ in 0..r.seq()? {
            let j = r.u32()?;
            tenant_of.insert(j, r.u32()?);
        }
        let turn = r.u32()?;
        let served = r.u32()?;
        let len = r.usize()?;
        if tenant_of.len() != len || queues.values().map(|q| q.len()).sum::<usize>() != len {
            bail!("snapshot corrupt: weighted-fair queue bookkeeping mismatch");
        }
        self.queues = queues;
        self.tenant_of = tenant_of;
        self.turn = turn;
        self.served = served;
        self.len = len;
        self.round_blocked.clear();
        self.offered = None;
        Ok(())
    }
}

// ---------------------------------------------------------------------
// QuotaGate
// ---------------------------------------------------------------------

/// Global FIFO order with over-quota skip and bounded backfill.
///
/// One forward scan per round: over-quota heads are skipped outright
/// (they cost nothing), and up to `backfill` doesn't-fit heads total are
/// stepped over before the round ends — so a blocked head delays, but no
/// longer stalls, everything behind it. The scan never revisits a failed
/// prefix: within a round placements only consume capacity and grow
/// usage, so earlier NoFit/OverQuota verdicts cannot flip, and FIFO
/// preference among *admissible* jobs is preserved by the forward order
/// alone.
#[derive(Debug)]
pub struct QuotaGate {
    q: JobQueue,
    backfill: usize,
    /// Round-local scan position.
    pos: usize,
    /// Round-local doesn't-fit heads stepped over this round.
    misses: usize,
    /// Round-local: the scan ended.
    round_over: bool,
}

impl QuotaGate {
    /// An empty quota-gate discipline with the given backfill window
    /// (≥ 1).
    pub fn new(backfill: usize) -> Self {
        QuotaGate {
            q: JobQueue::new(),
            backfill: backfill.max(1),
            pos: 0,
            misses: 0,
            round_over: false,
        }
    }
}

impl QueueDiscipline for QuotaGate {
    fn submit(&mut self, id: JobId, _tenant: TenantId) {
        self.q.submit(id);
    }

    fn reinsert_front(&mut self, id: JobId, _tenant: TenantId) {
        self.q.reinsert_front(id);
    }

    fn remove(&mut self, id: JobId) -> bool {
        self.q.remove(id)
    }

    fn len(&self) -> usize {
        self.q.len()
    }

    fn contains(&self, id: JobId) -> bool {
        self.q.position(id).is_some()
    }

    fn for_each(&self, f: &mut dyn FnMut(JobId)) {
        for id in self.q.iter() {
            f(id);
        }
    }

    fn begin_round(&mut self) {
        self.pos = 0;
        self.misses = 0;
        self.round_over = false;
    }

    fn next_candidate(&mut self, _ctx: &AdmissionCtx) -> Option<JobId> {
        if self.round_over {
            return None;
        }
        match self.q.get(self.pos) {
            Some(id) => Some(id),
            None => {
                self.round_over = true;
                None
            }
        }
    }

    fn report(
        &mut self,
        _id: JobId,
        _tenant: TenantId,
        outcome: AdmitOutcome,
        _ctx: &AdmissionCtx,
    ) {
        match outcome {
            AdmitOutcome::Placed => {
                // The candidate left the queue at `pos`, so `pos` already
                // points at the next job; the failed prefix is not
                // revisited (its verdicts cannot flip mid-round).
            }
            AdmitOutcome::OverQuota => {
                // Skipping an over-quota head is free: it is not waiting on
                // capacity, only on its own tenant's drain.
                self.pos += 1;
            }
            AdmitOutcome::NoFit | AdmitOutcome::VacatedNow => {
                self.misses += 1;
                if self.misses >= self.backfill {
                    self.round_over = true;
                } else {
                    self.pos += 1;
                }
            }
        }
    }

    fn snapshot_bin(&self, w: &mut BinWriter) {
        // `backfill` is config, rebuilt from the same `DisciplineKind` on
        // restore; only the queue is state.
        w.u8(2);
        self.q.snapshot_bin(w);
    }

    fn restore_bin(&mut self, r: &mut BinReader) -> Result<()> {
        if r.u8()? != 2 {
            bail!("snapshot corrupt: expected a quota-gate discipline");
        }
        self.q = JobQueue::restore_bin(r)?;
        self.pos = 0;
        self.misses = 0;
        self.round_over = false;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(dir: &TenantDirectory) -> AdmissionCtx<'_> {
        AdmissionCtx { tenants: dir }
    }

    /// Drive one admission round against a closure deciding each
    /// attempt's outcome; returns the placed ids in order.
    fn round(
        d: &mut dyn QueueDiscipline,
        dir: &TenantDirectory,
        tenant_of: &dyn Fn(JobId) -> TenantId,
        mut verdict: impl FnMut(JobId) -> AdmitOutcome,
    ) -> Vec<JobId> {
        let mut placed = Vec::new();
        d.begin_round();
        while let Some(id) = d.next_candidate(&ctx(dir)) {
            let t = tenant_of(id);
            let out = verdict(id);
            if out == AdmitOutcome::Placed {
                assert!(d.remove(id), "{id} placed but not queued");
                placed.push(id);
            }
            d.report(id, t, out, &ctx(dir));
        }
        placed
    }

    #[test]
    fn discipline_kind_parses() {
        assert_eq!(DisciplineKind::parse("fifo").unwrap(), DisciplineKind::Fifo);
        assert_eq!(
            DisciplineKind::parse("weighted_fair").unwrap(),
            DisciplineKind::WeightedFair
        );
        assert_eq!(
            DisciplineKind::parse("quota_gate").unwrap(),
            DisciplineKind::QuotaGate { backfill: DEFAULT_BACKFILL }
        );
        assert_eq!(
            DisciplineKind::parse("quota_gate:w=3").unwrap(),
            DisciplineKind::QuotaGate { backfill: 3 }
        );
        for bad in ["", "lifo", "quota_gate:w=0", "quota_gate:w=x", "quota_gate:3"] {
            assert!(DisciplineKind::parse(bad).is_err(), "{bad:?}");
        }
        assert_eq!(DisciplineKind::parse("quota_gate:w=3").unwrap().name(), "quota_gate:w=3");
    }

    #[test]
    fn tenant_directory_defaults_and_overrides() {
        let mut dir = TenantDirectory::new(Some(2.0));
        assert_eq!(dir.weight(TenantId(5)), 1);
        assert_eq!(dir.quota(TenantId(5)), Some(2.0));
        dir.set_weight(TenantId(5), 4);
        dir.set_quota(TenantId(5), 0.5);
        assert_eq!(dir.weight(TenantId(5)), 4);
        assert_eq!(dir.quota(TenantId(5)), Some(0.5));
        let open = TenantDirectory::new(None);
        assert_eq!(open.quota(TenantId(0)), None);
    }

    #[test]
    fn tenant_usage_resets_exactly_on_empty() {
        let mut u = TenantUsage::default();
        let t = TenantId(3);
        u.add(t, 0.1);
        u.add(t, 0.2);
        assert_eq!(u.occupied_jobs(t), 2);
        u.sub(t, 0.1);
        assert!(u.occupied_size(t) > 0.0);
        u.sub(t, 0.2);
        assert_eq!(u.occupied_size(t), 0.0, "exact zero when the tenant empties");
        assert_eq!(u.occupied_jobs(t), 0);
    }

    #[test]
    fn fifo_discipline_blocks_on_first_failure() {
        let dir = TenantDirectory::default();
        let mut d = Fifo::new();
        for i in 0..3 {
            d.submit(JobId(i), TenantId::DEFAULT);
        }
        // First job fits, second blocks: the third is never attempted.
        let mut attempts = Vec::new();
        let placed = round(&mut d, &dir, &|_| TenantId::DEFAULT, |id| {
            attempts.push(id);
            if id == JobId(0) { AdmitOutcome::Placed } else { AdmitOutcome::NoFit }
        });
        assert_eq!(placed, vec![JobId(0)]);
        assert_eq!(attempts, vec![JobId(0), JobId(1)], "blocked head ends the round");
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn weighted_fair_rotates_by_weight() {
        let mut dir = TenantDirectory::default();
        dir.set_weight(TenantId(0), 2);
        let mut d = WeightedFair::new();
        // Tenant 0: jobs 0,1,2; tenant 1: jobs 10,11.
        for i in [0u32, 1, 2] {
            d.submit(JobId(i), TenantId(0));
        }
        for i in [10u32, 11] {
            d.submit(JobId(i), TenantId(1));
        }
        // Everything fits: weight-2 tenant places twice, then the turn
        // rotates; within one round all five jobs land.
        let placed = round(&mut d, &dir, &|id| TenantId(if id.0 < 10 { 0 } else { 1 }), |_| {
            AdmitOutcome::Placed
        });
        assert_eq!(
            placed,
            vec![JobId(0), JobId(1), JobId(10), JobId(2), JobId(11)],
            "2 from tenant 0, turn passes, interleave"
        );
        assert!(d.is_empty());
    }

    #[test]
    fn weighted_fair_skips_blocked_tenant_within_round() {
        let dir = TenantDirectory::default();
        let mut d = WeightedFair::new();
        d.submit(JobId(0), TenantId(0)); // huge, never fits
        d.submit(JobId(10), TenantId(1));
        d.submit(JobId(11), TenantId(1));
        let mut attempts = Vec::new();
        let placed = round(&mut d, &dir, &|id| TenantId(if id.0 < 10 { 0 } else { 1 }), |id| {
            attempts.push(id.0);
            if id.0 < 10 { AdmitOutcome::NoFit } else { AdmitOutcome::Placed }
        });
        // Tenant 0's blocked head does not stall tenant 1, and it is not
        // re-attempted after placements (its verdict cannot flip
        // mid-round — placements only consume capacity).
        assert_eq!(placed, vec![JobId(10), JobId(11)]);
        assert_eq!(attempts, vec![0, 10, 11], "blocked head attempted exactly once");
        assert_eq!(d.len(), 1, "blocked job stays queued");
    }

    #[test]
    fn weighted_fair_attempts_every_nonempty_tenant_each_round() {
        let dir = TenantDirectory::default();
        let mut d = WeightedFair::new();
        for t in 0..5u32 {
            d.submit(JobId(100 + t), TenantId(t));
        }
        let mut attempted = Vec::new();
        let placed = round(&mut d, &dir, &|id| TenantId(id.0 - 100), |id| {
            attempted.push(id.0 - 100);
            AdmitOutcome::NoFit
        });
        assert!(placed.is_empty());
        attempted.sort();
        assert_eq!(attempted, vec![0, 1, 2, 3, 4], "every tenant's head attempted");
    }

    #[test]
    fn weighted_fair_empty_round_leaves_turn_untouched() {
        // The frozen-state contract: a placement-free round must not move
        // persistent state, so replaying it yields the same sequence.
        let dir = TenantDirectory::default();
        let mut d = WeightedFair::new();
        d.submit(JobId(0), TenantId(0));
        d.submit(JobId(1), TenantId(1));
        let first: Vec<JobId> = {
            let mut seen = Vec::new();
            round(&mut d, &dir, &|id| TenantId(id.0), |id| {
                seen.push(id);
                AdmitOutcome::NoFit
            });
            seen
        };
        let second: Vec<JobId> = {
            let mut seen = Vec::new();
            round(&mut d, &dir, &|id| TenantId(id.0), |id| {
                seen.push(id);
                AdmitOutcome::NoFit
            });
            seen
        };
        assert_eq!(first, second, "identical candidate sequence on frozen state");
    }

    #[test]
    fn quota_gate_skips_over_quota_and_backfills() {
        let dir = TenantDirectory::default();
        let mut d = QuotaGate::new(2);
        for i in 0..5 {
            d.submit(JobId(i), TenantId(i));
        }
        // Job 0 over quota (skipped, free), job 1 doesn't fit (one miss),
        // job 2 places (scan continues — the failed prefix cannot flip),
        // job 3 misses → window (2) exhausted → round over; job 4 is
        // never attempted.
        let mut attempts = Vec::new();
        let placed = round(&mut d, &dir, &|id| TenantId(id.0), |id| {
            attempts.push(id.0);
            match id.0 {
                0 => AdmitOutcome::OverQuota,
                2 => AdmitOutcome::Placed,
                _ => AdmitOutcome::NoFit,
            }
        });
        assert_eq!(placed, vec![JobId(2)]);
        assert_eq!(attempts, vec![0, 1, 2, 3], "skip, miss, place, miss, window out");
        assert_eq!(d.len(), 4);
    }

    #[test]
    fn quota_gate_round_ends_at_queue_end() {
        let dir = TenantDirectory::default();
        let mut d = QuotaGate::new(100);
        d.submit(JobId(0), TenantId(0));
        d.submit(JobId(1), TenantId(1));
        let placed = round(&mut d, &dir, &|id| TenantId(id.0), |_| AdmitOutcome::OverQuota);
        assert!(placed.is_empty());
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn discipline_snapshot_round_trip_preserves_candidate_order() {
        for kind in [
            DisciplineKind::Fifo,
            DisciplineKind::WeightedFair,
            DisciplineKind::QuotaGate { backfill: 4 },
        ] {
            let mut dir = TenantDirectory::default();
            dir.set_weight(TenantId(0), 2);
            let mut d = build_discipline(&kind);
            for i in 0..4u32 {
                d.submit(JobId(i), TenantId(i % 2));
            }
            d.reinsert_front(JobId(9), TenantId(1));
            // Move persistent state (the weighted-fair turn) with one
            // placed round before snapshotting.
            let _ = round(&mut *d, &dir, &|id| TenantId(id.0 % 2), |id| {
                if id == JobId(9) { AdmitOutcome::Placed } else { AdmitOutcome::NoFit }
            });
            let mut w = crate::util::bin::BinWriter::new();
            d.snapshot_bin(&mut w);
            let bytes = w.into_bytes();
            let mut restored = build_discipline(&kind);
            let mut r = crate::util::bin::BinReader::new(&bytes);
            restored.restore_bin(&mut r).unwrap();
            r.expect_end().unwrap();
            assert_eq!(restored.len(), d.len(), "{kind:?}");
            let seq = |d: &mut dyn QueueDiscipline| {
                let mut seen = Vec::new();
                round(d, &dir, &|id| TenantId(id.0 % 2), |id| {
                    seen.push(id);
                    AdmitOutcome::NoFit
                });
                seen
            };
            assert_eq!(seq(&mut *restored), seq(&mut *d), "{kind:?}");
        }
    }

    #[test]
    fn tenant_state_snapshot_round_trips() {
        let mut dir = TenantDirectory::new(Some(1.5));
        dir.set_weight(TenantId(2), 4);
        dir.set_quota(TenantId(7), 0.25);
        let mut w = crate::util::bin::BinWriter::new();
        dir.snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bin::BinReader::new(&bytes);
        let back = TenantDirectory::restore_bin(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(back.weight(TenantId(2)), 4);
        assert_eq!(back.quota(TenantId(7)), Some(0.25));
        assert_eq!(back.quota(TenantId(0)), Some(1.5), "default quota travels");

        let mut usage = TenantUsage::default();
        usage.add(TenantId(1), 0.1);
        usage.add(TenantId(1), 0.2);
        usage.add(TenantId(3), 0.7);
        let mut w = crate::util::bin::BinWriter::new();
        usage.snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bin::BinReader::new(&bytes);
        let back = TenantUsage::restore_bin(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(
            back.occupied_size(TenantId(1)).to_bits(),
            usage.occupied_size(TenantId(1)).to_bits(),
            "accumulated sizes are bit-exact"
        );
        assert_eq!(back.occupied_jobs(TenantId(3)), 1);
    }

    #[test]
    fn disciplines_share_bookkeeping_semantics() {
        for kind in [
            DisciplineKind::Fifo,
            DisciplineKind::WeightedFair,
            DisciplineKind::QuotaGate { backfill: 4 },
        ] {
            let mut d = build_discipline(&kind);
            d.submit(JobId(1), TenantId(0));
            d.submit(JobId(2), TenantId(1));
            d.reinsert_front(JobId(3), TenantId(0));
            assert_eq!(d.len(), 3, "{kind:?}");
            assert!(d.contains(JobId(3)));
            let mut seen = Vec::new();
            d.for_each(&mut |id| seen.push(id));
            assert_eq!(seen.len(), 3);
            assert!(d.remove(JobId(2)));
            assert!(!d.remove(JobId(2)));
            assert_eq!(d.len(), 2);
            assert!(!d.is_empty());
        }
    }
}
