//! Runtime estimators: predicted total/remaining execution time for the
//! prediction-aware policies.
//!
//! FitGpp deliberately schedules on *declared* attributes only; the
//! prediction-assisted literature (e.g. DL2, prediction-assisted online
//! scheduling) shows that even noisy runtime predictions beat
//! attribute-only victim ranking. This module supplies the estimate:
//!
//! * [`EstimatorKind`] is plain data — the config/CLI/sweep surface — and
//!   [`build_estimator`] turns it into behaviour, mirroring the
//!   [`PolicyKind`](crate::sched::policy::PolicyKind)/`build_policy`
//!   layering.
//! * [`RuntimeEstimator`] is the object-safe behaviour trait with three
//!   implementations: [`Oracle`] (perfect predictions — the upper bound),
//!   [`ClassEwma`] (per-tenant/per-class online EWMA over completed-job
//!   runtimes, backed by a mergeable [`QuantileSketch`] per bucket), and
//!   [`Noisy`] (oracle × a seeded multiplicative log-normal error — the
//!   sensitivity axis).
//! * [`SharedEstimator`] is the cloneable handle that closes the loop: one
//!   clone subscribes to the scheduler's event stream (folding every
//!   [`SchedulerEvent::Finished`] record in), the other backs the
//!   [`PolicyCtx::predicted_remaining`](crate::sched::policy::PolicyCtx)
//!   closure the policies read.
//!
//! ## Engine invariance
//!
//! Estimator state changes only on `Finished` events, which the controller
//! emits *after* the scheduling round they belong to — so a completion at
//! minute `T` influences predictions from minute `T+1` on, identically
//! under the per-minute and event-horizon engines (the event streams
//! themselves are pinned byte-identical across engines). A prediction for
//! a given job at a given minute is therefore a pure function of
//! `(workload prefix, config, seed)`, and the `Noisy(sigma=0) == Oracle`
//! acceptance pin holds across both engines for every policy.
//!
//! ## Interaction with the victim index
//!
//! Estimator updates never touch the scheduler's
//! [`VictimIndex`](crate::sched::VictimIndex): the index orders victims by
//! *declared* keys only (oracle remaining time, grace period, age, size),
//! and the prediction-ordered policies (`PSrtf`, `FitGppPr`) re-rank the
//! index's candidate pool with fresh predictions inside each plan call,
//! into scheduler-owned scratch. A `Finished` event folding into an EWMA
//! bucket therefore requires no index maintenance — predictions are read
//! at plan time, not cached at placement time.

use crate::job::{Job, JobClass, JobSpec};
use crate::sched::control::{EventSubscriber, SchedulerEvent};
use crate::sim::JobRecord;
use crate::stats::rng::Pcg64;
use crate::stats::sketch::QuantileSketch;
use crate::util::bin::{BinReader, BinWriter};
use anyhow::bail;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Which runtime estimator feeds the prediction-aware policies. Plain data
/// (configs, CLI flags, sweep axes); turned into behaviour by
/// [`build_estimator`] exactly once per run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EstimatorKind {
    /// Perfect predictions: the declared execution time, which in the
    /// simulator *is* the true total — the upper bound for what
    /// prediction-aware policies can gain.
    Oracle,
    /// Per-(tenant, class) online EWMA over completed-job runtimes.
    /// `alpha` in `(0, 1]` weights the newest completion; cold buckets
    /// (zero completions) fall back to the declared runtime.
    ClassEwma {
        /// EWMA smoothing factor for new completions.
        alpha: f64,
    },
    /// Oracle × a multiplicative log-normal error `exp(sigma · z)` with
    /// `z ~ N(0, 1)` drawn deterministically per job id (seeded). With
    /// `sigma == 0` the multiplier is exactly 1, byte-identical to
    /// [`EstimatorKind::Oracle`].
    Noisy {
        /// Log-space standard deviation of the multiplicative error.
        sigma: f64,
    },
}

impl Default for EstimatorKind {
    /// [`EstimatorKind::Oracle`] — byte-identical to the pre-prediction
    /// scheduler for every policy that ignores predictions.
    fn default() -> Self {
        EstimatorKind::Oracle
    }
}

impl EstimatorKind {
    /// Human-readable name (tables, CSV rows, CLI echo).
    pub fn name(&self) -> String {
        match self {
            EstimatorKind::Oracle => "oracle".into(),
            EstimatorKind::ClassEwma { alpha } => format!("ewma(a={alpha})"),
            EstimatorKind::Noisy { sigma } => format!("noisy(s={sigma})"),
        }
    }

    /// Parse from a CLI string: `oracle`, `ewma`, `ewma:alpha=0.5`,
    /// `noisy`, `noisy:sigma=0.5`. Defaults: `alpha = 0.2`,
    /// `sigma = 0.5`. Rejects `alpha` outside `(0, 1]` and negative or
    /// non-finite `sigma`.
    pub fn parse(s: &str) -> Option<EstimatorKind> {
        let lower = s.to_ascii_lowercase();
        let (head, rest) = match lower.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (lower.as_str(), None),
        };
        match head {
            "oracle" => {
                if rest.is_some() {
                    return None;
                }
                Some(EstimatorKind::Oracle)
            }
            "ewma" => {
                let mut alpha = 0.2;
                if let Some(rest) = rest {
                    for kv in rest.split(',') {
                        let (k, v) = kv.split_once('=')?;
                        match k {
                            "alpha" | "a" => alpha = v.parse().ok()?,
                            _ => return None,
                        }
                    }
                }
                if !(alpha > 0.0 && alpha <= 1.0) {
                    return None;
                }
                Some(EstimatorKind::ClassEwma { alpha })
            }
            "noisy" => {
                let mut sigma = 0.5;
                if let Some(rest) = rest {
                    for kv in rest.split(',') {
                        let (k, v) = kv.split_once('=')?;
                        match k {
                            "sigma" | "s" => sigma = v.parse().ok()?,
                            _ => return None,
                        }
                    }
                }
                if !(sigma.is_finite() && sigma >= 0.0) {
                    return None;
                }
                Some(EstimatorKind::Noisy { sigma })
            }
            _ => None,
        }
    }
}

/// An online estimator of total job runtime. Object-safe: the scheduler
/// holds one behind a [`SharedEstimator`] handle built by
/// [`build_estimator`] at construction.
///
/// # Contract
///
/// * **Determinism.** `predict_total` must be a pure function of the spec
///   and the sequence of records observed so far (plus the construction
///   seed) — never wall clock, thread identity, or global entropy — so
///   both simulator drive modes stay byte-identical.
/// * **Finite predictions.** Every prediction must be a finite,
///   non-negative `f64`; policies sort on these values.
/// * **Observation source.** `observe` receives exactly the `Finished`
///   records of the run, in completion order (the controller's normalized
///   event order).
pub trait RuntimeEstimator: Send {
    /// Predict the job's *total* execution time in minutes.
    fn predict_total(&self, spec: &JobSpec) -> f64;

    /// Fold one completed job's record into the estimator state.
    fn observe(&mut self, rec: &JobRecord);

    /// How many records have been observed (CI smoke checks assert this is
    /// nonzero on a streamed run).
    fn updates(&self) -> u64;

    /// Human-readable name (matches [`EstimatorKind::name`]).
    fn name(&self) -> String;

    /// Serialize *learned* estimator state for a snapshot. Construction
    /// parameters (`alpha`, `sigma`, the noise seed) are config, rebuilt
    /// from the run config on restore; only observation-derived state and
    /// counters are written.
    fn snapshot_bin(&self, w: &mut BinWriter);

    /// Restore state written by
    /// [`snapshot_bin`](RuntimeEstimator::snapshot_bin) into an estimator
    /// freshly built from the same [`EstimatorKind`].
    fn restore_bin(&mut self, r: &mut BinReader) -> anyhow::Result<()>;
}

/// Perfect predictions: the declared execution time (the simulator's
/// ground truth). Observations are counted but otherwise ignored.
#[derive(Debug, Default)]
pub struct Oracle {
    updates: u64,
}

impl RuntimeEstimator for Oracle {
    fn predict_total(&self, spec: &JobSpec) -> f64 {
        spec.exec_time as f64
    }

    fn observe(&mut self, _rec: &JobRecord) {
        self.updates += 1;
    }

    fn updates(&self) -> u64 {
        self.updates
    }

    fn name(&self) -> String {
        EstimatorKind::Oracle.name()
    }

    fn snapshot_bin(&self, w: &mut BinWriter) {
        w.u8(0);
        w.u64(self.updates);
    }

    fn restore_bin(&mut self, r: &mut BinReader) -> anyhow::Result<()> {
        if r.u8()? != 0 {
            bail!("snapshot corrupt: expected an oracle estimator");
        }
        self.updates = r.u64()?;
        Ok(())
    }
}

/// One (tenant, class) bucket of [`ClassEwma`] state.
#[derive(Debug, Clone)]
struct EwmaBucket {
    /// EWMA of completed runtimes in this bucket.
    ewma: f64,
    /// Completions folded in so far.
    n: u64,
    /// Mergeable distribution of the bucket's completed runtimes
    /// (diagnostics; quantiles of what the EWMA is tracking).
    sketch: QuantileSketch,
}

/// Per-tenant/per-class online EWMA over completed-job runtimes, with a
/// mergeable [`QuantileSketch`] per bucket recording the runtime
/// distribution the point estimate summarizes. A bucket with zero
/// completions falls back to the declared runtime (the cold-start pin:
/// with no observations, `predicted-SRTF` degrades to SRTF byte-for-byte
/// because declared equals true runtime in the simulator).
#[derive(Debug)]
pub struct ClassEwma {
    /// EWMA smoothing factor in `(0, 1]`.
    alpha: f64,
    /// State per `(tenant id, class)` bucket. `BTreeMap` for deterministic
    /// iteration in diagnostics.
    buckets: BTreeMap<(u32, JobClassKey), EwmaBucket>,
    updates: u64,
}

/// `JobClass` as an orderable map key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum JobClassKey {
    /// Trial-and-error.
    Te,
    /// Best-effort.
    Be,
}

fn class_key(c: JobClass) -> JobClassKey {
    match c {
        JobClass::Te => JobClassKey::Te,
        JobClass::Be => JobClassKey::Be,
    }
}

impl ClassEwma {
    /// A cold estimator with smoothing factor `alpha`.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of range: {alpha}");
        ClassEwma { alpha, buckets: BTreeMap::new(), updates: 0 }
    }

    /// The current per-bucket mean for `(tenant, class)`, if the bucket has
    /// seen any completions (tests; diagnostics).
    pub fn bucket_mean(&self, tenant: u32, class: JobClass) -> Option<f64> {
        self.buckets
            .get(&(tenant, class_key(class)))
            .filter(|b| b.n > 0)
            .map(|b| b.ewma)
    }
}

impl RuntimeEstimator for ClassEwma {
    fn predict_total(&self, spec: &JobSpec) -> f64 {
        match self.buckets.get(&(spec.tenant.0, class_key(spec.class))) {
            Some(b) if b.n > 0 => b.ewma,
            _ => spec.exec_time as f64, // cold start: declared runtime
        }
    }

    fn observe(&mut self, rec: &JobRecord) {
        self.updates += 1;
        let x = rec.exec_time as f64;
        let b = self
            .buckets
            .entry((rec.tenant.0, class_key(rec.class)))
            .or_insert_with(|| EwmaBucket { ewma: 0.0, n: 0, sketch: QuantileSketch::new() });
        b.ewma = if b.n == 0 { x } else { self.alpha * x + (1.0 - self.alpha) * b.ewma };
        b.n += 1;
        b.sketch.insert(x);
    }

    fn updates(&self) -> u64 {
        self.updates
    }

    fn name(&self) -> String {
        EstimatorKind::ClassEwma { alpha: self.alpha }.name()
    }

    fn snapshot_bin(&self, w: &mut BinWriter) {
        w.u8(1);
        w.seq(self.buckets.len());
        for ((tenant, class), b) in &self.buckets {
            w.u32(*tenant);
            w.u8(match class {
                JobClassKey::Te => 0,
                JobClassKey::Be => 1,
            });
            w.f64(b.ewma);
            w.u64(b.n);
            b.sketch.snapshot_bin(w);
        }
        w.u64(self.updates);
    }

    fn restore_bin(&mut self, r: &mut BinReader) -> anyhow::Result<()> {
        if r.u8()? != 1 {
            bail!("snapshot corrupt: expected an ewma estimator");
        }
        let mut buckets = BTreeMap::new();
        for _ in 0..r.seq()? {
            let tenant = r.u32()?;
            let class = match r.u8()? {
                0 => JobClassKey::Te,
                1 => JobClassKey::Be,
                other => bail!("snapshot corrupt: job class tag {other}"),
            };
            let ewma = r.f64()?;
            let n = r.u64()?;
            let sketch = QuantileSketch::restore_bin(r)?;
            buckets.insert((tenant, class), EwmaBucket { ewma, n, sketch });
        }
        self.buckets = buckets;
        self.updates = r.u64()?;
        Ok(())
    }
}

/// Oracle × a seeded multiplicative log-normal error: the prediction for
/// job `j` is `exec_time_j · exp(sigma · z_j)` with `z_j ~ N(0, 1)` drawn
/// deterministically from `(seed, j.id)` — no shared RNG state, so the
/// error a job sees is independent of when (and under which engine) the
/// policy asks.
#[derive(Debug)]
pub struct Noisy {
    sigma: f64,
    seed: u64,
    updates: u64,
}

impl Noisy {
    /// A noisy oracle with log-space error `sigma`, seeded.
    pub fn new(sigma: f64, seed: u64) -> Self {
        assert!(sigma.is_finite() && sigma >= 0.0, "sigma out of range: {sigma}");
        Noisy { sigma, seed, updates: 0 }
    }

    /// The per-job error multiplier `exp(sigma · z_id)`.
    fn multiplier(&self, id: u32) -> f64 {
        if self.sigma == 0.0 {
            // Exactly 1.0, so sigma = 0 is byte-identical to Oracle.
            return 1.0;
        }
        let mut rng = Pcg64::new(self.seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        // Box-Muller, matching stats::dist::Normal.
        let u1 = 1.0 - rng.next_f64();
        let u2 = rng.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        (self.sigma * z).exp()
    }
}

impl RuntimeEstimator for Noisy {
    fn predict_total(&self, spec: &JobSpec) -> f64 {
        spec.exec_time as f64 * self.multiplier(spec.id.0)
    }

    fn observe(&mut self, _rec: &JobRecord) {
        self.updates += 1;
    }

    fn updates(&self) -> u64 {
        self.updates
    }

    fn name(&self) -> String {
        EstimatorKind::Noisy { sigma: self.sigma }.name()
    }

    fn snapshot_bin(&self, w: &mut BinWriter) {
        // The per-job error draw is a pure function of (seed, job id) —
        // both config — so only the counter is state.
        w.u8(2);
        w.u64(self.updates);
    }

    fn restore_bin(&mut self, r: &mut BinReader) -> anyhow::Result<()> {
        if r.u8()? != 2 {
            bail!("snapshot corrupt: expected a noisy estimator");
        }
        self.updates = r.u64()?;
        Ok(())
    }
}

/// Turn a plain-data [`EstimatorKind`] into behaviour. Called once per run
/// (scheduler construction). `seed` drives only the [`Noisy`] error draws.
pub fn build_estimator(kind: &EstimatorKind, seed: u64) -> Box<dyn RuntimeEstimator> {
    match kind {
        EstimatorKind::Oracle => Box::new(Oracle::default()),
        EstimatorKind::ClassEwma { alpha } => Box::new(ClassEwma::new(*alpha)),
        EstimatorKind::Noisy { sigma } => Box::new(Noisy::new(*sigma, seed)),
    }
}

/// Cloneable handle around a boxed [`RuntimeEstimator`]: one clone is
/// subscribed to the controller's event stream (folding `Finished` records
/// in), another backs the policies' `predicted_remaining` closure. The
/// mutex is uncontended — simulation runs are single-threaded; sweeps give
/// every cell its own scheduler (and therefore its own estimator).
#[derive(Clone)]
pub struct SharedEstimator(Arc<Mutex<Box<dyn RuntimeEstimator>>>);

impl SharedEstimator {
    /// Build the estimator for `kind` and wrap it.
    pub fn new(kind: &EstimatorKind, seed: u64) -> Self {
        SharedEstimator(Arc::new(Mutex::new(build_estimator(kind, seed))))
    }

    /// Predicted *total* execution time for `spec`.
    pub fn predict_total(&self, spec: &JobSpec) -> f64 {
        self.0.lock().unwrap().predict_total(spec)
    }

    /// Predicted *remaining* execution time for a live job as of minute
    /// `now`: the predicted total minus the progress already made, clamped
    /// at zero. Progress is read through [`Job::remaining_at`] — the
    /// stored counter is lazily accounted and may be stale between
    /// transitions. Under [`Oracle`] this equals the job's true remaining
    /// time exactly.
    pub fn predicted_remaining(&self, job: &Job, now: crate::Minutes) -> f64 {
        let elapsed = (job.spec.exec_time - job.remaining_at(now)) as f64;
        (self.predict_total(&job.spec) - elapsed).max(0.0)
    }

    /// Fold one completed job's record in (also reachable by subscribing a
    /// clone to the event stream).
    pub fn observe(&self, rec: &JobRecord) {
        self.0.lock().unwrap().observe(rec);
    }

    /// How many `Finished` records have been folded in.
    pub fn updates(&self) -> u64 {
        self.0.lock().unwrap().updates()
    }

    /// The wrapped estimator's name.
    pub fn name(&self) -> String {
        self.0.lock().unwrap().name()
    }

    /// Serialize the wrapped estimator's state for a snapshot.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        self.0.lock().unwrap().snapshot_bin(w);
    }

    /// Restore state written by [`SharedEstimator::snapshot_bin`]. Every
    /// clone of this handle (the controller's event subscription, the
    /// policies' prediction closure) sees the restored state — the `Arc`
    /// is shared, not replaced.
    pub fn restore_bin(&self, r: &mut BinReader) -> anyhow::Result<()> {
        self.0.lock().unwrap().restore_bin(r)
    }
}

impl EventSubscriber for SharedEstimator {
    fn on_event(&mut self, ev: &SchedulerEvent) {
        if let SchedulerEvent::Finished { record, .. } = ev {
            self.observe(record);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobId, TenantId};
    use crate::resources::ResourceVec;

    fn spec(id: u32, class: JobClass, exec: u64, tenant: u32) -> JobSpec {
        JobSpec::new(id, class, ResourceVec::new(4.0, 32.0, 1.0), 0, exec, 0)
            .with_tenant(TenantId(tenant))
    }

    fn record(id: u32, class: JobClass, exec: u64, tenant: u32) -> JobRecord {
        let mut j = Job::new(spec(id, class, exec, tenant));
        j.start(crate::cluster::NodeId(0), 0);
        j.complete(exec);
        JobRecord::from_job(&j)
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(EstimatorKind::parse("oracle"), Some(EstimatorKind::Oracle));
        assert_eq!(EstimatorKind::parse("ORACLE"), Some(EstimatorKind::Oracle));
        assert_eq!(
            EstimatorKind::parse("ewma"),
            Some(EstimatorKind::ClassEwma { alpha: 0.2 })
        );
        assert_eq!(
            EstimatorKind::parse("ewma:alpha=0.5"),
            Some(EstimatorKind::ClassEwma { alpha: 0.5 })
        );
        assert_eq!(
            EstimatorKind::parse("noisy:sigma=0.25"),
            Some(EstimatorKind::Noisy { sigma: 0.25 })
        );
        assert_eq!(
            EstimatorKind::parse("noisy:s=0"),
            Some(EstimatorKind::Noisy { sigma: 0.0 })
        );
        assert_eq!(EstimatorKind::parse("ewma:alpha=0"), None);
        assert_eq!(EstimatorKind::parse("ewma:alpha=1.5"), None);
        assert_eq!(EstimatorKind::parse("noisy:sigma=-1"), None);
        assert_eq!(EstimatorKind::parse("bogus"), None);
        assert_eq!(EstimatorKind::parse("ewma:q=1"), None);
    }

    #[test]
    fn names_render() {
        assert_eq!(EstimatorKind::Oracle.name(), "oracle");
        assert_eq!(EstimatorKind::ClassEwma { alpha: 0.2 }.name(), "ewma(a=0.2)");
        assert_eq!(EstimatorKind::Noisy { sigma: 0.5 }.name(), "noisy(s=0.5)");
    }

    #[test]
    fn oracle_predicts_declared_total_and_exact_remaining() {
        let est = SharedEstimator::new(&EstimatorKind::Oracle, 7);
        let s = spec(0, JobClass::Be, 40, 0);
        assert_eq!(est.predict_total(&s), 40.0);
        let mut j = Job::new(s);
        j.start(crate::cluster::NodeId(0), 0);
        // 27 of the 40 declared minutes have elapsed by minute 27; the
        // lazily-accounted remaining is read through `remaining_at`.
        assert_eq!(est.predicted_remaining(&j, 27), 13.0);
        assert_eq!(est.predicted_remaining(&j, 0), 40.0);
    }

    #[test]
    fn ewma_cold_start_falls_back_to_declared() {
        let est = ClassEwma::new(0.3);
        assert_eq!(est.predict_total(&spec(0, JobClass::Be, 25, 0)), 25.0);
        assert_eq!(est.bucket_mean(0, JobClass::Be), None);
    }

    #[test]
    fn ewma_tracks_per_bucket_means() {
        let mut est = ClassEwma::new(0.5);
        est.observe(&record(0, JobClass::Be, 10, 0));
        est.observe(&record(1, JobClass::Be, 20, 0));
        // EWMA after [10, 20] with alpha 0.5: 0.5*20 + 0.5*10 = 15.
        assert_eq!(est.predict_total(&spec(9, JobClass::Be, 999, 0)), 15.0);
        // Other buckets stay cold.
        assert_eq!(est.predict_total(&spec(9, JobClass::Te, 7, 0)), 7.0);
        assert_eq!(est.predict_total(&spec(9, JobClass::Be, 7, 1)), 7.0);
        assert_eq!(est.updates(), 2);
    }

    #[test]
    fn ewma_converges_to_stationary_mean() {
        let mut est = ClassEwma::new(0.1);
        for i in 0..500 {
            est.observe(&record(i, JobClass::Be, 30, 0));
        }
        let p = est.predict_total(&spec(1000, JobClass::Be, 1, 0));
        assert!((p - 30.0).abs() < 1e-9, "stationary input pins the EWMA: {p}");
    }

    #[test]
    fn noisy_sigma_zero_is_bitwise_oracle() {
        let noisy = SharedEstimator::new(&EstimatorKind::Noisy { sigma: 0.0 }, 42);
        let oracle = SharedEstimator::new(&EstimatorKind::Oracle, 42);
        for id in 0..200u32 {
            let s = spec(id, JobClass::Be, 1 + (id as u64 * 7) % 300, id % 4);
            assert_eq!(
                noisy.predict_total(&s).to_bits(),
                oracle.predict_total(&s).to_bits(),
                "job {id}"
            );
        }
    }

    #[test]
    fn noisy_is_deterministic_per_seed_and_spread_per_job() {
        let a = Noisy::new(0.5, 7);
        let b = Noisy::new(0.5, 7);
        let c = Noisy::new(0.5, 8);
        let s0 = spec(0, JobClass::Be, 100, 0);
        let s1 = spec(1, JobClass::Be, 100, 0);
        assert_eq!(a.predict_total(&s0).to_bits(), b.predict_total(&s0).to_bits());
        assert_ne!(a.predict_total(&s0).to_bits(), c.predict_total(&s0).to_bits());
        assert_ne!(a.predict_total(&s0).to_bits(), a.predict_total(&s1).to_bits());
        assert!(a.predict_total(&s0) > 0.0 && a.predict_total(&s0).is_finite());
    }

    #[test]
    fn estimator_snapshot_round_trip_is_bit_exact() {
        let kind = EstimatorKind::ClassEwma { alpha: 0.3 };
        let est = SharedEstimator::new(&kind, 7);
        for i in 0..40u32 {
            est.observe(&record(i, if i % 3 == 0 { JobClass::Te } else { JobClass::Be },
                1 + (i as u64 * 13) % 90, i % 4));
        }
        let mut w = BinWriter::new();
        est.snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let restored = SharedEstimator::new(&kind, 7);
        let mut r = BinReader::new(&bytes);
        restored.restore_bin(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(restored.updates(), est.updates());
        for id in 100..140u32 {
            let s = spec(id, if id % 2 == 0 { JobClass::Te } else { JobClass::Be }, 55, id % 4);
            assert_eq!(
                restored.predict_total(&s).to_bits(),
                est.predict_total(&s).to_bits(),
                "job {id}"
            );
        }
        // The continued streams agree too: fold one more record into both.
        let extra = record(500, JobClass::Be, 33, 1);
        est.observe(&extra);
        restored.observe(&extra);
        let s = spec(999, JobClass::Be, 70, 1);
        assert_eq!(restored.predict_total(&s).to_bits(), est.predict_total(&s).to_bits());
    }

    #[test]
    fn shared_estimator_folds_finished_events() {
        let mut est = SharedEstimator::new(&EstimatorKind::ClassEwma { alpha: 0.5 }, 7);
        let sub_view = est.clone();
        let rec = record(0, JobClass::Be, 12, 0);
        est.on_event(&SchedulerEvent::Finished { at: 12, job: JobId(0), record: rec });
        assert_eq!(sub_view.updates(), 1, "clones share state");
        // Non-Finished events are ignored.
        est.on_event(&SchedulerEvent::Preempted { at: 1, job: JobId(0) });
        assert_eq!(sub_view.updates(), 1);
        assert_eq!(sub_view.predict_total(&spec(9, JobClass::Be, 999, 0)), 12.0);
    }
}
