//! The incrementally-maintained victim index: the preemption planner's
//! allocation-free view of the running-BE pool.
//!
//! Before this index, every `plan` call rebuilt its world from scratch:
//! [`PolicyCtx::running_be`](super::policy::PolicyCtx::running_be) scanned
//! every node and allocated fresh `Vec`s, the ordered policies (`lrtp`,
//! `srtf`, `youngest`) re-sorted the pool per blocked TE, and FitGpp
//! re-folded its Eq. 3 normalizers. On a saturated cluster with a deep TE
//! queue that is O(TE_queue × running_BE × nodes) per minute — with
//! allocations throughout. The index turns each of those scans into an
//! ordered walk over pre-maintained state, updated only at *transitions*
//! (place / preempt / resume / finish / cancel / drain / node-down).
//!
//! ## Why remaining-time order is transition-stable
//!
//! A Running job's live remaining time at minute `now` is
//! `remaining_at(now) = (synced_at + remaining) − now` (see
//! [`Job::remaining_at`]): lazy accounting means `remaining` is a snapshot
//! at `synced_at`, and Running jobs burn one minute per minute. The sum
//! `completion = synced_at + remaining` is therefore *invariant under
//! [`Job::sync`]* and constant between transitions — it is the job's
//! projected completion minute. Ordering by the integer key
//! `(completion, id)` equals ordering by `(remaining_at(now), id)` at
//! every common `now`, because subtracting the same `now` from all keys
//! preserves order. So the index can keep one sorted structure and never
//! touch it as the clock advances; only placements/evictions/finishes
//! mutate it.
//!
//! ## Why there is no predicted-remaining index
//!
//! Predictions (`psrtf`, `fitgpp_pr`) are *floats* produced by the
//! configured estimator, and estimator updates would invalidate any
//! maintained ordering anyway. Worse, a maintained float key is only
//! weakly consistent with the per-call computation the pre-index code
//! performed. The prediction-aware policies instead compute predictions
//! once per pool job per plan into scheduler-owned scratch — the
//! estimators are pure per call, so call-count changes are byte-safe —
//! and only the *iteration order* (this index's pool order) is shared.
//!
//! ## Membership rule
//!
//! Exactly the jobs `running_be_on` would return: **Running** (not
//! Draining) **BE** jobs on **schedulable (`Up`) nodes**, in allocation
//! order per node. Drain/fail remove a node's entries wholesale; restore,
//! resize, and reclassify rebuild the affected node from the cluster's
//! allocation list (sizes are normalized by node capacity, so a resize
//! changes every size key on the node).
//!
//! ## Allocation discipline
//!
//! The ordered sets are sorted `Vec<(u64, u32)>`s, not `BTreeSet`s: a
//! BTree node split allocates, which would show up inside the pinned
//! allocation-free bench cycles. A sorted `Vec` with `binary_search`
//! insert/remove is allocation-free once its capacity is warm (steady
//! state inserts exactly as often as it removes) and iterates in exactly
//! the order the policies need. The `entries` map is consulted by point
//! lookup only — never iterated — so `HashMap`'s nondeterministic order
//! is harmless.

use std::collections::HashMap;

use crate::cluster::{Cluster, NodeId};
use crate::job::{Job, JobId, JobState};
use crate::job_table::JobTable;
use crate::resources::ResourceVec;

/// Everything needed to take a job *out* of the index exactly, without
/// consulting the (possibly already-mutated) job table.
#[derive(Debug, Clone, Copy)]
struct Entry {
    node: NodeId,
    demand: ResourceVec,
    /// `synced_at + remaining` at insert time — the projected completion
    /// minute (transition-stable; see module docs).
    completion: u64,
    submit: u64,
    gp: u64,
    size_bits: u64,
}

/// Total-order bits for a non-negative f64 size key (same trick as the
/// cluster's capacity index): for `x ≥ 0`, `x.to_bits()` is monotone.
fn size_key_bits(x: f64) -> u64 {
    x.max(0.0).to_bits()
}

fn sorted_insert(v: &mut Vec<(u64, u32)>, key: (u64, u32)) {
    match v.binary_search(&key) {
        Ok(i) | Err(i) => v.insert(i, key),
    }
}

fn sorted_remove(v: &mut Vec<(u64, u32)>, key: (u64, u32)) {
    if let Ok(i) = v.binary_search(&key) {
        v.remove(i);
    } else {
        debug_assert!(false, "victim index: ordered set missing {key:?}");
    }
}

fn close(a: &ResourceVec, b: &ResourceVec) -> bool {
    const TOL: f64 = 1e-6;
    (a.cpu - b.cpu).abs() <= TOL
        && (a.ram_gb - b.ram_gb).abs() <= TOL
        && (a.gpu - b.gpu).abs() <= TOL
}

/// Incrementally-maintained view of the preemptible pool: per-node
/// running-BE lists (allocation order), ordered score indexes for the
/// remaining-time-, age-, GP-, and size-ordered policies, and the demand
/// aggregates behind the O(1) pre-plan reject. Owned by the scheduler,
/// threaded read-only through [`PolicyCtx`](super::policy::PolicyCtx).
#[derive(Debug, Clone)]
pub struct VictimIndex {
    /// Running-BE jobs per node, in allocation order (matches
    /// `running_be_on` exactly).
    lists: Vec<Vec<JobId>>,
    /// Σ demand of indexed jobs per node.
    node_demand: Vec<ResourceVec>,
    /// Σ demand over the whole pool — the preemptible-capacity aggregate.
    pool_demand: ResourceVec,
    /// `(completion, id)` ascending — SRTF order forward, LRTP order via
    /// [`by_remaining_desc`](Self::by_remaining_desc).
    by_completion: Vec<(u64, u32)>,
    /// `(submit, id)` ascending — Youngest order is the plain reverse.
    by_submit: Vec<(u64, u32)>,
    /// `(grace_period, id)` ascending — FitGpp's `max GP` normalizer is
    /// the last key.
    by_gp: Vec<(u64, u32)>,
    /// `(size bits, id)` ascending, size normalized by the job's *own*
    /// node capacity (Eq. 1) — FitGpp's `max size` normalizer is the last
    /// key.
    by_size: Vec<(u64, u32)>,
    /// Point-lookup map for exact removal (never iterated).
    entries: HashMap<u32, Entry>,
}

impl VictimIndex {
    /// An empty index over `n_nodes` nodes (the node count is fixed for a
    /// cluster's lifetime; drain/fail/restore flip availability, never the
    /// roster).
    pub fn new(n_nodes: usize) -> Self {
        VictimIndex {
            lists: vec![Vec::new(); n_nodes],
            node_demand: vec![ResourceVec::ZERO; n_nodes],
            pool_demand: ResourceVec::ZERO,
            by_completion: Vec::new(),
            by_submit: Vec::new(),
            by_gp: Vec::new(),
            by_size: Vec::new(),
            entries: HashMap::new(),
        }
    }

    /// Build from scratch by scanning the cluster — the oracle the
    /// incremental maintenance is checked against, and the constructor
    /// tests use to stand up a `PolicyCtx`.
    pub fn build(cluster: &Cluster, jobs: &JobTable) -> Self {
        let mut idx = Self::new(cluster.nodes.len());
        for n in &cluster.nodes {
            if !n.is_schedulable() {
                continue;
            }
            for id in n.jobs() {
                let j = &jobs[id];
                if j.is_be() && j.state == JobState::Running {
                    idx.insert(j, &n.capacity);
                }
            }
        }
        idx
    }

    /// Index a freshly-placed (or re-scanned) running BE job.
    /// `node_capacity` is the capacity of the job's node (Eq. 1 normalizes
    /// size per-node). Call *after* `Job::start` so `synced_at` is
    /// current.
    pub fn insert(&mut self, job: &Job, node_capacity: &ResourceVec) {
        debug_assert!(job.is_be() && job.state == JobState::Running);
        let id = job.id();
        let node = job.node.expect("indexed job must be bound to a node");
        let entry = Entry {
            node,
            demand: job.spec.demand,
            completion: job.synced_at.saturating_add(job.remaining),
            submit: job.spec.submit,
            gp: job.spec.grace_period,
            size_bits: size_key_bits(job.spec.demand.size(node_capacity)),
        };
        let prev = self.entries.insert(id.0, entry);
        debug_assert!(prev.is_none(), "victim index: double insert of {id:?}");
        self.lists[node.0 as usize].push(id);
        self.node_demand[node.0 as usize] += entry.demand;
        self.pool_demand += entry.demand;
        sorted_insert(&mut self.by_completion, (entry.completion, id.0));
        sorted_insert(&mut self.by_submit, (entry.submit, id.0));
        sorted_insert(&mut self.by_gp, (entry.gp, id.0));
        sorted_insert(&mut self.by_size, (entry.size_bits, id.0));
    }

    /// Drop a job from the index. Idempotent: transitions that *may*
    /// concern an indexed job (cancel of an active job, completion) call
    /// this unconditionally; if the job was never indexed (TE, draining,
    /// on a non-Up node) it is a no-op.
    pub fn remove(&mut self, id: JobId) {
        let Some(e) = self.entries.remove(&id.0) else {
            return;
        };
        let list = &mut self.lists[e.node.0 as usize];
        let pos = list
            .iter()
            .position(|j| *j == id)
            .expect("victim index: entry without list slot");
        list.remove(pos); // order-preserving, like the cluster's release
        self.node_demand[e.node.0 as usize] -= e.demand;
        self.pool_demand -= e.demand;
        // Snap the accumulators when a scope empties: bounds f64 drift
        // over long churn (mirrors the cluster's free-space snapping).
        if list.is_empty() {
            self.node_demand[e.node.0 as usize] = ResourceVec::ZERO;
        }
        if self.entries.is_empty() {
            self.pool_demand = ResourceVec::ZERO;
        }
        sorted_remove(&mut self.by_completion, (e.completion, id.0));
        sorted_remove(&mut self.by_submit, (e.submit, id.0));
        sorted_remove(&mut self.by_gp, (e.gp, id.0));
        sorted_remove(&mut self.by_size, (e.size_bits, id.0));
    }

    /// Drop every entry on `node` (drain / node-down: the node stops being
    /// schedulable, so its tenants leave the preemptible pool even though
    /// they may keep running until evicted).
    pub fn remove_node(&mut self, node: NodeId) {
        while let Some(&id) = self.lists[node.0 as usize].last() {
            self.remove(id);
        }
    }

    /// Re-derive `node`'s entries from the cluster's allocation list
    /// (restore / resize / reclassify: membership or size keys changed in
    /// ways cheaper to re-scan than to patch). No-op contribution for
    /// non-`Up` nodes.
    pub fn rebuild_node(&mut self, node: NodeId, cluster: &Cluster, jobs: &JobTable) {
        self.remove_node(node);
        let n = cluster.node(node);
        if !n.is_schedulable() {
            return;
        }
        for id in n.jobs() {
            let j = &jobs[id];
            if j.is_be() && j.state == JobState::Running {
                self.insert(j, &n.capacity);
            }
        }
    }

    /// Number of indexed (preemptible) jobs.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the preemptible pool empty?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The whole pool in node order × per-node allocation order — exactly
    /// the order `PolicyCtx::running_be()` produced.
    pub fn pool(&self) -> impl Iterator<Item = JobId> + '_ {
        self.lists.iter().flatten().copied()
    }

    /// Running-BE jobs on one node, allocation order (the per-node slice
    /// behind `running_be_on`).
    pub fn on_node(&self, node: NodeId) -> &[JobId] {
        &self.lists[node.0 as usize]
    }

    /// Pool in `(remaining_at(now), id)` ascending order — SRTF's victim
    /// order, valid at every `now` between transitions (see module docs).
    pub fn by_remaining_asc(&self) -> impl Iterator<Item = JobId> + '_ {
        self.by_completion.iter().map(|&(_, id)| JobId(id))
    }

    /// Pool in `(remaining desc, id asc)` order — LRTP's victim order.
    /// Equal-completion runs are emitted back-to-front as *groups*, each
    /// group forward: that is completion descending with ids ascending
    /// inside a tie, matching the pre-index
    /// `sort_by_key(|id| (Reverse(remaining), id.0))` exactly.
    pub fn by_remaining_desc(&self) -> GroupedRev<'_> {
        GroupedRev::new(&self.by_completion)
    }

    /// Pool in `(submit desc, id desc)` order — Youngest's victim order.
    /// The plain reverse of the ascending `(submit, id)` set is exactly
    /// the pre-index `(Reverse(submit), Reverse(id.0))` sort.
    pub fn by_age_youngest_first(&self) -> impl Iterator<Item = JobId> + '_ {
        self.by_submit.iter().rev().map(|&(_, id)| JobId(id))
    }

    /// FitGpp's Eq. 3 size normalizer: the largest normalized demand in
    /// the pool (0.0 when empty, dropping the term like the pre-index
    /// fold). Exact: sizes are ≥ 0, so the bit-ordered max *is* the f64
    /// max with identical bits.
    pub fn max_size(&self) -> f64 {
        self.by_size
            .last()
            .map_or(0.0, |&(bits, _)| f64::from_bits(bits))
    }

    /// FitGpp's Eq. 3 GP normalizer: the longest grace period in the pool
    /// as f64 (0.0 when empty). `u64 → f64` is monotone, so the last
    /// integer key converts to exactly the value the pre-index
    /// `max_gp.max(gp as f64)` fold produced.
    pub fn max_gp(&self) -> f64 {
        self.by_gp.last().map_or(0.0, |&(gp, _)| gp as f64)
    }

    /// Σ demand over the pool — evicting *everything* frees exactly this
    /// (modulo f64 rounding; callers add slack). The O(1) pre-plan reject
    /// bound is `total_effective_free + pool_demand + slack`.
    pub fn pool_demand(&self) -> &ResourceVec {
        &self.pool_demand
    }

    /// Σ demand of indexed jobs on one node — what
    /// `feasible_nodes` adds to a node's effective free space.
    pub fn node_demand(&self, node: NodeId) -> &ResourceVec {
        &self.node_demand[node.0 as usize]
    }

    /// Paranoid cross-check: the incremental state must match a
    /// from-scratch [`build`](Self::build) — lists and ordered sets
    /// *byte-equal*, aggregates within f64 drift tolerance. Wired into the
    /// scheduler's paranoid mode so every core test and property run
    /// exercises it each tick.
    pub fn check_against(&self, cluster: &Cluster, jobs: &JobTable) -> Result<(), String> {
        let fresh = Self::build(cluster, jobs);
        if self.lists != fresh.lists {
            return Err(format!(
                "victim index lists diverged: have {:?}, expected {:?}",
                self.lists, fresh.lists
            ));
        }
        if self.by_completion != fresh.by_completion {
            return Err("victim index by_completion diverged".into());
        }
        if self.by_submit != fresh.by_submit {
            return Err("victim index by_submit diverged".into());
        }
        if self.by_gp != fresh.by_gp {
            return Err("victim index by_gp diverged".into());
        }
        if self.by_size != fresh.by_size {
            return Err("victim index by_size diverged".into());
        }
        if !close(&self.pool_demand, &fresh.pool_demand) {
            return Err(format!(
                "victim index pool_demand drifted: have {}, expected {}",
                self.pool_demand, fresh.pool_demand
            ));
        }
        for (i, (a, b)) in self.node_demand.iter().zip(&fresh.node_demand).enumerate() {
            if !close(a, b) {
                return Err(format!(
                    "victim index node_demand[{i}] drifted: have {a}, expected {b}"
                ));
            }
        }
        Ok(())
    }
}

/// Iterator for [`VictimIndex::by_remaining_desc`]: walks an ascending
/// `(key, id)` slice as equal-key *groups* from the back, each group
/// front-to-back — key descending, ids ascending within a tie.
pub struct GroupedRev<'a> {
    keys: &'a [(u64, u32)],
    run_start: usize,
    pos: usize,
    run_end: usize,
}

impl<'a> GroupedRev<'a> {
    fn new(keys: &'a [(u64, u32)]) -> Self {
        // Start "past the end": the first `next()` locates the last run.
        let n = keys.len();
        GroupedRev { keys, run_start: n, pos: n, run_end: n }
    }
}

impl Iterator for GroupedRev<'_> {
    type Item = JobId;

    fn next(&mut self) -> Option<JobId> {
        if self.pos == self.run_end {
            if self.run_start == 0 {
                return None;
            }
            self.run_end = self.run_start;
            let key = self.keys[self.run_end - 1].0;
            let mut s = self.run_end - 1;
            while s > 0 && self.keys[s - 1].0 == key {
                s -= 1;
            }
            self.run_start = s;
            self.pos = s;
        }
        let (_, id) = self.keys[self.pos];
        self.pos += 1;
        Some(JobId(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::job::{JobClass, JobSpec};

    fn rv(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    /// Cluster of `n` tiny nodes with `placements[i] = (node, demand,
    /// submit, exec, gp)`, every job started at minute 0.
    fn setup(
        n: usize,
        placements: &[(u32, ResourceVec, u64, u64, u64)],
    ) -> (Cluster, JobTable) {
        let mut cluster = Cluster::new(&ClusterSpec::tiny(n));
        let mut jobs = JobTable::new();
        for (i, (node, demand, submit, exec, gp)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, *submit, *exec, *gp);
            let id = spec.id;
            let mut job = crate::job::Job::new(spec);
            job.start(NodeId(*node), 0);
            jobs.insert(job);
            cluster.bind(id, *demand, NodeId(*node));
        }
        (cluster, jobs)
    }

    #[test]
    fn build_matches_incremental_and_orders_hold() {
        let (cluster, jobs) = setup(
            2,
            &[
                (0, rv(2.0, 16.0, 0.0), 5, 30, 10),
                (0, rv(1.0, 8.0, 0.0), 1, 30, 20),
                (1, rv(4.0, 32.0, 1.0), 5, 7, 5),
            ],
        );
        let idx = VictimIndex::build(&cluster, &jobs);
        assert_eq!(idx.len(), 3);
        idx.check_against(&cluster, &jobs).unwrap();

        // Pool = node order × allocation order.
        let pool: Vec<JobId> = idx.pool().collect();
        assert_eq!(pool, vec![JobId(0), JobId(1), JobId(2)]);

        // SRTF: remaining asc (all started at 0 ⇒ completion == exec).
        let asc: Vec<JobId> = idx.by_remaining_asc().collect();
        assert_eq!(asc, vec![JobId(2), JobId(0), JobId(1)]);

        // Equal exec ⇒ ids ascending within the tie in both directions.
        // LRTP: remaining desc, ids asc within ties.
        let desc: Vec<JobId> = idx.by_remaining_desc().collect();
        assert_eq!(desc, vec![JobId(0), JobId(1), JobId(2)]);

        // Youngest: submit desc, id desc within ties.
        let young: Vec<JobId> = idx.by_age_youngest_first().collect();
        assert_eq!(young, vec![JobId(2), JobId(0), JobId(1)]);

        // Normalizers: max GP = 20; max size = job 2's (4/32 cpu … on the
        // tiny node: dominant axis decides).
        assert_eq!(idx.max_gp(), 20.0);
        let cap = cluster.node(NodeId(1)).capacity;
        assert_eq!(idx.max_size(), rv(4.0, 32.0, 1.0).size(&cap));
    }

    #[test]
    fn remove_is_idempotent_and_exact() {
        let (cluster, jobs) = setup(
            1,
            &[
                (0, rv(1.0, 8.0, 0.0), 0, 10, 5),
                (0, rv(2.0, 16.0, 0.0), 1, 20, 5),
            ],
        );
        let mut idx = VictimIndex::build(&cluster, &jobs);
        idx.remove(JobId(0));
        idx.remove(JobId(0)); // no-op
        assert_eq!(idx.len(), 1);
        assert_eq!(idx.pool().collect::<Vec<_>>(), vec![JobId(1)]);
        assert_eq!(*idx.pool_demand(), rv(2.0, 16.0, 0.0));
        idx.remove(JobId(1));
        assert!(idx.is_empty());
        assert!(idx.pool_demand().is_zero());
        assert_eq!(idx.max_size(), 0.0);
        assert_eq!(idx.max_gp(), 0.0);
    }

    #[test]
    fn remove_node_and_rebuild_roundtrip() {
        let (mut cluster, jobs) = setup(
            2,
            &[
                (0, rv(1.0, 8.0, 0.0), 0, 10, 5),
                (1, rv(2.0, 16.0, 0.0), 0, 20, 5),
            ],
        );
        let mut idx = VictimIndex::build(&cluster, &jobs);
        cluster.set_availability(NodeId(0), crate::cluster::NodeAvailability::Draining);
        idx.remove_node(NodeId(0));
        idx.check_against(&cluster, &jobs).unwrap();
        assert_eq!(idx.pool().collect::<Vec<_>>(), vec![JobId(1)]);

        cluster.set_availability(NodeId(0), crate::cluster::NodeAvailability::Up);
        idx.rebuild_node(NodeId(0), &cluster, &jobs);
        idx.check_against(&cluster, &jobs).unwrap();
        assert_eq!(idx.len(), 2);
    }

    #[test]
    fn grouped_rev_handles_all_tie_shapes() {
        // keys: [1,1,2,3,3,3] → groups from the back: [3,3,3],[2],[1,1].
        let keys = vec![(1, 10), (1, 11), (2, 12), (3, 13), (3, 14), (3, 15)];
        let out: Vec<u32> = GroupedRev::new(&keys).map(|id| id.0).collect();
        assert_eq!(out, vec![13, 14, 15, 12, 10, 11]);
        assert_eq!(GroupedRev::new(&[]).count(), 0);
        let single = vec![(7, 42)];
        assert_eq!(GroupedRev::new(&single).map(|id| id.0).collect::<Vec<_>>(), vec![42]);
    }

    #[test]
    fn down_nodes_are_not_indexed() {
        let (mut cluster, jobs) = setup(
            2,
            &[
                (0, rv(1.0, 8.0, 0.0), 0, 10, 5),
                (1, rv(2.0, 16.0, 0.0), 0, 20, 5),
            ],
        );
        cluster.set_availability(NodeId(1), crate::cluster::NodeAvailability::Down);
        // Note: a real fail_node evicts allocations first; membership here
        // only depends on schedulability.
        let idx = VictimIndex::build(&cluster, &jobs);
        assert_eq!(idx.pool().collect::<Vec<_>>(), vec![JobId(0)]);
    }
}
