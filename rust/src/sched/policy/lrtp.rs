//! LRTP — Longest Remaining Time Preemption, the Big-C strategy (§4.1).
//!
//! "It preferentially preempts the job with the longest remaining execution
//! time … [and] continue[s] the preemption process until they can prepare
//! enough resource for the incoming TE job." Per the paper we grant it a
//! **perfect execution-time oracle** (`PolicyCtx::oracle_remaining`) — the
//! very assumption FitGpp is designed to avoid.
//!
//! Victim selection is *global*, exactly as stated: the longest-remaining
//! running BE job anywhere in the cluster, repeated until **some** node's
//! projected free space (its own free + its chosen victims' demands) fits
//! the TE job. Victims therefore scatter across nodes — evictions on nodes
//! that never end up hosting the TE job are collateral damage. That
//! node-blindness is precisely why LRTP/RAND preempt an order of magnitude
//! more jobs than FitGpp in the paper's Tables 3–4 (FitGpp's Eq. 2 is the
//! fix), so we deliberately do *not* make the baseline smarter here. The
//! shared eviction loop lives in
//! [`greedy_global_plan`](super::greedy_global_plan).

use super::{greedy_global_plan, PlanScratch, PolicyCtx, PreemptionPlan, PreemptionPolicy};
use crate::job::JobSpec;
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`].
pub struct Lrtp;

impl PreemptionPolicy for Lrtp {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        _rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch)
    }
}

/// Plan LRTP eviction: the victim index's remaining-time-descending walk
/// (equal to sorting the pool by the perfect oracle — the index's integer
/// completion keys order identically to live remaining times, ties
/// included), fed to the greedy global loop. No scan, no sort, no
/// allocation: O(victims examined).
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
) -> Option<PreemptionPlan> {
    let mut it = ctx.victims.by_remaining_desc();
    greedy_global_plan(te, ctx, &mut scratch.greedy, true, || it.next())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyCtx;

    fn setup(
        nodes: usize,
        placements: &[(u32, ResourceVec, u64)], // (node, demand, remaining)
    ) -> (Cluster, crate::job_table::JobTable, Vec<u64>) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        let mut remaining = Vec::new();
        for (i, (node, demand, rem)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, 0, (*rem).max(1), 0);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), 0);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
            remaining.push(*rem);
        }
        (cluster, crate::job_table::JobTable::from_jobs(jobs), remaining)
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    #[test]
    fn picks_longest_remaining_globally() {
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 100), (1, d, 500)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        // Demand exceeds the free space on either node: one victim needed,
        // and it must be the remaining-500 job on node 1.
        let plan = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(plan.victims, vec![JobId(1)]);
        assert_eq!(plan.node, NodeId(1));
    }

    #[test]
    fn evicts_globally_until_some_node_fits() {
        // Longest jobs alternate across two full nodes; LRTP evicts in
        // global remaining-time order even when that scatters victims.
        let d = ResourceVec::new(16.0, 128.0, 4.0); // half a node
        let (cluster, jobs, rem) = setup(
            2,
            &[(0, d, 400), (0, d, 100), (1, d, 300), (1, d, 200)],
        );
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        // TE needs a whole node: evict rem-400 (node 0) — no node fits and
        // aggregate (half a node) is short; evict rem-300 (node 1) — still
        // no single-node fit, but the *aggregate* freed space now covers
        // the demand, so the node-blind baseline stops here (the scheduler
        // will re-plan if the drains under-deliver). Job 0's eviction is
        // collateral damage — the cascade FitGpp's Eq. 2 avoids.
        let p = plan(&te(ResourceVec::new(32.0, 256.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(p.victims, vec![JobId(0), JobId(2)]);
    }

    #[test]
    fn multi_victim_until_fit_on_one_node() {
        let d = ResourceVec::new(4.0, 32.0, 2.0);
        let (cluster, jobs, rem) =
            setup(1, &[(0, d, 10), (0, d, 40), (0, d, 30), (0, d, 20)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let p = plan(&te(ResourceVec::new(2.0, 16.0, 6.0)), &ctx, &mut PlanScratch::default()).unwrap();
        // free GPUs = 0; need 6 ⇒ evict longest three: rem 40, 30, 20.
        assert_eq!(p.victims, vec![JobId(1), JobId(2), JobId(3)]);
    }

    #[test]
    fn infeasible_everywhere_returns_none() {
        let d = ResourceVec::new(4.0, 32.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 10), (1, d, 20)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        assert!(plan(&te(ResourceVec::new(1.0, 1.0, 10.0)), &ctx, &mut PlanScratch::default()).is_none());
    }

    #[test]
    fn zero_victims_when_free_space_already_fits() {
        let d = ResourceVec::new(4.0, 32.0, 1.0);
        let (cluster, jobs, rem) = setup(1, &[(0, d, 10)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let p = plan(&te(ResourceVec::new(1.0, 1.0, 1.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert!(p.victims.is_empty());
    }
}
