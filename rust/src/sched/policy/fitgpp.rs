//! FitGpp — *Fitting Grace Period Preemption* (§3.2, Eq. 1–4), the paper's
//! contribution.
//!
//! Four strategies in one rule:
//! 1. **Minimize re-scheduling intervals**: prefer victims with small
//!    normalized demand `Size` (Eq. 1) — small jobs re-fit quickly, so the
//!    head-of-line blocking their re-queue could cause is short.
//! 2. **Minimize the number of preemptions**: only consider victims that
//!    can host the TE job *on their own* together with the node's free
//!    space (Eq. 2), so one preemption suffices.
//! 3. **Minimize preemption-incurred time loss**: prefer short grace
//!    periods (weight `s` in Eq. 3) — the TE job waits out the victim's GP.
//! 4. **Avoid starvation**: never pick a job already preempted `P` times.
//!
//! If no candidate satisfies Eq. 2 ∧ count < P, the paper falls back to "a
//! random BE job" — we reuse the RAND policy's node-sticky plan for that
//! (and count how often it fires; in the paper's experiments it never did).

use super::{rand_policy, PlanScratch, PolicyCtx, PreemptionPlan, PreemptionPolicy};
use crate::job::{JobId, JobSpec};
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`]: the paper's FitGpp with its two knobs.
pub struct FitGpp {
    /// Eq. 3 grace-period weight.
    pub s: f64,
    /// Per-job preemption cap `P` (`None` = unlimited).
    pub p_max: Option<u32>,
}

impl PreemptionPolicy for FitGpp {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch, self.s, self.p_max, rng)
    }
}

/// Eq. 3: `Score(j) = Size(D_j)/max_J Size + s * GP_j/max_J GP`.
///
/// Normalizers are taken over 𝒥 = all currently running BE jobs, exactly as
/// the paper writes it. A zero max (no running BE job demands anything /
/// all GPs are zero) drops that term.
pub fn score(
    size_j: f64,
    gp_j: f64,
    max_size: f64,
    max_gp: f64,
    s: f64,
) -> f64 {
    let size_term = if max_size > 0.0 { size_j / max_size } else { 0.0 };
    let gp_term = if max_gp > 0.0 { gp_j / max_gp } else { 0.0 };
    size_term + s * gp_term
}

/// Eq. 4: pick `argmin Score(j)` subject to `D_TE <= D_j + N_{node(j)}`
/// (element-wise) and `PreemptionCount_j < P`; fall back to a random plan
/// when the candidate set is empty.
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
    s: f64,
    p_max: Option<u32>,
    rng: &mut Pcg64,
) -> Option<PreemptionPlan> {
    if ctx.victims.is_empty() {
        return None;
    }

    // Normalizers over 𝒥 (all running BE jobs), read off the victim
    // index's ordered-set tails instead of a per-plan O(J) fold —
    // bit-identical: sizes are ≥ 0 so the bit-ordered maximum *is* the
    // f64 maximum, and `u64 → f64` is monotone for the GP keys. Size is
    // measured against the *hosting node's* capacity, which keeps Eq. 1
    // meaningful on heterogeneous clusters (identical to the paper on its
    // homogeneous testbed).
    let max_size = ctx.victims.max_size();
    let max_gp = ctx.victims.max_gp();

    let mut best: Option<(f64, JobId)> = None;
    for id in ctx.victims.pool() {
        let j = &ctx.jobs[id];
        if let Some(p) = p_max {
            if j.preemptions >= p {
                continue; // starvation guard (strategy 4)
            }
        }
        let node = j.node.expect("running job has a node");
        // Eq. 2: the victim plus the node's unallocated resources can host
        // the TE job on their own.
        let avail = j.spec.demand + ctx.effective_free[node.0 as usize];
        if !te.demand.fits_in(&avail) {
            continue;
        }
        // The same expression the index keyed, recomputed only for the
        // candidates that survive Eq. 2 — identical bits either way.
        let sz = j.spec.demand.size(&ctx.cluster.node(node).capacity);
        let sc = score(sz, j.spec.grace_period as f64, max_size, max_gp, s);
        // Deterministic tie-break on job id.
        let better = match best {
            None => true,
            Some((b, bid)) => sc < b || (sc == b && id < bid),
        };
        if better {
            best = Some((sc, id));
        }
    }

    if let Some((_, id)) = best {
        let node = ctx.jobs[id].node.unwrap();
        return Some(PreemptionPlan { node, victims: vec![id], fallback: false });
    }

    // Paper: "If there is no running BE job that meets the condition,
    // FitGpp preempts a random BE job." Multi-victim random continuation so
    // the plan still frees enough room; the P cap is still honoured so the
    // no-starvation guarantee (strategy 4) holds unconditionally.
    rand_policy::plan(te, ctx, scratch, rng, p_max).map(|mut p| {
        p.fallback = true;
        p
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::job_table::JobTable;
    use crate::resources::ResourceVec;

    /// Build a cluster + job table: `placements[i] = (node, demand, gp)`
    /// creates a running BE job i on that node.
    fn setup(
        nodes: usize,
        placements: &[(u32, ResourceVec, u64)],
    ) -> (Cluster, JobTable) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        for (i, (node, demand, gp)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, 0, 60, *gp);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), 0);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
        }
        (cluster, JobTable::from_jobs(jobs))
    }

    fn ctx<'a>(
        cluster: &'a Cluster,
        jobs: &'a JobTable,
        free: &'a [ResourceVec],
        oracle: &'a dyn Fn(JobId) -> u64,
        vidx: &'a crate::sched::victim_index::VictimIndex,
    ) -> PolicyCtx<'a> {
        PolicyCtx {
            cluster,
            jobs,
            effective_free: free,
            oracle_remaining: oracle,
            predicted_remaining: &PRED,
            victims: vidx,
        }
    }

    /// Zero-prediction stub — FitGpp never reads predictions.
    const PRED: fn(JobId) -> f64 = |_| 0.0;

    fn frees(cluster: &Cluster) -> Vec<ResourceVec> {
        cluster.nodes.iter().map(|n| n.free).collect()
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    const ORACLE: fn(JobId) -> u64 = |_| 0;

    #[test]
    fn prefers_smallest_qualifying_victim() {
        // Node 0: big job; node 1: small job. Both satisfy Eq. 2 for a tiny
        // TE job; FitGpp must pick the small one (lower Size, equal GP).
        let (cluster, jobs) = setup(
            2,
            &[
                (0, ResourceVec::new(24.0, 192.0, 6.0), 5),
                (1, ResourceVec::new(4.0, 32.0, 1.0), 5),
            ],
        );
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        let plan = plan(&te(ResourceVec::new(2.0, 16.0, 1.0)), &c, &mut PlanScratch::default(), 4.0, Some(1),&mut Pcg64::new(1)).unwrap();
        assert_eq!(plan.victims, vec![JobId(1)]);
        assert_eq!(plan.node, NodeId(1));
    }

    #[test]
    fn gp_weight_flips_choice() {
        // Two same-size victims; one has GP 20, the other GP 0. With s > 0
        // the short-GP job must win even though sizes tie.
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs) = setup(2, &[(0, d, 20), (1, d, 0)]);
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        let plan = plan(&te(d), &c, &mut PlanScratch::default(), 4.0, Some(1),&mut Pcg64::new(1)).unwrap();
        assert_eq!(plan.victims, vec![JobId(1)]);
    }

    #[test]
    fn s_zero_ignores_gp() {
        // With s = 0 only Size matters: the smaller job wins even with a
        // huge GP.
        let (cluster, jobs) = setup(
            2,
            &[
                (0, ResourceVec::new(8.0, 64.0, 2.0), 0),  // bigger, GP 0
                (1, ResourceVec::new(4.0, 32.0, 1.0), 20), // smaller, GP 20
            ],
        );
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        let plan = plan(&te(ResourceVec::new(2.0, 16.0, 1.0)), &c, &mut PlanScratch::default(), 0.0, Some(1),&mut Pcg64::new(1)).unwrap();
        assert_eq!(plan.victims, vec![JobId(1)]);
    }

    #[test]
    fn eq2_excludes_insufficient_victims() {
        // Node 0 holds two small jobs; TE needs more than either job +
        // node-free provides, so Eq. 2 disqualifies both and the random
        // fallback must produce a multi-victim plan on node 0.
        let d = ResourceVec::new(14.0, 120.0, 4.0);
        let (cluster, jobs) = setup(1, &[(0, d, 0), (0, d, 0)]);
        let free = frees(&cluster); // free = [4, 16, 0]
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        let plan = plan(&te(ResourceVec::new(20.0, 128.0, 6.0)), &c, &mut PlanScratch::default(), 4.0, Some(1),&mut Pcg64::new(7)).unwrap();
        assert_eq!(plan.victims.len(), 2, "fallback must evict both");
    }

    #[test]
    fn respects_preemption_cap() {
        let d = ResourceVec::new(4.0, 32.0, 1.0);
        let (cluster, mut jobs) = setup(2, &[(0, d, 0), (1, d, 5)]);
        jobs[JobId(0)].preemptions = 1; // job 0 already preempted once
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        // P = 1: job 0 is off-limits despite its better (lower-GP) score.
        let capped = plan(&te(d), &c, &mut PlanScratch::default(), 4.0, Some(1),&mut Pcg64::new(1)).unwrap();
        assert_eq!(capped.victims, vec![JobId(1)]);
        // P = ∞ re-admits job 0.
        let uncapped = plan(&te(d), &c, &mut PlanScratch::default(), 4.0, None,&mut Pcg64::new(1)).unwrap();
        assert_eq!(uncapped.victims, vec![JobId(0)]);
    }

    #[test]
    fn no_running_be_jobs_yields_none() {
        let (cluster, jobs) = setup(1, &[]);
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        assert!(plan(&te(ResourceVec::new(1.0, 1.0, 0.0)), &c, &mut PlanScratch::default(), 4.0, Some(1),&mut Pcg64::new(1)).is_none());
    }

    #[test]
    fn score_formula_matches_eq3() {
        // Size ratio 0.5, GP ratio 0.25, s = 4 ⇒ 0.5 + 4·0.25 = 1.5.
        assert!((score(1.0, 5.0, 2.0, 20.0, 4.0) - 1.5).abs() < 1e-12);
        // Degenerate normalizers drop their term.
        assert_eq!(score(1.0, 5.0, 0.0, 0.0, 4.0), 0.0);
    }

    #[test]
    fn free_space_counts_toward_eq2() {
        // The victim alone is too small, but victim + node free satisfies
        // Eq. 2 — it must qualify (single victim, no fallback).
        let (cluster, jobs) = setup(1, &[(0, ResourceVec::new(4.0, 32.0, 1.0), 0)]);
        let free = frees(&cluster); // 28 CPUs etc. free
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let c = ctx(&cluster, &jobs, &free, &ORACLE, &vidx);
        let plan = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &c, &mut PlanScratch::default(), 4.0, Some(1),&mut Pcg64::new(1)).unwrap();
        assert_eq!(plan.victims, vec![JobId(0)]);
    }
}
