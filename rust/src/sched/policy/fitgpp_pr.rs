//! FitGpp-PR — FitGpp with predicted-resume-cost victim ranking.
//!
//! Plain FitGpp (Eq. 3) ranks victims on declared attributes only: demand
//! size and grace-period length. That deliberately ignores how much the
//! victim itself loses by being preempted — a BE job one minute from
//! completion pays a far higher relative price than one that just started.
//! FitGpp-PR keeps everything FitGpp gets right (Eq. 2 single-victim
//! feasibility, the preemption cap, the argmin tie-break, the random
//! fallback) and swaps the grace-period term for a *predicted resume
//! cost*:
//!
//! ```text
//! R_j = (GP_j + 1) / (pred_remaining_j + 1)
//! Score(j) = Size(D_j)/max_J Size + s · R_j/max_J R
//! ```
//!
//! Small `R_j` — the preferred victims — means a short grace period
//! (quick to vacate, the TE job waits less) *and* a long predicted
//! remaining time (the eviction wastes a small fraction of the victim's
//! work, and it would have occupied the node for long anyway). The `+1`
//! offsets keep the ratio finite for zero grace periods and completed-any
//! -minute-now predictions, and keep `R_j` strictly positive so the
//! normalizer `max_J R` never degenerates and the term is always active.
//!
//! With the oracle estimator this is FitGpp upgraded with perfect
//! remaining-time knowledge — the upper bound the error-sensitivity sweep
//! erodes by cranking the `Noisy` estimator's sigma.

use super::{fitgpp, rand_policy, PlanScratch, PolicyCtx, PreemptionPlan, PreemptionPolicy};
use crate::job::{JobId, JobSpec};
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`]: FitGpp-PR with its two knobs.
pub struct FitGppPr {
    /// Weight of the resume-cost term (the analogue of FitGpp's `s`).
    pub s: f64,
    /// Per-job preemption cap `P` (`None` = unlimited).
    pub p_max: Option<u32>,
}

impl PreemptionPolicy for FitGppPr {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch, self.s, self.p_max, rng)
    }
}

/// The predicted resume cost `R_j = (GP_j + 1) / (pred_remaining_j + 1)`.
pub fn resume_cost(gp: f64, pred_remaining: f64) -> f64 {
    (gp + 1.0) / (pred_remaining + 1.0)
}

/// FitGpp's Eq. 4 with the resume-cost score: pick
/// `argmin Size/max_Size + s·R/max_R` subject to Eq. 2 and the preemption
/// cap; fall back to a random plan when the candidate set is empty.
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
    s: f64,
    p_max: Option<u32>,
    rng: &mut Pcg64,
) -> Option<PreemptionPlan> {
    if ctx.victims.is_empty() {
        return None;
    }

    // Normalizers over 𝒥 (all running BE jobs), exactly as FitGpp measures
    // them — Size against the hosting node's capacity (read off the victim
    // index's ordered-set tail, bit-identical to the old fold), R over the
    // pool. R depends on live estimator output, so it is computed per plan
    // into scratch — in pool order, one estimator call per job, the same
    // call sequence the pre-index pass made. R is strictly positive, so
    // max_r never degenerates.
    let max_size = ctx.victims.max_size();
    let mut max_r = 0.0f64;
    scratch.terms.clear();
    scratch.terms.extend(ctx.victims.pool().map(|id| {
        let j = &ctx.jobs[id];
        let node = ctx.cluster.node(j.node.expect("running job has a node"));
        let sz = j.spec.demand.size(&node.capacity);
        let r = resume_cost(j.spec.grace_period as f64, (ctx.predicted_remaining)(id));
        max_r = max_r.max(r);
        (sz, r)
    }));

    let mut best: Option<(f64, JobId)> = None;
    for (i, id) in ctx.victims.pool().enumerate() {
        let j = &ctx.jobs[id];
        if let Some(p) = p_max {
            if j.preemptions >= p {
                continue; // FitGpp's starvation guard, unchanged
            }
        }
        let node = j.node.expect("running job has a node");
        // Eq. 2, unchanged: the victim plus the node's unallocated
        // resources can host the TE job on their own.
        let avail = j.spec.demand + ctx.effective_free[node.0 as usize];
        if !te.demand.fits_in(&avail) {
            continue;
        }
        let (sz, r) = scratch.terms[i];
        let size_term = if max_size > 0.0 { sz / max_size } else { 0.0 };
        let sc = size_term + s * r / max_r;
        // Deterministic tie-break on job id, as in FitGpp.
        let better = match best {
            None => true,
            Some((b, bid)) => sc < b || (sc == b && id < bid),
        };
        if better {
            best = Some((sc, id));
        }
    }

    if let Some((_, id)) = best {
        let node = ctx.jobs[id].node.unwrap();
        return Some(PreemptionPlan { node, victims: vec![id], fallback: false });
    }

    // Same escape hatch as FitGpp: no qualifying candidate ⇒ random plan,
    // flagged, cap still honoured.
    rand_policy::plan(te, ctx, scratch, rng, p_max).map(|mut p| {
        p.fallback = true;
        p
    })
}

/// With `s = 0` the resume-cost term vanishes and FitGpp-PR must agree
/// with FitGpp on every input (both reduce to pure Size argmin). Exposed
/// for tests.
pub fn agrees_with_fitgpp_at_s_zero(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
    p_max: Option<u32>,
    seed: u64,
) -> bool {
    let a = plan(te, ctx, scratch, 0.0, p_max, &mut Pcg64::new(seed));
    let b = fitgpp::plan(te, ctx, scratch, 0.0, p_max, &mut Pcg64::new(seed));
    a == b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::job_table::JobTable;
    use crate::resources::ResourceVec;

    /// `placements[i] = (node, demand, gp, remaining)` creates a running BE
    /// job i on that node.
    fn setup(
        nodes: usize,
        placements: &[(u32, ResourceVec, u64, u64)],
    ) -> (Cluster, JobTable, Vec<u64>) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        let mut remaining = Vec::new();
        for (i, (node, demand, gp, rem)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, 0, (*rem).max(1), *gp);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), 0);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
            remaining.push(*rem);
        }
        (cluster, JobTable::from_jobs(jobs), remaining)
    }

    fn frees(cluster: &Cluster) -> Vec<ResourceVec> {
        cluster.nodes.iter().map(|n| n.free).collect()
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    const ORACLE: fn(JobId) -> u64 = |_| 0;

    #[test]
    fn prefers_long_remaining_victim_over_short() {
        // Two same-size, same-GP victims; job 0 is nearly done (remaining
        // 2), job 1 has 200 minutes left. Plain FitGpp cannot tell them
        // apart; FitGpp-PR must spare the nearly-done job.
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 5, 2), (1, d, 5, 200)]);
        let free = frees(&cluster);
        let pred = move |id: JobId| rem[id.0 as usize] as f64;
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &pred, victims: &vidx };
        let p = plan(&te(d), &ctx, &mut PlanScratch::default(), 4.0, Some(1), &mut Pcg64::new(1)).unwrap();
        assert_eq!(p.victims, vec![JobId(1)], "long-remaining job is the cheap resume");
        assert_eq!(p.node, NodeId(1));
    }

    #[test]
    fn short_grace_period_still_preferred() {
        // Same size, same remaining; GP 0 vs GP 20 — the quick-to-vacate
        // victim wins, as in FitGpp.
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 20, 50), (1, d, 0, 50)]);
        let free = frees(&cluster);
        let pred = move |id: JobId| rem[id.0 as usize] as f64;
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &pred, victims: &vidx };
        let p = plan(&te(d), &ctx, &mut PlanScratch::default(), 4.0, Some(1), &mut Pcg64::new(1)).unwrap();
        assert_eq!(p.victims, vec![JobId(1)]);
    }

    #[test]
    fn s_zero_reduces_to_fitgpp() {
        // With s = 0 both policies are pure Size argmin — byte-equal plans.
        let (cluster, jobs, rem) = setup(
            2,
            &[
                (0, ResourceVec::new(8.0, 64.0, 2.0), 10, 3),
                (1, ResourceVec::new(4.0, 32.0, 1.0), 0, 400),
            ],
        );
        let free = frees(&cluster);
        let pred = move |id: JobId| rem[id.0 as usize] as f64;
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &pred, victims: &vidx };
        assert!(agrees_with_fitgpp_at_s_zero(
            &te(ResourceVec::new(2.0, 16.0, 1.0)),
            &ctx,
            &mut PlanScratch::default(),
            Some(1),
            7
        ));
    }

    #[test]
    fn eq2_and_cap_carry_over() {
        // Job 0 satisfies Eq. 2 but is capped out; job 1 satisfies Eq. 2
        // and must be chosen despite a worse resume cost.
        let d = ResourceVec::new(4.0, 32.0, 1.0);
        let (cluster, mut jobs, rem) = setup(2, &[(0, d, 0, 500), (1, d, 5, 2)]);
        jobs[JobId(0)].preemptions = 1;
        let free = frees(&cluster);
        let pred = move |id: JobId| rem[id.0 as usize] as f64;
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &pred, victims: &vidx };
        let capped = plan(&te(d), &ctx, &mut PlanScratch::default(), 4.0, Some(1), &mut Pcg64::new(1)).unwrap();
        assert_eq!(capped.victims, vec![JobId(1)]);
        // P = ∞ re-admits job 0, whose resume cost is far lower.
        let uncapped = plan(&te(d), &ctx, &mut PlanScratch::default(), 4.0, None, &mut Pcg64::new(1)).unwrap();
        assert_eq!(uncapped.victims, vec![JobId(0)]);
    }

    #[test]
    fn fallback_fires_when_no_single_victim_suffices() {
        let d = ResourceVec::new(14.0, 120.0, 4.0);
        let (cluster, jobs, _) = setup(1, &[(0, d, 0, 10), (0, d, 0, 10)]);
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 10.0, victims: &vidx };
        let p = plan(&te(ResourceVec::new(20.0, 128.0, 6.0)), &ctx, &mut PlanScratch::default(), 4.0, Some(1), &mut Pcg64::new(7)).unwrap();
        assert!(p.fallback);
        assert_eq!(p.victims.len(), 2);
    }

    #[test]
    fn no_running_be_jobs_yields_none() {
        let (cluster, jobs, _) = setup(1, &[]);
        let free = frees(&cluster);
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        assert!(plan(&te(ResourceVec::new(1.0, 1.0, 0.0)), &ctx, &mut PlanScratch::default(), 4.0, Some(1), &mut Pcg64::new(1)).is_none());
    }

    #[test]
    fn resume_cost_formula() {
        assert!((resume_cost(0.0, 0.0) - 1.0).abs() < 1e-12);
        assert!((resume_cost(9.0, 4.0) - 2.0).abs() < 1e-12);
        // Longer remaining ⇒ cheaper resume; longer GP ⇒ dearer.
        assert!(resume_cost(5.0, 100.0) < resume_cost(5.0, 10.0));
        assert!(resume_cost(20.0, 10.0) > resume_cost(5.0, 10.0));
    }
}
