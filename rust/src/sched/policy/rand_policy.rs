//! RAND — random victim selection (§4.1 baseline).
//!
//! "RAND is a strategy that preempts a randomly selected running BE job …
//! [and] continue[s] the preemption process until they can prepare enough
//! resource for the incoming TE job."
//!
//! Like LRTP, selection is *global and node-blind*: a uniformly random
//! running BE job anywhere, repeated until some node's projected free
//! space fits the TE job (the loop lives in
//! [`greedy_global_plan`](super::greedy_global_plan)). Victims on nodes
//! that never host the TE job are collateral damage — which is why RAND
//! preempts an order of magnitude more jobs than FitGpp in the paper's
//! Tables 3–4.
//!
//! This module also serves as FitGpp's escape hatch ("preempts a random BE
//! job" when no Eq. 4 candidate exists). In that role it receives FitGpp's
//! `p_max` and never picks a job already preempted `P` times — otherwise
//! the paper's no-starvation guarantee (§3.2, strategy 4) would be void.
//! Stand-alone RAND passes `None` (the paper's RAND has no cap).

use super::{greedy_global_plan, PlanScratch, PolicyCtx, PreemptionPlan, PreemptionPolicy};
use crate::job::JobSpec;
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`] (stand-alone RAND: no preemption cap).
pub struct Rand;

impl PreemptionPolicy for Rand {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch, rng, None)
    }
}

/// Plan random eviction: uniformly random running BE victims (optionally
/// filtered by the `p_max` cap), fed to the greedy global loop.
///
/// The pool is built into scratch straight from the victim index,
/// filtering p-capped jobs *while* building instead of build-then-retain —
/// one pass, no allocation. Note: no O(1) pre-plan reject here — the pool
/// draw consumes RNG state per victim, and an early `None` that skips
/// those draws would fork the run's deterministic RNG stream.
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
    rng: &mut Pcg64,
    p_max: Option<u32>,
) -> Option<PreemptionPlan> {
    let PlanScratch { greedy, pool, .. } = scratch;
    pool.clear();
    match p_max {
        Some(p) => pool.extend(
            ctx.victims
                .pool()
                .filter(|id| ctx.jobs[*id].preemptions < p),
        ),
        None => pool.extend(ctx.victims.pool()),
    }
    greedy_global_plan(te, ctx, greedy, false, || {
        let i = rng.pick_index(pool.len())?;
        Some(pool.swap_remove(i))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyCtx;

    fn setup(nodes: usize, placements: &[(u32, ResourceVec)]) -> (Cluster, crate::job_table::JobTable) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        for (i, (node, demand)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, 0, 60, 0);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), 0);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
        }
        (cluster, crate::job_table::JobTable::from_jobs(jobs))
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    const ORACLE: fn(JobId) -> u64 = |_| 0;

    #[test]
    fn produces_fitting_plan() {
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs) = setup(2, &[(0, d), (0, d), (1, d)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        for seed in 0..32 {
            let mut rng = Pcg64::new(seed);
            let want = ResourceVec::new(4.0, 32.0, 8.0);
            let p = plan(&te(want), &ctx, &mut PlanScratch::default(), &mut rng,None).unwrap();
            // Either the plan's node fits after its victims drain, or the
            // plan stopped at aggregate fit (node-blind baseline).
            let mut node_proj = free[p.node.0 as usize];
            let mut agg = free.iter().fold(ResourceVec::ZERO, |a, f| a + *f);
            for v in &p.victims {
                let j = &jobs[*v];
                agg += j.spec.demand;
                if j.node == Some(p.node) {
                    node_proj += j.spec.demand;
                }
            }
            assert!(
                want.fits_in(&node_proj) || want.fits_in(&agg),
                "seed {seed}: plan does not fit"
            );
        }
    }

    #[test]
    fn victims_are_distinct() {
        let d = ResourceVec::new(4.0, 32.0, 1.0);
        let (cluster, jobs) = setup(1, &[(0, d), (0, d), (0, d), (0, d)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        for seed in 0..16 {
            let mut rng = Pcg64::new(seed);
            let p = plan(&te(ResourceVec::new(24.0, 200.0, 4.0)), &ctx, &mut PlanScratch::default(), &mut rng,None).unwrap();
            let mut ids: Vec<u32> = p.victims.iter().map(|v| v.0).collect();
            let before = ids.len();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), before, "no victim picked twice");
        }
    }

    #[test]
    fn different_seeds_reach_different_victims() {
        let d = ResourceVec::new(4.0, 32.0, 1.0);
        let (cluster, jobs) = setup(4, &[(0, d), (1, d), (2, d), (3, d)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let want = ResourceVec::new(30.0, 230.0, 8.0);
        let mut seen = std::collections::HashSet::new();
        for seed in 0..64 {
            let mut rng = Pcg64::new(seed);
            if let Some(p) = plan(&te(want), &ctx, &mut PlanScratch::default(), &mut rng,None) {
                if let Some(v) = p.victims.first() {
                    seen.insert(v.0);
                }
            }
        }
        assert!(seen.len() > 1, "randomness must spread victims: {seen:?}");
    }

    #[test]
    fn p_cap_filters_pool() {
        // Both jobs at the cap ⇒ no victims available ⇒ None.
        let d = ResourceVec::new(16.0, 128.0, 4.0);
        let (cluster, mut jobs) = setup(1, &[(0, d), (0, d)]);
        jobs[JobId(0)].preemptions = 1;
        jobs[JobId(1)].preemptions = 1;
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let mut rng = Pcg64::new(1);
        assert!(plan(&te(ResourceVec::new(32.0, 256.0, 8.0)), &ctx, &mut PlanScratch::default(), &mut rng,Some(1)).is_none());
        // Without the cap a plan exists.
        assert!(plan(&te(ResourceVec::new(32.0, 256.0, 8.0)), &ctx, &mut PlanScratch::default(), &mut rng,None).is_some());
    }

    #[test]
    fn none_when_no_be_running() {
        let (cluster, jobs) = setup(1, &[]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let mut rng = Pcg64::new(1);
        assert!(plan(&te(ResourceVec::new(64.0, 512.0, 16.0)), &ctx, &mut PlanScratch::default(), &mut rng,None).is_none());
    }
}
