//! Preemption policies: which running BE jobs to evict for an incoming TE
//! job.
//!
//! All policies answer the same question: *given a TE job that fits on no
//! node right now, produce a `PreemptionPlan` — a target node plus victim
//! set on that node whose eviction makes the TE job fit.* The scheduler
//! core then signals the victims (starting their grace periods), reserves
//! the target node's space, and starts the TE job once the space drains.
//!
//! Implemented policies:
//! * [`fitgpp`] — the paper's contribution (Eq. 1–4).
//! * [`lrtp`] — Big-C's Longest-Remaining-Time Preemption, with the
//!   paper's perfect-oracle assumption.
//! * [`rand`] — uniformly random victims.
//! * `Fifo` / `FastLane` — no preemption (baseline / bypass-only ablation).

pub mod fitgpp;
pub mod lrtp;
pub mod rand_policy;

use crate::cluster::{Cluster, NodeId};
use crate::job::{Job, JobId, JobSpec, JobState};
use crate::resources::ResourceVec;
use crate::stats::rng::Pcg64;

/// Which scheduling strategy to run. `PolicyKind` is plain data (configs,
/// CLI) and is turned into behaviour by [`plan_preemption`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyKind {
    /// Vanilla non-preemptive FIFO: one queue for everything, head blocks.
    Fifo,
    /// FIFO + TE fast-lane, but **no** preemption — an ablation separating
    /// the benefit of queue bypass from the benefit of preemption.
    FastLane,
    /// The paper's algorithm. `s` weights grace-period length vs demand
    /// size in Eq. 3; `p_max` is the per-job preemption cap `P`
    /// (`None` = unlimited, the paper's "P = ∞" configuration).
    FitGpp { s: f64, p_max: Option<u32> },
    /// Longest-Remaining-Time Preemption with a perfect execution-time
    /// oracle (the Big-C strategy as simulated in §4.1).
    Lrtp,
    /// Random victim selection.
    Rand,
}

impl PolicyKind {
    /// Does this policy ever preempt?
    pub fn preempts(&self) -> bool {
        !matches!(self, PolicyKind::Fifo | PolicyKind::FastLane)
    }

    /// Do TE jobs bypass the BE queue? The paper's preemptive system
    /// allocates surplus directly to TE jobs (§2); vanilla FIFO does not.
    pub fn te_bypass(&self) -> bool {
        !matches!(self, PolicyKind::Fifo)
    }

    pub fn name(&self) -> String {
        match self {
            PolicyKind::Fifo => "FIFO".into(),
            PolicyKind::FastLane => "FastLane".into(),
            PolicyKind::FitGpp { s, p_max } => match p_max {
                Some(p) => format!("FitGpp(s={s},P={p})"),
                None => format!("FitGpp(s={s},P=inf)"),
            },
            PolicyKind::Lrtp => "LRTP".into(),
            PolicyKind::Rand => "RAND".into(),
        }
    }

    /// Parse from a CLI string: `fifo`, `fastlane`, `fitgpp`, `fitgpp:s=4`,
    /// `fitgpp:s=4,p=1`, `fitgpp:s=8,p=inf`, `lrtp`, `rand`.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        let lower = s.to_ascii_lowercase();
        let (head, rest) = match lower.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (lower.as_str(), None),
        };
        match head {
            "fifo" => Some(PolicyKind::Fifo),
            "fastlane" => Some(PolicyKind::FastLane),
            "lrtp" => Some(PolicyKind::Lrtp),
            "rand" => Some(PolicyKind::Rand),
            "fitgpp" => {
                let mut s_param = 4.0;
                let mut p_max = Some(1);
                if let Some(rest) = rest {
                    for kv in rest.split(',') {
                        let (k, v) = kv.split_once('=')?;
                        match k {
                            "s" => s_param = v.parse().ok()?,
                            "p" => {
                                p_max = if v == "inf" {
                                    None
                                } else {
                                    Some(v.parse().ok()?)
                                }
                            }
                            _ => return None,
                        }
                    }
                }
                Some(PolicyKind::FitGpp { s: s_param, p_max })
            }
            _ => None,
        }
    }
}

/// The outcome of a preemption decision: evict `victims` (all hosted on
/// `node`) so the TE job can start on `node` once they drain.
#[derive(Debug, Clone, PartialEq)]
pub struct PreemptionPlan {
    /// Node the TE job will start on once the victims drain.
    pub node: NodeId,
    /// Victims to signal (all hosted on `node`).
    pub victims: Vec<JobId>,
    /// True when FitGpp's Eq. 4 candidate set was empty and the random
    /// escape hatch produced this plan (never fired in the paper's runs;
    /// counted by the scheduler so EXPERIMENTS.md can report it).
    pub fallback: bool,
}

/// Read-only view handed to policies.
pub struct PolicyCtx<'a> {
    /// Cluster state (node capacities, allocations).
    pub cluster: &'a Cluster,
    /// The full job table, indexed by job id.
    pub jobs: &'a [Job],
    /// Per-node free resources minus reservation holds — what is really
    /// available to new placements.
    pub effective_free: &'a [ResourceVec],
    /// The remaining-execution-time oracle (only LRTP may consult it; the
    /// paper grants Big-C perfect predictions, §4.1).
    pub oracle_remaining: &'a dyn Fn(JobId) -> u64,
}

impl<'a> PolicyCtx<'a> {
    /// Running (not draining) BE jobs on `node` — the preemptible set.
    pub fn running_be_on(&self, node: NodeId) -> Vec<JobId> {
        self.cluster
            .node(node)
            .jobs()
            .filter(|id| {
                let j = &self.jobs[id.0 as usize];
                j.is_be() && j.state == JobState::Running
            })
            .collect()
    }

    /// All running BE jobs cluster-wide (the paper's 𝒥 in Eq. 3).
    pub fn running_be(&self) -> Vec<JobId> {
        self.cluster
            .nodes
            .iter()
            .flat_map(|n| self.running_be_on(n.id))
            .collect()
    }

    /// Nodes on which evicting *every* running BE job would fit `demand` —
    /// the feasible set for multi-victim policies.
    pub fn feasible_nodes(&self, demand: &ResourceVec) -> Vec<NodeId> {
        self.cluster
            .nodes
            .iter()
            .filter(|n| {
                let mut avail = self.effective_free[n.id.0 as usize];
                for id in self.running_be_on(n.id) {
                    avail += self.jobs[id.0 as usize].spec.demand;
                }
                demand.fits_in(&avail)
            })
            .map(|n| n.id)
            .collect()
    }
}

/// Dispatch: produce a preemption plan for `te` under `kind`, or `None`
/// if the policy does not preempt / nothing feasible exists.
pub fn plan_preemption(
    kind: &PolicyKind,
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    rng: &mut Pcg64,
) -> Option<PreemptionPlan> {
    match kind {
        PolicyKind::Fifo | PolicyKind::FastLane => None,
        PolicyKind::FitGpp { s, p_max } => fitgpp::plan(te, ctx, *s, *p_max, rng),
        PolicyKind::Lrtp => lrtp::plan(te, ctx),
        PolicyKind::Rand => rand_policy::plan(te, ctx, rng, None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(PolicyKind::parse("fifo"), Some(PolicyKind::Fifo));
        assert_eq!(PolicyKind::parse("FIFO"), Some(PolicyKind::Fifo));
        assert_eq!(PolicyKind::parse("lrtp"), Some(PolicyKind::Lrtp));
        assert_eq!(PolicyKind::parse("rand"), Some(PolicyKind::Rand));
        assert_eq!(PolicyKind::parse("fastlane"), Some(PolicyKind::FastLane));
        assert_eq!(
            PolicyKind::parse("fitgpp"),
            Some(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) })
        );
        assert_eq!(
            PolicyKind::parse("fitgpp:s=8,p=inf"),
            Some(PolicyKind::FitGpp { s: 8.0, p_max: None })
        );
        assert_eq!(
            PolicyKind::parse("fitgpp:s=2,p=3"),
            Some(PolicyKind::FitGpp { s: 2.0, p_max: Some(3) })
        );
        assert_eq!(PolicyKind::parse("bogus"), None);
        assert_eq!(PolicyKind::parse("fitgpp:q=1"), None);
    }

    #[test]
    fn bypass_and_preempt_flags() {
        assert!(!PolicyKind::Fifo.preempts());
        assert!(!PolicyKind::Fifo.te_bypass());
        assert!(!PolicyKind::FastLane.preempts());
        assert!(PolicyKind::FastLane.te_bypass());
        assert!(PolicyKind::Lrtp.preempts());
        assert!(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }.preempts());
    }

    #[test]
    fn names_render() {
        assert_eq!(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }.name(), "FitGpp(s=4,P=1)");
        assert_eq!(PolicyKind::FitGpp { s: 4.0, p_max: None }.name(), "FitGpp(s=4,P=inf)");
    }
}
