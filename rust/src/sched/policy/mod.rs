//! Preemption policies: which running BE jobs to evict for an incoming TE
//! job.
//!
//! All policies answer the same question: *given a TE job that fits on no
//! node right now, produce a `PreemptionPlan` — a target node plus victim
//! set whose eviction makes the TE job fit.* The scheduler core then
//! signals the victims (starting their grace periods), reserves the target
//! node's space, and starts the TE job once the space drains.
//!
//! ## Layering
//!
//! [`PolicyKind`] is plain data — the config/CLI surface (parsed from
//! strings, stored in experiment configs, rendered in tables). Behaviour
//! lives behind the [`PreemptionPolicy`] trait; [`build_policy`] turns a
//! kind into a boxed strategy exactly once per run, so adding a policy
//! means adding a module + one `build_policy` arm — the scheduler core
//! never changes.
//!
//! Implemented policies:
//! * [`fitgpp`] — the paper's contribution (Eq. 1–4).
//! * [`lrtp`] — Big-C's Longest-Remaining-Time Preemption, with the
//!   paper's perfect-oracle assumption.
//! * [`srtf`] — Shortest-Remaining-Time-First eviction (ablation: evicts
//!   the jobs closest to completion, maximizing wasted progress-latency).
//! * [`youngest`] — preempt the most recently submitted BE job (ablation:
//!   minimizes sunk work per victim, ignores fit and grace periods).
//! * [`rand`](rand_policy) — uniformly random victims.
//! * `Fifo` / `FastLane` — no preemption (baseline / bypass-only ablation).
//! * [`psrtf`] — SRTF eviction driven by the *predicted* remaining time
//!   from the configured [`RuntimeEstimator`](crate::sched::predict) instead
//!   of the oracle.
//! * [`fitgpp_pr`] — FitGpp with predicted-resume-cost victim ranking.

pub mod fitgpp;
pub mod fitgpp_pr;
pub mod lrtp;
pub mod psrtf;
pub mod rand_policy;
pub mod srtf;
pub mod youngest;

use crate::cluster::{Cluster, NodeId};
use crate::job::{JobId, JobSpec, JobState};
use crate::job_table::JobTable;
use crate::resources::ResourceVec;
use crate::sched::victim_index::VictimIndex;
use crate::stats::rng::Pcg64;

/// Which scheduling strategy to run. `PolicyKind` is plain data (configs,
/// CLI) and is turned into behaviour by [`build_policy`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyKind {
    /// Vanilla non-preemptive FIFO: one queue for everything, head blocks.
    Fifo,
    /// FIFO + TE fast-lane, but **no** preemption — an ablation separating
    /// the benefit of queue bypass from the benefit of preemption.
    FastLane,
    /// The paper's algorithm. `s` weights grace-period length vs demand
    /// size in Eq. 3; `p_max` is the per-job preemption cap `P`
    /// (`None` = unlimited, the paper's "P = ∞" configuration).
    FitGpp { s: f64, p_max: Option<u32> },
    /// Longest-Remaining-Time Preemption with a perfect execution-time
    /// oracle (the Big-C strategy as simulated in §4.1).
    Lrtp,
    /// Random victim selection.
    Rand,
    /// Shortest-Remaining-Time-First eviction (oracle-assisted ablation).
    Srtf,
    /// Preempt the most recently submitted running BE job (ablation).
    Youngest,
    /// SRTF eviction ordered by *predicted* remaining time (the configured
    /// estimator instead of the oracle). Under the oracle estimator this is
    /// byte-identical to [`PolicyKind::Srtf`].
    PSrtf,
    /// FitGpp with predicted-resume-cost victim ranking: Eq. 3's
    /// grace-period term is replaced by `(GP_j + 1) / (pred_remaining_j + 1)`
    /// so victims that are both quick to vacate *and* predicted to be far
    /// from completion are preferred.
    FitGppPr {
        /// Weight of the resume-cost term (the analogue of FitGpp's `s`).
        s: f64,
        /// Per-job preemption cap `P` (`None` = unlimited).
        p_max: Option<u32>,
    },
}

impl PolicyKind {
    /// Does this policy ever preempt?
    pub fn preempts(&self) -> bool {
        !matches!(self, PolicyKind::Fifo | PolicyKind::FastLane)
    }

    /// Do TE jobs bypass the BE queue? The paper's preemptive system
    /// allocates surplus directly to TE jobs (§2); vanilla FIFO does not.
    pub fn te_bypass(&self) -> bool {
        !matches!(self, PolicyKind::Fifo)
    }

    /// Human-readable name (tables, CSV rows, CLI echo).
    pub fn name(&self) -> String {
        match self {
            PolicyKind::Fifo => "FIFO".into(),
            PolicyKind::FastLane => "FastLane".into(),
            PolicyKind::FitGpp { s, p_max } => match p_max {
                Some(p) => format!("FitGpp(s={s},P={p})"),
                None => format!("FitGpp(s={s},P=inf)"),
            },
            PolicyKind::Lrtp => "LRTP".into(),
            PolicyKind::Rand => "RAND".into(),
            PolicyKind::Srtf => "SRTF".into(),
            PolicyKind::Youngest => "Youngest".into(),
            PolicyKind::PSrtf => "P-SRTF".into(),
            PolicyKind::FitGppPr { s, p_max } => match p_max {
                Some(p) => format!("FitGpp-PR(s={s},P={p})"),
                None => format!("FitGpp-PR(s={s},P=inf)"),
            },
        }
    }

    /// Parse from a CLI string: `fifo`, `fastlane`, `fitgpp`, `fitgpp:s=4`,
    /// `fitgpp:s=4,p=1`, `fitgpp:s=8,p=inf`, `lrtp`, `rand`, `srtf`,
    /// `youngest`, `psrtf`, `fitgpp_pr` / `fitgpp-pr` (same `s=`/`p=`
    /// parameters as `fitgpp`).
    pub fn parse(s: &str) -> Option<PolicyKind> {
        let lower = s.to_ascii_lowercase();
        let (head, rest) = match lower.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (lower.as_str(), None),
        };
        // `fitgpp` and `fitgpp_pr` share the s=/p= parameter grammar.
        let parse_fitgpp_params = |rest: Option<&str>| -> Option<(f64, Option<u32>)> {
            let mut s_param = 4.0;
            let mut p_max = Some(1);
            if let Some(rest) = rest {
                for kv in rest.split(',') {
                    let (k, v) = kv.split_once('=')?;
                    match k {
                        "s" => s_param = v.parse().ok()?,
                        "p" => {
                            p_max = if v == "inf" {
                                None
                            } else {
                                Some(v.parse().ok()?)
                            }
                        }
                        _ => return None,
                    }
                }
            }
            Some((s_param, p_max))
        };
        match head {
            "fifo" => Some(PolicyKind::Fifo),
            "fastlane" => Some(PolicyKind::FastLane),
            "lrtp" => Some(PolicyKind::Lrtp),
            "rand" => Some(PolicyKind::Rand),
            "srtf" => Some(PolicyKind::Srtf),
            "youngest" => Some(PolicyKind::Youngest),
            "psrtf" => Some(PolicyKind::PSrtf),
            "fitgpp" => {
                let (s, p_max) = parse_fitgpp_params(rest)?;
                Some(PolicyKind::FitGpp { s, p_max })
            }
            "fitgpp_pr" | "fitgpp-pr" => {
                let (s, p_max) = parse_fitgpp_params(rest)?;
                Some(PolicyKind::FitGppPr { s, p_max })
            }
            _ => None,
        }
    }
}

/// The outcome of a preemption decision: evict `victims` so the TE job can
/// start on `node` once they drain.
#[derive(Debug, Clone, PartialEq)]
pub struct PreemptionPlan {
    /// Node the TE job will start on once the victims drain.
    pub node: NodeId,
    /// Victims to signal.
    pub victims: Vec<JobId>,
    /// True when FitGpp's Eq. 4 candidate set was empty and the random
    /// escape hatch produced this plan (never fired in the paper's runs;
    /// counted by the scheduler so EXPERIMENTS.md can report it).
    pub fallback: bool,
}

/// Read-only view handed to policies.
pub struct PolicyCtx<'a> {
    /// Cluster state (node capacities, allocations).
    pub cluster: &'a Cluster,
    /// The live job table (resident jobs only), indexed by job id.
    pub jobs: &'a JobTable,
    /// Per-node free resources minus reservation holds — what is really
    /// available to new placements.
    pub effective_free: &'a [ResourceVec],
    /// The remaining-execution-time oracle (only LRTP/SRTF may consult it;
    /// the paper grants Big-C perfect predictions, §4.1).
    pub oracle_remaining: &'a dyn Fn(JobId) -> u64,
    /// The *predicted* remaining execution time from the configured
    /// [`RuntimeEstimator`](crate::sched::predict::RuntimeEstimator) —
    /// what the prediction-aware policies ([`psrtf`], [`fitgpp_pr`]) rank
    /// victims on. Under the oracle estimator this equals
    /// `oracle_remaining` exactly.
    pub predicted_remaining: &'a dyn Fn(JobId) -> f64,
    /// The scheduler's incrementally-maintained [`VictimIndex`]: the
    /// preemptible pool (running BE jobs on `Up` nodes) pre-sorted by every
    /// key the policies rank on, plus the demand aggregates behind the
    /// O(1) pre-plan reject. Policies pull victims from here instead of
    /// rescanning the cluster; [`PolicyCtx::running_be`] remains as the
    /// from-scratch oracle the index is checked against.
    pub victims: &'a VictimIndex,
}

impl<'a> PolicyCtx<'a> {
    /// Running (not draining) BE jobs on `node` — the preemptible set.
    /// Empty for non-`Up` nodes: a TE job can never be *placed* on a
    /// draining or down node, so evicting its tenants would burn grace
    /// periods for space the TE job cannot use. Every policy's victim pool
    /// flows through here, so the availability rule holds uniformly.
    pub fn running_be_on(&self, node: NodeId) -> Vec<JobId> {
        let n = self.cluster.node(node);
        if !n.is_schedulable() {
            return Vec::new();
        }
        n.jobs()
            .filter(|id| {
                let j = &self.jobs[*id];
                j.is_be() && j.state == JobState::Running
            })
            .collect()
    }

    /// All running BE jobs cluster-wide (the paper's 𝒥 in Eq. 3).
    pub fn running_be(&self) -> Vec<JobId> {
        self.cluster
            .nodes
            .iter()
            .flat_map(|n| self.running_be_on(n.id))
            .collect()
    }

    /// Nodes on which evicting *every* running BE job would fit `demand` —
    /// the feasible set for multi-victim policies. Writes into
    /// caller-owned scratch ([`PlanScratch::nodes`]) and reads the
    /// index's per-node demand aggregate instead of rescanning
    /// allocations: O(nodes), allocation-free once the buffer is warm.
    pub fn feasible_nodes_into(&self, demand: &ResourceVec, out: &mut Vec<NodeId>) {
        out.clear();
        for n in &self.cluster.nodes {
            let avail =
                self.effective_free[n.id.0 as usize] + *self.victims.node_demand(n.id);
            if demand.fits_in(&avail) {
                out.push(n.id);
            }
        }
    }
}

/// Scheduler-owned reusable buffers for the plan path. Passed *explicitly*
/// to [`PreemptionPolicy::plan`] so the trait's no-hidden-state contract
/// survives: a policy still cannot carry decision state across calls —
/// scratch contents are cleared before use and carry capacity, never data.
/// One instance lives on the scheduler; after warmup every plan runs
/// allocation-free (the perf gate pins this).
#[derive(Debug, Default)]
pub struct PlanScratch {
    /// The greedy eviction loop's projected-free and victim buffers.
    pub greedy: GreedyScratch,
    /// Victim-id pool for policies that materialize a filtered list
    /// (RAND's p-cap filter, FitGpp's candidate recheck).
    pub pool: Vec<JobId>,
    /// `(float key, id)` buffer for per-plan computed orderings (P-SRTF's
    /// predicted remaining times — predictions are floats from the live
    /// estimator, so they are computed per plan, never index-maintained).
    pub keyed: Vec<(f64, u32)>,
    /// `(size, score-term)` per-pool-job buffer (FitGpp-PR's pass 1).
    pub terms: Vec<(f64, f64)>,
    /// Feasible-node buffer for [`PolicyCtx::feasible_nodes_into`].
    pub nodes: Vec<NodeId>,
}

/// The buffers behind [`greedy_global_plan`], split out so a policy can
/// mutably borrow them alongside [`PlanScratch::pool`] (the victim-source
/// closure and the greedy loop are live at once).
#[derive(Debug, Default)]
pub struct GreedyScratch {
    projected: Vec<ResourceVec>,
    victims: Vec<JobId>,
}

/// A pluggable preemption strategy. Object-safe: the scheduler holds one
/// `Box<dyn PreemptionPolicy>` built by [`build_policy`] at construction.
///
/// # Contract
///
/// * **Determinism.** Given identical `(te, ctx)` views and an RNG in an
///   identical state, `plan` must return an identical plan. All randomness
///   must flow through the supplied `rng` — never thread-local or global
///   entropy — so `(workload, config, seed)` fully determines a run and
///   both simulator drive modes stay byte-identical.
/// * **No hidden state.** Implementations must not carry mutable state
///   across calls or across runs: a policy value constructed from the same
///   [`PolicyKind`] must behave identically whether it plans once or a
///   million times. Anything the decision needs must come from `ctx`; the
///   `scratch` buffers are capacity-only reuse (cleared before every use)
///   and must never smuggle data between calls.
/// * **Victim validity.** Every returned victim must be a *running BE* job
///   (TE jobs are never preempted; draining jobs are already signalled),
///   and victims must be distinct.
/// * **No side effects.** `plan` observes; only the scheduler core mutates
///   cluster or job state.
pub trait PreemptionPolicy: Send {
    /// Produce a preemption plan for `te`, or `None` if this policy does
    /// not preempt / nothing feasible exists.
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        rng: &mut Pcg64,
    ) -> Option<PreemptionPlan>;
}

/// The non-preemptive strategy shared by `Fifo` and `FastLane`.
struct NoPreemption;

impl PreemptionPolicy for NoPreemption {
    fn plan(
        &self,
        _: &JobSpec,
        _: &PolicyCtx<'_>,
        _: &mut PlanScratch,
        _: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        None
    }
}

/// Turn a plain-data [`PolicyKind`] into behaviour. Called once per run
/// (scheduler construction); the returned object is immutable thereafter
/// (see the [`PreemptionPolicy`] contract).
pub fn build_policy(kind: &PolicyKind) -> Box<dyn PreemptionPolicy> {
    match kind {
        PolicyKind::Fifo | PolicyKind::FastLane => Box::new(NoPreemption),
        PolicyKind::FitGpp { s, p_max } => Box::new(fitgpp::FitGpp { s: *s, p_max: *p_max }),
        PolicyKind::Lrtp => Box::new(lrtp::Lrtp),
        PolicyKind::Rand => Box::new(rand_policy::Rand),
        PolicyKind::Srtf => Box::new(srtf::Srtf),
        PolicyKind::Youngest => Box::new(youngest::Youngest),
        PolicyKind::PSrtf => Box::new(psrtf::PSrtf),
        PolicyKind::FitGppPr { s, p_max } => {
            Box::new(fitgpp_pr::FitGppPr { s: *s, p_max: *p_max })
        }
    }
}

/// The greedy *global* eviction loop shared by the node-blind baselines
/// (LRTP, RAND, SRTF, Youngest): pull victims from `next_victim` one at a
/// time until some node's projected free space fits the TE job.
///
/// The paper's baselines measure "enough resource" against the *aggregate*
/// freed space, not a single node (FitGpp's Eq. 2 is the per-node fix). If
/// the victims' scattered space sums to the demand but no single node fits
/// yet, stop here — the scheduler will re-plan once the drains land and the
/// TE job still cannot be placed. At least one victim is chosen per plan so
/// re-planning always makes progress (the Draining victims leave the
/// candidate pool). Reservations land on the node with the most projected
/// headroom.
///
/// Allocation discipline: the steady-state (no-plan-found) path is
/// allocation-free — projected frees and accumulated victims live in the
/// caller's [`GreedyScratch`]. A *successful* plan clones the victim list
/// out of scratch, but a success is a transition (victims get signalled),
/// not steady state, so the perf gate's blocked-TE cycles never see it.
/// Slack added per axis to the pre-plan reject bound so f64 drift in the
/// maintained aggregates (and summation-order differences vs the greedy
/// loop's own arithmetic) can never reject a demand the loop would have
/// planned.
const PLAN_BOUND_SLACK: f64 = 1e-6;

/// O(1) pre-plan reject: true when `te` cannot be placed even after
/// evicting *every* preemptible job — its demand exceeds the cluster-wide
/// effective free plus the index's preemptible-demand aggregate (both
/// incrementally maintained). When this returns true the greedy loop below
/// is guaranteed to exhaust its pool and return `None`, so RNG-free
/// callers skip it entirely. Callers whose victim source draws from the
/// run's RNG (RAND, FitGpp's fallback) must **not** use it: skipping the
/// loop would skip draws and fork the deterministic RNG stream.
pub(crate) fn plan_bound_rejects(te: &JobSpec, ctx: &PolicyCtx<'_>) -> bool {
    let slack = ResourceVec::new(PLAN_BOUND_SLACK, PLAN_BOUND_SLACK, PLAN_BOUND_SLACK);
    let bound = ctx.cluster.total_effective_free() + *ctx.victims.pool_demand() + slack;
    !te.demand.fits_in(&bound)
}

pub(crate) fn greedy_global_plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    greedy: &mut GreedyScratch,
    use_bound: bool,
    mut next_victim: impl FnMut() -> Option<JobId>,
) -> Option<PreemptionPlan> {
    // A demand no node could ever satisfy is not plannable (the paper's
    // clusters never see one — demands are capped at node capacity).
    if !te.demand.fits_in(&ctx.cluster.max_capacity()) {
        return None;
    }
    if use_bound && plan_bound_rejects(te, ctx) {
        return None;
    }

    // Projected free per node as victims accumulate, in caller-owned
    // scratch (capacity reused across plans — no steady-state allocation).
    greedy.projected.clear();
    greedy.projected.extend_from_slice(ctx.effective_free);
    greedy.victims.clear();
    let fit_node = |proj: &[ResourceVec]| {
        proj.iter()
            .enumerate()
            .find(|(_, f)| te.demand.fits_in(f))
            .map(|(i, _)| NodeId(i as u32))
    };

    let total_cap = ctx.cluster.total_capacity();
    // The projected cluster-wide aggregate, maintained incrementally: one
    // O(nodes) fold up front, then O(1) per victim (was an O(nodes)
    // re-fold per victim).
    let mut aggregate = ctx
        .effective_free
        .iter()
        .fold(ResourceVec::ZERO, |acc, f| acc + *f);
    loop {
        if let Some(node) = fit_node(&greedy.projected) {
            return Some(PreemptionPlan {
                node,
                victims: greedy.victims.clone(),
                fallback: false,
            });
        }
        if !greedy.victims.is_empty() && te.demand.fits_in(&aggregate) {
            let node = greedy
                .projected
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| {
                    a.size(&total_cap).partial_cmp(&b.size(&total_cap)).unwrap()
                })
                .map(|(i, _)| NodeId(i as u32))
                .unwrap();
            return Some(PreemptionPlan {
                node,
                victims: greedy.victims.clone(),
                fallback: false,
            });
        }
        let Some(id) = next_victim() else {
            return None; // pool exhausted — no fit possible
        };
        let j = &ctx.jobs[id];
        let node = j.node.expect("running");
        greedy.projected[node.0 as usize] += j.spec.demand;
        aggregate += j.spec.demand;
        greedy.victims.push(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(PolicyKind::parse("fifo"), Some(PolicyKind::Fifo));
        assert_eq!(PolicyKind::parse("FIFO"), Some(PolicyKind::Fifo));
        assert_eq!(PolicyKind::parse("lrtp"), Some(PolicyKind::Lrtp));
        assert_eq!(PolicyKind::parse("rand"), Some(PolicyKind::Rand));
        assert_eq!(PolicyKind::parse("srtf"), Some(PolicyKind::Srtf));
        assert_eq!(PolicyKind::parse("SRTF"), Some(PolicyKind::Srtf));
        assert_eq!(PolicyKind::parse("youngest"), Some(PolicyKind::Youngest));
        assert_eq!(PolicyKind::parse("fastlane"), Some(PolicyKind::FastLane));
        assert_eq!(
            PolicyKind::parse("fitgpp"),
            Some(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) })
        );
        assert_eq!(
            PolicyKind::parse("fitgpp:s=8,p=inf"),
            Some(PolicyKind::FitGpp { s: 8.0, p_max: None })
        );
        assert_eq!(
            PolicyKind::parse("fitgpp:s=2,p=3"),
            Some(PolicyKind::FitGpp { s: 2.0, p_max: Some(3) })
        );
        assert_eq!(PolicyKind::parse("psrtf"), Some(PolicyKind::PSrtf));
        assert_eq!(
            PolicyKind::parse("fitgpp_pr"),
            Some(PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) })
        );
        assert_eq!(
            PolicyKind::parse("fitgpp-pr:s=8,p=inf"),
            Some(PolicyKind::FitGppPr { s: 8.0, p_max: None })
        );
        assert_eq!(PolicyKind::parse("bogus"), None);
        assert_eq!(PolicyKind::parse("fitgpp:q=1"), None);
        assert_eq!(PolicyKind::parse("fitgpp_pr:q=1"), None);
    }

    #[test]
    fn bypass_and_preempt_flags() {
        assert!(!PolicyKind::Fifo.preempts());
        assert!(!PolicyKind::Fifo.te_bypass());
        assert!(!PolicyKind::FastLane.preempts());
        assert!(PolicyKind::FastLane.te_bypass());
        assert!(PolicyKind::Lrtp.preempts());
        assert!(PolicyKind::Srtf.preempts());
        assert!(PolicyKind::Srtf.te_bypass());
        assert!(PolicyKind::Youngest.preempts());
        assert!(PolicyKind::Youngest.te_bypass());
        assert!(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }.preempts());
        assert!(PolicyKind::PSrtf.preempts());
        assert!(PolicyKind::PSrtf.te_bypass());
        assert!(PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) }.preempts());
        assert!(PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) }.te_bypass());
    }

    #[test]
    fn names_render() {
        assert_eq!(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }.name(), "FitGpp(s=4,P=1)");
        assert_eq!(PolicyKind::FitGpp { s: 4.0, p_max: None }.name(), "FitGpp(s=4,P=inf)");
        assert_eq!(PolicyKind::Srtf.name(), "SRTF");
        assert_eq!(PolicyKind::Youngest.name(), "Youngest");
        assert_eq!(PolicyKind::PSrtf.name(), "P-SRTF");
        assert_eq!(
            PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) }.name(),
            "FitGpp-PR(s=4,P=1)"
        );
        assert_eq!(
            PolicyKind::FitGppPr { s: 4.0, p_max: None }.name(),
            "FitGpp-PR(s=4,P=inf)"
        );
    }

    #[test]
    fn build_policy_covers_every_kind() {
        // Non-preemptive kinds yield a strategy that always declines.
        use crate::cluster::ClusterSpec;
        let cluster = Cluster::new(&ClusterSpec::tiny(1));
        let jobs = JobTable::new();
        let free = vec![ResourceVec::pfn_node()];
        let oracle = |_: JobId| 0u64;
        let vidx = VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx {
            cluster: &cluster,
            jobs: &jobs,
            effective_free: &free,
            oracle_remaining: &oracle,
            predicted_remaining: &|_: JobId| 0.0,
            victims: &vidx,
        };
        let te = JobSpec::new(0, crate::job::JobClass::Te, ResourceVec::new(1.0, 1.0, 0.0), 0, 5, 0);
        let mut rng = Pcg64::new(1);
        let mut scratch = PlanScratch::default();
        for kind in [
            PolicyKind::Fifo,
            PolicyKind::FastLane,
            PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            PolicyKind::Lrtp,
            PolicyKind::Rand,
            PolicyKind::Srtf,
            PolicyKind::Youngest,
            PolicyKind::PSrtf,
            PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
        ] {
            let p = build_policy(&kind);
            // An empty cluster view must never yield victims.
            let plan = p.plan(&te, &ctx, &mut scratch, &mut rng);
            let victims_empty = match &plan {
                None => true,
                Some(pl) => pl.victims.is_empty(),
            };
            assert!(victims_empty, "{kind:?} invented victims on an empty cluster");
        }
    }
}
