//! Preempt-youngest — evict the most recently submitted BE job (ablation).
//!
//! A common operational heuristic: the youngest running BE job has the
//! least sunk work, so killing it wastes the least progress. Unlike
//! LRTP/SRTF it needs **no oracle** — submission time is declared, not
//! predicted — which makes it the cheapest-information baseline in the
//! suite. It is still node-blind and fit-blind (no Eq. 2), so like the
//! paper's baselines it scatters collateral evictions; comparing it to
//! FitGpp isolates how much of FitGpp's win comes from per-node fit
//! awareness rather than from victim-age heuristics.
//!
//! Selection order: submission time descending (youngest first); ties —
//! jobs submitted in the same minute — break toward the *higher* job id,
//! i.e. the later submission within that minute. Victims accumulate
//! through the shared greedy global loop
//! ([`greedy_global_plan`](super::greedy_global_plan)).

use super::{greedy_global_plan, PlanScratch, PolicyCtx, PreemptionPlan, PreemptionPolicy};
use crate::job::JobSpec;
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`].
pub struct Youngest;

impl PreemptionPolicy for Youngest {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        _rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch)
    }
}

/// Plan preempt-youngest eviction: the victim index's youngest-first walk
/// — submission time descending, ties to the higher id (the plain reverse
/// of the maintained `(submit, id)` ordering) — fed to the greedy global
/// loop. No scan, no sort, no allocation: O(victims examined).
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
) -> Option<PreemptionPlan> {
    let mut it = ctx.victims.by_age_youngest_first();
    greedy_global_plan(te, ctx, &mut scratch.greedy, true, || it.next())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyCtx;

    fn setup(
        nodes: usize,
        placements: &[(u32, ResourceVec, u64)], // (node, demand, submit)
    ) -> (Cluster, crate::job_table::JobTable) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        for (i, (node, demand, submit)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, *submit, 60, 0);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), *submit);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
        }
        (cluster, crate::job_table::JobTable::from_jobs(jobs))
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    const ORACLE: fn(JobId) -> u64 = |_| 0;

    #[test]
    fn picks_latest_submission_first() {
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs) = setup(2, &[(0, d, 0), (1, d, 40)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let p = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(p.victims, vec![JobId(1)], "submitted-at-40 job is youngest");
        assert_eq!(p.node, NodeId(1));
    }

    #[test]
    fn same_minute_ties_break_to_higher_id() {
        let d = ResourceVec::new(16.0, 128.0, 4.0);
        let (cluster, jobs) = setup(1, &[(0, d, 7), (0, d, 7)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        // Needs one half-node victim: the higher id (later submission
        // within the minute) is the youngest.
        let p = plan(&te(d), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(p.victims, vec![JobId(1)]);
    }

    #[test]
    fn cascades_until_fit() {
        let d = ResourceVec::new(16.0, 128.0, 4.0);
        let (cluster, jobs) = setup(2, &[(0, d, 1), (0, d, 2), (1, d, 3), (1, d, 4)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        // Whole-node demand: evict submit-4 (node 1) — no fit, aggregate
        // short; evict submit-3 (node 1) — node 1 now fits entirely.
        let p = plan(&te(ResourceVec::new(32.0, 256.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(p.victims, vec![JobId(3), JobId(2)]);
        assert_eq!(p.node, NodeId(1));
    }

    #[test]
    fn infeasible_everywhere_returns_none() {
        let d = ResourceVec::new(4.0, 32.0, 2.0);
        let (cluster, jobs) = setup(1, &[(0, d, 0)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &ORACLE, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        assert!(plan(&te(ResourceVec::new(1.0, 1.0, 10.0)), &ctx, &mut PlanScratch::default()).is_none());
    }
}
