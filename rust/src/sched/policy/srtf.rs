//! SRTF — Shortest-Remaining-Time-First eviction (ablation).
//!
//! The mirror image of [`lrtp`](super::lrtp): it preempts the running BE
//! job with the *shortest* remaining execution time first, using the same
//! perfect oracle (`PolicyCtx::oracle_remaining`) and the same greedy
//! global eviction loop. Jobs nearest completion need the least space for
//! the shortest time, but evicting them throws away almost-finished work
//! and makes their flow time balloon — the worst case the paper's Eq. 3
//! size/GP trade-off is designed to avoid. Keeping this strategy swappable
//! demonstrates the [`PreemptionPolicy`](super::PreemptionPolicy) layering
//! and gives the sensitivity sweeps a pessimal oracle-assisted baseline.
//!
//! Selection is global and node-blind like the paper's baselines: victims
//! accumulate in ascending-remaining-time order (ties break toward the
//! lower job id) until some node's projected free space — or, failing
//! that, the aggregate freed space — fits the TE job.

use super::{greedy_global_plan, PlanScratch, PolicyCtx, PreemptionPlan, PreemptionPolicy};
use crate::job::JobSpec;
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`].
pub struct Srtf;

impl PreemptionPolicy for Srtf {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        _rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch)
    }
}

/// Plan SRTF eviction: the victim index's remaining-time-ascending walk
/// (equal to sorting the pool by the perfect oracle — the index's integer
/// completion keys order identically to live remaining times, ties
/// included), fed to the greedy global loop. No scan, no sort, no
/// allocation: O(victims examined).
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
) -> Option<PreemptionPlan> {
    let mut it = ctx.victims.by_remaining_asc();
    greedy_global_plan(te, ctx, &mut scratch.greedy, true, || it.next())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyCtx;

    fn setup(
        nodes: usize,
        placements: &[(u32, ResourceVec, u64)], // (node, demand, remaining)
    ) -> (Cluster, crate::job_table::JobTable, Vec<u64>) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        let mut remaining = Vec::new();
        for (i, (node, demand, rem)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, 0, (*rem).max(1), 0);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), 0);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
            remaining.push(*rem);
        }
        (cluster, crate::job_table::JobTable::from_jobs(jobs), remaining)
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    #[test]
    fn picks_shortest_remaining_globally() {
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 100), (1, d, 5)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let plan = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(plan.victims, vec![JobId(1)], "remaining-5 job is evicted first");
        assert_eq!(plan.node, NodeId(1));
    }

    #[test]
    fn ties_break_to_lower_id() {
        let d = ResourceVec::new(16.0, 128.0, 4.0);
        let (cluster, jobs, rem) = setup(1, &[(0, d, 10), (0, d, 10)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        let p = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(p.victims, vec![JobId(0), JobId(1)]);
    }

    #[test]
    fn infeasible_everywhere_returns_none() {
        let d = ResourceVec::new(4.0, 32.0, 2.0);
        let (cluster, jobs, rem) = setup(1, &[(0, d, 10)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &|_: JobId| 0.0, victims: &vidx };
        assert!(plan(&te(ResourceVec::new(1.0, 1.0, 10.0)), &ctx, &mut PlanScratch::default()).is_none());
    }
}
