//! P-SRTF — predicted-Shortest-Remaining-Time-First eviction.
//!
//! The same greedy global loop as [`srtf`](super::srtf), but victims are
//! ordered by the *predicted* remaining execution time from the configured
//! [`RuntimeEstimator`](crate::sched::predict::RuntimeEstimator)
//! (`PolicyCtx::predicted_remaining`) instead of the perfect oracle. This
//! is the policy the prediction-assisted scheduling literature actually
//! deploys — real systems don't have oracles — and the error-sensitivity
//! sweep measures how fast its advantage decays as predictions degrade.
//!
//! Under the oracle estimator, predicted remaining equals true remaining
//! exactly, so P-SRTF is byte-identical to SRTF (pinned by
//! `tests/prediction.rs`); the same holds for a cold-start `ClassEwma`,
//! whose declared-runtime fallback coincides with the simulator's ground
//! truth.
//!
//! Ties (equal predictions) break toward the lower job id, mirroring SRTF,
//! so determinism is preserved even when an estimator collapses many jobs
//! onto one predicted value (e.g. a per-class EWMA).

use super::{
    greedy_global_plan, plan_bound_rejects, PlanScratch, PolicyCtx, PreemptionPlan,
    PreemptionPolicy,
};
use crate::job::{JobId, JobSpec};
use crate::stats::rng::Pcg64;

/// Trait wrapper for [`plan`].
pub struct PSrtf;

impl PreemptionPolicy for PSrtf {
    fn plan(
        &self,
        te: &JobSpec,
        ctx: &PolicyCtx<'_>,
        scratch: &mut PlanScratch,
        _rng: &mut Pcg64,
    ) -> Option<PreemptionPlan> {
        plan(te, ctx, scratch)
    }
}

/// Plan P-SRTF eviction: the victim index's pool with predicted remaining
/// times computed *per plan* into scratch (predictions are live estimator
/// floats, so unlike the integer completion keys they are not
/// index-maintained), sorted ascending (ties toward the lower id) and fed
/// to the greedy global loop. The O(1) pre-plan reject runs before the
/// prediction pass — a hopeless demand skips the estimator entirely
/// (estimators are pure per call, so the changed call count is
/// byte-invisible).
pub fn plan(
    te: &JobSpec,
    ctx: &PolicyCtx<'_>,
    scratch: &mut PlanScratch,
) -> Option<PreemptionPlan> {
    if plan_bound_rejects(te, ctx) {
        return None;
    }
    let PlanScratch { greedy, keyed, .. } = scratch;
    keyed.clear();
    keyed.extend(
        ctx.victims
            .pool()
            .map(|id| ((ctx.predicted_remaining)(id), id.0)),
    );
    // Unstable sort is safe: the id tiebreak makes the comparator a total
    // order, so the result is the same permutation the old stable
    // sort-by-prediction produced.
    keyed.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut it = keyed.iter().map(|&(_, id)| JobId(id));
    greedy_global_plan(te, ctx, greedy, false, || it.next())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterSpec, NodeId};
    use crate::job::{Job, JobClass, JobId, JobSpec};
    use crate::resources::ResourceVec;
    use crate::sched::policy::PolicyCtx;

    fn setup(
        nodes: usize,
        placements: &[(u32, ResourceVec, u64)], // (node, demand, remaining)
    ) -> (Cluster, crate::job_table::JobTable, Vec<u64>) {
        let spec = ClusterSpec::tiny(nodes);
        let mut cluster = Cluster::new(&spec);
        let mut jobs = Vec::new();
        let mut remaining = Vec::new();
        for (i, (node, demand, rem)) in placements.iter().enumerate() {
            let spec = JobSpec::new(i as u32, JobClass::Be, *demand, 0, (*rem).max(1), 0);
            let mut job = Job::new(spec);
            job.start(NodeId(*node), 0);
            cluster.bind(JobId(i as u32), *demand, NodeId(*node));
            jobs.push(job);
            remaining.push(*rem);
        }
        (cluster, crate::job_table::JobTable::from_jobs(jobs), remaining)
    }

    fn te(demand: ResourceVec) -> JobSpec {
        JobSpec::new(999, JobClass::Te, demand, 0, 5, 0)
    }

    #[test]
    fn picks_shortest_predicted_remaining_globally() {
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 100), (1, d, 5)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let pred = move |id: JobId| rem[id.0 as usize] as f64;
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &|_: JobId| 0, predicted_remaining: &pred, victims: &vidx };
        let plan = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(plan.victims, vec![JobId(1)], "predicted-5 job is evicted first");
        assert_eq!(plan.node, NodeId(1));
    }

    #[test]
    fn predictions_override_the_oracle() {
        // True remaining says evict job 1; the estimator says job 0. The
        // policy must follow the estimator — that's the whole point (and
        // the sensitivity sweep's mechanism).
        let d = ResourceVec::new(8.0, 64.0, 2.0);
        let (cluster, jobs, rem) = setup(2, &[(0, d, 100), (1, d, 5)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let oracle = move |id: JobId| rem[id.0 as usize];
        let pred = |id: JobId| if id.0 == 0 { 1.0 } else { 1000.0 };
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &oracle, predicted_remaining: &pred, victims: &vidx };
        let plan = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(plan.victims, vec![JobId(0)]);
        assert_eq!(plan.node, NodeId(0));
    }

    #[test]
    fn ties_break_to_lower_id() {
        let d = ResourceVec::new(16.0, 128.0, 4.0);
        let (cluster, jobs, _) = setup(1, &[(0, d, 10), (0, d, 10)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        // A class-level estimator collapsing both jobs onto one prediction.
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &|_: JobId| 0, predicted_remaining: &|_: JobId| 10.0, victims: &vidx };
        let p = plan(&te(ResourceVec::new(30.0, 200.0, 8.0)), &ctx, &mut PlanScratch::default()).unwrap();
        assert_eq!(p.victims, vec![JobId(0), JobId(1)]);
    }

    #[test]
    fn infeasible_everywhere_returns_none() {
        let d = ResourceVec::new(4.0, 32.0, 2.0);
        let (cluster, jobs, _) = setup(1, &[(0, d, 10)]);
        let free: Vec<_> = cluster.nodes.iter().map(|n| n.free).collect();
        let vidx = crate::sched::victim_index::VictimIndex::build(&cluster, &jobs);
        let ctx = PolicyCtx { cluster: &cluster, jobs: &jobs, effective_free: &free, oracle_remaining: &|_: JobId| 0, predicted_remaining: &|_: JobId| 10.0, victims: &vidx };
        assert!(plan(&te(ResourceVec::new(1.0, 1.0, 10.0)), &ctx, &mut PlanScratch::default()).is_none());
    }
}
