//! Stub of the `xla` (PJRT) bindings used by the live runtime.
//!
//! The offline build image does not ship the `xla` crate (xla-rs over
//! `xla_extension`), so this module provides an API-compatible stub: every
//! type the [`crate::runtime`] and [`crate::live`] layers touch exists and
//! type-checks, and every entry point that would need the real PJRT client
//! returns an [`XlaError`] at runtime. Callers already handle that path —
//! live mode and the runtime tests skip gracefully when the backend (or the
//! AOT artifacts) are unavailable.
//!
//! Restoring the real backend is a one-line swap: delete this module, add
//! the `xla` dependency back to `Cargo.toml`, and remove the `use
//! crate::xla;` imports (the call sites are untouched — they compile
//! against the same names and signatures).

use std::fmt;

/// Error produced by every stubbed PJRT entry point.
#[derive(Debug, Clone)]
pub struct XlaError {
    msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError {
        msg: format!(
            "PJRT backend not built into this binary ({what}): the xla crate is stubbed \
             in this offline build — see rust/src/xla.rs"
        ),
    })
}

/// Marker for element types a [`Literal`] can carry.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}
impl NativeType for u64 {}

/// Stub of the PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Compile a computation for this client. Always fails in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO-text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap an [`HloModuleProto`].
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Marker for argument types accepted by
/// [`PjRtLoadedExecutable::execute`] (owned or borrowed literals).
pub trait ExecuteInput {}

impl ExecuteInput for Literal {}
impl ExecuteInput for &Literal {}

/// Stub of a compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs, returning per-device output buffers.
    /// Always fails in the stub.
    pub fn execute<T: ExecuteInput>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of a device-resident buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to the host as a [`Literal`]. Always fails in the
    /// stub.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a host-side literal (an n-d array value).
#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to `dims`. Always fails in the stub (a stub literal carries
    /// no data to reshape).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("Literal::reshape")
    }

    /// Copy the contents to a host `Vec`. Always fails in the stub.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }

    /// Read the first element. Always fails in the stub.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, XlaError> {
        unavailable("Literal::get_first_element")
    }

    /// Destructure a tuple literal. Always fails in the stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend not built"));
    }

    #[test]
    fn stub_literal_paths_error_not_panic() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.get_first_element::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let _clone = lit.clone();
    }
}
