//! Mini property-testing kit (proptest is not available offline).
//!
//! Seeded generators + a runner that reports the failing seed so any
//! counterexample replays deterministically:
//!
//! ```text
//! property failed (case 17, seed 0xDEADBEEF): <message>
//! ```
//!
//! Used by `rust/tests/properties.rs` for the coordinator invariants
//! (resource conservation, FIFO ordering, preemption caps, …).

use crate::stats::rng::Pcg64;

/// Configuration of a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed (each case derives its own).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Base seed is stable so CI failures reproduce; override per-call
        // or via FITGPP_PROP_SEED for fuzzing sessions.
        let seed = std::env::var("FITGPP_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xF17_6990);
        let cases = std::env::var("FITGPP_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, seed }
    }
}

/// Run `prop` for `cfg.cases` cases. Each case gets an independent RNG
/// derived from the base seed; `prop` returns `Err(msg)` to fail. Panics
/// with the case index + derived seed on failure.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed (case {case}, seed {case_seed:#x}): {msg}");
        }
    }
}

/// Assert-style helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Generators for common values.
pub mod gen {
    use crate::job::{JobClass, JobSpec};
    use crate::resources::ResourceVec;
    use crate::stats::rng::Pcg64;

    /// Uniform integer in `[lo, hi]`.
    pub fn int(rng: &mut Pcg64, lo: u64, hi: u64) -> u64 {
        lo + rng.below(hi - lo + 1)
    }

    /// A random demand that fits a PFN node; occasionally extreme
    /// (full-node) to probe edge behaviour.
    pub fn demand(rng: &mut Pcg64) -> ResourceVec {
        if rng.chance(0.05) {
            return ResourceVec::pfn_node(); // whole-node job
        }
        ResourceVec::new(
            int(rng, 1, 32) as f64,
            int(rng, 1, 256) as f64,
            int(rng, 0, 8) as f64,
        )
    }

    /// A random job spec with dense id `id`, submit in `[0, span]`.
    pub fn job_spec(rng: &mut Pcg64, id: u32, span: u64) -> JobSpec {
        let class = if rng.chance(0.3) { JobClass::Te } else { JobClass::Be };
        let exec = match class {
            JobClass::Te => int(rng, 1, 30),
            JobClass::Be => int(rng, 1, 240),
        };
        JobSpec {
            id: crate::job::JobId(id),
            class,
            demand: demand(rng),
            submit: int(rng, 0, span),
            exec_time: exec,
            grace_period: int(rng, 0, 20),
            tenant: crate::job::TenantId::DEFAULT,
        }
    }

    /// A whole random workload (sorted, dense ids).
    pub fn workload(rng: &mut Pcg64, n: usize, span: u64) -> crate::workload::Workload {
        let specs = (0..n).map(|i| job_spec(rng, i as u32, span)).collect();
        crate::workload::Workload::new(specs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", PropConfig { cases: 10, seed: 1 }, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_panics_with_seed() {
        check("fails", PropConfig { cases: 5, seed: 1 }, |rng| {
            let x = rng.below(100);
            if x < 1000 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_produce_valid_values() {
        let mut rng = Pcg64::new(2);
        for i in 0..200 {
            let s = gen::job_spec(&mut rng, i, 100);
            assert!(s.exec_time >= 1);
            assert!(s.grace_period <= 20);
            assert!(s.demand.fits_in(&crate::resources::ResourceVec::pfn_node()));
        }
        let wl = gen::workload(&mut rng, 50, 100);
        assert_eq!(wl.len(), 50);
    }
}
