//! Experiment configuration: JSON-backed config files for the `fitgpp`
//! binary and examples, so runs are declarative and reproducible.
//!
//! ```json
//! {
//!   "cluster": {"nodes": 84, "cpu": 32, "ram_gb": 256, "gpu": 8},
//!   "policy": "fitgpp:s=4,p=1",
//!   "placement": "best-fit",
//!   "workload": {
//!     "kind": "synthetic", "jobs": 65536, "te_fraction": 0.3,
//!     "target_load": 2.0, "gp_scale": 1.0, "seed": 7
//!   }
//! }
//! ```

use crate::cluster::{ClusterSpec, Placement};
use crate::resources::ResourceVec;
use crate::sched::policy::PolicyKind;
use crate::sim::SimConfig;
use crate::util::json::Json;
use crate::workload::{synthetic::SyntheticWorkload, trace::Trace, Workload};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Workload source in a config file.
#[derive(Debug, Clone)]
pub enum WorkloadConfig {
    /// The §4.2 synthetic generator.
    Synthetic {
        jobs: usize,
        te_fraction: f64,
        target_load: f64,
        gp_scale: f64,
        seed: u64,
    },
    /// The synthesized institution trace (§4.4 stand-in).
    Institution { jobs: usize, seed: u64 },
    /// Replay a CSV trace file.
    TraceFile { path: String },
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Cluster to simulate.
    pub cluster: ClusterSpec,
    /// Policy under test.
    pub policy: PolicyKind,
    /// Placement rule.
    pub placement: Placement,
    /// §2 ablation knob.
    pub progress_during_grace: bool,
    /// Policy-RNG seed.
    pub seed: u64,
    /// Workload source.
    pub workload: WorkloadConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            cluster: ClusterSpec::pfn(),
            policy: PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
            placement: Placement::BestFit,
            progress_during_grace: false,
            seed: 7,
            workload: WorkloadConfig::Synthetic {
                jobs: 1 << 16,
                te_fraction: 0.3,
                target_load: 2.0,
                gp_scale: 1.0,
                seed: 7,
            },
        }
    }
}

fn parse_placement(s: &str) -> Result<Placement> {
    Ok(match s {
        "first-fit" => Placement::FirstFit,
        "best-fit" => Placement::BestFit,
        "worst-fit" => Placement::WorstFit,
        other => bail!("unknown placement {other:?}"),
    })
}

fn placement_name(p: Placement) -> &'static str {
    match p {
        Placement::FirstFit => "first-fit",
        Placement::BestFit => "best-fit",
        Placement::WorstFit => "worst-fit",
    }
}

impl ExperimentConfig {
    /// Parse from JSON text. Missing fields take defaults.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing experiment config")?;
        let mut cfg = ExperimentConfig::default();

        let c = v.get("cluster");
        if !matches!(c, Json::Null) {
            let nodes = c.get("nodes").as_u64().unwrap_or(84) as usize;
            let cap = ResourceVec::new(
                c.get("cpu").as_f64().unwrap_or(32.0),
                c.get("ram_gb").as_f64().unwrap_or(256.0),
                c.get("gpu").as_f64().unwrap_or(8.0),
            );
            cfg.cluster = ClusterSpec::homogeneous(nodes, cap);
        }
        if let Some(p) = v.get("policy").as_str() {
            cfg.policy = PolicyKind::parse(p).with_context(|| format!("bad policy {p:?}"))?;
        }
        if let Some(p) = v.get("placement").as_str() {
            cfg.placement = parse_placement(p)?;
        }
        if let Some(b) = v.get("progress_during_grace").as_bool() {
            cfg.progress_during_grace = b;
        }
        if let Some(s) = v.get("seed").as_u64() {
            cfg.seed = s;
        }

        let w = v.get("workload");
        if !matches!(w, Json::Null) {
            let kind = w.get("kind").as_str().unwrap_or("synthetic");
            cfg.workload = match kind {
                "synthetic" => WorkloadConfig::Synthetic {
                    jobs: w.get("jobs").as_u64().unwrap_or(1 << 16) as usize,
                    te_fraction: w.get("te_fraction").as_f64().unwrap_or(0.3),
                    target_load: w.get("target_load").as_f64().unwrap_or(2.0),
                    gp_scale: w.get("gp_scale").as_f64().unwrap_or(1.0),
                    seed: w.get("seed").as_u64().unwrap_or(7),
                },
                "institution" => WorkloadConfig::Institution {
                    jobs: w.get("jobs").as_u64().unwrap_or(50_000) as usize,
                    seed: w.get("seed").as_u64().unwrap_or(7),
                },
                "trace" => WorkloadConfig::TraceFile {
                    path: w
                        .get("path")
                        .as_str()
                        .context("trace workload needs \"path\"")?
                        .to_string(),
                },
                other => bail!("unknown workload kind {other:?}"),
            };
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&text)
    }

    /// Serialize (for `fitgpp config --dump`).
    pub fn to_json(&self) -> Json {
        let cap = self.cluster.nodes.first().copied().unwrap_or(ResourceVec::pfn_node());
        let workload = match &self.workload {
            WorkloadConfig::Synthetic { jobs, te_fraction, target_load, gp_scale, seed } => Json::obj(vec![
                ("kind", Json::str("synthetic")),
                ("jobs", Json::num(*jobs as f64)),
                ("te_fraction", Json::num(*te_fraction)),
                ("target_load", Json::num(*target_load)),
                ("gp_scale", Json::num(*gp_scale)),
                ("seed", Json::num(*seed as f64)),
            ]),
            WorkloadConfig::Institution { jobs, seed } => Json::obj(vec![
                ("kind", Json::str("institution")),
                ("jobs", Json::num(*jobs as f64)),
                ("seed", Json::num(*seed as f64)),
            ]),
            WorkloadConfig::TraceFile { path } => Json::obj(vec![
                ("kind", Json::str("trace")),
                ("path", Json::str(path)),
            ]),
        };
        Json::obj(vec![
            (
                "cluster",
                Json::obj(vec![
                    ("nodes", Json::num(self.cluster.nodes.len() as f64)),
                    ("cpu", Json::num(cap.cpu)),
                    ("ram_gb", Json::num(cap.ram_gb)),
                    ("gpu", Json::num(cap.gpu)),
                ]),
            ),
            ("policy", Json::str(&self.policy.name().to_lowercase().replace("(s=", ":s=").replace(",p=", ",p=").replace(')', ""))),
            ("placement", Json::str(placement_name(self.placement))),
            ("progress_during_grace", Json::Bool(self.progress_during_grace)),
            ("seed", Json::num(self.seed as f64)),
            ("workload", workload),
        ])
    }

    /// Materialize the workload described by this config.
    pub fn build_workload(&self) -> Result<Workload> {
        Ok(match &self.workload {
            WorkloadConfig::Synthetic { jobs, te_fraction, target_load, gp_scale, seed } => {
                SyntheticWorkload::paper_section_4_2(*seed)
                    .with_cluster(self.cluster.clone())
                    .with_num_jobs(*jobs)
                    .with_te_fraction(*te_fraction)
                    .with_target_load(*target_load)
                    .with_gp_scale(*gp_scale)
                    .generate()
            }
            WorkloadConfig::Institution { jobs, seed } => Trace::synthesize_institution(*seed, *jobs),
            WorkloadConfig::TraceFile { path } => Trace::read_csv(Path::new(path))?,
        })
    }

    /// Materialize the simulator config.
    pub fn sim_config(&self) -> SimConfig {
        let mut c = SimConfig::new(self.cluster.clone(), self.policy);
        c.placement = self.placement;
        c.progress_during_grace = self.progress_during_grace;
        c.seed = self.seed;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = ExperimentConfig::from_json(
            r#"{
                "cluster": {"nodes": 4, "cpu": 16, "ram_gb": 64, "gpu": 4},
                "policy": "lrtp",
                "placement": "first-fit",
                "seed": 11,
                "workload": {"kind": "synthetic", "jobs": 128, "te_fraction": 0.5}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.nodes.len(), 4);
        assert_eq!(cfg.policy, PolicyKind::Lrtp);
        assert_eq!(cfg.placement, Placement::FirstFit);
        assert!(
            matches!(cfg.workload, WorkloadConfig::Synthetic { jobs: 128, .. }),
            "expected a 128-job synthetic workload, got {:?}",
            cfg.workload
        );
        if let WorkloadConfig::Synthetic { te_fraction, .. } = cfg.workload {
            assert_eq!(te_fraction, 0.5);
        }
    }

    #[test]
    fn defaults_fill_gaps() {
        let cfg = ExperimentConfig::from_json("{}").unwrap();
        assert_eq!(cfg.cluster.nodes.len(), 84);
        assert!(matches!(cfg.policy, PolicyKind::FitGpp { .. }));
    }

    #[test]
    fn rejects_bad_policy_and_kind() {
        assert!(ExperimentConfig::from_json(r#"{"policy": "wat"}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"workload": {"kind": "wat"}}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"placement": "wat"}"#).is_err());
    }

    #[test]
    fn builds_small_synthetic_workload() {
        let cfg = ExperimentConfig::from_json(
            r#"{"cluster": {"nodes": 2}, "workload": {"kind": "synthetic", "jobs": 64}}"#,
        )
        .unwrap();
        let wl = cfg.build_workload().unwrap();
        assert_eq!(wl.len(), 64);
    }

    #[test]
    fn json_roundtrip_shape() {
        let cfg = ExperimentConfig::default();
        let j = cfg.to_json().to_pretty();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.cluster.nodes.len(), cfg.cluster.nodes.len());
        assert_eq!(back.policy, cfg.policy);
    }
}
