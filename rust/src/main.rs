//! `fitgpp` — the leader binary: run simulations, generate workloads,
//! replay traces, and drive the live cluster.
//!
//! ```text
//! fitgpp simulate --policy fitgpp:s=4,p=1 --jobs 8192
//! fitgpp compare  --jobs 8192                      # all policies, Table-1 style, parallel
//! fitgpp sweep    --policies fifo,lrtp,rand,fitgpp:s=4,p=1 --seeds 100,101,102,103
//! fitgpp generate --jobs 4096 --out trace.csv
//! fitgpp replay   --trace trace.csv --policy lrtp
//! fitgpp replay   --trace big.csv --stream --max-live 20000   # O(live-set) memory
//! fitgpp simulate --stream --jobs 1000000          # stream the §4.2 generator
//! fitgpp simulate --closed-loop --users 64 --trials 32        # TE trial-and-error loop
//! fitgpp simulate --scenario chaos.json --events-out events.jsonl  # fault/cancel injections
//! fitgpp simulate --stream --discipline weighted_fair --tenants 8  # tenant-aware admission
//! fitgpp replay --trace big.csv --stream --discipline quota_gate --tenants 4 --quota 0.3
//! fitgpp simulate --policy psrtf --estimator ewma:alpha=0.2   # prediction-aware SRTF
//! fitgpp sweep --policies srtf,psrtf,fitgpp_pr:s=4,p=1 --estimators sensitivity
//! fitgpp live     --policy fitgpp:s=4,p=1 --jobs 12 --nodes 2
//! fitgpp serve    --uds /tmp/fitgpp.sock --tick-ms 5 --snapshot-dir snaps --snapshot-every 100
//! fitgpp serve    --uds /tmp/fitgpp.sock --restore snaps   # continue from the latest snapshot
//! fitgpp attack   --uds /tmp/fitgpp.sock --clients 256 --jobs 20000
//! fitgpp config   --dump                           # print default config JSON
//! ```

use anyhow::{bail, Context, Result};
use fitgpp::cluster::ClusterSpec;
use fitgpp::config::ExperimentConfig;
use fitgpp::live::{LiveCluster, LiveConfig};
use fitgpp::metrics::{slowdown_table, SlowdownReport};
use fitgpp::sched::admission::DisciplineKind;
use fitgpp::sched::control::{EventSubscriber, JsonlErrorFlag, JsonlEventLog};
use fitgpp::sched::policy::PolicyKind;
use fitgpp::sched::predict::EstimatorKind;
use fitgpp::serve::{AttackConfig, ServeConfig};
use fitgpp::sim::scenario::ScenarioScript;
use fitgpp::sim::{SimConfig, SimEngine, SimResult, Simulator};
use fitgpp::sweep::{compare_on, SweepSpec};
use fitgpp::util::cli::Cli;
use fitgpp::workload::{
    source::{ClosedLoopParams, ClosedLoopSource, TenantAssigner, WorkloadSource},
    synthetic::SyntheticWorkload,
    trace::{CsvStreamSource, Trace},
    Workload,
};
use std::io::BufWriter;
use std::path::{Path, PathBuf};
use std::time::Instant;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().collect();
    let sub = if argv.len() > 1 && !argv[1].starts_with('-') {
        argv.remove(1)
    } else {
        "help".to_string()
    };
    match sub.as_str() {
        "simulate" => simulate(argv),
        "compare" => compare(argv),
        "sweep" => sweep(argv),
        "generate" => generate(argv),
        "replay" => replay(argv),
        "live" => live(argv),
        "serve" => serve(argv),
        "attack" => attack(argv),
        "config" => config_cmd(argv),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown subcommand {other:?}");
        }
    }
}

fn print_help() {
    println!(
        "fitgpp — low-latency job scheduling with preemption (FitGpp)\n\n\
         SUBCOMMANDS:\n\
         \x20 simulate   run one policy on a synthetic workload (--stream / --closed-loop)\n\
         \x20 compare    run FIFO/LRTP/RAND/FitGpp in parallel, print the Table-1 layout\n\
         \x20 sweep      run a policy x te-ratio x gp-scale x seed grid on all cores\n\
         \x20 generate   write a synthetic workload as a CSV trace\n\
         \x20 replay     replay a CSV trace under a policy (--stream for O(live-set) memory)\n\
         \x20 live       drive real PJRT training jobs under the scheduler\n\
         \x20 serve      expose the control plane as a JSONL wire service (TCP / unix socket)\n\
         \x20 attack     replay a workload against a live serve instance as closed-loop clients\n\
         \x20 config     print the default experiment config JSON\n\n\
         Run `fitgpp <subcommand> --help` for options."
    );
}

fn common_cli(name: &'static str, about: &'static str) -> Cli {
    Cli::new(name, about)
        .opt("policy", Some("fitgpp:s=4,p=1"), "fifo | fastlane | lrtp | rand | srtf | youngest | psrtf | fitgpp:s=<f>,p=<n|inf> | fitgpp_pr:s=<f>,p=<n|inf>")
        .opt("jobs", Some("8192"), "number of jobs to generate")
        .opt("nodes", Some("84"), "number of cluster nodes")
        .opt("te-fraction", Some("0.3"), "fraction of TE jobs")
        .opt("load", Some("2.0"), "target FIFO cluster load (arrival calibration)")
        .opt("gp-scale", Some("1.0"), "grace-period distribution scale (Fig. 7)")
        .opt("seed", Some("7"), "workload seed")
        .opt("config", None, "JSON experiment config file (overrides other flags)")
        .opt("json-out", None, "write machine-readable results to this path")
}

fn parse_policy(s: &str) -> Result<PolicyKind> {
    PolicyKind::parse(s).with_context(|| format!("bad --policy {s:?}"))
}

/// Load `--scenario <file>` if given.
fn load_scenario(args: &fitgpp::util::cli::Args) -> Result<Option<ScenarioScript>> {
    match args.get("scenario") {
        Some(p) => Ok(Some(ScenarioScript::from_file(Path::new(p))?)),
        None => Ok(None),
    }
}

/// Build the `--events-out <file>` JSONL subscriber list (empty without
/// the flag), plus an error flag to check after the run: the log flushes
/// when the run drops it, and a write/flush failure must fail the command
/// rather than ship a silently truncated log.
fn event_subscribers(
    args: &fitgpp::util::cli::Args,
) -> Result<(Vec<Box<dyn EventSubscriber>>, Option<JsonlErrorFlag>)> {
    match args.get("events-out") {
        Some(p) => {
            let f = std::fs::File::create(p)
                .with_context(|| format!("creating --events-out {p}"))?;
            eprintln!("logging scheduler events to {p}");
            let log = JsonlEventLog::new(BufWriter::new(f));
            let flag = log.error_flag();
            Ok((vec![Box::new(log)], Some(flag)))
        }
        None => Ok((Vec::new(), None)),
    }
}

/// Fail the command if the `--events-out` log recorded a write error.
fn check_event_log(flag: Option<JsonlErrorFlag>) -> Result<()> {
    if let Some(err) = flag.and_then(|f| f.get()) {
        bail!("--events-out log is incomplete: {err}");
    }
    Ok(())
}

/// Print the control-plane cancellation summary when a scenario killed
/// jobs (cancelled jobs are excluded from every percentile table).
fn report_cancellations(res: &SimResult) {
    if res.metrics.cancelled_total() > 0 {
        println!(
            "cancelled by the control plane: {} TE, {} BE (excluded from the percentiles above)",
            res.metrics.cancelled.te, res.metrics.cancelled.be
        );
    }
}

/// Print the per-tenant fairness table (only when the run actually had
/// more than one tenant).
fn report_tenants(res: &SimResult) {
    if res.tenants_seen() > 1 {
        println!("{}", res.tenant_table());
    }
}

/// Shared tenant/discipline CLI options (simulate + replay).
fn tenant_cli(cli: Cli) -> Cli {
    cli.opt("discipline", Some("fifo"), "admission discipline: fifo | weighted_fair | quota_gate[:w=<n>]")
        .opt("tenants", Some("1"), "assign this many tenants round-robin over the workload")
        .opt("quota", None, "occupied-Size quota applied to every tenant (Eq. 1 Size vs total capacity)")
        .opt("tenant-burst", None, "periodic tenant storm: <tenant>:<period>:<len> (minutes)")
}

/// Shared runtime-estimator CLI options (simulate + replay).
fn estimator_cli(cli: Cli) -> Cli {
    cli.opt("estimator", Some("oracle"), "runtime estimator: oracle | ewma[:alpha=<f>] | noisy[:sigma=<f>]")
        .opt("pred-error", None, "shorthand for --estimator noisy:sigma=<f> (multiplicative log-normal error)")
}

/// Apply `--estimator` / `--pred-error` onto a simulation config.
/// `--pred-error <sigma>` wins when both are given — it is the sweep-style
/// "how wrong can predictions be" knob.
fn apply_estimator(cfg: &mut SimConfig, args: &fitgpp::util::cli::Args) -> Result<()> {
    let raw = args.get_or("estimator", "oracle");
    cfg.estimator = EstimatorKind::parse(raw)
        .with_context(|| format!("bad --estimator {raw:?}"))?;
    if let Some(sig) = args.get("pred-error") {
        let sigma: f64 = sig.parse().context("bad --pred-error")?;
        if !sigma.is_finite() || sigma < 0.0 {
            bail!("--pred-error must be finite and non-negative");
        }
        cfg.estimator = EstimatorKind::Noisy { sigma };
    }
    Ok(())
}

/// Parse `--tenants` / `--tenant-burst` into an assignment rule.
fn tenant_assigner(args: &fitgpp::util::cli::Args) -> Result<TenantAssigner> {
    let n = args.get_u64("tenants", 1);
    if n == 0 || n > u32::MAX as u64 {
        bail!("--tenants must be between 1 and {}", u32::MAX);
    }
    let mut assigner = TenantAssigner::round_robin(n as u32);
    if let Some(spec) = args.get("tenant-burst") {
        let parts: Vec<&str> = spec.split(':').collect();
        let [tenant, period, len] = parts.as_slice() else {
            bail!("bad --tenant-burst {spec:?}: expected <tenant>:<period>:<len>");
        };
        let tenant: u32 = tenant.parse().context("bad --tenant-burst tenant")?;
        let period: u64 = period.parse().context("bad --tenant-burst period")?;
        let len: u64 = len.parse().context("bad --tenant-burst len")?;
        if period == 0 {
            bail!("--tenant-burst period must be positive");
        }
        if tenant >= n as u32 {
            bail!("--tenant-burst tenant {tenant} out of range (--tenants {n})");
        }
        assigner = assigner.with_burst(tenant, period, len);
    }
    Ok(assigner)
}

/// Parse `--quota` (the per-tenant occupied-Size cap), if given.
fn parse_quota(args: &fitgpp::util::cli::Args) -> Result<Option<f64>> {
    match args.get("quota") {
        Some(q) => {
            let q: f64 = q.parse().context("bad --quota")?;
            if !q.is_finite() || q < 0.0 {
                bail!("--quota must be finite and non-negative");
            }
            Ok(Some(q))
        }
        None => Ok(None),
    }
}

/// Apply `--discipline` / `--quota` onto a simulation config.
fn apply_discipline(cfg: &mut SimConfig, args: &fitgpp::util::cli::Args) -> Result<()> {
    cfg.discipline = DisciplineKind::parse(args.get_or("discipline", "fifo"))?;
    if let Some(q) = parse_quota(args)? {
        cfg.default_quota = Some(q);
    }
    Ok(())
}

fn build(args: &fitgpp::util::cli::Args) -> Result<(ExperimentConfig, Workload)> {
    if let Some(path) = args.get("config") {
        let cfg = ExperimentConfig::from_file(Path::new(path))?;
        let wl = cfg.build_workload()?;
        return Ok((cfg, wl));
    }
    let mut cfg = ExperimentConfig::default();
    cfg.cluster = ClusterSpec::homogeneous(
        args.get_usize("nodes", 84),
        fitgpp::resources::ResourceVec::pfn_node(),
    );
    cfg.policy = parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?;
    let wl = SyntheticWorkload::paper_section_4_2(args.get_u64("seed", 7))
        .with_cluster(cfg.cluster.clone())
        .with_num_jobs(args.get_usize("jobs", 8192))
        .with_te_fraction(args.get_f64("te-fraction", 0.3))
        .with_target_load(args.get_f64("load", 2.0))
        .with_gp_scale(args.get_f64("gp-scale", 1.0))
        .generate();
    Ok((cfg, wl))
}

/// Print a streamed run: sketch-backed table plus live-set/throughput
/// accounting, optionally enforcing a live-set ceiling.
fn report_streamed(
    res: &SimResult,
    wall_sec: f64,
    max_live: Option<usize>,
    json_out: Option<&str>,
) -> Result<()> {
    println!("{}", res.summary_table());
    let jobs = res.metrics.jobs_seen;
    println!(
        "streamed {jobs} jobs in {wall_sec:.2}s ({:.0} jobs/sec) | peak live set {} | makespan {} min | unfinished {}",
        jobs as f64 / wall_sec.max(1e-9),
        res.peak_live,
        res.makespan,
        res.unfinished
    );
    report_tenants(res);
    report_cancellations(res);
    println!("prediction updates: {}", res.prediction_updates);
    if let Some(cap) = max_live {
        if res.peak_live > cap {
            bail!("peak live set {} exceeded --max-live {cap}", res.peak_live);
        }
        println!("live-set bound ok: {} <= {cap}", res.peak_live);
    }
    if let Some(path) = json_out {
        std::fs::write(path, res.to_json().to_pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn simulate(argv: Vec<String>) -> Result<()> {
    let cli = estimator_cli(tenant_cli(
        common_cli("fitgpp simulate", "run one policy on a synthetic workload")
            .flag("stream", "stream the workload generator (O(live-set) memory, sketch-backed percentiles)")
            .flag("closed-loop", "closed-loop arrivals: users resubmit after completion + think time")
            .opt("users", Some("64"), "closed-loop: concurrent users")
            .opt("trials", Some("32"), "closed-loop: trials per user")
            .opt("think", Some("10"), "closed-loop: mean think time (minutes)")
            .opt("scenario", None, "JSON scenario file: timed commands + te_patience rule (see EXPERIMENTS.md)")
            .opt("events-out", None, "write the scheduler's JSONL event log to this path"),
    ));
    let args = parse_or_exit(&cli, argv);
    let assigner = tenant_assigner(&args)?;

    if args.has("closed-loop") {
        let users = args.get_usize("users", 64);
        let trials = args.get_usize("trials", 32);
        if users == 0 || trials == 0 {
            bail!("--users and --trials must be positive");
        }
        if assigner.burst.is_some() {
            // Closed loops assign tenants by *user* (a user's whole trial
            // history is one tenant); a time-windowed burst rule cannot
            // apply, so refuse rather than silently ignore it.
            bail!("--tenant-burst applies to open arrival sources, not --closed-loop");
        }
        let mut params = ClosedLoopParams::demo(users, trials as u32).with_tenants(assigner.tenants);
        if let Some(v) = args.get("te-fraction") {
            params.te_fraction = v.parse::<f64>().context("bad --te-fraction")?.clamp(0.0, 1.0);
        }
        params.think_mean = args.get_f64("think", 10.0);
        let mut source = ClosedLoopSource::new(params, args.get_u64("seed", 7));
        let policy = parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?;
        let mut cfg = SimConfig::new(
            ClusterSpec::homogeneous(
                args.get_usize("nodes", 84),
                fitgpp::resources::ResourceVec::pfn_node(),
            ),
            policy,
        );
        cfg.seed = args.get_u64("seed", 7);
        cfg.record_jobs = false;
        cfg.scenario = load_scenario(&args)?;
        apply_discipline(&mut cfg, &args)?;
        apply_estimator(&mut cfg, &args)?;
        eprintln!(
            "closed loop: {} users x {} trials, think ~{} min; policy {}",
            args.get_usize("users", 64),
            args.get_usize("trials", 32),
            args.get_f64("think", 10.0),
            policy.name()
        );
        let t0 = Instant::now();
        let (subs, ev_err) = event_subscribers(&args)?;
        let res = Simulator::new(cfg).run_with(&mut source, subs);
        check_event_log(ev_err)?;
        return report_streamed(&res, t0.elapsed().as_secs_f64(), None, args.get("json-out"));
    }

    if args.has("stream") {
        let params = SyntheticWorkload::paper_section_4_2(args.get_u64("seed", 7))
            .with_cluster(ClusterSpec::homogeneous(
                args.get_usize("nodes", 84),
                fitgpp::resources::ResourceVec::pfn_node(),
            ))
            .with_num_jobs(args.get_usize("jobs", 8192))
            .with_te_fraction(args.get_f64("te-fraction", 0.3))
            .with_target_load(args.get_f64("load", 2.0))
            .with_gp_scale(args.get_f64("gp-scale", 1.0))
            .with_tenant_assigner(assigner);
        let policy = parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?;
        let mut cfg = SimConfig::new(params.cluster.clone(), policy);
        cfg.seed = params.seed;
        cfg.record_jobs = false;
        cfg.scenario = load_scenario(&args)?;
        apply_discipline(&mut cfg, &args)?;
        apply_estimator(&mut cfg, &args)?;
        eprintln!("streaming {} §4.2 jobs; policy {}", params.num_jobs, policy.name());
        let t0 = Instant::now();
        let mut source = params.stream();
        let (subs, ev_err) = event_subscribers(&args)?;
        let res = Simulator::new(cfg).run_with(&mut source, subs);
        check_event_log(ev_err)?;
        return report_streamed(&res, t0.elapsed().as_secs_f64(), None, args.get("json-out"));
    }

    let (cfg, mut wl) = build(&args)?;
    wl.assign_tenants(&assigner);
    eprintln!(
        "workload: {} jobs ({:.1}% TE), span {} min; policy {}",
        wl.len(),
        wl.te_fraction() * 100.0,
        wl.submit_span(),
        cfg.policy.name()
    );
    let mut sim_cfg = cfg.sim_config();
    sim_cfg.scenario = load_scenario(&args)?;
    apply_discipline(&mut sim_cfg, &args)?;
    apply_estimator(&mut sim_cfg, &args)?;
    let (subs, ev_err) = event_subscribers(&args)?;
    let res = Simulator::new(sim_cfg).run_with(&mut WorkloadSource::new(&wl), subs);
    check_event_log(ev_err)?;
    println!("{}", res.summary_table());
    println!(
        "preempted jobs: {:.3}% | preemption signals: {} | makespan {} min",
        res.preempted_fraction() * 100.0,
        res.sched_stats.preemption_signals,
        res.makespan
    );
    report_tenants(&res);
    report_cancellations(&res);
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, res.to_json().to_pretty())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn compare(argv: Vec<String>) -> Result<()> {
    let cli = common_cli("fitgpp compare", "run all four §4 policies in parallel and print Table 1")
        .opt("threads", Some("0"), "worker threads (0 = all cores)");
    let args = parse_or_exit(&cli, argv);
    let (cfg, wl) = build(&args)?;
    let policies = [
        PolicyKind::Fifo,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?,
    ];
    // The template carries the full experiment semantics (placement,
    // progress-during-grace, seed, engine) from the config/flags.
    let cells = compare_on(&wl, &cfg.sim_config(), &policies, args.get_usize("threads", 0));
    let mut rows: Vec<(String, SlowdownReport)> = Vec::new();
    for c in &cells {
        eprintln!(
            "{} done: makespan {} min ({:.2}s)",
            c.cell.policy.name(),
            c.makespan,
            c.wall.as_secs_f64()
        );
        rows.push((c.cell.policy.name(), c.slowdown));
    }
    let named: Vec<(&str, _)> = rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
    println!(
        "{}",
        slowdown_table("Percentiles of slowdown rates (cf. paper Table 1)", &named).to_text()
    );
    Ok(())
}

/// Parse a comma-separated list with a typed element parser.
fn parse_list<T, F: Fn(&str) -> Option<T>>(raw: &str, what: &str, f: F) -> Result<Vec<T>> {
    let mut out = Vec::new();
    for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        out.push(f(tok).with_context(|| format!("bad {what} entry {tok:?}"))?);
    }
    if out.is_empty() {
        bail!("empty {what} list");
    }
    Ok(out)
}

/// Parse a comma-separated policy list. Policy syntax itself uses commas
/// (`fitgpp:s=4,p=1`), so a token like `p=1` — a `key=value` with no `:` —
/// is a continuation of the previous entry, not a new one.
fn parse_policy_list(raw: &str) -> Result<Vec<PolicyKind>> {
    let mut entries: Vec<String> = Vec::new();
    for tok in raw.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let continuation = tok.contains('=') && !tok.contains(':');
        if continuation {
            if let Some(last) = entries.last_mut() {
                last.push(',');
                last.push_str(tok);
                continue;
            }
        }
        entries.push(tok.to_string());
    }
    if entries.is_empty() {
        bail!("empty policy list");
    }
    entries
        .iter()
        .map(|e| {
            PolicyKind::parse(e).with_context(|| format!("bad policy entry {e:?}"))
        })
        .collect()
}

fn sweep(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new(
        "fitgpp sweep",
        "run a policy x te-ratio x gp-scale x seed grid on all cores",
    )
    .opt("policies", Some("fifo,lrtp,rand,fitgpp:s=4,p=1"), "comma-separated policy list")
    .opt("te-ratios", Some("0.3"), "comma-separated TE-job fractions (Fig. 6 axis)")
    .opt("gp-scales", Some("1.0"), "comma-separated grace-period scales (Fig. 7 axis)")
    .opt("seeds", Some("100,101"), "comma-separated workload seeds")
    .opt("jobs", Some("4096"), "jobs per workload")
    .opt("nodes", Some("84"), "number of cluster nodes")
    .opt("load", Some("2.0"), "target FIFO cluster load")
    .opt("threads", Some("0"), "worker threads (0 = FITGPP_THREADS, else all cores)")
    .opt("engine", Some("event-horizon"), "event-horizon | per-minute")
    .opt("discipline", Some("fifo"), "admission discipline: fifo | weighted_fair | quota_gate[:w=<n>]")
    .opt("tenants", Some("1"), "assign this many tenants round-robin over every workload")
    .opt("quota", None, "occupied-Size quota applied to every tenant in every cell")
    .opt("estimators", Some("oracle"), "comma-separated estimator axis: oracle | ewma[:alpha=<f>] | noisy[:sigma=<f>] | sensitivity")
    .opt("json-out", None, "write the full sweep JSON here")
    .opt("csv-out", None, "write one CSV row per cell here");
    let args = parse_or_exit(&cli, argv);

    let policies = parse_policy_list(args.get_or("policies", "fifo,lrtp,rand,fitgpp:s=4,p=1"))?;
    let te_ratios = parse_list(args.get_or("te-ratios", "0.3"), "te-ratio", |s| {
        s.parse::<f64>().ok()
    })?;
    let gp_scales = parse_list(args.get_or("gp-scales", "1.0"), "gp-scale", |s| {
        s.parse::<f64>().ok()
    })?;
    let seeds = parse_list(args.get_or("seeds", "100,101"), "seed", |s| {
        s.parse::<u64>().ok()
    })?;
    let engine = match args.get_or("engine", "event-horizon") {
        "event-horizon" => SimEngine::EventHorizon,
        "per-minute" => SimEngine::PerMinute,
        other => bail!("unknown --engine {other:?}"),
    };

    let discipline = DisciplineKind::parse(args.get_or("discipline", "fifo"))?;
    let tenants = tenant_assigner(&args)?.tenants;
    let quota = parse_quota(&args)?;
    // "sensitivity" expands to the canonical error-sensitivity axis
    // (oracle, cold-start EWMA, noisy at sigma 0 / 0.25 / 0.5 / 1.0).
    let estimators = match args.get_or("estimators", "oracle") {
        "sensitivity" => fitgpp::sweep::error_sensitivity_estimators(),
        raw => parse_list(raw, "estimator", EstimatorKind::parse)?,
    };

    let spec = SweepSpec::new(
        ClusterSpec::homogeneous(
            args.get_usize("nodes", 84),
            fitgpp::resources::ResourceVec::pfn_node(),
        ),
        policies,
    )
    .with_te_ratios(te_ratios)
    .with_gp_scales(gp_scales)
    .with_seeds(seeds)
    .with_num_jobs(args.get_usize("jobs", 4096))
    .with_target_load(args.get_f64("load", 2.0))
    .with_engine(engine)
    .with_discipline(discipline)
    .with_tenants(tenants)
    .with_default_quota(quota)
    .with_estimators(estimators)
    .with_threads(args.get_usize("threads", 0));

    eprintln!(
        "sweep: {} cells on {} threads ({} distinct workloads)",
        spec.cells().len(),
        spec.threads_effective(),
        spec.seeds.len() * spec.te_ratios.len() * spec.gp_scales.len()
    );
    let res = spec.run();
    println!(
        "{}",
        res.table1("Sweep: slowdown percentiles pooled across seeds").to_text()
    );
    if res.estimators().len() > 1 {
        println!(
            "{}",
            res.estimator_grid("Prediction-error sensitivity (TE p95 / BE p50, pooled across seeds)")
                .to_text()
        );
    }
    println!(
        "{} cells in {:.1}s wall on {} threads ({:.1}s serial-equivalent sim time)",
        res.cells.len(),
        res.wall.as_secs_f64(),
        res.threads,
        res.total_cell_wall().as_secs_f64()
    );
    if let Some(path) = args.get("json-out") {
        std::fs::write(path, res.to_json().to_pretty())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("csv-out") {
        std::fs::write(path, res.to_csv())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn generate(argv: Vec<String>) -> Result<()> {
    let cli = common_cli("fitgpp generate", "write a synthetic workload as CSV")
        .opt("out", Some("workload.csv"), "output CSV path")
        .flag("institution", "synthesize the §4.4 institution trace instead");
    let args = parse_or_exit(&cli, argv);
    let wl = if args.has("institution") {
        Trace::synthesize_institution(args.get_u64("seed", 7), args.get_usize("jobs", 8192))
    } else {
        build(&args)?.1
    };
    let out = args.get_string("out", "workload.csv");
    Trace::write_csv(&wl, Path::new(&out))?;
    println!("wrote {} jobs to {out}", wl.len());
    Ok(())
}

fn replay(argv: Vec<String>) -> Result<()> {
    let cli = estimator_cli(tenant_cli(
        common_cli("fitgpp replay", "replay a CSV trace under a policy")
            .opt("trace", None, "input CSV trace path (required)")
            .flag("stream", "stream the trace through a buffered reader (O(live-set) memory)")
            .opt("max-live", None, "fail if the peak resident live set exceeds this (streaming smoke checks)")
            .opt("scenario", None, "JSON scenario file: timed commands + te_patience rule (see EXPERIMENTS.md)")
            .opt("events-out", None, "write the scheduler's JSONL event log to this path"),
    ));
    let args = parse_or_exit(&cli, argv);
    let assigner = tenant_assigner(&args)?;
    let path = args.get("trace").context("--trace is required")?;
    let policy = parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?;
    let nodes = args.get_usize("nodes", 84);
    let mut cfg = SimConfig::new(
        ClusterSpec::homogeneous(nodes, fitgpp::resources::ResourceVec::pfn_node()),
        policy,
    );
    cfg.scenario = load_scenario(&args)?;
    apply_discipline(&mut cfg, &args)?;
    apply_estimator(&mut cfg, &args)?;
    let max_live = match args.get("max-live") {
        Some(v) => Some(v.parse::<usize>().context("bad --max-live")?),
        None => None,
    };

    if args.has("stream") {
        cfg.record_jobs = false;
        let mut source = CsvStreamSource::open(Path::new(path))?.with_tenants(assigner);
        let t0 = Instant::now();
        let (subs, ev_err) = event_subscribers(&args)?;
        let res = Simulator::new(cfg).run_with(&mut source, subs);
        if let Some(e) = source.error() {
            bail!("trace stream aborted after {} rows: {e:#}", source.rows_yielded());
        }
        check_event_log(ev_err)?;
        return report_streamed(&res, t0.elapsed().as_secs_f64(), max_live, args.get("json-out"));
    }

    let mut wl = Trace::read_csv(Path::new(path))?;
    wl.assign_tenants(&assigner);
    let (subs, ev_err) = event_subscribers(&args)?;
    let res = Simulator::new(cfg).run_with(&mut WorkloadSource::new(&wl), subs);
    check_event_log(ev_err)?;
    println!("{}", res.summary_table());
    report_tenants(&res);
    report_cancellations(&res);
    if let Some(cap) = max_live {
        if res.peak_live > cap {
            bail!("peak live set {} exceeded --max-live {cap}", res.peak_live);
        }
    }
    if let Some(p) = args.get("json-out") {
        std::fs::write(p, res.to_json().to_pretty())?;
    }
    Ok(())
}

fn live(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("fitgpp live", "drive real PJRT training jobs under the scheduler")
        .opt("policy", Some("fitgpp:s=4,p=1"), "scheduling policy")
        .opt("jobs", Some("10"), "number of live jobs")
        .opt("nodes", Some("2"), "number of live-demo cluster nodes")
        .opt("tick-ms", Some("150"), "wall milliseconds per simulated minute")
        .opt("seed", Some("7"), "seed")
        .opt("json-out", None, "write the live report JSON here");
    let args = parse_or_exit(&cli, argv);
    let policy = parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?;
    let nodes = args.get_usize("nodes", 2);
    if nodes == 0 {
        bail!("--nodes must be positive");
    }
    let mut cfg = LiveConfig::demo(policy).with_nodes(nodes);
    cfg.tick_ms = args.get_u64("tick-ms", 150);
    cfg.seed = args.get_u64("seed", 7);
    let wl = fitgpp::live::demo_workload(args.get_usize("jobs", 10), cfg.seed);
    let cluster = LiveCluster::new(cfg)?;
    let report = cluster.run(&wl)?;
    println!(
        "live run: {} ticks in {:.1}s, {} total train steps, {} scheduler events",
        report.ticks,
        report.wall.as_secs_f64(),
        report.total_steps,
        report.sched_events.len()
    );
    for r in &report.records {
        let drop = report.loss_drop(r.id);
        println!(
            "  {} [{}] slowdown {:.2} preemptions {} loss {}",
            r.id,
            r.class.as_str(),
            r.slowdown,
            r.preemptions,
            match drop {
                Some((a, b)) => format!("{a:.3} → {b:.3}"),
                None => "n/a".to_string(),
            }
        );
    }
    if let Some(p) = args.get("json-out") {
        std::fs::write(p, report.to_json().to_pretty())?;
    }
    Ok(())
}

/// Build the simulation config a `serve` instance runs (and must rebuild
/// identically when restoring a snapshot — the snapshot pins a
/// fingerprint of it, so pass the same flags to the restoring process).
fn serve_sim_config(args: &fitgpp::util::cli::Args) -> Result<SimConfig> {
    let policy = parse_policy(args.get_or("policy", "fitgpp:s=4,p=1"))?;
    let mut cfg = SimConfig::new(
        ClusterSpec::homogeneous(
            args.get_usize("nodes", 84),
            fitgpp::resources::ResourceVec::pfn_node(),
        ),
        policy,
    );
    cfg.seed = args.get_u64("seed", 7);
    cfg.engine = match args.get_or("engine", "event-horizon") {
        "event-horizon" => SimEngine::EventHorizon,
        "per-minute" => SimEngine::PerMinute,
        other => bail!("unknown --engine {other:?}"),
    };
    cfg.scenario = load_scenario(args)?;
    apply_discipline(&mut cfg, args)?;
    apply_estimator(&mut cfg, args)?;
    Ok(cfg)
}

/// The workload a `serve`/`attack` run replays: `--trace <csv>` when
/// given, otherwise `--jobs` §4.2 synthetic jobs (0 = empty — a serve
/// instance fed purely over the wire).
fn serve_workload(args: &fitgpp::util::cli::Args, default_jobs: usize) -> Result<Workload> {
    if let Some(path) = args.get("trace") {
        return Trace::read_csv(Path::new(path));
    }
    let jobs = args.get_usize("jobs", default_jobs);
    if jobs == 0 {
        return Ok(Workload::new(Vec::new()));
    }
    Ok(SyntheticWorkload::paper_section_4_2(args.get_u64("seed", 7))
        .with_cluster(ClusterSpec::homogeneous(
            args.get_usize("nodes", 84),
            fitgpp::resources::ResourceVec::pfn_node(),
        ))
        .with_num_jobs(jobs)
        .with_te_fraction(args.get_f64("te-fraction", 0.3))
        .with_target_load(args.get_f64("load", 2.0))
        .with_gp_scale(args.get_f64("gp-scale", 1.0))
        .generate())
}

fn serve(argv: Vec<String>) -> Result<()> {
    let cli = estimator_cli(tenant_cli(
        common_cli("fitgpp serve", "expose the control plane as a JSONL wire service")
            .opt("tcp", None, "TCP listen address, e.g. 127.0.0.1:7700")
            .opt("uds", None, "unix-domain socket path to listen on")
            .opt("tick-ms", Some("0"), "wall milliseconds per simulated minute (0 = free-run)")
            .opt("queue-cap", Some("1024"), "per-connection outbound queue bound, in lines (slow consumers get 'lagged' notices)")
            .opt("batch-max", Some("256"), "most event/response lines coalesced into one fan-out write (1 = per-line)")
            .opt("snapshot-dir", None, "write auto/final snapshots into this directory")
            .opt("snapshot-every", Some("0"), "auto-snapshot period in virtual minutes (0 = off)")
            .opt("restore", None, "restore from this snapshot file — or the latest *.snap in this directory")
            .opt("scenario", None, "JSON scenario file replayed against the served run")
            .flag("exit-when-done", "exit when the workload drains instead of parking for wire traffic"),
    ));
    let args = parse_or_exit(&cli, argv);
    let sim = serve_sim_config(&args)?;
    let mut wl = serve_workload(&args, 0)?;
    wl.assign_tenants(&tenant_assigner(&args)?);
    let mut cfg = ServeConfig::new(sim);
    cfg.tcp = args.get("tcp").map(String::from);
    cfg.uds = args.get("uds").map(PathBuf::from);
    if cfg.tcp.is_none() && cfg.uds.is_none() {
        bail!("serve needs --tcp and/or --uds to listen on");
    }
    cfg.tick_ms = args.get_u64("tick-ms", 0);
    cfg.queue_cap = args.get_usize("queue-cap", 1024);
    cfg.batch_max = args.get_usize("batch-max", 256).max(1);
    cfg.snapshot_dir = args.get("snapshot-dir").map(PathBuf::from);
    cfg.snapshot_every = args.get_u64("snapshot-every", 0);
    cfg.exit_when_done = args.has("exit-when-done");
    if let Some(raw) = args.get("restore") {
        let p = PathBuf::from(raw);
        let p = if p.is_dir() {
            fitgpp::serve::snapshot::latest_in(&p)?
                .with_context(|| format!("no *.snap snapshot found in {raw}"))?
        } else {
            p
        };
        cfg.restore_from = Some(p);
    }
    if !wl.is_empty() {
        eprintln!(
            "serving {} preloaded jobs ({:.1}% TE), span {} min",
            wl.len(),
            wl.te_fraction() * 100.0,
            wl.submit_span()
        );
    }
    let t0 = Instant::now();
    let outcome = fitgpp::serve::server::run(cfg, &mut WorkloadSource::new(&wl))?;
    println!("{}", outcome.result.summary_table());
    report_tenants(&outcome.result);
    report_cancellations(&outcome.result);
    println!("{}", fitgpp::serve::conservation_line(&outcome.result));
    let s = &outcome.stats;
    println!(
        "serve: {} connections, {} requests, {} events sent, {} dropped (lagged), {} snapshots ({:.1} ms stall), {:.1}s wall{}",
        s.connections,
        s.requests,
        s.events_sent,
        s.events_dropped,
        s.snapshots,
        s.snapshot_stall_ms,
        t0.elapsed().as_secs_f64(),
        if outcome.stopped { " (stopped by signal/shutdown)" } else { "" }
    );
    if let Some(p) = args.get("json-out") {
        std::fs::write(p, outcome.result.to_json().to_pretty())?;
        eprintln!("wrote {p}");
    }
    Ok(())
}

fn attack(argv: Vec<String>) -> Result<()> {
    let cli = common_cli(
        "fitgpp attack",
        "replay a workload against a live serve instance as concurrent closed-loop wire clients",
    )
    .opt("tcp", None, "TCP address of the server")
    .opt("uds", None, "unix-domain socket path of the server")
    .opt("clients", Some("64"), "concurrent closed-loop client connections")
    .opt("think-ms", Some("0"), "wall-clock think time between a finish and the next submit")
    .opt("speed", Some("0"), "wall ms per virtual submit minute (0 = as fast as the loop allows)")
    .opt("id-base", Some("0"), "offset added to every replayed job id")
    .opt("timeout-ms", Some("60000"), "per-wait timeout before a client gives up on an ack/finish")
    .opt("max-jobs", Some("0"), "cap the replayed job count (0 = the whole workload)")
    .opt("trace", None, "replay this CSV trace instead of the synthetic workload")
    .flag("closed-loop", "drain a closed-loop trial-and-error generator instead (--users/--trials)")
    .opt("users", Some("64"), "closed-loop: concurrent users")
    .opt("trials", Some("32"), "closed-loop: trials per user")
    .flag("open-loop", "fire submits without waiting for each job's finished event");
    let args = parse_or_exit(&cli, argv);
    let limit = match args.get_usize("max-jobs", 0) {
        0 => usize::MAX,
        n => n,
    };
    let specs = if args.has("closed-loop") {
        let users = args.get_usize("users", 64);
        let trials = args.get_usize("trials", 32);
        if users == 0 || trials == 0 {
            bail!("--users and --trials must be positive");
        }
        let params = ClosedLoopParams::demo(users, trials as u32);
        let mut src = ClosedLoopSource::new(params, args.get_u64("seed", 7));
        fitgpp::serve::attack::drain_source(&mut src, limit)
    } else {
        let wl = serve_workload(&args, 256)?;
        let mut src = WorkloadSource::new(&wl);
        fitgpp::serve::attack::drain_source(&mut src, limit)
    };
    if specs.is_empty() {
        bail!("nothing to replay: the workload drained to zero jobs");
    }
    let mut cfg = AttackConfig::new();
    cfg.tcp = args.get("tcp").map(String::from);
    cfg.uds = args.get("uds").map(PathBuf::from);
    if cfg.tcp.is_none() && cfg.uds.is_none() {
        bail!("attack needs --tcp or --uds to aim at");
    }
    cfg.clients = args.get_usize("clients", 64);
    cfg.think_ms = args.get_u64("think-ms", 0);
    cfg.speed_ms_per_minute = args.get_u64("speed", 0);
    let id_base = args.get_u64("id-base", 0);
    if id_base > u32::MAX as u64 {
        bail!("--id-base must fit in 32 bits");
    }
    cfg.id_base = id_base as u32;
    cfg.await_finish = !args.has("open-loop");
    cfg.timeout_ms = args.get_u64("timeout-ms", 60_000);
    eprintln!(
        "attacking with {} clients x {} jobs ({})",
        cfg.clients,
        specs.len(),
        if cfg.await_finish { "closed loop" } else { "open loop" }
    );
    let report = fitgpp::serve::attack::run(&cfg, specs)?;
    println!("{}", report.to_json_line());
    println!(
        "attack: {} submitted, {} acked, {} finished, {} lagged notices, {} timeouts, {} errors, {} disconnects in {:.1}s",
        report.submitted,
        report.acked,
        report.finished_seen,
        report.lagged_notices,
        report.timeouts,
        report.errors,
        report.disconnects,
        report.wall_ms as f64 / 1000.0
    );
    if let Some(p) = args.get("json-out") {
        std::fs::write(p, report.to_json_line())?;
        eprintln!("wrote {p}");
    }
    if report.disconnects > 0 {
        bail!("{} attack clients lost their connection", report.disconnects);
    }
    Ok(())
}

fn config_cmd(argv: Vec<String>) -> Result<()> {
    let cli = Cli::new("fitgpp config", "print the default experiment config")
        .flag("dump", "print default config JSON");
    let _ = parse_or_exit(&cli, argv);
    println!("{}", ExperimentConfig::default().to_json().to_pretty());
    Ok(())
}

/// Parse args; print help and exit on `-h`; print error + help and exit 2
/// on bad flags.
fn parse_or_exit(cli: &Cli, argv: Vec<String>) -> fitgpp::util::cli::Args {
    match cli.parse_from(argv) {
        Ok(a) => a,
        Err(fitgpp::util::cli::CliError::Help) => {
            print!("{}", cli.help_text());
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{}", cli.help_text());
            std::process::exit(2);
        }
    }
}
