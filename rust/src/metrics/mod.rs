//! Evaluation metrics: slowdown-rate percentiles (Tables 1 & 5),
//! re-scheduling intervals (Table 2), and preemption statistics
//! (Tables 3 & 4).

use crate::job::JobClass;
use crate::sim::SimResult;
use crate::stats::summary::percentiles;
use crate::util::json::Json;
use crate::util::table::{sig3, Table};

/// 50th/95th/99th percentiles — the triple every slowdown table reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Percentiles {
    pub fn of(xs: &[f64]) -> Percentiles {
        if xs.is_empty() {
            return Percentiles { p50: f64::NAN, p95: f64::NAN, p99: f64::NAN };
        }
        let v = percentiles(xs, &[50.0, 95.0, 99.0]);
        Percentiles { p50: v[0], p95: v[1], p99: v[2] }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
        ])
    }
}

/// Slowdown-rate percentiles for TE and BE jobs (Table 1 / Table 5 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownReport {
    /// Trial-and-error (latency-sensitive) class.
    pub te: Percentiles,
    /// Best-effort class.
    pub be: Percentiles,
}

impl SlowdownReport {
    pub fn from_result(res: &SimResult) -> Self {
        SlowdownReport {
            te: Percentiles::of(&res.slowdowns(JobClass::Te)),
            be: Percentiles::of(&res.slowdowns(JobClass::Be)),
        }
    }
}

/// Re-scheduling interval percentiles in minutes (Table 2 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntervalsReport {
    /// Median interval.
    pub p50: f64,
    /// 75th percentile.
    pub p75: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Number of completed vacate→restart intervals pooled.
    pub count: usize,
}

impl IntervalsReport {
    pub fn from_result(res: &SimResult) -> Self {
        let iv = res.resched_intervals();
        if iv.is_empty() {
            return IntervalsReport { p50: f64::NAN, p75: f64::NAN, p95: f64::NAN, p99: f64::NAN, count: 0 };
        }
        let v = percentiles(&iv, &[50.0, 75.0, 95.0, 99.0]);
        IntervalsReport { p50: v[0], p75: v[1], p95: v[2], p99: v[3], count: iv.len() }
    }
}

/// Preemption statistics (Tables 3 & 4 rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptionReport {
    /// Fraction of all jobs preempted ≥ 1 time (Table 3).
    pub fraction_preempted: f64,
    /// Fractions preempted exactly 1 / exactly 2 / ≥ 3 times (Table 4).
    pub hist: [f64; 3],
}

impl PreemptionReport {
    pub fn from_result(res: &SimResult) -> Self {
        PreemptionReport {
            fraction_preempted: res.preempted_fraction(),
            hist: res.preemption_histogram(),
        }
    }
}

/// Render the paper's Table-1 layout for a set of runs (one row per
/// policy).
pub fn slowdown_table(title: &str, rows: &[(&str, SlowdownReport)]) -> Table {
    let mut t = Table::new(
        title,
        &["policy", "TE 50th", "TE 95th", "TE 99th", "BE 50th", "BE 95th", "BE 99th"],
    );
    for (name, r) in rows {
        t.row(vec![
            name.to_string(),
            sig3(r.te.p50),
            sig3(r.te.p95),
            sig3(r.te.p99),
            sig3(r.be.p50),
            sig3(r.be.p95),
            sig3(r.be.p99),
        ]);
    }
    t
}

/// Render the paper's Table-2 layout.
pub fn intervals_table(title: &str, rows: &[(&str, IntervalsReport)]) -> Table {
    let mut t = Table::new(title, &["policy", "50th", "75th", "95th", "99th", "n"]);
    for (name, r) in rows {
        t.row(vec![
            name.to_string(),
            sig3(r.p50),
            sig3(r.p75),
            sig3(r.p95),
            sig3(r.p99),
            r.count.to_string(),
        ]);
    }
    t
}

/// Render the paper's Table-3 layout (percentage form, e.g. `6.3e-1%`).
pub fn preempted_table(title: &str, rows: &[(&str, PreemptionReport)]) -> Table {
    let mut t = Table::new(title, &["policy", "preempted jobs"]);
    for (name, r) in rows {
        t.row(vec![name.to_string(), format!("{}%", sig3(r.fraction_preempted * 100.0))]);
    }
    t
}

/// Render the paper's Table-4 layout.
pub fn preempt_hist_table(title: &str, rows: &[(&str, PreemptionReport)]) -> Table {
    let mut t = Table::new(title, &["policy", "1", "2", ">=3"]);
    for (name, r) in rows {
        t.row(vec![
            name.to_string(),
            format!("{}%", sig3(r.hist[0] * 100.0)),
            format!("{}%", sig3(r.hist[1] * 100.0)),
            format!("{}%", sig3(r.hist[2] * 100.0)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_data() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::of(&xs);
        assert!((p.p50 - 50.5).abs() < 1e-9);
        assert!((p.p95 - 95.05).abs() < 1e-9);
        assert!((p.p99 - 99.01).abs() < 1e-9);
    }

    #[test]
    fn empty_percentiles_are_nan() {
        let p = Percentiles::of(&[]);
        assert!(p.p50.is_nan());
    }

    #[test]
    fn tables_render_rows() {
        let r = SlowdownReport {
            te: Percentiles { p50: 1.0, p95: 1.15, p99: 1.54 },
            be: Percentiles { p50: 3.28, p95: 6.06, p99: 10.3 },
        };
        let t = slowdown_table("Table 1", &[("FitGpp (s=4.0)", r)]);
        let text = t.to_text();
        assert!(text.contains("FitGpp"));
        assert!(text.contains("10.3"));
    }

    #[test]
    fn preempted_table_uses_percent() {
        let r = PreemptionReport { fraction_preempted: 0.0063, hist: [0.0052, 0.00038, 0.000098] };
        let t = preempted_table("Table 3", &[("FitGpp", r)]);
        assert!(t.to_text().contains("0.63%"));
        let h = preempt_hist_table("Table 4", &[("FitGpp", r)]);
        assert!(h.to_text().contains("0.52%"));
    }
}
