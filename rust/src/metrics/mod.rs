//! Evaluation metrics: slowdown-rate percentiles (Tables 1 & 5),
//! re-scheduling intervals (Table 2), and preemption statistics
//! (Tables 3 & 4).
//!
//! Two backends feed the same report types:
//!
//! * **Exact** — computed from pooled `JobRecord`s with one shared sort
//!   per sample (the `record_jobs` mode; the equivalence oracle).
//! * **Streaming** — [`StreamingMetrics`], a mergeable sink the simulator
//!   folds each *retiring* job into: per-class
//!   [`QuantileSketch`]es plus exact counters, O(1) memory however long
//!   the run. Sweep cells merge these sinks instead of pooling raw
//!   slowdown vectors.

use crate::job::JobClass;
use crate::sim::{JobRecord, SimResult};
use crate::stats::sketch::QuantileSketch;
use crate::stats::summary::{percentile_sorted, percentiles, sort_ascending};
use crate::util::bin::{BinReader, BinWriter};
use crate::util::json::Json;
use crate::util::table::{sig3, Table};
use std::collections::BTreeMap;

/// One value per job class — the keyed-counter helper behind every
/// "TE column / BE column" pair in the sink. Replaces the hand-rolled
/// `foo_te` / `foo_be` field pairs (one match on [`JobClass`] in one
/// place) and is reused verbatim by the per-tenant metrics map.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassKeyed<T> {
    /// The trial-and-error (latency-sensitive) class's value.
    pub te: T,
    /// The best-effort class's value.
    pub be: T,
}

impl<T> ClassKeyed<T> {
    /// The value for `class`.
    pub fn get(&self, class: JobClass) -> &T {
        match class {
            JobClass::Te => &self.te,
            JobClass::Be => &self.be,
        }
    }

    /// Mutable value for `class`.
    pub fn get_mut(&mut self, class: JobClass) -> &mut T {
        match class {
            JobClass::Te => &mut self.te,
            JobClass::Be => &mut self.be,
        }
    }

    /// Fold `other` in, one class at a time (`f` merges one pair).
    pub fn merge_with(&mut self, other: &Self, mut f: impl FnMut(&mut T, &T)) {
        f(&mut self.te, &other.te);
        f(&mut self.be, &other.be);
    }
}

impl ClassKeyed<u64> {
    /// Increment the counter for `class`.
    pub fn bump(&mut self, class: JobClass) {
        *self.get_mut(class) += 1;
    }

    /// Sum across both classes.
    pub fn total(&self) -> u64 {
        self.te + self.be
    }
}

/// 50th/95th/99th percentiles — the triple every slowdown table reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
}

impl Percentiles {
    pub fn of(xs: &[f64]) -> Percentiles {
        if xs.is_empty() {
            return Percentiles { p50: f64::NAN, p95: f64::NAN, p99: f64::NAN };
        }
        Self::of_sorted(&sort_ascending(xs))
    }

    /// The triple over an already-sorted sample — the shared-sort path for
    /// callers that compute several reports from one sample.
    pub fn of_sorted(sorted: &[f64]) -> Percentiles {
        if sorted.is_empty() {
            return Percentiles { p50: f64::NAN, p95: f64::NAN, p99: f64::NAN };
        }
        Percentiles {
            p50: percentile_sorted(sorted, 50.0),
            p95: percentile_sorted(sorted, 95.0),
            p99: percentile_sorted(sorted, 99.0),
        }
    }

    /// The triple estimated from a streaming sketch (no sort, no samples
    /// held; ≤ ~0.5% relative error). NaN on an empty sketch, matching
    /// [`Percentiles::of`] on an empty slice.
    pub fn from_sketch(s: &QuantileSketch) -> Percentiles {
        Percentiles {
            p50: s.percentile(50.0),
            p95: s.percentile(95.0),
            p99: s.percentile(99.0),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
        ])
    }
}

/// Slowdown-rate percentiles for TE and BE jobs (Table 1 / Table 5 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SlowdownReport {
    /// Trial-and-error (latency-sensitive) class.
    pub te: Percentiles,
    /// Best-effort class.
    pub be: Percentiles,
}

impl SlowdownReport {
    pub fn from_result(res: &SimResult) -> Self {
        SlowdownReport {
            te: Percentiles::of(&res.slowdowns(JobClass::Te)),
            be: Percentiles::of(&res.slowdowns(JobClass::Be)),
        }
    }
}

/// Re-scheduling interval percentiles in minutes (Table 2 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntervalsReport {
    /// Median interval.
    pub p50: f64,
    /// 75th percentile.
    pub p75: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Number of completed vacate→restart intervals pooled.
    pub count: usize,
}

impl IntervalsReport {
    pub fn from_result(res: &SimResult) -> Self {
        let iv = res.resched_intervals();
        if iv.is_empty() {
            return IntervalsReport { p50: f64::NAN, p75: f64::NAN, p95: f64::NAN, p99: f64::NAN, count: 0 };
        }
        let v = percentiles(&iv, &[50.0, 75.0, 95.0, 99.0]);
        IntervalsReport { p50: v[0], p75: v[1], p95: v[2], p99: v[3], count: iv.len() }
    }
}

/// Preemption statistics (Tables 3 & 4 rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PreemptionReport {
    /// Fraction of all jobs preempted ≥ 1 time (Table 3).
    pub fraction_preempted: f64,
    /// Fractions preempted exactly 1 / exactly 2 / ≥ 3 times (Table 4).
    pub hist: [f64; 3],
}

impl PreemptionReport {
    pub fn from_result(res: &SimResult) -> Self {
        PreemptionReport {
            fraction_preempted: res.preempted_fraction(),
            hist: res.preemption_histogram(),
        }
    }
}

/// A mergeable streaming metrics sink: everything the report types need,
/// accumulated one retiring job at a time in O(1) memory.
///
/// The simulator folds each job into the sink the tick it completes (or at
/// cut-off, for unfinished jobs); with `record_jobs` off this is the *only*
/// per-job state the run keeps. Sinks from different runs/cells
/// [`merge`](StreamingMetrics::merge) associatively and commutatively, so
/// the sweep layer pools across seeds by merging sketches instead of
/// concatenating and re-sorting raw slowdown vectors.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StreamingMetrics {
    /// Slowdown sketches over completed jobs, keyed by class.
    pub slowdown: ClassKeyed<QuantileSketch>,
    /// Re-scheduling intervals (vacate → restart), all jobs pooled.
    pub intervals: QuantileSketch,
    /// Jobs observed (completed + unfinished).
    pub jobs_seen: u64,
    /// Jobs that completed.
    pub completed: u64,
    /// Jobs unfinished at cut-off.
    pub unfinished: u64,
    /// Jobs preempted exactly 1 / exactly 2 / ≥ 3 times (Table 4
    /// numerators).
    pub preempt_hist: [u64; 3],
    /// Jobs preempted at least once (Table 3 numerator).
    pub preempted: u64,
    /// Jobs cancelled by the control plane, keyed by class. Cancelled
    /// jobs are counted here and **nowhere else** — not in `jobs_seen`,
    /// the slowdown sketches, or the preemption histogram — so scenario
    /// runs report Table 1-style statistics over exactly the jobs that
    /// ran to an outcome.
    pub cancelled: ClassKeyed<u64>,
    /// Per-tenant sub-sinks, keyed by [`TenantId`](crate::job::TenantId)
    /// value. Every observed job is folded into its tenant's entry as
    /// well as the global fields above; the map merges keywise, so sweep
    /// cells pool per-tenant percentiles exactly like the global ones.
    /// Single-tenant runs hold one entry (tenant 0).
    pub tenants: BTreeMap<u32, TenantMetrics>,
}

/// One tenant's slice of the sink: per-class slowdown sketches plus the
/// completion / cancellation / preemption counters the fairness tables
/// report. Built from the same [`ClassKeyed`] helper as the global sink.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantMetrics {
    /// Slowdown sketches over the tenant's completed jobs, by class.
    pub slowdown: ClassKeyed<QuantileSketch>,
    /// The tenant's completed jobs, by class.
    pub completed: ClassKeyed<u64>,
    /// The tenant's control-plane cancellations, by class.
    pub cancelled: ClassKeyed<u64>,
    /// The tenant's jobs preempted at least once.
    pub preempted: u64,
    /// The tenant's jobs unfinished at cut-off.
    pub unfinished: u64,
}

impl TenantMetrics {
    /// Fold another tenant slice in.
    pub fn merge(&mut self, other: &TenantMetrics) {
        self.slowdown.merge_with(&other.slowdown, |a, b| a.merge(b));
        self.completed.merge_with(&other.completed, |a, b| *a += *b);
        self.cancelled.merge_with(&other.cancelled, |a, b| *a += *b);
        self.preempted += other.preempted;
        self.unfinished += other.unfinished;
    }

    /// Sketch-backed slowdown report for this tenant.
    pub fn slowdown_report(&self) -> SlowdownReport {
        SlowdownReport {
            te: Percentiles::from_sketch(&self.slowdown.te),
            be: Percentiles::from_sketch(&self.slowdown.be),
        }
    }

    /// Jobs observed for this tenant (completed + unfinished; cancelled
    /// jobs excluded, as in the global sink).
    pub fn jobs_seen(&self) -> u64 {
        self.completed.total() + self.unfinished
    }

    /// Serialize this tenant slice for a snapshot.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        self.slowdown.te.snapshot_bin(w);
        self.slowdown.be.snapshot_bin(w);
        w.u64(self.completed.te);
        w.u64(self.completed.be);
        w.u64(self.cancelled.te);
        w.u64(self.cancelled.be);
        w.u64(self.preempted);
        w.u64(self.unfinished);
    }

    /// Rebuild a slice written by [`TenantMetrics::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        Ok(TenantMetrics {
            slowdown: ClassKeyed {
                te: QuantileSketch::restore_bin(r)?,
                be: QuantileSketch::restore_bin(r)?,
            },
            completed: ClassKeyed { te: r.u64()?, be: r.u64()? },
            cancelled: ClassKeyed { te: r.u64()?, be: r.u64()? },
            preempted: r.u64()?,
            unfinished: r.u64()?,
        })
    }

    /// Machine-readable dump (one entry of the JSON `tenants` object).
    pub fn to_json(&self) -> Json {
        let r = self.slowdown_report();
        Json::obj(vec![
            ("jobs_seen", Json::num(self.jobs_seen() as f64)),
            ("completed", Json::num(self.completed.total() as f64)),
            ("unfinished", Json::num(self.unfinished as f64)),
            ("preempted", Json::num(self.preempted as f64)),
            (
                "cancelled",
                Json::obj(vec![
                    ("te", Json::num(self.cancelled.te as f64)),
                    ("be", Json::num(self.cancelled.be as f64)),
                ]),
            ),
            (
                "slowdown",
                Json::obj(vec![("te", r.te.to_json()), ("be", r.be.to_json())]),
            ),
        ])
    }
}

impl StreamingMetrics {
    /// An empty sink.
    pub fn new() -> Self {
        StreamingMetrics::default()
    }

    /// Fold one job's outcome in.
    pub fn observe(&mut self, r: &JobRecord) {
        self.jobs_seen += 1;
        let tenant = self.tenants.entry(r.tenant.0).or_default();
        match r.preemptions {
            0 => {}
            1 => {
                self.preempt_hist[0] += 1;
                self.preempted += 1;
                tenant.preempted += 1;
            }
            2 => {
                self.preempt_hist[1] += 1;
                self.preempted += 1;
                tenant.preempted += 1;
            }
            _ => {
                self.preempt_hist[2] += 1;
                self.preempted += 1;
                tenant.preempted += 1;
            }
        }
        for iv in &r.resched_intervals {
            self.intervals.insert(*iv as f64);
        }
        if r.finished_at.is_some() {
            self.completed += 1;
            tenant.completed.bump(r.class);
            self.slowdown.get_mut(r.class).insert(r.slowdown);
            tenant.slowdown.get_mut(r.class).insert(r.slowdown);
        } else {
            self.unfinished += 1;
            tenant.unfinished += 1;
        }
    }

    /// Fold one cancelled job in: only the per-class cancellation
    /// counters (global and tenant) move. Slowdown percentiles, the
    /// preemption histogram, and `jobs_seen` deliberately exclude
    /// cancelled jobs — a scenario that kills impatient TE jobs must not
    /// skew the Table 1 layout.
    pub fn observe_cancelled(&mut self, r: &JobRecord) {
        debug_assert!(r.cancelled && r.finished_at.is_none());
        self.cancelled.bump(r.class);
        self.tenants.entry(r.tenant.0).or_default().cancelled.bump(r.class);
    }

    /// Total cancellations across both classes.
    pub fn cancelled_total(&self) -> u64 {
        self.cancelled.total()
    }

    /// Fold another sink in (order-independent for every reported value).
    pub fn merge(&mut self, other: &StreamingMetrics) {
        self.slowdown.merge_with(&other.slowdown, |a, b| a.merge(b));
        self.intervals.merge(&other.intervals);
        self.jobs_seen += other.jobs_seen;
        self.completed += other.completed;
        self.unfinished += other.unfinished;
        for (a, b) in self.preempt_hist.iter_mut().zip(&other.preempt_hist) {
            *a += *b;
        }
        self.preempted += other.preempted;
        self.cancelled.merge_with(&other.cancelled, |a, b| *a += *b);
        for (t, m) in &other.tenants {
            self.tenants.entry(*t).or_default().merge(m);
        }
    }

    /// Sketch-backed slowdown report (Table 1 / Table 5 row).
    pub fn slowdown_report(&self) -> SlowdownReport {
        SlowdownReport {
            te: Percentiles::from_sketch(&self.slowdown.te),
            be: Percentiles::from_sketch(&self.slowdown.be),
        }
    }

    /// Sketch-backed re-scheduling-interval report (Table 2 row).
    pub fn intervals_report(&self) -> IntervalsReport {
        IntervalsReport {
            p50: self.intervals.percentile(50.0),
            p75: self.intervals.percentile(75.0),
            p95: self.intervals.percentile(95.0),
            p99: self.intervals.percentile(99.0),
            count: self.intervals.count() as usize,
        }
    }

    /// Exact preemption report (counters, not sketches — identical to the
    /// record-based computation).
    pub fn preemption_report(&self) -> PreemptionReport {
        let n = self.jobs_seen.max(1) as f64;
        PreemptionReport {
            fraction_preempted: if self.jobs_seen == 0 {
                0.0
            } else {
                self.preempted as f64 / n
            },
            hist: [
                self.preempt_hist[0] as f64 / n,
                self.preempt_hist[1] as f64 / n,
                self.preempt_hist[2] as f64 / n,
            ],
        }
    }

    /// Machine-readable dump.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("jobs_seen", Json::num(self.jobs_seen as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("unfinished", Json::num(self.unfinished as f64)),
            ("te_slowdown", self.slowdown.te.to_json()),
            ("be_slowdown", self.slowdown.be.to_json()),
            ("intervals", self.intervals.to_json()),
            ("preempted", Json::num(self.preempted as f64)),
            (
                "cancelled",
                Json::obj(vec![
                    ("te", Json::num(self.cancelled.te as f64)),
                    ("be", Json::num(self.cancelled.be as f64)),
                ]),
            ),
            ("tenants", self.tenants_json()),
        ])
    }

    /// Serialize the full sink for a snapshot (sketches travel bit-exact,
    /// so a restored run's reports match the uninterrupted run's exactly).
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        self.slowdown.te.snapshot_bin(w);
        self.slowdown.be.snapshot_bin(w);
        self.intervals.snapshot_bin(w);
        w.u64(self.jobs_seen);
        w.u64(self.completed);
        w.u64(self.unfinished);
        for h in &self.preempt_hist {
            w.u64(*h);
        }
        w.u64(self.preempted);
        w.u64(self.cancelled.te);
        w.u64(self.cancelled.be);
        w.seq(self.tenants.len());
        for (t, m) in &self.tenants {
            w.u32(*t);
            m.snapshot_bin(w);
        }
    }

    /// Rebuild a sink written by [`StreamingMetrics::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let slowdown = ClassKeyed {
            te: QuantileSketch::restore_bin(r)?,
            be: QuantileSketch::restore_bin(r)?,
        };
        let intervals = QuantileSketch::restore_bin(r)?;
        let jobs_seen = r.u64()?;
        let completed = r.u64()?;
        let unfinished = r.u64()?;
        let preempt_hist = [r.u64()?, r.u64()?, r.u64()?];
        let preempted = r.u64()?;
        let cancelled = ClassKeyed { te: r.u64()?, be: r.u64()? };
        let mut tenants = BTreeMap::new();
        for _ in 0..r.seq()? {
            let t = r.u32()?;
            tenants.insert(t, TenantMetrics::restore_bin(r)?);
        }
        Ok(StreamingMetrics {
            slowdown,
            intervals,
            jobs_seen,
            completed,
            unfinished,
            preempt_hist,
            preempted,
            cancelled,
            tenants,
        })
    }

    /// The per-tenant map as a JSON object keyed by tenant id.
    pub fn tenants_json(&self) -> Json {
        Json::Obj(
            self.tenants
                .iter()
                .map(|(t, m)| (t.to_string(), m.to_json()))
                .collect(),
        )
    }
}

/// Render the per-tenant fairness table (one row per tenant): job counts
/// and per-class slowdown percentiles from the tenant sub-sinks.
pub fn tenant_table(title: &str, tenants: &BTreeMap<u32, TenantMetrics>) -> Table {
    let mut t = Table::new(
        title,
        &[
            "tenant", "jobs", "TE 50th", "TE 95th", "TE 99th", "BE 50th", "BE 95th", "BE 99th",
            "cancelled",
        ],
    );
    for (id, m) in tenants {
        let r = m.slowdown_report();
        t.row(vec![
            format!("tenant-{id}"),
            m.jobs_seen().to_string(),
            sig3(r.te.p50),
            sig3(r.te.p95),
            sig3(r.te.p99),
            sig3(r.be.p50),
            sig3(r.be.p95),
            sig3(r.be.p99),
            m.cancelled.total().to_string(),
        ]);
    }
    t
}

/// Render the paper's Table-1 layout for a set of runs (one row per
/// policy).
pub fn slowdown_table(title: &str, rows: &[(&str, SlowdownReport)]) -> Table {
    let mut t = Table::new(
        title,
        &["policy", "TE 50th", "TE 95th", "TE 99th", "BE 50th", "BE 95th", "BE 99th"],
    );
    for (name, r) in rows {
        t.row(vec![
            name.to_string(),
            sig3(r.te.p50),
            sig3(r.te.p95),
            sig3(r.te.p99),
            sig3(r.be.p50),
            sig3(r.be.p95),
            sig3(r.be.p99),
        ]);
    }
    t
}

/// Render the paper's Table-2 layout.
pub fn intervals_table(title: &str, rows: &[(&str, IntervalsReport)]) -> Table {
    let mut t = Table::new(title, &["policy", "50th", "75th", "95th", "99th", "n"]);
    for (name, r) in rows {
        t.row(vec![
            name.to_string(),
            sig3(r.p50),
            sig3(r.p75),
            sig3(r.p95),
            sig3(r.p99),
            r.count.to_string(),
        ]);
    }
    t
}

/// Render the paper's Table-3 layout (percentage form, e.g. `6.3e-1%`).
pub fn preempted_table(title: &str, rows: &[(&str, PreemptionReport)]) -> Table {
    let mut t = Table::new(title, &["policy", "preempted jobs"]);
    for (name, r) in rows {
        t.row(vec![name.to_string(), format!("{}%", sig3(r.fraction_preempted * 100.0))]);
    }
    t
}

/// Render the paper's Table-4 layout.
pub fn preempt_hist_table(title: &str, rows: &[(&str, PreemptionReport)]) -> Table {
    let mut t = Table::new(title, &["policy", "1", "2", ">=3"]);
    for (name, r) in rows {
        t.row(vec![
            name.to_string(),
            format!("{}%", sig3(r.hist[0] * 100.0)),
            format!("{}%", sig3(r.hist[1] * 100.0)),
            format!("{}%", sig3(r.hist[2] * 100.0)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_data() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::of(&xs);
        assert!((p.p50 - 50.5).abs() < 1e-9);
        assert!((p.p95 - 95.05).abs() < 1e-9);
        assert!((p.p99 - 99.01).abs() < 1e-9);
    }

    #[test]
    fn empty_percentiles_are_nan() {
        let p = Percentiles::of(&[]);
        assert!(p.p50.is_nan());
    }

    #[test]
    fn tables_render_rows() {
        let r = SlowdownReport {
            te: Percentiles { p50: 1.0, p95: 1.15, p99: 1.54 },
            be: Percentiles { p50: 3.28, p95: 6.06, p99: 10.3 },
        };
        let t = slowdown_table("Table 1", &[("FitGpp (s=4.0)", r)]);
        let text = t.to_text();
        assert!(text.contains("FitGpp"));
        assert!(text.contains("10.3"));
    }

    #[test]
    fn class_keyed_counters_and_tenant_map() {
        use crate::job::{JobId, TenantId};
        use crate::resources::ResourceVec;
        let rec = |id: u32, class: JobClass, tenant: u32, finished: bool| JobRecord {
            id: JobId(id),
            class,
            demand: ResourceVec::new(1.0, 1.0, 0.0),
            submit: 0,
            exec_time: 10,
            grace_period: 0,
            first_start: Some(0),
            finished_at: if finished { Some(10) } else { None },
            preemptions: 0,
            evictions: 0,
            resched_intervals: Vec::new(),
            slowdown: 1.0,
            cancelled: false,
            tenant: TenantId(tenant),
        };
        let mut sink = StreamingMetrics::new();
        sink.observe(&rec(0, JobClass::Te, 0, true));
        sink.observe(&rec(1, JobClass::Be, 1, true));
        sink.observe(&rec(2, JobClass::Be, 1, false));
        let mut cancelled = rec(3, JobClass::Te, 1, false);
        cancelled.cancelled = true;
        sink.observe_cancelled(&cancelled);

        assert_eq!(sink.jobs_seen, 3);
        assert_eq!(sink.cancelled.te, 1);
        assert_eq!(sink.cancelled_total(), 1);
        assert_eq!(sink.tenants.len(), 2);
        let t1 = &sink.tenants[&1];
        assert_eq!(t1.completed.be, 1);
        assert_eq!(t1.unfinished, 1);
        assert_eq!(t1.cancelled.te, 1);
        assert_eq!(t1.jobs_seen(), 2);
        assert_eq!(sink.tenants[&0].completed.te, 1);

        // Keywise merge: tenant slices pool like the global sketches.
        let mut other = StreamingMetrics::new();
        other.observe(&rec(4, JobClass::Be, 1, true));
        sink.merge(&other);
        assert_eq!(sink.tenants[&1].completed.be, 2);
        assert_eq!(sink.completed, 4);

        // Rendering: one row per tenant, json roundtrips.
        let table = tenant_table("fairness", &sink.tenants);
        assert!(table.to_text().contains("tenant-1"));
        let j = sink.to_json().to_pretty();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("tenants").get("1").get("completed").as_u64(), Some(2));
    }

    #[test]
    fn streaming_metrics_snapshot_round_trips() {
        use crate::job::{JobId, TenantId};
        use crate::resources::ResourceVec;
        let rec = |id: u32, class: JobClass, tenant: u32, finished: bool| JobRecord {
            id: JobId(id),
            class,
            demand: ResourceVec::new(1.0, 1.0, 0.0),
            submit: 0,
            exec_time: 10,
            grace_period: 0,
            first_start: Some(0),
            finished_at: if finished { Some(10) } else { None },
            preemptions: (id % 4),
            evictions: 0,
            resched_intervals: vec![3, 7],
            slowdown: 1.0 + id as f64 * 0.13,
            cancelled: false,
            tenant: TenantId(tenant),
        };
        let mut sink = StreamingMetrics::new();
        for i in 0..25u32 {
            sink.observe(&rec(i, if i % 3 == 0 { JobClass::Te } else { JobClass::Be }, i % 3, i % 5 != 0));
        }
        let mut cancelled = rec(99, JobClass::Te, 1, false);
        cancelled.cancelled = true;
        sink.observe_cancelled(&cancelled);

        let mut w = BinWriter::new();
        sink.snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        let back = StreamingMetrics::restore_bin(&mut r).unwrap();
        r.expect_end().unwrap();
        // PartialEq covers every field including the sketches.
        assert_eq!(back, sink);
        assert_eq!(
            back.slowdown_report().be.p95.to_bits(),
            sink.slowdown_report().be.p95.to_bits(),
            "sketch percentiles are bit-exact"
        );
    }

    #[test]
    fn preempted_table_uses_percent() {
        let r = PreemptionReport { fraction_preempted: 0.0063, hist: [0.0052, 0.00038, 0.000098] };
        let t = preempted_table("Table 3", &[("FitGpp", r)]);
        assert!(t.to_text().contains("0.63%"));
        let h = preempt_hist_table("Table 4", &[("FitGpp", r)]);
        assert!(h.to_text().contains("0.52%"));
    }
}
