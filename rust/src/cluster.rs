//! Cluster and node state: capacities, allocations, and placement search.
//!
//! The paper's evaluation cluster is 84 homogeneous nodes of 32 CPUs /
//! 256 GB RAM / 8 GPUs. We support heterogeneous nodes too (capacities are
//! per-node), since nothing in FitGpp requires homogeneity — Eq. 1
//! normalizes by the *hosting node's* capacity.

use crate::job::JobId;
use crate::resources::ResourceVec;
use std::collections::HashMap;
use std::fmt;

/// Dense node identifier (index into `Cluster::nodes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node-{}", self.0)
    }
}

/// Static description of a cluster (used by configs and generators).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Capacity of each node. Homogeneous clusters repeat one entry.
    pub nodes: Vec<ResourceVec>,
}

impl ClusterSpec {
    /// Homogeneous cluster of `n` nodes with capacity `cap` each.
    pub fn homogeneous(n: usize, cap: ResourceVec) -> Self {
        ClusterSpec { nodes: vec![cap; n] }
    }

    /// The paper's evaluation cluster: 84 × (32 CPU, 256 GB, 8 GPU) — the
    /// private DL-development cluster at the authors' institution (§4.1).
    pub fn pfn() -> Self {
        Self::homogeneous(84, ResourceVec::pfn_node())
    }

    /// A small cluster for tests/examples.
    pub fn tiny(n: usize) -> Self {
        Self::homogeneous(n, ResourceVec::pfn_node())
    }

    /// Total capacity across all nodes.
    pub fn total_capacity(&self) -> ResourceVec {
        self.nodes.iter().fold(ResourceVec::ZERO, |acc, c| acc + *c)
    }
}

/// One node's live state.
#[derive(Debug, Clone)]
pub struct Node {
    /// This node's identifier.
    pub id: NodeId,
    /// Total capacity.
    pub capacity: ResourceVec,
    /// Unallocated resources (the paper's `N` in Eq. 2).
    pub free: ResourceVec,
    /// Jobs currently occupying resources here (Running or Draining), with
    /// their demands. Insertion order is preserved for determinism.
    allocations: Vec<(JobId, ResourceVec)>,
}

impl Node {
    fn new(id: NodeId, capacity: ResourceVec) -> Self {
        Node { id, capacity, free: capacity, allocations: Vec::new() }
    }

    /// Jobs hosted on this node, in allocation order.
    pub fn jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.allocations.iter().map(|(id, _)| *id)
    }

    pub fn num_jobs(&self) -> usize {
        self.allocations.len()
    }

    /// Allocated (capacity - free) resources.
    pub fn used(&self) -> ResourceVec {
        self.capacity - self.free
    }

    fn allocate(&mut self, job: JobId, demand: ResourceVec) {
        debug_assert!(demand.fits_in(&self.free), "oversubscription on {}", self.id);
        self.free -= demand;
        self.allocations.push((job, demand));
    }

    fn release(&mut self, job: JobId) -> ResourceVec {
        let idx = self
            .allocations
            .iter()
            .position(|(id, _)| *id == job)
            .unwrap_or_else(|| panic!("{} not on {}", job, self.id));
        let (_, demand) = self.allocations.remove(idx);
        self.free += demand;
        // Snap tiny FP residue so long simulations never drift.
        if (self.free.cpu - self.capacity.cpu).abs() < 1e-6
            && (self.free.ram_gb - self.capacity.ram_gb).abs() < 1e-6
            && (self.free.gpu - self.capacity.gpu).abs() < 1e-6
        {
            self.free = self.capacity;
        }
        demand
    }
}

/// Placement strategy for the admission step. The paper does not pin one
/// down; best-fit (minimize residual free Size) is the default because it
/// concentrates fragmentation, which is also what makes Eq. 2's
/// single-victim test meaningful. An ablation bench compares them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// First node (lowest id) with room.
    FirstFit,
    /// Node minimizing `Size(free - demand)` after placement.
    BestFit,
    /// Node maximizing residual free Size (spreads load).
    WorstFit,
}

/// Live cluster state: nodes plus a job → node index for O(1) lookup.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    location: HashMap<JobId, NodeId>,
}

impl Cluster {
    pub fn new(spec: &ClusterSpec) -> Self {
        Cluster {
            nodes: spec
                .nodes
                .iter()
                .enumerate()
                .map(|(i, cap)| Node::new(NodeId(i as u32), *cap))
                .collect(),
            location: HashMap::new(),
        }
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    /// Where is `job` hosted?
    pub fn locate(&self, job: JobId) -> Option<NodeId> {
        self.location.get(&job).copied()
    }

    /// Total free resources across nodes (not directly usable for fit tests
    /// — a job must fit on a *single* node — but useful for load metrics).
    pub fn total_free(&self) -> ResourceVec {
        self.nodes.iter().fold(ResourceVec::ZERO, |acc, n| acc + n.free)
    }

    pub fn total_capacity(&self) -> ResourceVec {
        self.nodes.iter().fold(ResourceVec::ZERO, |acc, n| acc + n.capacity)
    }

    /// Find a node for `demand` under `placement`, or `None` if it fits
    /// nowhere. Deterministic: ties break toward the lower node id.
    pub fn find_node(&self, demand: &ResourceVec, placement: Placement) -> Option<NodeId> {
        match placement {
            Placement::FirstFit => self
                .nodes
                .iter()
                .find(|n| demand.fits_in(&n.free))
                .map(|n| n.id),
            Placement::BestFit => self
                .nodes
                .iter()
                .filter(|n| demand.fits_in(&n.free))
                .min_by(|a, b| {
                    let ra = (a.free - *demand).size(&a.capacity);
                    let rb = (b.free - *demand).size(&b.capacity);
                    ra.partial_cmp(&rb).unwrap().then(a.id.cmp(&b.id))
                })
                .map(|n| n.id),
            Placement::WorstFit => self
                .nodes
                .iter()
                .filter(|n| demand.fits_in(&n.free))
                .max_by(|a, b| {
                    let ra = (a.free - *demand).size(&a.capacity);
                    let rb = (b.free - *demand).size(&b.capacity);
                    ra.partial_cmp(&rb).unwrap().then(b.id.cmp(&a.id))
                })
                .map(|n| n.id),
        }
    }

    /// Bind `job` with `demand` on `node`. Panics on oversubscription (the
    /// scheduler must only place after a successful fit test).
    pub fn bind(&mut self, job: JobId, demand: ResourceVec, node: NodeId) {
        assert!(
            self.location.insert(job, node).is_none(),
            "{job} double-bound"
        );
        self.node_mut(node).allocate(job, demand);
    }

    /// Release `job`'s resources. Returns the node it was on.
    pub fn unbind(&mut self, job: JobId) -> NodeId {
        let node = self.location.remove(&job).unwrap_or_else(|| panic!("{job} not bound"));
        self.node_mut(node).release(job);
        node
    }

    /// Invariant check used by tests and the simulator's debug mode:
    /// free ≥ 0, free ≤ capacity, and free + Σ allocations == capacity.
    pub fn check_invariants(&self) -> Result<(), String> {
        for n in &self.nodes {
            if n.free.any_negative() {
                return Err(format!("{}: negative free {}", n.id, n.free));
            }
            if !n.free.fits_in(&n.capacity) {
                return Err(format!("{}: free {} exceeds capacity {}", n.id, n.free, n.capacity));
            }
            let allocated = n
                .allocations
                .iter()
                .fold(ResourceVec::ZERO, |acc, (_, d)| acc + *d);
            let sum = allocated + n.free;
            let diff = sum - n.capacity;
            if diff.cpu.abs() > 1e-6 || diff.ram_gb.abs() > 1e-6 || diff.gpu.abs() > 1e-6 {
                return Err(format!(
                    "{}: conservation violated: alloc {} + free {} != cap {}",
                    n.id, allocated, n.free, n.capacity
                ));
            }
        }
        for (job, node) in &self.location {
            if !self.node(*node).allocations.iter().any(|(id, _)| id == job) {
                return Err(format!("{job} in index but not on {node}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    #[test]
    fn spec_pfn_matches_paper() {
        let s = ClusterSpec::pfn();
        assert_eq!(s.nodes.len(), 84);
        assert_eq!(s.total_capacity(), ResourceVec::new(84.0 * 32.0, 84.0 * 256.0, 84.0 * 8.0));
    }

    #[test]
    fn bind_unbind_roundtrip() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        c.bind(JobId(1), demand(4.0, 32.0, 1.0), NodeId(0));
        assert_eq!(c.locate(JobId(1)), Some(NodeId(0)));
        assert_eq!(c.node(NodeId(0)).free, demand(28.0, 224.0, 7.0));
        c.check_invariants().unwrap();
        let n = c.unbind(JobId(1));
        assert_eq!(n, NodeId(0));
        assert_eq!(c.node(NodeId(0)).free, ResourceVec::pfn_node());
        assert!(c.locate(JobId(1)).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    #[should_panic]
    fn double_bind_panics() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        c.bind(JobId(1), demand(1.0, 1.0, 0.0), NodeId(0));
        c.bind(JobId(1), demand(1.0, 1.0, 0.0), NodeId(1));
    }

    #[test]
    fn first_fit_picks_lowest_id() {
        let mut c = Cluster::new(&ClusterSpec::tiny(3));
        c.bind(JobId(1), demand(32.0, 256.0, 8.0), NodeId(0)); // fill node 0
        let n = c.find_node(&demand(1.0, 1.0, 0.0), Placement::FirstFit);
        assert_eq!(n, Some(NodeId(1)));
    }

    #[test]
    fn best_fit_minimizes_residual() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        // Node 0 is half full; best-fit should prefer it over empty node 1.
        c.bind(JobId(1), demand(16.0, 128.0, 4.0), NodeId(0));
        let n = c.find_node(&demand(8.0, 64.0, 2.0), Placement::BestFit);
        assert_eq!(n, Some(NodeId(0)));
        // Worst-fit spreads instead.
        let n = c.find_node(&demand(8.0, 64.0, 2.0), Placement::WorstFit);
        assert_eq!(n, Some(NodeId(1)));
    }

    #[test]
    fn no_fit_returns_none() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        for (i, node) in [(0u32, NodeId(0)), (1, NodeId(1))] {
            c.bind(JobId(i), demand(30.0, 250.0, 8.0), node);
        }
        assert_eq!(c.find_node(&demand(4.0, 4.0, 1.0), Placement::FirstFit), None);
    }

    #[test]
    fn gpu_axis_blocks_fit_alone() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.bind(JobId(1), demand(1.0, 1.0, 8.0), NodeId(0)); // all GPUs taken
        assert_eq!(c.find_node(&demand(1.0, 1.0, 1.0), Placement::FirstFit), None);
        assert!(c.find_node(&demand(1.0, 1.0, 0.0), Placement::FirstFit).is_some());
    }

    #[test]
    fn invariants_catch_conservation() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.bind(JobId(1), demand(4.0, 4.0, 1.0), NodeId(0));
        c.check_invariants().unwrap();
        // Forcibly corrupt.
        c.nodes[0].free.cpu += 5.0;
        assert!(c.check_invariants().is_err());
    }

    #[test]
    fn heterogeneous_capacities() {
        let spec = ClusterSpec {
            nodes: vec![ResourceVec::new(8.0, 64.0, 0.0), ResourceVec::new(32.0, 256.0, 8.0)],
        };
        let c = Cluster::new(&spec);
        // A GPU job can only land on node 1.
        assert_eq!(
            c.find_node(&demand(1.0, 1.0, 1.0), Placement::FirstFit),
            Some(NodeId(1))
        );
    }
}
