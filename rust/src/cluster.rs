//! Cluster and node state: capacities, allocations, reservation holds, and
//! the incremental free-capacity index behind placement search.
//!
//! The paper's evaluation cluster is 84 homogeneous nodes of 32 CPUs /
//! 256 GB RAM / 8 GPUs. We support heterogeneous nodes too (capacities are
//! per-node), since nothing in FitGpp requires homogeneity — Eq. 1
//! normalizes by the *hosting node's* capacity.
//!
//! ## The free-capacity index
//!
//! Admission asks two questions thousands of times per simulated run:
//! *does this demand fit anywhere?* and *which node hosts it under the
//! placement rule?* The seed implementation answered both with an O(nodes)
//! scan per query. The index answers them incrementally — it is updated on
//! every [`bind`](Cluster::bind) / [`unbind`](Cluster::unbind) /
//! [`reserve`](Cluster::reserve) / [`unreserve`](Cluster::unreserve)
//! (O(log nodes) each, far rarer than queries) and offers:
//!
//! * [`Cluster::fits_nowhere`] — per-axis maxima of *effective* free
//!   (free − hold) across nodes. If the demand exceeds the max on any axis
//!   no node can fit it: an O(1) reject, which is the common case on a
//!   saturated cluster (§4.2 runs at FIFO load 2.0).
//! * [`Cluster::fit_candidates`] — nodes ordered by the Eq. 1 `Size` of
//!   their effective free space, range-pruned from below: componentwise
//!   fit implies `Size(demand) ≤ Size(effective free)` (Size is monotone),
//!   so nodes too full to matter are skipped without being visited.
//!
//! Both are *sound over-approximations*: they never hide a fitting node,
//! so placement decisions are identical to the full scan. Because both
//! simulator drive modes share this index, engine equivalence alone cannot
//! catch an unsound prune — the randomized property
//! `prop_capacity_index_never_hides_a_fitting_node`
//! (`rust/tests/properties.rs`) checks it against a linear scan directly.

use crate::job::JobId;
use crate::resources::ResourceVec;
use crate::util::bin::{BinReader, BinWriter};
use anyhow::bail;
use std::collections::{BTreeSet, HashMap};
use std::fmt;

/// Dense node identifier (index into `Cluster::nodes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node-{}", self.0)
    }
}

/// Cluster-state inconsistencies surfaced as typed errors instead of
/// panics, so a corrupt input (e.g. a malformed trace driving the
/// scheduler into an impossible release) degrades one operation rather
/// than aborting a whole sweep.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    /// The job is not bound anywhere.
    NotBound(JobId),
    /// The location index says the job is on a node whose allocation list
    /// disagrees (index corruption).
    NotOnNode(JobId, NodeId),
    /// A resize would shrink the node below its current allocations.
    CapacityBelowUse(NodeId),
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::NotBound(job) => write!(f, "{job} is not bound to any node"),
            ClusterError::NotOnNode(job, node) => {
                write!(f, "{job} indexed on {node} but absent from its allocations")
            }
            ClusterError::CapacityBelowUse(node) => {
                write!(f, "{node} cannot shrink below its current allocations")
            }
        }
    }
}

/// Control-plane availability of a node. Only `Up` nodes accept new
/// placements; the free-capacity index reports non-`Up` nodes as having
/// zero effective free space, so every placement and preemption-planning
/// path excludes them without special-casing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeAvailability {
    /// Healthy: schedulable.
    #[default]
    Up,
    /// Draining for maintenance: hosted jobs run to completion, but no new
    /// placement may land here.
    Draining,
    /// Failed / removed: hosts nothing (the scheduler evicts hosted jobs
    /// when it marks a node down) and accepts nothing.
    Down,
}

impl std::error::Error for ClusterError {}

/// Static description of a cluster (used by configs and generators).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Capacity of each node. Homogeneous clusters repeat one entry.
    pub nodes: Vec<ResourceVec>,
}

impl ClusterSpec {
    /// Homogeneous cluster of `n` nodes with capacity `cap` each.
    pub fn homogeneous(n: usize, cap: ResourceVec) -> Self {
        ClusterSpec { nodes: vec![cap; n] }
    }

    /// The paper's evaluation cluster: 84 × (32 CPU, 256 GB, 8 GPU) — the
    /// private DL-development cluster at the authors' institution (§4.1).
    pub fn pfn() -> Self {
        Self::homogeneous(84, ResourceVec::pfn_node())
    }

    /// A small cluster for tests/examples.
    pub fn tiny(n: usize) -> Self {
        Self::homogeneous(n, ResourceVec::pfn_node())
    }

    /// The live-demo cluster preset: `n` small nodes sized for the PJRT
    /// worker threads the live executor actually spawns (8 CPU, 64 GB,
    /// 4 GPU each). `LiveConfig::demo` and the `fitgpp live --nodes N` CLI
    /// path both route through this.
    pub fn live_demo(n: usize) -> Self {
        Self::homogeneous(n, ResourceVec::new(8.0, 64.0, 4.0))
    }

    /// Total capacity across all nodes.
    pub fn total_capacity(&self) -> ResourceVec {
        self.nodes.iter().fold(ResourceVec::ZERO, |acc, c| acc + *c)
    }
}

/// One node's live state.
#[derive(Debug, Clone)]
pub struct Node {
    /// This node's identifier.
    pub id: NodeId,
    /// Total capacity.
    pub capacity: ResourceVec,
    /// Unallocated resources (the paper's `N` in Eq. 2).
    pub free: ResourceVec,
    /// Control-plane availability (Up / Draining / Down).
    pub availability: NodeAvailability,
    /// Reservation holds pinned here by the scheduler (space drained for an
    /// incoming TE job, invisible to other placements).
    hold: ResourceVec,
    /// Jobs currently occupying resources here (Running or Draining), with
    /// their demands. Insertion order is preserved for determinism.
    allocations: Vec<(JobId, ResourceVec)>,
}

impl Node {
    fn new(id: NodeId, capacity: ResourceVec) -> Self {
        Node {
            id,
            capacity,
            free: capacity,
            availability: NodeAvailability::Up,
            hold: ResourceVec::ZERO,
            allocations: Vec::new(),
        }
    }

    /// May new placements land here? Only `Up` nodes are schedulable.
    pub fn is_schedulable(&self) -> bool {
        self.availability == NodeAvailability::Up
    }

    /// Jobs hosted on this node, in allocation order.
    pub fn jobs(&self) -> impl Iterator<Item = JobId> + '_ {
        self.allocations.iter().map(|(id, _)| *id)
    }

    /// Number of jobs hosted here.
    pub fn num_jobs(&self) -> usize {
        self.allocations.len()
    }

    /// Allocated (capacity - free) resources.
    pub fn used(&self) -> ResourceVec {
        self.capacity - self.free
    }

    /// Sum of reservation holds pinned to this node.
    pub fn hold(&self) -> ResourceVec {
        self.hold
    }

    /// Free space actually available to new placements: free minus holds,
    /// clamped at zero (a hold may exceed free while its victims drain).
    /// A non-`Up` node reports zero — Draining/Down nodes accept no
    /// placements, and routing that fact through this one accessor keeps
    /// the capacity index, the admission paths, and every preemption
    /// policy's cluster view consistent.
    pub fn effective_free(&self) -> ResourceVec {
        if !self.is_schedulable() {
            return ResourceVec::ZERO;
        }
        self.free.saturating_sub(&self.hold)
    }

    fn allocate(&mut self, job: JobId, demand: ResourceVec) {
        debug_assert!(demand.fits_in(&self.free), "oversubscription on {}", self.id);
        self.free -= demand;
        self.allocations.push((job, demand));
    }

    fn release(&mut self, job: JobId) -> Result<ResourceVec, ClusterError> {
        let idx = self
            .allocations
            .iter()
            .position(|(id, _)| *id == job)
            .ok_or_else(|| ClusterError::NotOnNode(job, self.id))?;
        let (_, demand) = self.allocations.remove(idx);
        self.free += demand;
        // Snap tiny FP residue so long simulations never drift.
        if (self.free.cpu - self.capacity.cpu).abs() < 1e-6
            && (self.free.ram_gb - self.capacity.ram_gb).abs() < 1e-6
            && (self.free.gpu - self.capacity.gpu).abs() < 1e-6
        {
            self.free = self.capacity;
        }
        Ok(demand)
    }
}

/// Placement strategy for the admission step. The paper does not pin one
/// down; best-fit (minimize residual free Size) is the default because it
/// concentrates fragmentation, which is also what makes Eq. 2's
/// single-victim test meaningful. An ablation bench compares them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// First node (lowest id) with room.
    FirstFit,
    /// Node minimizing `Size(free - demand)` after placement.
    BestFit,
    /// Node maximizing residual free Size (spreads load).
    WorstFit,
}

/// Map a non-negative `f64` to order-preserving bits (clamping the tiny
/// negative residue FP arithmetic can leave) for use as a BTreeSet key.
fn key_bits(x: f64) -> u64 {
    x.max(0.0).to_bits()
}

/// Slack subtracted from the Size lower bound in [`Cluster::fit_candidates`]
/// so the `fits_in` EPS tolerance can never push a fitting node below the
/// range cut.
const SIZE_SLACK: f64 = 1e-6;

/// The incremental free-capacity index: every node keyed by the Eq. 1
/// `Size` of its effective free space, plus per-axis orderings for the
/// componentwise-maximum reject. `keys` remembers exactly what was inserted
/// per node so updates remove the right entries bit-for-bit.
#[derive(Debug, Clone, Default)]
struct FreeIndex {
    by_size: BTreeSet<(u64, u32)>,
    by_axis: [BTreeSet<(u64, u32)>; 3],
    keys: Vec<[u64; 4]>, // [size, cpu, ram, gpu] bits per node
    /// Σ effective free across all nodes, maintained alongside the per-node
    /// keys (an O(1) read for the planner's pre-plan reject bound). Derived
    /// from the remembered key bits so insert/remove stay exactly paired.
    eff_total: ResourceVec,
}

impl FreeIndex {
    fn new(nodes: &[Node]) -> Self {
        let mut ix = FreeIndex { keys: vec![[0; 4]; nodes.len()], ..Default::default() };
        for n in nodes {
            ix.insert(n);
        }
        ix
    }

    fn node_keys(node: &Node) -> [u64; 4] {
        let eff = node.effective_free();
        [
            key_bits(eff.size(&node.capacity)),
            key_bits(eff.cpu),
            key_bits(eff.ram_gb),
            key_bits(eff.gpu),
        ]
    }

    fn insert(&mut self, node: &Node) {
        let k = Self::node_keys(node);
        let id = node.id.0;
        self.by_size.insert((k[0], id));
        for (axis, set) in self.by_axis.iter_mut().enumerate() {
            set.insert((k[axis + 1], id));
        }
        self.keys[id as usize] = k;
        self.eff_total += Self::keys_to_eff(&k);
    }

    fn remove(&mut self, id: NodeId) {
        let k = self.keys[id.0 as usize];
        self.by_size.remove(&(k[0], id.0));
        for (axis, set) in self.by_axis.iter_mut().enumerate() {
            set.remove(&(k[axis + 1], id.0));
        }
        self.eff_total -= Self::keys_to_eff(&k);
    }

    /// The effective-free vector a node's remembered keys encode
    /// (effective free is clamped at zero before keying, so this is exact).
    fn keys_to_eff(k: &[u64; 4]) -> ResourceVec {
        ResourceVec::new(
            f64::from_bits(k[1]),
            f64::from_bits(k[2]),
            f64::from_bits(k[3]),
        )
    }

    fn update(&mut self, node: &Node) {
        self.remove(node.id);
        self.insert(node);
    }

    /// Componentwise maximum of effective free across all nodes.
    fn max_effective_free(&self) -> ResourceVec {
        let axis_max = |axis: usize| {
            self.by_axis[axis]
                .iter()
                .next_back()
                .map(|(bits, _)| f64::from_bits(*bits))
                .unwrap_or(0.0)
        };
        ResourceVec::new(axis_max(0), axis_max(1), axis_max(2))
    }
}

/// Live cluster state: nodes, a job → node index for O(1) lookup, and the
/// incremental free-capacity index for placement queries.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Per-node live state.
    pub nodes: Vec<Node>,
    location: HashMap<JobId, NodeId>,
    index: FreeIndex,
    /// Componentwise maximum node capacity — normalizer giving a lower
    /// bound on `Size(demand, any node capacity)` for the range prune.
    max_capacity: ResourceVec,
    /// Σ node capacity, cached at construction and refreshed on resize —
    /// the planner reads it once per victim loop, so the per-call fold was
    /// pure waste.
    total_capacity: ResourceVec,
}

impl Cluster {
    /// Build a cluster from its spec (all nodes empty).
    pub fn new(spec: &ClusterSpec) -> Self {
        let nodes: Vec<Node> = spec
            .nodes
            .iter()
            .enumerate()
            .map(|(i, cap)| Node::new(NodeId(i as u32), *cap))
            .collect();
        let index = FreeIndex::new(&nodes);
        let max_capacity = spec.nodes.iter().fold(ResourceVec::ZERO, |acc, c| acc.max(c));
        let total_capacity = spec.nodes.iter().fold(ResourceVec::ZERO, |acc, c| acc + *c);
        Cluster { nodes, location: HashMap::new(), index, max_capacity, total_capacity }
    }

    /// Shared view of one node.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    /// Mutable view of one node. Callers that change `free` must go through
    /// [`Cluster::bind`]/[`Cluster::unbind`] instead, or the capacity index
    /// goes stale ([`Cluster::check_invariants`] detects that).
    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    /// Where is `job` hosted?
    pub fn locate(&self, job: JobId) -> Option<NodeId> {
        self.location.get(&job).copied()
    }

    /// Total free resources across nodes (not directly usable for fit tests
    /// — a job must fit on a *single* node — but useful for load metrics).
    pub fn total_free(&self) -> ResourceVec {
        self.nodes.iter().fold(ResourceVec::ZERO, |acc, n| acc + n.free)
    }

    /// Total capacity across nodes (cached; refreshed on resize).
    pub fn total_capacity(&self) -> ResourceVec {
        self.total_capacity
    }

    /// Total *effective* free across nodes, maintained incrementally by the
    /// capacity index — O(1), unlike summing [`Node::effective_free`] per
    /// call. Non-`Up` nodes contribute zero. Feeds the preemption planner's
    /// pre-plan reject: a demand exceeding `total_effective_free +
    /// preemptible demand` cannot be planned even by evicting everything.
    pub fn total_effective_free(&self) -> ResourceVec {
        self.index.eff_total
    }

    /// Componentwise maximum node capacity (cached at construction; node
    /// capacities are immutable). A demand that does not fit this vector
    /// fits no node under any circumstances.
    pub fn max_capacity(&self) -> ResourceVec {
        self.max_capacity
    }

    /// O(1) saturation reject: true when `demand` exceeds the componentwise
    /// maximum *effective* free across all nodes — no node can fit it, with
    /// or without placement preferences. False means "some node might".
    pub fn fits_nowhere(&self, demand: &ResourceVec) -> bool {
        !demand.fits_in(&self.index.max_effective_free())
    }

    /// Nodes whose effective-free `Size` is large enough that `demand`
    /// could componentwise fit, ascending by `(Size, id)`. A sound
    /// over-approximation of the fitting set: callers still run
    /// `fits_in` per candidate, but nodes too full to matter are never
    /// visited.
    pub fn fit_candidates(&self, demand: &ResourceVec) -> impl Iterator<Item = NodeId> + '_ {
        let lower = (demand.size(&self.max_capacity) - SIZE_SLACK).max(0.0);
        self.index
            .by_size
            .range((key_bits(lower), 0)..)
            .map(|(_, id)| NodeId(*id))
    }

    /// Find a node for `demand` under `placement` considering **raw free**
    /// space (reservation holds ignored; non-`Up` nodes excluded), or
    /// `None` if it fits nowhere. Deterministic: ties break toward the
    /// lower node id. The scheduler's hold-aware search lives in
    /// `sched::core`; this entry point serves diagnostics and setup code.
    pub fn find_node(&self, demand: &ResourceVec, placement: Placement) -> Option<NodeId> {
        match placement {
            Placement::FirstFit => self
                .nodes
                .iter()
                .find(|n| n.is_schedulable() && demand.fits_in(&n.free))
                .map(|n| n.id),
            Placement::BestFit => self
                .nodes
                .iter()
                .filter(|n| n.is_schedulable() && demand.fits_in(&n.free))
                .min_by(|a, b| {
                    let ra = (a.free - *demand).size(&a.capacity);
                    let rb = (b.free - *demand).size(&b.capacity);
                    ra.partial_cmp(&rb).unwrap().then(a.id.cmp(&b.id))
                })
                .map(|n| n.id),
            Placement::WorstFit => self
                .nodes
                .iter()
                .filter(|n| n.is_schedulable() && demand.fits_in(&n.free))
                .max_by(|a, b| {
                    let ra = (a.free - *demand).size(&a.capacity);
                    let rb = (b.free - *demand).size(&b.capacity);
                    ra.partial_cmp(&rb).unwrap().then(b.id.cmp(&a.id))
                })
                .map(|n| n.id),
        }
    }

    /// Bind `job` with `demand` on `node`. Panics on oversubscription (the
    /// scheduler must only place after a successful fit test).
    pub fn bind(&mut self, job: JobId, demand: ResourceVec, node: NodeId) {
        assert!(
            self.location.insert(job, node).is_none(),
            "{job} double-bound"
        );
        self.nodes[node.0 as usize].allocate(job, demand);
        self.index.update(&self.nodes[node.0 as usize]);
    }

    /// Release `job`'s resources. Returns the node it was on, or a typed
    /// error when the job is not bound (the caller decides whether that is
    /// fatal — the scheduler treats it as an internal inconsistency).
    pub fn unbind(&mut self, job: JobId) -> Result<NodeId, ClusterError> {
        let node = self
            .location
            .get(&job)
            .copied()
            .ok_or_else(|| ClusterError::NotBound(job))?;
        self.nodes[node.0 as usize].release(job)?;
        self.location.remove(&job);
        self.index.update(&self.nodes[node.0 as usize]);
        Ok(node)
    }

    /// Pin `amount` of `node`'s space for an incoming reservation: invisible
    /// to placements until [`Cluster::unreserve`]d. May exceed current free
    /// (the held space materializes as victims drain).
    pub fn reserve(&mut self, node: NodeId, amount: ResourceVec) {
        let n = &mut self.nodes[node.0 as usize];
        n.hold += amount;
        self.index.update(&self.nodes[node.0 as usize]);
    }

    /// Release `amount` of reservation hold on `node` (clamped at zero).
    pub fn unreserve(&mut self, node: NodeId, amount: ResourceVec) {
        let n = &mut self.nodes[node.0 as usize];
        n.hold = n.hold.saturating_sub(&amount);
        self.index.update(&self.nodes[node.0 as usize]);
    }

    /// Change `node`'s control-plane availability and refresh its index
    /// entry (a non-`Up` node indexes at zero effective free, so the O(1)
    /// saturation reject and the candidate range both exclude it).
    pub fn set_availability(&mut self, node: NodeId, availability: NodeAvailability) {
        self.nodes[node.0 as usize].availability = availability;
        self.index.update(&self.nodes[node.0 as usize]);
    }

    /// Release every allocation on `node` at once (node failure). Returns
    /// the evicted jobs in allocation order — deterministic, so requeue
    /// order (and therefore every downstream scheduling decision) is
    /// reproducible. The caller owns the job-side transitions.
    pub fn evict_all(&mut self, node: NodeId) -> Vec<JobId> {
        let ids: Vec<JobId> = self.nodes[node.0 as usize].jobs().collect();
        for id in &ids {
            self.nodes[node.0 as usize]
                .release(*id)
                .expect("allocation list is authoritative");
            self.location.remove(id);
        }
        self.index.update(&self.nodes[node.0 as usize]);
        ids
    }

    /// Change `node`'s capacity (elastic cluster resize). Fails with
    /// [`ClusterError::CapacityBelowUse`] if current allocations would no
    /// longer fit; otherwise free space and the capacity index (whose keys
    /// normalize by the node's own capacity) are recomputed, as is the
    /// cached cluster-wide maximum capacity.
    pub fn resize(&mut self, node: NodeId, capacity: ResourceVec) -> Result<(), ClusterError> {
        let n = &mut self.nodes[node.0 as usize];
        let used = n.used();
        if !used.fits_in(&capacity) {
            return Err(ClusterError::CapacityBelowUse(node));
        }
        n.capacity = capacity;
        n.free = capacity - used;
        self.max_capacity = self
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, n| acc.max(&n.capacity));
        self.total_capacity = self
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, n| acc + n.capacity);
        self.index.update(&self.nodes[node.0 as usize]);
        Ok(())
    }

    /// Serialize the per-node live state for a snapshot: capacity, free
    /// (bit-exact — [`Node::release`] snaps FP residue, so recomputing free
    /// on restore could diverge), availability, reservation holds, and the
    /// allocation lists in order. The derived structures — the job→node
    /// `location` map, the free-capacity index, and the cached capacity
    /// aggregates — are *not* written; [`Cluster::restore_bin`] rebuilds
    /// them, and [`Cluster::check_invariants`] cross-checks the rebuild.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.seq(self.nodes.len());
        for n in &self.nodes {
            n.capacity.snapshot_bin(w);
            n.free.snapshot_bin(w);
            w.u8(match n.availability {
                NodeAvailability::Up => 0,
                NodeAvailability::Draining => 1,
                NodeAvailability::Down => 2,
            });
            n.hold.snapshot_bin(w);
            w.seq(n.allocations.len());
            for (job, demand) in &n.allocations {
                w.u32(job.0);
                demand.snapshot_bin(w);
            }
        }
    }

    /// Rebuild a cluster written by [`Cluster::snapshot_bin`], rederiving
    /// the location map, the free-capacity index, and the cached capacity
    /// aggregates from the node state.
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let n_nodes = r.seq()?;
        let mut nodes = Vec::with_capacity(n_nodes);
        let mut location = HashMap::new();
        for i in 0..n_nodes {
            let id = NodeId(i as u32);
            let capacity = ResourceVec::restore_bin(r)?;
            let free = ResourceVec::restore_bin(r)?;
            let availability = match r.u8()? {
                0 => NodeAvailability::Up,
                1 => NodeAvailability::Draining,
                2 => NodeAvailability::Down,
                other => bail!("snapshot corrupt: node availability tag {other}"),
            };
            let hold = ResourceVec::restore_bin(r)?;
            let n_allocs = r.seq()?;
            let mut allocations = Vec::with_capacity(n_allocs);
            for _ in 0..n_allocs {
                let job = JobId(r.u32()?);
                let demand = ResourceVec::restore_bin(r)?;
                if location.insert(job, id).is_some() {
                    bail!("snapshot corrupt: {job} allocated on two nodes");
                }
                allocations.push((job, demand));
            }
            nodes.push(Node { id, capacity, free, availability, hold, allocations });
        }
        let index = FreeIndex::new(&nodes);
        let max_capacity = nodes.iter().fold(ResourceVec::ZERO, |acc, n| acc.max(&n.capacity));
        let total_capacity = nodes.iter().fold(ResourceVec::ZERO, |acc, n| acc + n.capacity);
        let cluster = Cluster { nodes, location, index, max_capacity, total_capacity };
        if let Err(e) = cluster.check_invariants() {
            bail!("snapshot corrupt: restored cluster fails invariants: {e}");
        }
        Ok(cluster)
    }

    /// Invariant check used by tests and the simulator's debug mode:
    /// free ≥ 0, free ≤ capacity, free + Σ allocations == capacity, the
    /// location index matches the per-node allocation lists, and the
    /// capacity index agrees with recomputed per-node keys.
    pub fn check_invariants(&self) -> Result<(), String> {
        for n in &self.nodes {
            if n.free.any_negative() {
                return Err(format!("{}: negative free {}", n.id, n.free));
            }
            if !n.free.fits_in(&n.capacity) {
                return Err(format!("{}: free {} exceeds capacity {}", n.id, n.free, n.capacity));
            }
            if n.hold.any_negative() {
                return Err(format!("{}: negative hold {}", n.id, n.hold));
            }
            let allocated = n
                .allocations
                .iter()
                .fold(ResourceVec::ZERO, |acc, (_, d)| acc + *d);
            let sum = allocated + n.free;
            let diff = sum - n.capacity;
            if diff.cpu.abs() > 1e-6 || diff.ram_gb.abs() > 1e-6 || diff.gpu.abs() > 1e-6 {
                return Err(format!(
                    "{}: conservation violated: alloc {} + free {} != cap {}",
                    n.id, allocated, n.free, n.capacity
                ));
            }
            if n.availability == NodeAvailability::Down
                && (!n.allocations.is_empty() || !n.hold.is_zero())
            {
                return Err(format!(
                    "{}: down node still hosts {} jobs / hold {}",
                    n.id,
                    n.allocations.len(),
                    n.hold
                ));
            }
            let expect = FreeIndex::node_keys(n);
            let axes_indexed = self
                .index
                .by_axis
                .iter()
                .enumerate()
                .all(|(axis, set)| set.contains(&(expect[axis + 1], n.id.0)));
            if self.index.keys[n.id.0 as usize] != expect
                || !self.index.by_size.contains(&(expect[0], n.id.0))
                || !axes_indexed
            {
                return Err(format!("{}: capacity index is stale", n.id));
            }
        }
        for (job, node) in &self.location {
            if !self.node(*node).allocations.iter().any(|(id, _)| id == job) {
                return Err(format!("{job} in index but not on {node}"));
            }
        }
        let eff_sum = self
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, n| acc + n.effective_free());
        let eff_diff = eff_sum - self.index.eff_total;
        if eff_diff.cpu.abs() > 1e-6 || eff_diff.ram_gb.abs() > 1e-6 || eff_diff.gpu.abs() > 1e-6 {
            return Err(format!(
                "effective-free aggregate drifted: index says {}, nodes sum to {}",
                self.index.eff_total, eff_sum
            ));
        }
        let cap_sum = self
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, n| acc + n.capacity);
        if cap_sum != self.total_capacity {
            return Err(format!(
                "total-capacity cache stale: cached {}, nodes sum to {}",
                self.total_capacity, cap_sum
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demand(c: f64, r: f64, g: f64) -> ResourceVec {
        ResourceVec::new(c, r, g)
    }

    #[test]
    fn spec_pfn_matches_paper() {
        let s = ClusterSpec::pfn();
        assert_eq!(s.nodes.len(), 84);
        assert_eq!(s.total_capacity(), ResourceVec::new(84.0 * 32.0, 84.0 * 256.0, 84.0 * 8.0));
    }

    #[test]
    fn bind_unbind_roundtrip() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        c.bind(JobId(1), demand(4.0, 32.0, 1.0), NodeId(0));
        assert_eq!(c.locate(JobId(1)), Some(NodeId(0)));
        assert_eq!(c.node(NodeId(0)).free, demand(28.0, 224.0, 7.0));
        c.check_invariants().unwrap();
        let n = c.unbind(JobId(1)).unwrap();
        assert_eq!(n, NodeId(0));
        assert_eq!(c.node(NodeId(0)).free, ResourceVec::pfn_node());
        assert!(c.locate(JobId(1)).is_none());
        c.check_invariants().unwrap();
    }

    #[test]
    fn unbind_unknown_job_is_a_typed_error() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        assert_eq!(c.unbind(JobId(9)), Err(ClusterError::NotBound(JobId(9))));
        // The failed release left state untouched.
        c.check_invariants().unwrap();
    }

    #[test]
    #[should_panic]
    fn double_bind_panics() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        c.bind(JobId(1), demand(1.0, 1.0, 0.0), NodeId(0));
        c.bind(JobId(1), demand(1.0, 1.0, 0.0), NodeId(1));
    }

    #[test]
    fn first_fit_picks_lowest_id() {
        let mut c = Cluster::new(&ClusterSpec::tiny(3));
        c.bind(JobId(1), demand(32.0, 256.0, 8.0), NodeId(0)); // fill node 0
        let n = c.find_node(&demand(1.0, 1.0, 0.0), Placement::FirstFit);
        assert_eq!(n, Some(NodeId(1)));
    }

    #[test]
    fn best_fit_minimizes_residual() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        // Node 0 is half full; best-fit should prefer it over empty node 1.
        c.bind(JobId(1), demand(16.0, 128.0, 4.0), NodeId(0));
        let n = c.find_node(&demand(8.0, 64.0, 2.0), Placement::BestFit);
        assert_eq!(n, Some(NodeId(0)));
        // Worst-fit spreads instead.
        let n = c.find_node(&demand(8.0, 64.0, 2.0), Placement::WorstFit);
        assert_eq!(n, Some(NodeId(1)));
    }

    #[test]
    fn no_fit_returns_none() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        for (i, node) in [(0u32, NodeId(0)), (1, NodeId(1))] {
            c.bind(JobId(i), demand(30.0, 250.0, 8.0), node);
        }
        assert_eq!(c.find_node(&demand(4.0, 4.0, 1.0), Placement::FirstFit), None);
    }

    #[test]
    fn gpu_axis_blocks_fit_alone() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.bind(JobId(1), demand(1.0, 1.0, 8.0), NodeId(0)); // all GPUs taken
        assert_eq!(c.find_node(&demand(1.0, 1.0, 1.0), Placement::FirstFit), None);
        assert!(c.find_node(&demand(1.0, 1.0, 0.0), Placement::FirstFit).is_some());
    }

    #[test]
    fn invariants_catch_conservation() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.bind(JobId(1), demand(4.0, 4.0, 1.0), NodeId(0));
        c.check_invariants().unwrap();
        // Forcibly corrupt.
        c.nodes[0].free.cpu += 5.0;
        assert!(c.check_invariants().is_err());
    }

    #[test]
    fn heterogeneous_capacities() {
        let spec = ClusterSpec {
            nodes: vec![ResourceVec::new(8.0, 64.0, 0.0), ResourceVec::new(32.0, 256.0, 8.0)],
        };
        let c = Cluster::new(&spec);
        // A GPU job can only land on node 1.
        assert_eq!(
            c.find_node(&demand(1.0, 1.0, 1.0), Placement::FirstFit),
            Some(NodeId(1))
        );
    }

    #[test]
    fn fits_nowhere_tracks_axis_maxima() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        assert!(!c.fits_nowhere(&demand(32.0, 256.0, 8.0)));
        // Take all GPUs on both nodes: any GPU demand now fits nowhere,
        // while CPU-only demands still fit.
        c.bind(JobId(0), demand(1.0, 1.0, 8.0), NodeId(0));
        c.bind(JobId(1), demand(1.0, 1.0, 8.0), NodeId(1));
        assert!(c.fits_nowhere(&demand(1.0, 1.0, 1.0)));
        assert!(!c.fits_nowhere(&demand(31.0, 255.0, 0.0)));
        // Releasing one restores the axis maximum.
        c.unbind(JobId(0)).unwrap();
        assert!(!c.fits_nowhere(&demand(1.0, 1.0, 8.0)));
    }

    #[test]
    fn reserve_hides_space_from_the_index() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.reserve(NodeId(0), demand(32.0, 256.0, 8.0));
        assert_eq!(c.node(NodeId(0)).effective_free(), ResourceVec::ZERO);
        assert!(c.fits_nowhere(&demand(1.0, 1.0, 0.0)));
        c.unreserve(NodeId(0), demand(32.0, 256.0, 8.0));
        assert!(!c.fits_nowhere(&demand(32.0, 256.0, 8.0)));
        c.check_invariants().unwrap();
    }

    #[test]
    fn fit_candidates_exclude_full_nodes_but_keep_all_fitting() {
        let mut c = Cluster::new(&ClusterSpec::tiny(4));
        // Node 0 completely full, node 1 nearly full, nodes 2-3 open.
        c.bind(JobId(0), demand(32.0, 256.0, 8.0), NodeId(0));
        c.bind(JobId(1), demand(31.0, 250.0, 8.0), NodeId(1));
        let want = demand(8.0, 64.0, 2.0);
        let cands: Vec<u32> = c.fit_candidates(&want).map(|n| n.0).collect();
        assert!(!cands.contains(&0), "full node must be pruned");
        assert!(cands.contains(&2) && cands.contains(&3), "open nodes must survive");
        // Every node that actually fits is among the candidates.
        for n in &c.nodes {
            if want.fits_in(&n.effective_free()) {
                assert!(cands.contains(&n.id.0), "candidate set hid {}", n.id);
            }
        }
    }

    #[test]
    fn draining_node_accepts_no_placement_but_keeps_jobs() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        c.bind(JobId(0), demand(4.0, 32.0, 1.0), NodeId(0));
        c.set_availability(NodeId(0), NodeAvailability::Draining);
        // Effective free collapses to zero: the index prunes the node.
        assert_eq!(c.node(NodeId(0)).effective_free(), ResourceVec::ZERO);
        assert_eq!(
            c.find_node(&demand(1.0, 1.0, 0.0), Placement::FirstFit),
            Some(NodeId(1)),
            "placements must route around the draining node"
        );
        // The hosted job is untouched and raw free still reflects it.
        assert_eq!(c.locate(JobId(0)), Some(NodeId(0)));
        c.check_invariants().unwrap();
        // Restoring the node re-exposes its space.
        c.set_availability(NodeId(0), NodeAvailability::Up);
        assert!(!c.node(NodeId(0)).effective_free().is_zero());
        c.check_invariants().unwrap();
    }

    #[test]
    fn evict_all_releases_in_allocation_order() {
        let mut c = Cluster::new(&ClusterSpec::tiny(2));
        c.bind(JobId(3), demand(4.0, 32.0, 1.0), NodeId(0));
        c.bind(JobId(1), demand(8.0, 64.0, 2.0), NodeId(0));
        c.bind(JobId(2), demand(1.0, 1.0, 0.0), NodeId(1));
        let lost = c.evict_all(NodeId(0));
        assert_eq!(lost, vec![JobId(3), JobId(1)], "allocation order, not id order");
        assert_eq!(c.node(NodeId(0)).free, ResourceVec::pfn_node());
        assert!(c.locate(JobId(3)).is_none() && c.locate(JobId(1)).is_none());
        assert_eq!(c.locate(JobId(2)), Some(NodeId(1)), "other nodes untouched");
        c.set_availability(NodeId(0), NodeAvailability::Down);
        c.check_invariants().unwrap();
        // A full-node demand now fits nowhere: node 0 is down (despite being
        // empty) and node 1 is partially used.
        assert!(c.fits_nowhere(&demand(32.0, 256.0, 8.0)));
        assert!(c.find_node(&demand(32.0, 256.0, 8.0), Placement::BestFit).is_none());
    }

    #[test]
    fn resize_grows_and_shrinks_with_guard() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.bind(JobId(0), demand(16.0, 128.0, 4.0), NodeId(0));
        // Shrinking below current use is a typed error; state is untouched.
        assert_eq!(
            c.resize(NodeId(0), demand(8.0, 64.0, 2.0)),
            Err(ClusterError::CapacityBelowUse(NodeId(0)))
        );
        c.check_invariants().unwrap();
        // Growing doubles the free headroom and updates the index + the
        // cached max capacity used by the candidate range prune.
        c.resize(NodeId(0), demand(64.0, 512.0, 16.0)).unwrap();
        assert_eq!(c.node(NodeId(0)).free, demand(48.0, 384.0, 12.0));
        assert_eq!(c.max_capacity(), demand(64.0, 512.0, 16.0));
        assert!(!c.fits_nowhere(&demand(48.0, 384.0, 12.0)));
        c.check_invariants().unwrap();
        // Shrinking to exactly the current use leaves zero free.
        c.unbind(JobId(0)).unwrap();
        c.resize(NodeId(0), demand(4.0, 4.0, 1.0)).unwrap();
        assert_eq!(c.node(NodeId(0)).free, demand(4.0, 4.0, 1.0));
        c.check_invariants().unwrap();
    }

    #[test]
    fn down_node_with_leftovers_fails_invariants() {
        let mut c = Cluster::new(&ClusterSpec::tiny(1));
        c.bind(JobId(0), demand(1.0, 1.0, 0.0), NodeId(0));
        c.set_availability(NodeId(0), NodeAvailability::Down);
        assert!(c.check_invariants().is_err(), "down nodes must host nothing");
    }

    #[test]
    fn index_survives_bind_unbind_reserve_cycles() {
        let mut c = Cluster::new(&ClusterSpec::tiny(3));
        c.bind(JobId(0), demand(16.0, 128.0, 4.0), NodeId(1));
        c.reserve(NodeId(2), demand(10.0, 80.0, 2.0));
        c.check_invariants().unwrap();
        c.unbind(JobId(0)).unwrap();
        c.unreserve(NodeId(2), demand(10.0, 80.0, 2.0));
        c.check_invariants().unwrap();
        // After a full cycle, every node is indexed at full capacity again.
        assert!(!c.fits_nowhere(&demand(32.0, 256.0, 8.0)));
        let cands: Vec<u32> = c.fit_candidates(&demand(32.0, 256.0, 8.0)).map(|n| n.0).collect();
        assert_eq!(cands, vec![0, 1, 2]);
    }
}
