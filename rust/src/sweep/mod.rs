//! Thread-parallel sweep harness: run grids of
//! policy × s × P × TE-ratio × GP-scale × seed simulations and aggregate
//! the results deterministically.
//!
//! The paper's entire evaluation (§4) is such a grid — Table 1 is
//! 4 policies × 8 workloads, Fig. 4 is an `s` sweep, Fig. 5 a `P` sweep,
//! Fig. 6 a TE-ratio sweep, Fig. 7 a GP-scale sweep. The seed repository
//! ran every cell serially; this module is the scaling substrate that
//! replaces those loops:
//!
//! * **Work stealing** — cells go into a shared queue (an atomic cursor);
//!   idle workers steal the next unclaimed cell, so a slow cell (FIFO's
//!   long makespans) never gates the grid behind a fixed partition.
//! * **Workload caching** — cells that share a `(seed, te_ratio, gp_scale)`
//!   coordinate share one generated [`Workload`] (generation runs its own
//!   internal calibration simulation and is as expensive as a policy run).
//! * **Deterministic, order-independent aggregation** — every
//!   [`CellResult`] is routed back to its grid index, so
//!   [`SweepResult::cells`] is identical whatever the thread count or
//!   completion order; a test pins `threads = 1` against `threads = N`.
//! * **Streamed cells, mergeable pooling** — each cell streams its
//!   (shared) workload through the pull-based source interface and
//!   retains only a mergeable [`StreamingMetrics`] sink; cross-seed
//!   pooling ([`SweepResult::pooled_percentiles`]) merges quantile
//!   sketches instead of concatenating and re-sorting raw slowdown
//!   vectors, so sweep memory no longer scales with total jobs × cells.
//!
//! ```no_run
//! use fitgpp::prelude::*;
//!
//! let res = SweepSpec::table1(8192, &[100, 101, 102, 103]).run();
//! println!("{}", res.table1("Table 1: slowdown percentiles").to_text());
//! ```

use crate::cluster::ClusterSpec;
use crate::job::JobClass;
use crate::metrics::{
    slowdown_table, Percentiles, PreemptionReport, SlowdownReport, StreamingMetrics,
};
use crate::sched::admission::DisciplineKind;
use crate::sched::policy::PolicyKind;
use crate::sched::predict::EstimatorKind;
use crate::workload::source::TenantAssigner;
use crate::sim::{SimConfig, SimEngine, Simulator};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workload::synthetic::SyntheticWorkload;
use crate::workload::Workload;
use crate::Minutes;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// One point of the grid: a policy run on the §4.2 synthetic workload with
/// the given knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellSpec {
    /// Position in [`SweepSpec::cells`] order (stable aggregation key).
    pub index: usize,
    /// Policy under test.
    pub policy: PolicyKind,
    /// Fraction of TE jobs in the workload (Fig. 6 axis).
    pub te_ratio: f64,
    /// Grace-period distribution scale (Fig. 7 axis).
    pub gp_scale: f64,
    /// Workload seed; also used as the simulation's policy-RNG seed.
    pub seed: u64,
    /// Runtime estimator feeding the prediction-aware policies (the
    /// error-sensitivity axis; [`EstimatorKind::Oracle`] on every other
    /// sweep).
    pub estimator: EstimatorKind,
}

/// The grid description. Cells are the cross product
/// `seeds × te_ratios × gp_scales × estimators × policies`.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Cluster every cell simulates.
    pub cluster: ClusterSpec,
    /// Policy axis. For FitGpp parameter sweeps put one `FitGpp { .. }`
    /// variant per grid point here (see [`SweepSpec::fitgpp_s_grid`]).
    pub policies: Vec<PolicyKind>,
    /// TE-ratio axis (default `[0.3]`, the paper's base mix).
    pub te_ratios: Vec<f64>,
    /// GP-scale axis (default `[1.0]`).
    pub gp_scales: Vec<f64>,
    /// Workload seeds (the paper pools eight generated workloads).
    pub seeds: Vec<u64>,
    /// Jobs per workload.
    pub num_jobs: usize,
    /// FIFO-calibrated target cluster load (§4.2 uses 2.0).
    pub target_load: f64,
    /// Simulation engine for every cell.
    pub engine: SimEngine,
    /// §2 ablation knob, forwarded to every cell.
    pub progress_during_grace: bool,
    /// Admission discipline for every cell (fairness-vs-latency sweeps
    /// put `weighted_fair` here; default `fifo`).
    pub discipline: DisciplineKind,
    /// Tenants assigned round-robin over each workload (1 = the
    /// single-tenant pre-refactor behaviour).
    pub tenants: u32,
    /// Occupied-Size quota applied to every tenant in every cell.
    pub default_quota: Option<f64>,
    /// Estimator axis (default `[Oracle]` — a single-element axis leaves
    /// every pre-prediction grid unchanged). Workload generation is
    /// estimator-independent, so the axis multiplies cells but not
    /// generated workloads.
    pub estimators: Vec<EstimatorKind>,
    /// Worker threads; `0` = `FITGPP_THREADS` env var, else all cores.
    pub threads: usize,
}

impl SweepSpec {
    /// A sweep over `policies` on `cluster` with paper-default axes.
    pub fn new(cluster: ClusterSpec, policies: Vec<PolicyKind>) -> Self {
        SweepSpec {
            cluster,
            policies,
            te_ratios: vec![0.3],
            gp_scales: vec![1.0],
            seeds: vec![7],
            num_jobs: 4096,
            target_load: 2.0,
            engine: SimEngine::default(),
            progress_during_grace: false,
            discipline: DisciplineKind::Fifo,
            tenants: 1,
            default_quota: None,
            estimators: vec![EstimatorKind::Oracle],
            threads: 0,
        }
    }

    /// The Table-1 grid: the four §4.1 policies (FitGpp at its headline
    /// s = 4, P = 1 setting) on the paper's 84-node cluster, one cell per
    /// workload seed.
    pub fn table1(num_jobs: usize, seeds: &[u64]) -> Self {
        SweepSpec::new(ClusterSpec::pfn(), paper_policies())
            .with_num_jobs(num_jobs)
            .with_seeds(seeds.to_vec())
    }

    /// Replace the policy axis with `FitGpp { s, p_max }` for each `s`
    /// (the Fig. 4 sweep).
    pub fn fitgpp_s_grid(mut self, s_values: &[f64], p_max: Option<u32>) -> Self {
        self.policies = s_values
            .iter()
            .map(|&s| PolicyKind::FitGpp { s, p_max })
            .collect();
        self
    }

    /// Replace the policy axis with `FitGpp { s, p_max }` for each `p_max`
    /// (the Fig. 5 sweep).
    pub fn fitgpp_p_grid(mut self, s: f64, p_values: &[Option<u32>]) -> Self {
        self.policies = p_values
            .iter()
            .map(|&p_max| PolicyKind::FitGpp { s, p_max })
            .collect();
        self
    }

    /// Set the cluster.
    pub fn with_cluster(mut self, cluster: ClusterSpec) -> Self {
        self.cluster = cluster;
        self
    }

    /// Set the TE-ratio axis.
    pub fn with_te_ratios(mut self, ratios: Vec<f64>) -> Self {
        assert!(!ratios.is_empty());
        self.te_ratios = ratios;
        self
    }

    /// Set the GP-scale axis.
    pub fn with_gp_scales(mut self, scales: Vec<f64>) -> Self {
        assert!(!scales.is_empty());
        self.gp_scales = scales;
        self
    }

    /// Set the workload seeds.
    pub fn with_seeds(mut self, seeds: Vec<u64>) -> Self {
        assert!(!seeds.is_empty());
        self.seeds = seeds;
        self
    }

    /// Set jobs per workload.
    pub fn with_num_jobs(mut self, n: usize) -> Self {
        self.num_jobs = n;
        self
    }

    /// Set the target FIFO load of the workload calibration.
    pub fn with_target_load(mut self, load: f64) -> Self {
        self.target_load = load;
        self
    }

    /// Pin the simulation engine (the speedup bench runs both).
    pub fn with_engine(mut self, engine: SimEngine) -> Self {
        self.engine = engine;
        self
    }

    /// Set the admission discipline for every cell.
    pub fn with_discipline(mut self, discipline: DisciplineKind) -> Self {
        self.discipline = discipline;
        self
    }

    /// Assign `n` tenants round-robin over every workload (≥ 1).
    pub fn with_tenants(mut self, n: u32) -> Self {
        assert!(n >= 1);
        self.tenants = n;
        self
    }

    /// Apply an occupied-Size quota to every tenant in every cell.
    pub fn with_default_quota(mut self, quota: Option<f64>) -> Self {
        self.default_quota = quota;
        self
    }

    /// Set the estimator axis (the error-sensitivity sweep).
    pub fn with_estimators(mut self, estimators: Vec<EstimatorKind>) -> Self {
        assert!(!estimators.is_empty());
        self.estimators = estimators;
        self
    }

    /// Pin the worker-thread count (`1` = serial reference order).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Resolve the worker count: explicit `threads`, else `FITGPP_THREADS`,
    /// else the machine's available parallelism.
    pub fn threads_effective(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        if let Some(n) = std::env::var("FITGPP_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            if n > 0 {
                return n;
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// Enumerate the grid in deterministic order: seeds (outer) ×
    /// te_ratios × gp_scales × estimators × policies (inner). Cells
    /// sharing a workload coordinate are contiguous.
    pub fn cells(&self) -> Vec<CellSpec> {
        let mut out = Vec::new();
        for &seed in &self.seeds {
            for &te_ratio in &self.te_ratios {
                for &gp_scale in &self.gp_scales {
                    for &estimator in &self.estimators {
                        for &policy in &self.policies {
                            out.push(CellSpec {
                                index: out.len(),
                                policy,
                                te_ratio,
                                gp_scale,
                                seed,
                                estimator,
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Generate the workload for one `(seed, te_ratio, gp_scale)`
    /// coordinate (tenants assigned round-robin when `tenants > 1`).
    pub fn build_workload(&self, seed: u64, te_ratio: f64, gp_scale: f64) -> Workload {
        SyntheticWorkload::paper_section_4_2(seed)
            .with_cluster(self.cluster.clone())
            .with_num_jobs(self.num_jobs)
            .with_te_fraction(te_ratio)
            .with_target_load(self.target_load)
            .with_gp_scale(gp_scale)
            .with_tenant_assigner(TenantAssigner::round_robin(self.tenants))
            .generate()
    }

    /// Run the whole grid. Workloads are generated once per coordinate and
    /// shared; cells run on [`Self::threads_effective`] workers with
    /// dynamic work stealing; results come back in grid order regardless of
    /// completion order.
    pub fn run(&self) -> SweepResult {
        let t0 = Instant::now();
        let threads = self.threads_effective();
        let cells = self.cells();

        // Unique workload coordinates, in first-use order (f64 axes are
        // keyed by bit pattern — they come verbatim from the axis vectors).
        let mut keys: Vec<(u64, u64, u64)> = Vec::new();
        let mut key_index: HashMap<(u64, u64, u64), usize> = HashMap::new();
        let mut cell_wl: Vec<usize> = Vec::with_capacity(cells.len());
        for c in &cells {
            let key = (c.seed, c.te_ratio.to_bits(), c.gp_scale.to_bits());
            let idx = *key_index.entry(key).or_insert_with(|| {
                keys.push(key);
                keys.len() - 1
            });
            cell_wl.push(idx);
        }

        let workloads: Vec<Arc<Workload>> =
            parallel_map(&keys, threads, |_, &(seed, te_bits, gp_bits)| {
                Arc::new(self.build_workload(
                    seed,
                    f64::from_bits(te_bits),
                    f64::from_bits(gp_bits),
                ))
            });

        let jobs: Vec<(CellSpec, Arc<Workload>)> = cells
            .iter()
            .map(|c| (*c, Arc::clone(&workloads[cell_wl[c.index]])))
            .collect();
        let results = parallel_map(&jobs, threads, |_, (cell, wl)| self.run_cell(*cell, wl));

        SweepResult {
            cells: results,
            wall: t0.elapsed(),
            threads,
            workloads_generated: keys.len(),
        }
    }

    /// Run a single cell on a prepared workload.
    pub fn run_cell(&self, cell: CellSpec, workload: &Workload) -> CellResult {
        let mut cfg = SimConfig::new(self.cluster.clone(), cell.policy);
        cfg.seed = cell.seed;
        cfg.engine = self.engine;
        cfg.progress_during_grace = self.progress_during_grace;
        cfg.discipline = self.discipline;
        cfg.default_quota = self.default_quota;
        cfg.estimator = cell.estimator;
        run_sim_cell(cell, cfg, workload)
    }
}

/// Simulate one cell under an explicit [`SimConfig`] and package the
/// results. The cell *streams* its workload through the pull-based source
/// interface; per-cell reports stay exact (records mode), but only the
/// mergeable [`StreamingMetrics`] sink is retained for cross-seed pooling
/// — raw slowdown vectors are never held by the sweep.
fn run_sim_cell(cell: CellSpec, cfg: SimConfig, workload: &Workload) -> CellResult {
    let c0 = Instant::now();
    let res = Simulator::new(cfg).run_source(&mut workload.source());
    CellResult {
        cell,
        slowdown: res.slowdown_report(),
        preemption: res.preemption_report(),
        metrics: res.metrics.clone(),
        makespan: res.makespan,
        unfinished: res.unfinished,
        peak_live: res.peak_live,
        preemption_signals: res.sched_stats.preemption_signals,
        fast_forwarded_ticks: res.sched_stats.fast_forwarded_ticks,
        wall: c0.elapsed(),
    }
}

/// The four §4.1 policies, FitGpp at its headline setting.
pub fn paper_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
    ]
}

/// Every implemented policy: the §4.1 four plus the bypass-only FastLane
/// ablation, the SRTF / preempt-youngest ablations, and the two
/// prediction-aware policies that ride on the
/// [`PreemptionPolicy`](crate::sched::policy::PreemptionPolicy) trait.
pub fn extended_policies() -> Vec<PolicyKind> {
    vec![
        PolicyKind::Fifo,
        PolicyKind::FastLane,
        PolicyKind::Lrtp,
        PolicyKind::Rand,
        PolicyKind::Srtf,
        PolicyKind::Youngest,
        PolicyKind::FitGpp { s: 4.0, p_max: Some(1) },
        PolicyKind::PSrtf,
        PolicyKind::FitGppPr { s: 4.0, p_max: Some(1) },
    ]
}

/// The estimator axis of the error-sensitivity sweep: exact oracle, the
/// cold-starting per-class EWMA, a zero-noise control (pinned byte-identical
/// to the oracle), and three nonzero noise levels.
pub fn error_sensitivity_estimators() -> Vec<EstimatorKind> {
    vec![
        EstimatorKind::Oracle,
        EstimatorKind::ClassEwma { alpha: 0.2 },
        EstimatorKind::Noisy { sigma: 0.0 },
        EstimatorKind::Noisy { sigma: 0.25 },
        EstimatorKind::Noisy { sigma: 0.5 },
        EstimatorKind::Noisy { sigma: 1.0 },
    ]
}

/// Everything one cell produced (exact per-cell reports plus the
/// mergeable streaming sink, so callers can pool across seeds — like the
/// paper's "statistics over eight workloads" — by merging sketches in O(1)
/// memory instead of concatenating raw slowdown vectors).
#[derive(Debug, Clone)]
pub struct CellResult {
    /// The grid point this belongs to.
    pub cell: CellSpec,
    /// Slowdown percentiles of this cell alone (exact).
    pub slowdown: SlowdownReport,
    /// Preemption statistics of this cell alone.
    pub preemption: PreemptionReport,
    /// The cell's mergeable metrics sink (cross-seed pooling).
    pub metrics: StreamingMetrics,
    /// Simulated minutes until the cell's run stopped.
    pub makespan: Minutes,
    /// Jobs unfinished at cut-off (0 when draining).
    pub unfinished: usize,
    /// High-water mark of the cell's resident job table.
    pub peak_live: usize,
    /// Preemption signals the scheduler issued.
    pub preemption_signals: u64,
    /// Simulated minutes the event-horizon engine advanced in bulk.
    pub fast_forwarded_ticks: u64,
    /// Wall-clock time of this cell's simulation (excludes workload
    /// generation, which is shared).
    pub wall: Duration,
}

/// All cells of a sweep, in grid order, plus run-level accounting.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// Per-cell results, ordered by [`CellSpec::index`].
    pub cells: Vec<CellResult>,
    /// End-to-end wall clock of the sweep (generation + simulation).
    pub wall: Duration,
    /// Worker threads used.
    pub threads: usize,
    /// Distinct workloads generated (cells ÷ policy-axis size).
    pub workloads_generated: usize,
}

impl SweepResult {
    /// Distinct policies, in grid order.
    pub fn policies(&self) -> Vec<PolicyKind> {
        let mut out: Vec<PolicyKind> = Vec::new();
        for c in &self.cells {
            if !out.contains(&c.cell.policy) {
                out.push(c.cell.policy);
            }
        }
        out
    }

    /// Distinct estimators, in grid order.
    pub fn estimators(&self) -> Vec<EstimatorKind> {
        let mut out: Vec<EstimatorKind> = Vec::new();
        for c in &self.cells {
            if !out.contains(&c.cell.estimator) {
                out.push(c.cell.estimator);
            }
        }
        out
    }

    /// The prediction-error sensitivity grid: one row per
    /// (estimator, policy) pair with TE p95 and BE median pooled across
    /// seeds — how much each policy's latency promise degrades as runtime
    /// predictions go from exact to badly wrong.
    pub fn estimator_grid(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["estimator", "policy", "te_p95", "be_p50"]);
        for est in self.estimators() {
            for pol in self.policies() {
                let keep = |c: &CellSpec| c.estimator == est && c.policy == pol;
                let te = self.pooled_percentiles_where(keep, JobClass::Te);
                let be = self.pooled_percentiles_where(keep, JobClass::Be);
                t.row(vec![
                    est.name(),
                    pol.name(),
                    format!("{:.3}", te.p95),
                    format!("{:.3}", be.p50),
                ]);
            }
        }
        t
    }

    /// Merge the metrics sinks of every cell matching `keep` — the
    /// cross-seed pool as one mergeable sketch bundle (O(1) memory; no raw
    /// slowdown vectors, no re-sorting per percentile query).
    pub fn pooled_metrics_where<F: Fn(&CellSpec) -> bool>(&self, keep: F) -> StreamingMetrics {
        let mut pooled = StreamingMetrics::new();
        for c in &self.cells {
            if keep(&c.cell) {
                pooled.merge(&c.metrics);
            }
        }
        pooled
    }

    /// Percentiles of `class` over the pooled sketch of every cell
    /// matching `keep`.
    pub fn pooled_percentiles_where<F: Fn(&CellSpec) -> bool>(
        &self,
        keep: F,
        class: JobClass,
    ) -> Percentiles {
        let pooled = self.pooled_metrics_where(keep);
        Percentiles::from_sketch(pooled.slowdown.get(class))
    }

    /// Percentiles of the cross-seed pool for one policy and class (the
    /// paper's "statistics over eight workloads"), from merged sketches.
    pub fn pooled_percentiles(&self, policy: PolicyKind, class: JobClass) -> Percentiles {
        self.pooled_percentiles_where(|c| c.policy == policy, class)
    }

    /// Pooled per-policy slowdown reports, in grid order.
    pub fn slowdown_rows(&self) -> Vec<(String, SlowdownReport)> {
        self.policies()
            .into_iter()
            .map(|p| {
                (
                    p.name(),
                    SlowdownReport {
                        te: self.pooled_percentiles(p, JobClass::Te),
                        be: self.pooled_percentiles(p, JobClass::Be),
                    },
                )
            })
            .collect()
    }

    /// Render the paper's Table-1 layout, pooling across seeds per policy.
    pub fn table1(&self, title: &str) -> Table {
        let rows = self.slowdown_rows();
        let named: Vec<(&str, SlowdownReport)> =
            rows.iter().map(|(n, r)| (n.as_str(), *r)).collect();
        slowdown_table(title, &named)
    }

    /// Sum of per-cell simulation walls — the serial-equivalent time, i.e.
    /// what the grid would cost on one thread (excluding generation).
    pub fn total_cell_wall(&self) -> Duration {
        self.cells.iter().map(|c| c.wall).sum()
    }

    /// [`Self::to_csv`] with the wall-clock column stripped — the
    /// comparison key for "same grid, different engine/threads" checks
    /// (wall time is the only legitimately nondeterministic column).
    pub fn to_csv_without_wall(&self) -> String {
        self.to_csv()
            .lines()
            .map(|l| l.rsplit_once(',').map(|(head, _)| head).unwrap_or("").to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// One CSV row per cell (plotting scripts; stable column set).
    pub fn to_csv(&self) -> String {
        let mut t = Table::new(
            "",
            &[
                "policy", "te_ratio", "gp_scale", "seed", "te_p50", "te_p95", "te_p99",
                "be_p50", "be_p95", "be_p99", "preempted_frac", "signals", "makespan",
                "unfinished", "peak_live", "estimator", "wall_ms",
            ],
        );
        for c in &self.cells {
            t.row(vec![
                c.cell.policy.name(),
                format!("{}", c.cell.te_ratio),
                format!("{}", c.cell.gp_scale),
                c.cell.seed.to_string(),
                format!("{:.6}", c.slowdown.te.p50),
                format!("{:.6}", c.slowdown.te.p95),
                format!("{:.6}", c.slowdown.te.p99),
                format!("{:.6}", c.slowdown.be.p50),
                format!("{:.6}", c.slowdown.be.p95),
                format!("{:.6}", c.slowdown.be.p99),
                format!("{:.8}", c.preemption.fraction_preempted),
                c.preemption_signals.to_string(),
                c.makespan.to_string(),
                c.unfinished.to_string(),
                c.peak_live.to_string(),
                c.cell.estimator.name(),
                format!("{:.3}", c.wall.as_secs_f64() * 1e3),
            ]);
        }
        t.to_csv()
    }

    /// Machine-readable dump of the whole sweep.
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("policy", Json::str(&c.cell.policy.name())),
                    ("te_ratio", Json::num(c.cell.te_ratio)),
                    ("gp_scale", Json::num(c.cell.gp_scale)),
                    ("seed", Json::num(c.cell.seed as f64)),
                    (
                        "slowdown",
                        Json::obj(vec![
                            ("te", c.slowdown.te.to_json()),
                            ("be", c.slowdown.be.to_json()),
                        ]),
                    ),
                    (
                        "preempted_frac",
                        Json::num(c.preemption.fraction_preempted),
                    ),
                    ("signals", Json::num(c.preemption_signals as f64)),
                    ("makespan", Json::num(c.makespan as f64)),
                    ("unfinished", Json::num(c.unfinished as f64)),
                    ("peak_live", Json::num(c.peak_live as f64)),
                    ("estimator", Json::str(&c.cell.estimator.name())),
                    ("wall_ms", Json::num(c.wall.as_secs_f64() * 1e3)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("threads", Json::num(self.threads as f64)),
            ("wall_sec", Json::num(self.wall.as_secs_f64())),
            (
                "workloads_generated",
                Json::num(self.workloads_generated as f64),
            ),
            ("cells", Json::Arr(cells)),
        ])
    }
}

/// Run a fixed workload under several policies in parallel — the `compare`
/// fast path, usable with trace-file workloads the grid generator cannot
/// express. `template` carries everything but the policy (cluster,
/// placement, progress-during-grace, seed, engine), so a config-file
/// experiment keeps its exact semantics. Results are in `policies` order;
/// `threads == 0` resolves like [`SweepSpec::threads_effective`].
pub fn compare_on(
    workload: &Workload,
    template: &SimConfig,
    policies: &[PolicyKind],
    threads: usize,
) -> Vec<CellResult> {
    let resolver = SweepSpec::new(template.cluster.clone(), policies.to_vec())
        .with_threads(threads);
    let te_ratio = workload.te_fraction();
    let jobs: Vec<(usize, PolicyKind)> =
        policies.iter().copied().enumerate().collect();
    parallel_map(&jobs, resolver.threads_effective(), |_, &(index, policy)| {
        let mut cfg = template.clone();
        cfg.policy = policy;
        run_sim_cell(
            CellSpec {
                index,
                policy,
                te_ratio,
                gp_scale: 1.0,
                seed: template.seed,
                estimator: template.estimator,
            },
            cfg,
            workload,
        )
    })
}

/// Run `f` over `items` on `threads` workers with dynamic self-scheduling:
/// idle workers steal the next unclaimed index from a shared atomic
/// cursor, so long items never gate short ones behind a static partition.
/// Results return in input order regardless of completion order; with
/// `threads == 1` this degenerates to a plain serial map (no thread spawn).
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i, &items[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|o| o.expect("every cell delivered exactly once"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec::new(
            ClusterSpec::tiny(2),
            vec![PolicyKind::Fifo, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) }],
        )
        .with_num_jobs(96)
        .with_seeds(vec![5, 6])
    }

    #[test]
    fn grid_enumeration_is_the_cross_product() {
        let spec = tiny_spec()
            .with_te_ratios(vec![0.1, 0.3])
            .with_gp_scales(vec![1.0, 4.0]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // Policies innermost: consecutive cells share the workload coord.
        assert_eq!(cells[0].seed, cells[1].seed);
        assert_eq!(cells[0].te_ratio, cells[1].te_ratio);
        assert_ne!(cells[0].policy, cells[1].policy);
    }

    #[test]
    fn parallel_map_preserves_order_and_covers_all() {
        let items: Vec<u64> = (0..57).collect();
        let doubled = parallel_map(&items, 4, |i, &x| {
            assert_eq!(i as u64, x);
            x * 2
        });
        assert_eq!(doubled, (0..57).map(|x| x * 2).collect::<Vec<_>>());
        assert_eq!(parallel_map(&[] as &[u64], 4, |_, &x| x), Vec::<u64>::new());
    }

    #[test]
    fn sweep_is_deterministic_and_thread_count_invariant() {
        let serial = tiny_spec().with_threads(1).run();
        let parallel = tiny_spec().with_threads(4).run();
        assert_eq!(serial.cells.len(), parallel.cells.len());
        // Everything except wall clock must be identical.
        assert_eq!(
            serial.to_csv_without_wall(),
            parallel.to_csv_without_wall(),
            "aggregation must be order-independent"
        );
        assert_eq!(serial.workloads_generated, 2, "one workload per seed, shared across policies");
        assert_eq!(parallel.threads, 4);
    }

    #[test]
    fn cell_matches_direct_simulation() {
        let spec = tiny_spec();
        let res = spec.with_threads(2).run();
        let c = &res.cells[0];
        let wl = tiny_spec().build_workload(c.cell.seed, c.cell.te_ratio, c.cell.gp_scale);
        let mut cfg = SimConfig::new(ClusterSpec::tiny(2), c.cell.policy);
        cfg.seed = c.cell.seed;
        let direct = Simulator::new(cfg).run(&wl);
        assert_eq!(c.makespan, direct.makespan);
        assert_eq!(c.slowdown, direct.slowdown_report());
        assert_eq!(c.unfinished, 0);
    }

    #[test]
    fn pooling_merges_sketches_across_seeds() {
        let res = tiny_spec().with_threads(2).run();
        let pooled = res.pooled_metrics_where(|c| c.policy == PolicyKind::Fifo);
        let per_cell: u64 = res
            .cells
            .iter()
            .filter(|c| c.cell.policy == PolicyKind::Fifo)
            .map(|c| c.metrics.slowdown.be.count())
            .sum();
        assert_eq!(pooled.slowdown.be.count(), per_cell);
        assert!(per_cell > 0);
        let p = res.pooled_percentiles(PolicyKind::Fifo, JobClass::Be);
        assert!(p.p50 >= 1.0 && p.p50 <= p.p95 && p.p95 <= p.p99, "{p:?}");
        // Pooled sketch percentiles track the exact pooled values within
        // the sketch's error bound (cells run with exact records too).
        let rows = res.slowdown_rows();
        assert_eq!(rows.len(), 2);
        let t = res.table1("t");
        assert!(t.to_text().contains("FIFO"));
    }

    #[test]
    fn cells_stream_with_bounded_live_sets() {
        let res = tiny_spec().with_threads(2).run();
        for c in &res.cells {
            assert!(c.peak_live >= 1);
            assert!(
                c.peak_live <= 96,
                "live set may never exceed the workload ({})",
                c.peak_live
            );
            assert_eq!(c.metrics.jobs_seen, 96);
        }
    }

    #[test]
    fn multi_tenant_weighted_fair_sweep_pools_per_tenant() {
        let res = tiny_spec()
            .with_discipline(DisciplineKind::WeightedFair)
            .with_tenants(4)
            .with_threads(2)
            .run();
        for c in &res.cells {
            assert_eq!(c.metrics.tenants.len(), 4, "4 tenants observed per cell");
            assert_eq!(c.unfinished, 0, "weighted-fair cells still drain");
        }
        // Cross-seed pooling merges the tenant maps keywise.
        let pooled = res.pooled_metrics_where(|c| c.policy == PolicyKind::Fifo);
        assert_eq!(pooled.tenants.len(), 4);
        let per_tenant_total: u64 = pooled.tenants.values().map(|m| m.jobs_seen()).sum();
        assert_eq!(per_tenant_total, pooled.jobs_seen);
    }

    #[test]
    fn compare_on_runs_each_policy_once() {
        let wl = tiny_spec().build_workload(5, 0.3, 1.0);
        let mut template = SimConfig::new(ClusterSpec::tiny(2), PolicyKind::Fifo);
        template.seed = 1;
        let cells = compare_on(&wl, &template, &paper_policies(), 2);
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].cell.policy, PolicyKind::Fifo);
        assert!(cells.iter().all(|c| c.unfinished == 0));
        assert!(cells.iter().all(|c| c.cell.seed == 1));
    }

    #[test]
    fn estimator_axis_multiplies_cells_but_not_workloads() {
        let spec = SweepSpec::new(ClusterSpec::tiny(2), vec![PolicyKind::PSrtf])
            .with_num_jobs(96)
            .with_seeds(vec![5, 6])
            .with_estimators(vec![
                EstimatorKind::Oracle,
                EstimatorKind::Noisy { sigma: 0.0 },
                EstimatorKind::Noisy { sigma: 0.5 },
            ]);
        let cells = spec.cells();
        assert_eq!(cells.len(), 2 * 3, "seeds × estimators × 1 policy");
        let res = spec.with_threads(2).run();
        assert_eq!(res.workloads_generated, 2, "estimator axis reuses workloads");
        assert_eq!(res.estimators().len(), 3);

        // Zero-noise control: every cell under Noisy(sigma=0) matches its
        // Oracle sibling exactly (CSV rows differ only in the estimator
        // and wall columns, which sit last).
        let row = |est: EstimatorKind, seed: u64| {
            let c = res
                .cells
                .iter()
                .find(|c| c.cell.estimator == est && c.cell.seed == seed)
                .unwrap();
            (c.slowdown, c.makespan, c.preemption_signals, c.peak_live)
        };
        for &seed in &[5, 6] {
            assert_eq!(
                row(EstimatorKind::Oracle, seed),
                row(EstimatorKind::Noisy { sigma: 0.0 }, seed),
                "Noisy(0) must be indistinguishable from Oracle"
            );
        }

        // The sensitivity grid has one row per (estimator, policy) pair
        // and the CSV carries the estimator column.
        let grid = res.estimator_grid("sensitivity");
        assert_eq!(grid.to_csv().lines().count(), 1 + 3);
        assert!(res.to_csv().lines().next().unwrap().contains("estimator"));
        assert!(res.to_csv().contains("noisy(s=0.5)"));
    }
}
