//! Summary statistics: exact percentiles (the paper reports 50th/95th/99th
//! percentile slowdown rates) and basic moments.

/// Sort a copy ascending — the one shared sort every exact-percentile
/// path funnels through. Callers that need several percentiles (or several
/// reports) over the same sample should call this once and use the
/// `*_sorted` variants instead of re-sorting per query.
pub fn sort_ascending(xs: &[f64]) -> Vec<f64> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v
}

/// Exact percentile by sorting a copy — linear-interpolation definition
/// (same as `numpy.percentile(..., method="linear")`), so the python tests
/// can cross-check values bit-for-bit.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&p), "p out of range: {p}");
    percentile_sorted(&sort_ascending(xs), p)
}

/// Percentile over an already-sorted slice (ascending). Callers computing
/// several percentiles should sort once and use this.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Compute several percentiles with one sort.
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    percentiles_sorted(&sort_ascending(xs), ps)
}

/// Several percentiles over an already-sorted slice (no copy, no sort).
pub fn percentiles_sorted(sorted: &[f64], ps: &[f64]) -> Vec<f64> {
    ps.iter().map(|&p| percentile_sorted(sorted, p)).collect()
}

/// Five-number-ish summary used by reports.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a non-empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty slice");
        Self::of_sorted(&sort_ascending(xs))
    }

    /// Summarize an already-sorted (ascending) non-empty sample — the
    /// shared-sort fast path for callers that also need raw percentiles.
    pub fn of_sorted(v: &[f64]) -> Summary {
        assert!(!v.is_empty(), "summary of empty slice");
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
            max: v[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_of_odd() {
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 50.0), 2.0);
    }

    #[test]
    fn median_of_even_interpolates() {
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.5);
    }

    #[test]
    fn extremes() {
        let xs = [5.0, 1.0, 9.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn single_element() {
        assert_eq!(percentile(&[42.0], 95.0), 42.0);
    }

    #[test]
    fn matches_numpy_linear_example() {
        // numpy.percentile([1,2,3,4,5,6,7,8,9,10], 95) == 9.55
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert!((percentile(&xs, 95.0) - 9.55).abs() < 1e-12);
    }

    #[test]
    fn percentiles_batch_equals_individual() {
        let xs: Vec<f64> = (0..101).map(|i| (i * 37 % 101) as f64).collect();
        let ps = [50.0, 95.0, 99.0];
        let batch = percentiles(&xs, &ps);
        for (b, &p) in batch.iter().zip(&ps) {
            assert_eq!(*b, percentile(&xs, p));
        }
    }

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.5);
        assert!((s.mean - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn empty_percentile_panics() {
        percentile(&[], 50.0);
    }
}
