//! PCG-XSH-RR 64/32: a small, fast, statistically solid PRNG
//! (O'Neill 2014). Two 32-bit outputs are combined for `u64`/`f64` draws.
//!
//! Determinism matters here: every simulation result in EXPERIMENTS.md is
//! reproducible from a seed, and the property-test kit (`testkit`) replays
//! failures from a reported seed.

/// PCG-XSH-RR 64/32 generator. `Pcg64` refers to the 64-bit *state* (the
/// conventional "pcg32" engine) with convenience 64-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create from a seed; the stream constant is fixed (one stream is
    /// enough — independent substreams are made via `split`).
    pub fn new(seed: u64) -> Self {
        let mut r = Pcg64 { state: 0, inc: (54u64 << 1) | 1 };
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(r.inc);
        r.state = r.state.wrapping_add(seed);
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(r.inc);
        r
    }

    /// Derive an independent generator (different stream) — used to give
    /// each workload dimension (exec time, CPU, RAM, GPU, GP, arrivals) its
    /// own substream so changing one does not perturb the others.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let mut r = Pcg64 { state: 0, inc: ((tag.wrapping_mul(2) | 1) << 1) | 1 };
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(r.inc);
        r.state = r.state.wrapping_add(seed);
        r.state = r.state.wrapping_mul(PCG_MULT).wrapping_add(r.inc);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hilo(x, n);
            if lo >= n.wrapping_neg() % n {
                return hi;
            }
            // retry (rare)
            let _ = x;
        }
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element index from a slice length; `None` for
    /// empty slices.
    pub fn pick_index(&mut self, len: usize) -> Option<usize> {
        if len == 0 {
            None
        } else {
            Some(self.below(len as u64) as usize)
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Expose the raw `(state, increment)` pair for snapshots. Together
    /// with [`Pcg64::from_parts`] this round-trips the generator exactly:
    /// the restored stream continues from the same point.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg64::state_parts`] pair.
    pub fn from_parts(state: u64, inc: u64) -> Self {
        Pcg64 { state, inc }
    }
}

#[inline]
fn mul_hilo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_of_parent_consumption() {
        let mut a = Pcg64::new(7);
        let mut sub_a = a.split(1);
        let mut b = Pcg64::new(7);
        let mut sub_b = b.split(1);
        for _ in 0..32 {
            assert_eq!(sub_a.next_u64(), sub_b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Pcg64::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg64::new(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg64::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn state_parts_round_trip_continues_the_stream() {
        let mut a = Pcg64::new(23);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg64::from_parts(state, inc);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pick_index_empty_is_none() {
        let mut r = Pcg64::new(17);
        assert_eq!(r.pick_index(0), None);
        assert!(r.pick_index(3).unwrap() < 3);
    }
}
