//! Distribution sampling: normal (Box-Muller), truncated normal (the
//! paper's §4.2 workload model), lognormal (institution-trace synthesis),
//! and exponential (Poisson inter-arrivals).

use super::rng::Pcg64;

/// A sampleable 1-D distribution.
pub trait Sample {
    /// Draw one value using `rng`.
    fn sample(&self, rng: &mut Pcg64) -> f64;
}

/// Normal(mean, std) via Box-Muller (no cached spare: keeps sampling
/// stateless so substreams stay aligned regardless of call counts).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Normal {
    /// Mean.
    pub mean: f64,
    /// Standard deviation.
    pub std: f64,
}

impl Normal {
    /// Construct; `std` must be non-negative.
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0, "std must be non-negative");
        Normal { mean, std }
    }
}

impl Sample for Normal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        if self.std == 0.0 {
            return self.mean;
        }
        // Box-Muller; u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - rng.next_f64();
        let u2 = rng.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        self.mean + self.std * z
    }
}

/// The paper's workload primitive (§4.2): a normal distribution *truncated*
/// to `[lo, hi]`, sampled by rejection with a resample cap (falls back to
/// clamping after `MAX_REJECT` misses, which only triggers for degenerate
/// parameterizations).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TruncatedNormal {
    /// The untruncated normal.
    pub inner: Normal,
    /// Lower truncation bound.
    pub lo: f64,
    /// Upper truncation bound.
    pub hi: f64,
}

const MAX_REJECT: usize = 1024;

impl TruncatedNormal {
    /// Construct; requires `lo < hi`.
    pub fn new(mean: f64, std: f64, lo: f64, hi: f64) -> Self {
        assert!(lo < hi, "truncation interval must be non-empty ({lo}..{hi})");
        TruncatedNormal { inner: Normal::new(mean, std), lo, hi }
    }

    /// Scale the whole distribution (mean, std, and both truncation points)
    /// by `k` — exactly how Fig. 7 builds its "2.0" / "4.0" / "8.0" GP
    /// distributions from the "1.0" baseline.
    pub fn scaled(&self, k: f64) -> Self {
        TruncatedNormal {
            inner: Normal::new(self.inner.mean * k, self.inner.std * k),
            lo: self.lo * k,
            hi: self.hi * k,
        }
    }
}

impl Sample for TruncatedNormal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        for _ in 0..MAX_REJECT {
            let x = self.inner.sample(rng);
            if x >= self.lo && x <= self.hi {
                return x;
            }
        }
        self.inner.mean.clamp(self.lo, self.hi)
    }
}

/// LogNormal: `exp(Normal(mu, sigma))`. Used to synthesize the heavy-tailed
/// execution times of the institution trace (§4.4 substitution — see
/// DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogNormal {
    /// Mean of the underlying normal (log scale).
    pub mu: f64,
    /// Std of the underlying normal (log scale).
    pub sigma: f64,
}

impl LogNormal {
    /// Construct from log-scale parameters.
    pub fn new(mu: f64, sigma: f64) -> Self {
        LogNormal { mu, sigma }
    }

    /// Construct from the desired median and p95 of the resulting
    /// distribution (more intuitive for trace calibration).
    pub fn from_median_p95(median: f64, p95: f64) -> Self {
        assert!(p95 > median && median > 0.0);
        let mu = median.ln();
        // p95 = exp(mu + 1.6449 sigma)
        let sigma = (p95.ln() - mu) / 1.6448536269514722;
        LogNormal { mu, sigma }
    }
}

impl Sample for LogNormal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        Normal::new(self.mu, self.sigma).sample(rng).exp()
    }
}

/// Exponential(rate) via inverse CDF — Poisson-process inter-arrival gaps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Exponential {
    /// Rate parameter λ (events per unit time).
    pub rate: f64,
}

impl Exponential {
    /// Construct; `rate` must be positive.
    pub fn new(rate: f64) -> Self {
        assert!(rate > 0.0);
        Exponential { rate }
    }
}

impl Sample for Exponential {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let u = 1.0 - rng.next_f64(); // (0, 1]
        -u.ln() / self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var.sqrt())
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(1);
        let d = Normal::new(5.0, 2.0);
        let xs: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, std) = moments(&xs);
        assert!((mean - 5.0).abs() < 0.03, "mean={mean}");
        assert!((std - 2.0).abs() < 0.03, "std={std}");
    }

    #[test]
    fn normal_zero_std_is_constant() {
        let mut rng = Pcg64::new(2);
        let d = Normal::new(3.0, 0.0);
        for _ in 0..10 {
            assert_eq!(d.sample(&mut rng), 3.0);
        }
    }

    #[test]
    fn truncated_normal_respects_bounds() {
        let mut rng = Pcg64::new(3);
        // The paper's TE execution-time model: mean 5 min, trunc at 30 min.
        let d = TruncatedNormal::new(5.0, 5.0, 1.0, 30.0);
        for _ in 0..50_000 {
            let x = d.sample(&mut rng);
            assert!((1.0..=30.0).contains(&x), "x={x}");
        }
    }

    #[test]
    fn truncated_normal_mean_shifts_up_when_left_truncated() {
        let mut rng = Pcg64::new(4);
        let d = TruncatedNormal::new(0.0, 1.0, 0.0, 10.0);
        let xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, _) = moments(&xs);
        // Half-normal mean = sqrt(2/pi) ≈ 0.7979.
        assert!((mean - 0.7979).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn truncated_scaled_matches_fig7_construction() {
        let base = TruncatedNormal::new(3.0, 4.0, 0.0, 20.0);
        let twice = base.scaled(2.0);
        assert_eq!(twice.inner.mean, 6.0);
        assert_eq!(twice.inner.std, 8.0);
        assert_eq!(twice.hi, 40.0);
    }

    #[test]
    fn degenerate_truncation_falls_back_to_clamp() {
        let mut rng = Pcg64::new(5);
        // Mean far outside a tiny window: rejection will exhaust.
        let d = TruncatedNormal::new(1000.0, 0.001, 0.0, 1.0);
        let x = d.sample(&mut rng);
        assert_eq!(x, 1.0);
    }

    #[test]
    fn lognormal_median_p95_calibration() {
        let mut rng = Pcg64::new(6);
        let d = LogNormal::from_median_p95(10.0, 100.0);
        let mut xs: Vec<f64> = (0..100_000).map(|_| d.sample(&mut rng)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[xs.len() / 2];
        let p95 = xs[(xs.len() as f64 * 0.95) as usize];
        assert!((med - 10.0).abs() < 0.3, "median={med}");
        assert!((p95 - 100.0).abs() < 5.0, "p95={p95}");
    }

    #[test]
    fn exponential_mean_is_inverse_rate() {
        let mut rng = Pcg64::new(7);
        let d = Exponential::new(0.25);
        let xs: Vec<f64> = (0..200_000).map(|_| d.sample(&mut rng)).collect();
        let (mean, _) = moments(&xs);
        assert!((mean - 4.0).abs() < 0.05, "mean={mean}");
    }
}
