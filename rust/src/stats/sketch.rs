//! A deterministic, mergeable streaming quantile sketch.
//!
//! Log-spaced histogram (HDR/DDSketch-style): a positive value `v` lands in
//! bin `⌊log_γ(v / MIN_TRACKED)⌋` with growth factor `γ = 1.005`, so every
//! bin spans a 0.5% relative range and any quantile estimate (the bin's
//! geometric midpoint, clamped to the exact observed min/max) carries at
//! most ~0.25% relative error — comfortably inside the 1% the streaming
//! acceptance tests demand, at a few KiB of O(1) memory per sketch.
//!
//! Properties the streaming pipeline relies on:
//!
//! * **Deterministic & seed-free** — the sketch is a pure function of the
//!   inserted multiset; insertion order only affects the (unused-for-
//!   quantiles) floating-point `sum` in its last bits.
//! * **Mergeable** — [`QuantileSketch::merge`] adds bin counts
//!   elementwise, so sweep cells can be combined in any grouping with the
//!   same result as one big sketch over the pooled samples. This replaces
//!   pooling raw per-job slowdown vectors (O(total jobs) memory and a
//!   re-sort per percentile query) in [`sweep`](crate::sweep).
//! * **Bounded** — bins are allocated lazily up to a hard cap
//!   ([`MAX_BINS`], covering `[1e-9, ~1e12]`); values outside the tracked
//!   range clamp into the edge bins but still update the exact min/max.
//!
//! Slowdown rates (≥ 1) and re-scheduling intervals (≥ 0 minutes) both fit
//! the tracked range with room to spare.

use crate::util::bin::{BinReader, BinWriter};
use crate::util::json::Json;

/// Geometric bin growth factor (0.5% bins ⇒ ≤ ~0.25% quantile error).
const GAMMA: f64 = 1.005;
/// Smallest positive value tracked with full relative resolution.
const MIN_TRACKED: f64 = 1e-9;
/// Hard cap on bin count: `MIN_TRACKED * GAMMA^MAX_BINS ≈ 2.6e12`.
const MAX_BINS: usize = 9_800;

/// Mergeable log-histogram quantile sketch. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    /// Counts of values in `(0, ∞)`, log-binned; grown lazily.
    bins: Vec<u64>,
    /// Values ≤ 0 (slowdowns never are; zero-minute intervals can be).
    zero_or_less: u64,
    /// Total inserted values.
    count: u64,
    /// Running sum (mean reporting only; not used by quantiles).
    sum: f64,
    /// Exact minimum seen.
    min: f64,
    /// Exact maximum seen.
    max: f64,
}

impl Default for QuantileSketch {
    /// Same as [`QuantileSketch::new`] — `min`/`max` start at the infinity
    /// sentinels, not zero.
    fn default() -> Self {
        QuantileSketch::new()
    }
}

/// Bin index of a positive value.
fn bin_of(v: f64) -> usize {
    debug_assert!(v > 0.0);
    let idx = (v / MIN_TRACKED).ln() / GAMMA.ln();
    if idx <= 0.0 {
        0
    } else {
        (idx as usize).min(MAX_BINS - 1)
    }
}

/// Geometric midpoint of a bin (the quantile estimate for values in it).
fn bin_mid(b: usize) -> f64 {
    MIN_TRACKED * GAMMA.powf(b as f64 + 0.5)
}

impl QuantileSketch {
    /// An empty sketch.
    pub fn new() -> Self {
        QuantileSketch {
            bins: Vec::new(),
            zero_or_less: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Insert one value. Non-finite values are ignored (they cannot be
    /// ranked); the simulator never produces them.
    pub fn insert(&mut self, v: f64) {
        if !v.is_finite() {
            debug_assert!(false, "non-finite sample {v}");
            return;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v <= 0.0 {
            self.zero_or_less += 1;
            return;
        }
        let b = bin_of(v);
        if b >= self.bins.len() {
            self.bins.resize(b + 1, 0);
        }
        self.bins[b] += 1;
    }

    /// Fold another sketch in. Equivalent (for quantiles, exactly; for
    /// `sum`, up to float associativity) to having inserted both sample
    /// streams into one sketch.
    ///
    /// # Error contract
    ///
    /// Every sketch uses the same fixed bin layout (`GAMMA`,
    /// `MIN_TRACKED`), so bins align index-for-index and merging is plain
    /// elementwise addition — it never widens a bin or re-buckets a
    /// sample. Consequently the merged sketch carries *exactly* the same
    /// ≤ ~0.25% relative quantile error as a single sketch over the pooled
    /// stream: error does not compound with the number of merges, the
    /// grouping, or the order (merge is commutative and associative on
    /// everything quantiles read). Merging an empty sketch — in either
    /// direction — is the identity, and exact `min`/`max`/`count` pool
    /// losslessly.
    pub fn merge(&mut self, other: &QuantileSketch) {
        if other.bins.len() > self.bins.len() {
            self.bins.resize(other.bins.len(), 0);
        }
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += *b;
        }
        self.zero_or_less += other.zero_or_less;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of inserted values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact minimum, or NaN when empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.min }
    }

    /// Exact maximum, or NaN when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 { f64::NAN } else { self.max }
    }

    /// Mean of the inserted values, or NaN when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimate the `q`-quantile (`q` in `[0, 1]`), or NaN when empty.
    ///
    /// Uses the same rank convention as the exact
    /// [`percentile`](crate::stats::summary::percentile) (`rank =
    /// q·(n−1)`, the numpy "linear" method), so sketch and exact values are
    /// directly comparable; the estimate is the containing bin's geometric
    /// midpoint clamped to the exact `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
        if self.count == 0 {
            return f64::NAN;
        }
        // Rank of the target sample, rounded to the nearest whole sample.
        let rank = (q * (self.count - 1) as f64).round() as u64;
        if rank == 0 {
            return self.min; // the extremes are tracked exactly
        }
        if rank + 1 >= self.count {
            return self.max;
        }
        if rank < self.zero_or_less {
            // All non-positive values estimate as the exact minimum (they
            // are indistinguishable inside the sketch).
            return self.min;
        }
        let mut seen = self.zero_or_less;
        for (b, c) in self.bins.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bin_mid(b).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Percentile convenience (`p` in `[0, 100]`).
    pub fn percentile(&self, p: f64) -> f64 {
        self.quantile(p / 100.0)
    }

    /// Serialize for a deterministic snapshot. `sum`/`min`/`max` travel as
    /// raw bits, so the restored sketch is bit-identical (including the
    /// `±∞` empty-sketch sentinels).
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.seq(self.bins.len());
        for &b in &self.bins {
            w.u64(b);
        }
        w.u64(self.zero_or_less);
        w.u64(self.count);
        w.f64(self.sum);
        w.f64(self.min);
        w.f64(self.max);
    }

    /// Rebuild a sketch written by [`QuantileSketch::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let n = r.seq()?;
        let mut bins = Vec::with_capacity(n);
        for _ in 0..n {
            bins.push(r.u64()?);
        }
        Ok(QuantileSketch {
            bins,
            zero_or_less: r.u64()?,
            count: r.u64()?,
            sum: r.f64()?,
            min: r.f64()?,
            max: r.f64()?,
        })
    }

    /// Machine-readable dump (count, mean, min/max, p50/p95/p99).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("min", Json::num(self.min())),
            ("max", Json::num(self.max())),
            ("p50", Json::num(self.percentile(50.0))),
            ("p95", Json::num(self.percentile(95.0))),
            ("p99", Json::num(self.percentile(99.0))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::{LogNormal, Sample};
    use crate::stats::rng::Pcg64;
    use crate::stats::summary::percentile;

    #[test]
    fn empty_sketch_is_nan() {
        let s = QuantileSketch::new();
        assert!(s.quantile(0.5).is_nan());
        assert!(s.mean().is_nan());
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn single_value_is_exact() {
        let mut s = QuantileSketch::new();
        s.insert(42.0);
        assert_eq!(s.quantile(0.0), 42.0);
        assert_eq!(s.quantile(0.5), 42.0);
        assert_eq!(s.quantile(1.0), 42.0);
    }

    #[test]
    fn relative_error_bounded_on_uniform_grid() {
        let mut s = QuantileSketch::new();
        let xs: Vec<f64> = (1..=10_000).map(|i| i as f64 / 10.0).collect();
        for &x in &xs {
            s.insert(x);
        }
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let exact = percentile(&xs, p);
            let est = s.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.01, "p{p}: exact {exact}, sketch {est}, rel {rel}");
        }
    }

    #[test]
    fn relative_error_bounded_on_heavy_tail() {
        // Heavy-tailed lognormal — the BE-slowdown regime the sketch backs
        // in production.
        let dist = LogNormal::from_median_p95(3.0, 80.0);
        let mut rng = Pcg64::new(99);
        let mut s = QuantileSketch::new();
        let xs: Vec<f64> = (0..50_000)
            .map(|_| 1.0 + dist.sample(&mut rng))
            .inspect(|&x| s.insert(x))
            .collect();
        for p in [50.0, 95.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = s.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.01, "p{p}: exact {exact}, sketch {est}, rel {rel}");
        }
    }

    #[test]
    fn merge_equals_pooled_insertion() {
        let mut rng = Pcg64::new(7);
        let mut pooled = QuantileSketch::new();
        let mut parts: Vec<QuantileSketch> = (0..4).map(|_| QuantileSketch::new()).collect();
        for i in 0..8_000 {
            let v = rng.next_f64() * 200.0 + 0.5;
            pooled.insert(v);
            parts[i % 4].insert(v);
        }
        let mut merged = QuantileSketch::new();
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.count(), pooled.count());
        for p in [10.0, 50.0, 95.0, 99.0] {
            assert_eq!(
                merged.percentile(p).to_bits(),
                pooled.percentile(p).to_bits(),
                "merge must be exactly equivalent to pooled insertion"
            );
        }
        // Merge order must not matter either.
        let mut reversed = QuantileSketch::new();
        for p in parts.iter().rev() {
            reversed.merge(p);
        }
        assert_eq!(reversed.percentile(95.0).to_bits(), merged.percentile(95.0).to_bits());
    }

    #[test]
    fn merging_empty_sketches_is_the_identity() {
        // empty <- empty stays empty.
        let mut e = QuantileSketch::new();
        e.merge(&QuantileSketch::new());
        assert_eq!(e.count(), 0);
        assert!(e.quantile(0.5).is_nan());
        assert!(e.min().is_nan() && e.max().is_nan());

        // nonempty <- empty changes nothing (bit-for-bit).
        let mut s = QuantileSketch::new();
        for v in [1.0, 2.5, 40.0] {
            s.insert(v);
        }
        let before = s.clone();
        s.merge(&QuantileSketch::new());
        assert_eq!(s, before);

        // empty <- nonempty equals the source on everything quantiles
        // read (sum may differ in its last bits only when folding many
        // parts; a single merge is exact here too).
        let mut t = QuantileSketch::new();
        t.merge(&before);
        assert_eq!(t, before);
    }

    #[test]
    fn merging_singletons_matches_direct_insertion() {
        let values = [0.003, 1.0, 7.25, 7.25, 1e4];
        let mut direct = QuantileSketch::new();
        let mut merged = QuantileSketch::new();
        for &v in &values {
            direct.insert(v);
            let mut one = QuantileSketch::new();
            one.insert(v);
            merged.merge(&one);
        }
        assert_eq!(merged, direct, "singleton merges == direct insertion");
        assert_eq!(merged.quantile(0.0), 0.003);
        assert_eq!(merged.quantile(1.0), 1e4);
        assert_eq!(merged.count(), values.len() as u64);
    }

    #[test]
    fn merged_heavy_tail_keeps_the_single_sketch_error_bound() {
        // Shard a heavy-tailed lognormal stream (the BE-slowdown regime)
        // over 8 sketches, merge, and hold the merged result to the same
        // 1% bound the single-sketch tests use — per the merge contract,
        // pooling must not widen the error.
        let dist = LogNormal::from_median_p95(2.0, 60.0);
        let mut rng = Pcg64::new(17);
        let mut pooled = QuantileSketch::new();
        let mut shards: Vec<QuantileSketch> = (0..8).map(|_| QuantileSketch::new()).collect();
        let xs: Vec<f64> = (0..40_000)
            .map(|i| {
                let v = 1.0 + dist.sample(&mut rng);
                pooled.insert(v);
                shards[i % 8].insert(v);
                v
            })
            .collect();
        let mut merged = QuantileSketch::new();
        for s in &shards {
            merged.merge(s);
        }
        for p in [50.0, 95.0, 99.0] {
            let exact = percentile(&xs, p);
            let est = merged.percentile(p);
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.01, "p{p}: exact {exact}, merged {est}, rel {rel}");
            assert_eq!(
                merged.percentile(p).to_bits(),
                pooled.percentile(p).to_bits(),
                "merged quantiles must equal the pooled sketch exactly"
            );
        }
        assert_eq!(merged.count(), pooled.count());
        assert_eq!(merged.min(), pooled.min());
        assert_eq!(merged.max(), pooled.max());
    }

    #[test]
    fn zero_and_extreme_values_survive() {
        let mut s = QuantileSketch::new();
        s.insert(0.0);
        s.insert(1e-12); // below MIN_TRACKED: clamps into bin 0
        s.insert(1e15); // above the cap: clamps into the last bin
        assert_eq!(s.count(), 3);
        assert_eq!(s.quantile(0.0), 0.0, "min is exact");
        assert_eq!(s.quantile(1.0), 1e15, "max is exact");
    }

    #[test]
    fn snapshot_round_trip_is_bit_exact() {
        let mut rng = Pcg64::new(29);
        let mut s = QuantileSketch::new();
        for _ in 0..5_000 {
            s.insert(rng.next_f64() * 1e4);
        }
        s.insert(0.0);
        let mut w = crate::util::bin::BinWriter::new();
        s.snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let mut r = crate::util::bin::BinReader::new(&bytes);
        let t = QuantileSketch::restore_bin(&mut r).unwrap();
        r.expect_end().unwrap();
        assert_eq!(t, s);
        assert_eq!(t.sum.to_bits(), s.sum.to_bits());

        // The empty sketch's ±∞ sentinels survive too.
        let mut w = crate::util::bin::BinWriter::new();
        QuantileSketch::new().snapshot_bin(&mut w);
        let bytes = w.into_bytes();
        let e = QuantileSketch::restore_bin(&mut crate::util::bin::BinReader::new(&bytes)).unwrap();
        assert_eq!(e, QuantileSketch::new());
    }

    #[test]
    fn memory_stays_bounded() {
        let mut s = QuantileSketch::new();
        let mut rng = Pcg64::new(3);
        for _ in 0..100_000 {
            s.insert(1.0 + rng.next_f64() * 1e6);
        }
        assert!(s.bins.len() <= MAX_BINS);
    }
}
