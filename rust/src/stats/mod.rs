//! Statistics substrate: deterministic RNG, distribution sampling, and
//! summary statistics (percentiles).
//!
//! The offline image ships no `rand`/`statrs`, so this module implements
//! the small, well-specified pieces the evaluation needs: a PCG generator,
//! Box-Muller normals with truncation (the paper's §4.2 workload model),
//! lognormals (for the synthesized institution trace), exponential
//! inter-arrivals, and exact percentile computation.

pub mod dist;
pub mod rng;
pub mod summary;

pub use dist::{Exponential, LogNormal, Normal, TruncatedNormal};
pub use rng::Pcg64;
pub use summary::{percentile, percentiles, Summary};
