//! Statistics substrate: deterministic RNG, distribution sampling, and
//! summary statistics (percentiles).
//!
//! The offline image ships no `rand`/`statrs`, so this module implements
//! the small, well-specified pieces the evaluation needs: a PCG generator,
//! Box-Muller normals with truncation (the paper's §4.2 workload model),
//! lognormals (for the synthesized institution trace), exponential
//! inter-arrivals, exact percentile computation, and a mergeable streaming
//! quantile [`sketch`] for O(1)-memory percentiles over streamed runs.

pub mod dist;
pub mod rng;
pub mod sketch;
pub mod summary;

pub use dist::{Exponential, LogNormal, Normal, TruncatedNormal};
pub use rng::Pcg64;
pub use sketch::QuantileSketch;
pub use summary::{percentile, percentiles, Summary};
