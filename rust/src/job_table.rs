//! The resident job table: a slab with a free list, keyed by [`JobId`].
//!
//! The streaming simulator (see [`sim`](crate::sim)) keeps only *live* jobs
//! resident: a job is inserted when its arrival is pulled from the
//! [`ArrivalSource`](crate::workload::source::ArrivalSource) and removed
//! ("retired") the tick it completes, with its outcome folded into a
//! metrics sink. Resident state is therefore O(live jobs), not
//! O(total jobs) — the property that opens year-scale and million-job
//! traces (`peak_live` is the high-water counter the scale bench and CI
//! smoke assert on).
//!
//! Retired slots go on a free list and are reused, so the slab does not
//! grow past the live-set high-water mark. The id → slot index is a dense
//! `Vec<u32>` (ids are assigned densely in submission order by every
//! workload source); at 4 bytes per job ever seen it is negligible next to
//! the ~200-byte `Job` records the slab avoids keeping.
//!
//! Lookups of retired or not-yet-inserted ids return `None` from
//! [`JobTable::get`] / [`JobTable::epoch_of`] — the
//! [`EventClock`](crate::sched::clock::EventClock) relies on this to treat
//! events predicted for retired jobs as stale.

use crate::job::{Job, JobId};

const ABSENT: u32 = u32::MAX;
/// Sentinel for "was resident, has been retired" — distinct from `ABSENT`
/// ("never seen") so the control plane can tell a stale reference to a
/// finished job from a reference to one that has not arrived yet.
const RETIRED: u32 = u32::MAX - 1;

/// Slab of live jobs with O(1) insert/lookup/retire by [`JobId`].
#[derive(Debug, Default)]
pub struct JobTable {
    /// Slab slots; `None` = free (on the free list).
    slots: Vec<Option<Job>>,
    /// Indices of free slots, reused LIFO.
    free: Vec<u32>,
    /// Job id → slot index (`ABSENT` when not resident).
    slot_of: Vec<u32>,
    /// Jobs currently resident.
    live: usize,
    /// High-water mark of `live` — the counter the scale bench asserts on.
    peak_live: usize,
    /// Total jobs ever inserted.
    inserted: u64,
}

impl JobTable {
    /// An empty table.
    pub fn new() -> Self {
        JobTable::default()
    }

    /// Build a table holding `jobs` (tests and small fixed workloads).
    pub fn from_jobs(jobs: Vec<Job>) -> Self {
        let mut t = JobTable::new();
        for j in jobs {
            t.insert(j);
        }
        t
    }

    /// Insert a job. Panics (debug) if the id is already resident.
    pub fn insert(&mut self, job: Job) {
        let id = job.id().0 as usize;
        if id >= self.slot_of.len() {
            self.slot_of.resize(id + 1, ABSENT);
        }
        debug_assert_eq!(self.slot_of[id], ABSENT, "{} inserted twice", job.id());
        let slot = match self.free.pop() {
            Some(s) => s as usize,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        self.slots[slot] = Some(job);
        self.slot_of[id] = slot as u32;
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        self.inserted += 1;
    }

    /// Retire a job: remove it and free its slot for reuse. Panics if the
    /// id is not resident.
    pub fn remove(&mut self, id: JobId) -> Job {
        let slot = self.slot_of[id.0 as usize];
        assert!(slot < RETIRED, "{id} not resident");
        self.slot_of[id.0 as usize] = RETIRED;
        self.free.push(slot);
        self.live -= 1;
        self.slots[slot as usize].take().expect("occupied slot")
    }

    /// Shared view of a resident job, or `None` if retired / never seen.
    pub fn get(&self, id: JobId) -> Option<&Job> {
        let slot = *self.slot_of.get(id.0 as usize)?;
        if slot >= RETIRED {
            return None;
        }
        self.slots[slot as usize].as_ref()
    }

    /// Mutable view of a resident job.
    pub fn get_mut(&mut self, id: JobId) -> Option<&mut Job> {
        let slot = *self.slot_of.get(id.0 as usize)?;
        if slot >= RETIRED {
            return None;
        }
        self.slots[slot as usize].as_mut()
    }

    /// Epoch of a resident job; `None` marks the id's clock entries stale
    /// (retired jobs have no future events).
    pub fn epoch_of(&self, id: JobId) -> Option<u64> {
        self.get(id).map(|j| j.epoch)
    }

    /// Is `id` currently resident?
    pub fn contains(&self, id: JobId) -> bool {
        self.get(id).is_some()
    }

    /// Has a job with this id *ever* been inserted? True for resident and
    /// retired jobs, false for jobs no source has yielded yet. The
    /// scenario driver uses this to tell a stale cancellation (target
    /// already retired → drop) from a premature one (target not yet
    /// arrived → hold and retry).
    pub fn seen(&self, id: JobId) -> bool {
        self.slot_of
            .get(id.0 as usize)
            .is_some_and(|slot| *slot != ABSENT)
    }

    /// Number of resident jobs.
    pub fn live(&self) -> usize {
        self.live
    }

    /// High-water mark of the resident set over the table's lifetime.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Total jobs ever inserted (live + retired).
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// True when no job is resident.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate the resident jobs in slot order (deterministic for a given
    /// insert/retire sequence, *not* id order).
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }
}

impl std::ops::Index<JobId> for JobTable {
    type Output = Job;

    fn index(&self, id: JobId) -> &Job {
        self.get(id)
            .unwrap_or_else(|| panic!("{id} not resident in the job table"))
    }
}

impl std::ops::IndexMut<JobId> for JobTable {
    fn index_mut(&mut self, id: JobId) -> &mut Job {
        self.get_mut(id)
            .unwrap_or_else(|| panic!("{id} not resident in the job table"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobClass, JobSpec};
    use crate::resources::ResourceVec;

    fn job(id: u32) -> Job {
        Job::new(JobSpec::new(
            id,
            JobClass::Be,
            ResourceVec::new(1.0, 1.0, 0.0),
            0,
            10,
            2,
        ))
    }

    #[test]
    fn insert_lookup_remove_roundtrip() {
        let mut t = JobTable::new();
        t.insert(job(0));
        t.insert(job(1));
        assert_eq!(t.live(), 2);
        assert!(t.contains(JobId(0)));
        assert_eq!(t[JobId(1)].id(), JobId(1));
        let j = t.remove(JobId(0));
        assert_eq!(j.id(), JobId(0));
        assert!(!t.contains(JobId(0)));
        assert!(t.get(JobId(0)).is_none());
        assert_eq!(t.live(), 1);
        assert_eq!(t.inserted(), 2);
    }

    #[test]
    fn slots_are_reused_and_peak_tracks_high_water() {
        let mut t = JobTable::new();
        // Interleave insert/remove: the slab must not grow past the peak
        // live set.
        for i in 0..100u32 {
            t.insert(job(i));
            if i >= 3 {
                t.remove(JobId(i - 3));
            }
        }
        assert_eq!(t.peak_live(), 4);
        assert_eq!(t.slots.len(), 4, "slab bounded by peak live set");
        assert_eq!(t.live(), 4);
        assert_eq!(t.inserted(), 100);
    }

    #[test]
    fn retired_ids_report_no_epoch() {
        let mut t = JobTable::new();
        t.insert(job(7));
        assert_eq!(t.epoch_of(JobId(7)), Some(0));
        t[JobId(7)].epoch += 3;
        assert_eq!(t.epoch_of(JobId(7)), Some(3));
        t.remove(JobId(7));
        assert_eq!(t.epoch_of(JobId(7)), None);
        assert_eq!(t.epoch_of(JobId(999)), None, "never-seen id");
    }

    #[test]
    fn seen_distinguishes_retired_from_future_ids() {
        let mut t = JobTable::new();
        t.insert(job(0));
        t.insert(job(1));
        assert!(t.seen(JobId(0)) && t.seen(JobId(1)));
        assert!(!t.seen(JobId(2)), "not yielded yet");
        t.remove(JobId(0));
        assert!(t.seen(JobId(0)), "retired is still seen");
        assert!(!t.contains(JobId(0)));
        // The freed slot is reused without confusing the bookkeeping.
        t.insert(job(2));
        assert!(t.seen(JobId(2)) && t.contains(JobId(2)));
        assert!(t.seen(JobId(0)) && !t.contains(JobId(0)));
    }

    #[test]
    fn iter_visits_exactly_the_live_set() {
        let mut t = JobTable::from_jobs(vec![job(0), job(1), job(2)]);
        t.remove(JobId(1));
        let ids: Vec<u32> = t.iter().map(|j| j.id().0).collect();
        assert_eq!(ids.len(), 2);
        assert!(ids.contains(&0) && ids.contains(&2));
    }

    #[test]
    #[should_panic]
    fn indexing_a_retired_job_panics() {
        let mut t = JobTable::from_jobs(vec![job(0)]);
        t.remove(JobId(0));
        let _ = &t[JobId(0)];
    }
}
