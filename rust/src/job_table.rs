//! The resident job table: a slab with a free list, keyed by [`JobId`],
//! with hot scheduling fields split into struct-of-arrays columns.
//!
//! The streaming simulator (see [`sim`](crate::sim)) keeps only *live* jobs
//! resident: a job is inserted when its arrival is pulled from the
//! [`ArrivalSource`](crate::workload::source::ArrivalSource) and removed
//! ("retired") the tick it completes, with its outcome folded into a
//! metrics sink. Resident state is therefore O(live jobs), not
//! O(total jobs) — the property that opens year-scale and million-job
//! traces (`peak_live` is the high-water counter the scale bench and CI
//! smoke assert on).
//!
//! Retired slots go on a free list and are reused, so the slab does not
//! grow past the live-set high-water mark. The id → slot index is a dense
//! `Vec<u32>` (ids are assigned densely in submission order by every
//! workload source); at 4 bytes per job ever seen it is negligible next to
//! the ~200-byte `Job` records the slab avoids keeping.
//!
//! ## Struct-of-arrays columns
//!
//! The fields the scheduler's hot loops touch for *every* queued or active
//! job each round — the clock-staleness epoch, the admission-layer tenant,
//! and the demand vector — live in parallel slot-indexed arrays
//! (`epochs` / `tenants` / `demands`) rather than inside the ~200-byte
//! `Job` records, so admission scans and event-staleness probes walk
//! cache-line-friendly columns instead of chasing full structs. The
//! columns are bounded by the slab (peak-live slots, not ids ever seen)
//! and are reset on slot reuse. The lifecycle epoch moved here outright:
//! [`Job`] no longer carries one, and transitions are stamped via
//! [`JobTable::bump_epoch`] by the scheduler that owns the clock.
//!
//! Lookups of retired or not-yet-inserted ids return `None` from
//! [`JobTable::get`] / [`JobTable::epoch_of`] — the
//! [`EventClock`](crate::sched::clock::EventClock) relies on this to treat
//! events predicted for retired jobs as stale.

use crate::job::{Job, JobId, TenantId};
use crate::resources::ResourceVec;
use crate::util::bin::{BinReader, BinWriter};
use crate::Minutes;
use anyhow::bail;

const ABSENT: u32 = u32::MAX;
/// Sentinel for "was resident, has been retired" — distinct from `ABSENT`
/// ("never seen") so the control plane can tell a stale reference to a
/// finished job from a reference to one that has not arrived yet.
const RETIRED: u32 = u32::MAX - 1;

/// Slab of live jobs with O(1) insert/lookup/retire by [`JobId`], plus
/// slot-indexed struct-of-arrays columns for the hot scheduling fields
/// (see the module docs).
#[derive(Debug, Default)]
pub struct JobTable {
    /// Slab slots; `None` = free (on the free list).
    slots: Vec<Option<Job>>,
    /// Indices of free slots, reused LIFO.
    free: Vec<u32>,
    /// Job id → slot index (`ABSENT` when not resident).
    slot_of: Vec<u32>,
    /// Per-slot lifecycle epoch (bumped by [`JobTable::bump_epoch`] on
    /// every transition; stamps [`EventClock`](crate::sched::clock::EventClock)
    /// entries). Reset to 0 when a freed slot is reused.
    epochs: Vec<u64>,
    /// Per-slot tenant (immutable copy of `spec.tenant`; the admission
    /// layer's fair-share scans read this column, not the `Job`).
    tenants: Vec<TenantId>,
    /// Per-slot demand vector (immutable copy of `spec.demand`; placement
    /// and quota probes read this column).
    demands: Vec<ResourceVec>,
    /// Jobs currently resident.
    live: usize,
    /// High-water mark of `live` — the counter the scale bench asserts on.
    peak_live: usize,
    /// Total jobs ever inserted.
    inserted: u64,
}

impl JobTable {
    /// An empty table.
    pub fn new() -> Self {
        JobTable::default()
    }

    /// Build a table holding `jobs` (tests and small fixed workloads).
    pub fn from_jobs(jobs: Vec<Job>) -> Self {
        let mut t = JobTable::new();
        for j in jobs {
            t.insert(j);
        }
        t
    }

    /// Insert a job. Panics (debug) if the id is already resident.
    pub fn insert(&mut self, job: Job) {
        let id = job.id().0 as usize;
        if id >= self.slot_of.len() {
            self.slot_of.resize(id + 1, ABSENT);
        }
        debug_assert_eq!(self.slot_of[id], ABSENT, "{} inserted twice", job.id());
        let tenant = job.spec.tenant;
        let demand = job.spec.demand;
        let slot = match self.free.pop() {
            Some(s) => {
                let s = s as usize;
                self.epochs[s] = 0;
                self.tenants[s] = tenant;
                self.demands[s] = demand;
                s
            }
            None => {
                self.slots.push(None);
                self.epochs.push(0);
                self.tenants.push(tenant);
                self.demands.push(demand);
                self.slots.len() - 1
            }
        };
        self.slots[slot] = Some(job);
        self.slot_of[id] = slot as u32;
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        self.inserted += 1;
    }

    /// Retire a job: remove it and free its slot for reuse. Panics if the
    /// id is not resident (debug builds distinguish a double-retire).
    pub fn remove(&mut self, id: JobId) -> Job {
        let slot = self.slot_of[id.0 as usize];
        debug_assert!(slot != RETIRED, "{id} retired twice");
        assert!(slot < RETIRED, "{id} not resident");
        self.slot_of[id.0 as usize] = RETIRED;
        self.free.push(slot);
        self.live -= 1;
        self.slots[slot as usize].take().expect("occupied slot")
    }

    /// Shared view of a resident job, or `None` if retired / never seen.
    pub fn get(&self, id: JobId) -> Option<&Job> {
        let slot = *self.slot_of.get(id.0 as usize)?;
        if slot >= RETIRED {
            return None;
        }
        self.slots[slot as usize].as_ref()
    }

    /// Mutable view of a resident job.
    pub fn get_mut(&mut self, id: JobId) -> Option<&mut Job> {
        let slot = *self.slot_of.get(id.0 as usize)?;
        if slot >= RETIRED {
            return None;
        }
        self.slots[slot as usize].as_mut()
    }

    /// Epoch of a resident job; `None` marks the id's clock entries stale
    /// (retired jobs have no future events). A column probe — the `Job`
    /// record itself is never touched.
    pub fn epoch_of(&self, id: JobId) -> Option<u64> {
        let slot = *self.slot_of.get(id.0 as usize)?;
        if slot >= RETIRED {
            return None;
        }
        Some(self.epochs[slot as usize])
    }

    /// Bump a resident job's lifecycle epoch (invalidating every clock
    /// entry stamped with the old one) and return the new value — the
    /// stamp for any entry pushed for the job's *new* state. Panics if the
    /// id is not resident.
    pub fn bump_epoch(&mut self, id: JobId) -> u64 {
        let slot = self.slot_of[id.0 as usize];
        assert!(slot < RETIRED, "{id} not resident");
        let e = &mut self.epochs[slot as usize];
        *e += 1;
        *e
    }

    /// Tenant of a resident job (column read). Panics if not resident,
    /// like indexing — queued ids are resident by invariant.
    pub fn tenant_of(&self, id: JobId) -> TenantId {
        let slot = self.slot_of[id.0 as usize];
        assert!(slot < RETIRED, "{id} not resident");
        self.tenants[slot as usize]
    }

    /// Demand vector of a resident job (column read). Panics if not
    /// resident.
    pub fn demand_of(&self, id: JobId) -> &ResourceVec {
        let slot = self.slot_of[id.0 as usize];
        assert!(slot < RETIRED, "{id} not resident");
        &self.demands[slot as usize]
    }

    /// Settle every resident job's lazily-accounted counters up to `now`
    /// (see [`Job::sync`]) — end-of-run accounting before records or
    /// accrued-wait slowdowns are read.
    pub fn settle_all(&mut self, now: Minutes) {
        for s in self.slots.iter_mut().flatten() {
            s.sync(now);
        }
    }

    /// Is `id` currently resident?
    pub fn contains(&self, id: JobId) -> bool {
        self.get(id).is_some()
    }

    /// Has a job with this id *ever* been inserted? True for resident and
    /// retired jobs, false for jobs no source has yielded yet. The
    /// scenario driver uses this to tell a stale cancellation (target
    /// already retired → drop) from a premature one (target not yet
    /// arrived → hold and retry).
    pub fn seen(&self, id: JobId) -> bool {
        self.slot_of
            .get(id.0 as usize)
            .is_some_and(|slot| *slot != ABSENT)
    }

    /// Number of resident jobs.
    pub fn live(&self) -> usize {
        self.live
    }

    /// High-water mark of the resident set over the table's lifetime.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Total jobs ever inserted (live + retired).
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// True when no job is resident.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate the resident jobs in slot order (deterministic for a given
    /// insert/retire sequence, *not* id order).
    pub fn iter(&self) -> impl Iterator<Item = &Job> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    /// Serialize the slab exactly — slot contents (including which slots
    /// are free), the free-list LIFO order, the `slot_of` map with its
    /// `ABSENT`/`RETIRED` sentinels, the SoA columns, and the counters.
    /// Slot indices are part of the behavioural state: iteration order and
    /// slot-reuse order both feed scheduling determinism, so a restored
    /// table must reproduce them bit-for-bit.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.seq(self.slots.len());
        for slot in &self.slots {
            match slot {
                Some(job) => {
                    w.bool(true);
                    job.snapshot_bin(w);
                }
                None => w.bool(false),
            }
        }
        w.seq(self.free.len());
        for &f in &self.free {
            w.u32(f);
        }
        w.seq(self.slot_of.len());
        for &s in &self.slot_of {
            w.u32(s);
        }
        w.seq(self.epochs.len());
        for &e in &self.epochs {
            w.u64(e);
        }
        w.seq(self.tenants.len());
        for t in &self.tenants {
            w.u32(t.0);
        }
        w.seq(self.demands.len());
        for d in &self.demands {
            d.snapshot_bin(w);
        }
        w.usize(self.live);
        w.usize(self.peak_live);
        w.u64(self.inserted);
    }

    /// Rebuild a table written by [`JobTable::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let n_slots = r.seq()?;
        let mut slots = Vec::with_capacity(n_slots);
        for _ in 0..n_slots {
            if r.bool()? {
                slots.push(Some(Job::restore_bin(r)?));
            } else {
                slots.push(None);
            }
        }
        let n = r.seq()?;
        let mut free = Vec::with_capacity(n);
        for _ in 0..n {
            free.push(r.u32()?);
        }
        let n = r.seq()?;
        let mut slot_of = Vec::with_capacity(n);
        for _ in 0..n {
            slot_of.push(r.u32()?);
        }
        let n = r.seq()?;
        let mut epochs = Vec::with_capacity(n);
        for _ in 0..n {
            epochs.push(r.u64()?);
        }
        let n = r.seq()?;
        let mut tenants = Vec::with_capacity(n);
        for _ in 0..n {
            tenants.push(TenantId(r.u32()?));
        }
        let n = r.seq()?;
        let mut demands = Vec::with_capacity(n);
        for _ in 0..n {
            demands.push(ResourceVec::restore_bin(r)?);
        }
        let live = r.usize()?;
        let peak_live = r.usize()?;
        let inserted = r.u64()?;
        if epochs.len() != n_slots || tenants.len() != n_slots || demands.len() != n_slots {
            bail!("snapshot corrupt: job-table columns do not match the slab");
        }
        let resident = slots.iter().filter(|s| s.is_some()).count();
        if resident != live || free.len() + live != n_slots {
            bail!("snapshot corrupt: job-table free list / live count mismatch");
        }
        Ok(JobTable {
            slots,
            free,
            slot_of,
            epochs,
            tenants,
            demands,
            live,
            peak_live,
            inserted,
        })
    }
}

impl std::ops::Index<JobId> for JobTable {
    type Output = Job;

    fn index(&self, id: JobId) -> &Job {
        self.get(id)
            .unwrap_or_else(|| panic!("{id} not resident in the job table"))
    }
}

impl std::ops::IndexMut<JobId> for JobTable {
    fn index_mut(&mut self, id: JobId) -> &mut Job {
        self.get_mut(id)
            .unwrap_or_else(|| panic!("{id} not resident in the job table"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobClass, JobSpec};
    use crate::resources::ResourceVec;

    fn job(id: u32) -> Job {
        Job::new(JobSpec::new(
            id,
            JobClass::Be,
            ResourceVec::new(1.0, 1.0, 0.0),
            0,
            10,
            2,
        ))
    }

    #[test]
    fn insert_lookup_remove_roundtrip() {
        let mut t = JobTable::new();
        t.insert(job(0));
        t.insert(job(1));
        assert_eq!(t.live(), 2);
        assert!(t.contains(JobId(0)));
        assert_eq!(t[JobId(1)].id(), JobId(1));
        let j = t.remove(JobId(0));
        assert_eq!(j.id(), JobId(0));
        assert!(!t.contains(JobId(0)));
        assert!(t.get(JobId(0)).is_none());
        assert_eq!(t.live(), 1);
        assert_eq!(t.inserted(), 2);
    }

    #[test]
    fn slots_are_reused_and_peak_tracks_high_water() {
        let mut t = JobTable::new();
        // Interleave insert/remove: the slab must not grow past the peak
        // live set.
        for i in 0..100u32 {
            t.insert(job(i));
            if i >= 3 {
                t.remove(JobId(i - 3));
            }
        }
        assert_eq!(t.peak_live(), 4);
        assert_eq!(t.slots.len(), 4, "slab bounded by peak live set");
        assert_eq!(t.live(), 4);
        assert_eq!(t.inserted(), 100);
    }

    #[test]
    fn free_list_reuse_over_100k_churn_cycles() {
        // A windowed churn: 100k insert/retire cycles with at most 65 jobs
        // live at once. The slab and every SoA column must stay bounded by
        // the high-water mark — any free-list leak shows up as growth.
        const WINDOW: u32 = 64;
        let mut t = JobTable::new();
        for i in 0..100_000u32 {
            t.insert(job(i));
            if i >= WINDOW {
                t.remove(JobId(i - WINDOW));
            }
        }
        assert_eq!(t.inserted(), 100_000);
        assert_eq!(t.live(), WINDOW as usize + 1);
        assert_eq!(t.peak_live(), WINDOW as usize + 1);
        assert_eq!(t.slots.len(), t.peak_live(), "slab never grows past peak_live");
        assert_eq!(
            t.free.len() + t.live(),
            t.slots.len(),
            "every non-live slot is on the free list exactly once"
        );
        assert_eq!(t.epochs.len(), t.slots.len(), "columns track the slab");
        assert_eq!(t.tenants.len(), t.slots.len());
        assert_eq!(t.demands.len(), t.slots.len());
    }

    #[test]
    #[should_panic]
    fn double_retire_is_caught() {
        let mut t = JobTable::from_jobs(vec![job(0)]);
        t.remove(JobId(0));
        t.remove(JobId(0));
    }

    #[test]
    fn retired_ids_report_no_epoch() {
        let mut t = JobTable::new();
        t.insert(job(7));
        assert_eq!(t.epoch_of(JobId(7)), Some(0));
        assert_eq!(t.bump_epoch(JobId(7)), 1);
        assert_eq!(t.bump_epoch(JobId(7)), 2);
        assert_eq!(t.epoch_of(JobId(7)), Some(2));
        t.remove(JobId(7));
        assert_eq!(t.epoch_of(JobId(7)), None);
        assert_eq!(t.epoch_of(JobId(999)), None, "never-seen id");
    }

    #[test]
    fn slot_reuse_resets_the_epoch_column() {
        let mut t = JobTable::new();
        t.insert(job(0));
        t.bump_epoch(JobId(0));
        t.bump_epoch(JobId(0));
        t.remove(JobId(0));
        t.insert(job(1)); // reuses slot 0
        assert_eq!(t.epoch_of(JobId(1)), Some(0), "fresh epoch on reuse");
    }

    #[test]
    fn soa_columns_mirror_the_spec() {
        let mut t = JobTable::new();
        t.insert(job(3));
        assert_eq!(t.tenant_of(JobId(3)), t[JobId(3)].spec.tenant);
        assert_eq!(*t.demand_of(JobId(3)), t[JobId(3)].spec.demand);
    }

    #[test]
    fn seen_distinguishes_retired_from_future_ids() {
        let mut t = JobTable::new();
        t.insert(job(0));
        t.insert(job(1));
        assert!(t.seen(JobId(0)) && t.seen(JobId(1)));
        assert!(!t.seen(JobId(2)), "not yielded yet");
        t.remove(JobId(0));
        assert!(t.seen(JobId(0)), "retired is still seen");
        assert!(!t.contains(JobId(0)));
        // The freed slot is reused without confusing the bookkeeping.
        t.insert(job(2));
        assert!(t.seen(JobId(2)) && t.contains(JobId(2)));
        assert!(t.seen(JobId(0)) && !t.contains(JobId(0)));
    }

    #[test]
    fn iter_visits_exactly_the_live_set() {
        let mut t = JobTable::from_jobs(vec![job(0), job(1), job(2)]);
        t.remove(JobId(1));
        let ids: Vec<u32> = t.iter().map(|j| j.id().0).collect();
        assert_eq!(ids.len(), 2);
        assert!(ids.contains(&0) && ids.contains(&2));
    }

    #[test]
    fn settle_all_syncs_every_resident_job() {
        let mut t = JobTable::from_jobs(vec![job(0), job(1)]);
        t.settle_all(6);
        assert_eq!(t[JobId(0)].waiting, 6, "pending jobs accrued their wait");
        assert_eq!(t[JobId(1)].waiting, 6);
    }

    #[test]
    #[should_panic]
    fn indexing_a_retired_job_panics() {
        let mut t = JobTable::from_jobs(vec![job(0)]);
        t.remove(JobId(0));
        let _ = &t[JobId(0)];
    }
}
