//! Small utilities the offline image forces us to own: JSON, CLI flag
//! parsing, and fixed-width table rendering.

pub mod cli;
pub mod json;
pub mod table;
