//! Small utilities the offline image forces us to own: JSON, CLI flag
//! parsing, fixed-width table rendering, and the snapshot binary codec.

pub mod bin;
pub mod cli;
pub mod json;
pub mod table;
