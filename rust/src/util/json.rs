//! A small, strict JSON parser and writer (serde is not available offline).
//!
//! Used for: the AOT artifact manifest (`artifacts/manifest.json`, written
//! by `python/compile/aot.py`), experiment configs, and machine-readable
//! result dumps consumed by plotting scripts.
//!
//! Scope: full JSON per RFC 8259 minus `\u` surrogate-pair edge cases
//! beyond the BMP (accepted, decoded permissively). Numbers are f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — results files diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (always an f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted for deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where it occurred.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset into the input at which parsing failed.
    pub pos: usize,
    /// Human-readable description of the failure.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- accessors ----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` convenience: returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    // ---- parse ---------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- serialize -----------------------------------------------------

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Append one JSON number. Crate-visible so the direct line encoders
/// ([`crate::sched::control::JsonLineEncoder`], the wire response
/// encoder) share the exact formatting code with the value tree — byte
/// identity between the two paths holds by construction. Allocation-free:
/// both branches format straight into `out` via `fmt::Write`.
pub(crate) fn write_num(out: &mut String, x: f64) {
    use fmt::Write as _;
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; null is the least-bad round-trip.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

/// Append one JSON string (quotes included, escapes applied). Shared with
/// the direct line encoders like [`write_num`]; allocation-free.
pub(crate) fn write_escaped(out: &mut String, s: &str) {
    use fmt::Write as _;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // UTF-8 continuation: collect the full sequence.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    self.pos = (start + width).min(self.b.len());
                    match std::str::from_utf8(&self.b[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("fitgpp")),
            ("s", Json::num(4.0)),
            ("tags", Json::arr(vec![Json::str("te"), Json::str("be")])),
            ("nested", Json::obj(vec![("p", Json::Null)])),
        ]);
        for text in [v.to_string(), v.to_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line1\nline2\t\"quoted\" \\slash\\ \u{1F600}";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::num(7.0).to_string(), "7");
        assert_eq!(Json::num(7.5).to_string(), "7.5");
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Str("3".into()).as_u64(), None);
    }

    #[test]
    fn deterministic_key_order() {
        let a = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"b":1}"#).unwrap();
        assert_eq!(a.to_string(), b.to_string());
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
