//! Fixed-width and markdown table rendering for benchmark/report output —
//! every bench prints the same rows the paper's tables report.

/// A simple table builder: header + rows of strings, rendered either as
/// aligned plain text or GitHub-flavoured markdown.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Title line (empty = omitted).
    pub title: String,
    /// Column names.
    pub header: Vec<String>,
    /// Data rows; each row's width must match the header.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with a title and column names.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// CSV rendering (for plotting scripts). Fields containing commas,
    /// quotes, or newlines are quoted per RFC 4180 (policy names like
    /// `FitGpp(s=4,P=1)` embed commas).
    pub fn to_csv(&self) -> String {
        fn field(s: &str) -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let render = |cells: &[String]| -> String {
            cells.iter().map(|c| field(c)).collect::<Vec<_>>().join(",")
        };
        let mut out = String::new();
        out.push_str(&render(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float the way the paper's tables do: 3 significant digits,
/// scientific for small values (e.g. `6.3e-1%`).
pub fn sig3(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 100.0 {
        format!("{x:.0}")
    } else if a >= 10.0 {
        format!("{x:.1}")
    } else if a >= 0.1 {
        format!("{x:.2}")
    } else {
        format!("{x:.1e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_text() {
        let mut t = Table::new("T", &["name", "p50", "p95"]);
        t.row(vec!["FIFO".into(), "9.38".into(), "33.4".into()]);
        t.row(vec!["FitGpp".into(), "1.00".into(), "1.15".into()]);
        let s = t.to_text();
        assert!(s.contains("== T =="));
        assert!(s.contains("FIFO"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| a | b |\n|---|---|\n| 1 | 2 |"));
    }

    #[test]
    fn csv_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn csv_quotes_fields_with_commas() {
        let mut t = Table::new("x", &["policy", "v"]);
        t.row(vec!["FitGpp(s=4,P=1)".into(), "1".into()]);
        t.row(vec!["say \"hi\"".into(), "2".into()]);
        assert_eq!(
            t.to_csv(),
            "policy,v\n\"FitGpp(s=4,P=1)\",1\n\"say \"\"hi\"\"\",2\n"
        );
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        Table::new("x", &["a", "b"]).row(vec!["1".into()]);
    }

    #[test]
    fn sig3_bands() {
        assert_eq!(sig3(235.0), "235");
        assert_eq!(sig3(33.4), "33.4");
        assert_eq!(sig3(9.38), "9.38");
        assert_eq!(sig3(0.0063), "6.3e-3");
        assert_eq!(sig3(0.0), "0");
    }
}
