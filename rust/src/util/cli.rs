//! Minimal CLI flag parser (clap is not available offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, `-h/--help` text generation, and typed accessors with
//! defaults. Used by the `fitgpp` binary and every example.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option spec for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// Help text shown by `-h`.
    pub help: &'static str,
    /// Default shown in help (None = no default).
    pub default: Option<&'static str>,
    /// True for boolean flags (no value token).
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-flag tokens, in order.
    pub positional: Vec<String>,
    program: String,
}

/// Parse failures (rendered with the same messages thiserror would have
/// produced; the derive macro is not available offline).
#[derive(Debug)]
pub enum CliError {
    /// An option that was never declared on the [`Cli`].
    Unknown(String),
    /// A value-taking option given as the last token with no value.
    MissingValue(String),
    /// A typed accessor could not parse the raw value: `(name, raw, cause)`.
    BadValue(String, String, String),
    /// `-h`/`--help` was passed.
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} requires a value"),
            CliError::BadValue(name, raw, cause) => {
                write!(f, "invalid value for --{name}: {raw:?} ({cause})")
            }
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

/// A command-line interface: a name, a description, and its options.
#[derive(Debug, Clone)]
pub struct Cli {
    /// Program name shown in help.
    pub name: &'static str,
    /// One-line description shown in help.
    pub about: &'static str,
    /// Declared options.
    pub opts: Vec<OptSpec>,
}

impl Cli {
    /// Start a CLI description.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, opts: Vec::new() }
    }

    /// Option taking a value, with an optional default shown in help.
    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false });
        self
    }

    /// Boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "USAGE: {} [OPTIONS] [ARGS...]\n\nOPTIONS:", self.name);
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <value>", o.name)
            };
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "{left:<32}{}{default}", o.help);
        }
        let _ = writeln!(s, "  {:<30}print this help", "-h, --help");
        s
    }

    /// Parse an explicit argv (first element = program name optional).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, CliError> {
        let mut it = argv.into_iter().peekable();
        let program = it.peek().cloned().unwrap_or_default();
        let mut args = Args { program, ..Default::default() };
        let mut first = true;
        while let Some(tok) = it.next() {
            if first {
                first = false;
                if !tok.starts_with('-') {
                    continue; // program name
                }
            }
            if tok == "-h" || tok == "--help" {
                return Err(CliError::Help);
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.is_flag {
                    args.flags.push(name);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => it.next().ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    args.opts.insert(name, val);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`; on `-h` prints help and exits 0; on error
    /// prints the error + help and exits 2.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args()) {
            Ok(a) => a,
            Err(CliError::Help) => {
                print!("{}", self.help_text());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n");
                eprint!("{}", self.help_text());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn program(&self) -> &str {
        &self.program
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    fn typed<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(raw) => raw.parse::<T>().map_err(|e| {
                CliError::BadValue(name.to_string(), raw.to_string(), e.to_string())
            }),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.typed(name, default).unwrap_or_else(|e| fail(e))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.typed(name, default).unwrap_or_else(|e| fail(e))
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.typed(name, default).unwrap_or_else(|e| fail(e))
    }

    pub fn get_string(&self, name: &str, default: &str) -> String {
        self.get_or(name, default).to_string()
    }
}

fn fail(e: CliError) -> ! {
    eprintln!("error: {e}");
    std::process::exit(2);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("jobs", Some("1024"), "number of jobs")
            .opt("policy", None, "policy name")
            .flag("verbose", "chatty")
    }

    fn argv(s: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.iter().map(|x| x.to_string()))
            .collect()
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let a = cli().parse_from(argv(&["--jobs", "64", "--policy=fitgpp"])).unwrap();
        assert_eq!(a.get("jobs"), Some("64"));
        assert_eq!(a.get("policy"), Some("fitgpp"));
        assert_eq!(a.get_u64("jobs", 0), 64);
    }

    #[test]
    fn flags_and_positionals() {
        let a = cli().parse_from(argv(&["--verbose", "input.csv", "out.csv"])).unwrap();
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.positional, vec!["input.csv", "out.csv"]);
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse_from(argv(&[])).unwrap();
        assert_eq!(a.get_u64("jobs", 1024), 1024);
        assert_eq!(a.get_f64("missing", 4.0), 4.0);
        assert_eq!(a.get_string("policy", "fifo"), "fifo");
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            cli().parse_from(argv(&["--nope", "1"])),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cli().parse_from(argv(&["--jobs"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn help_flag_detected() {
        assert!(matches!(cli().parse_from(argv(&["-h"])), Err(CliError::Help)));
        assert!(matches!(cli().parse_from(argv(&["--help"])), Err(CliError::Help)));
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = cli().parse_from(argv(&["--jobs", "abc"])).unwrap();
        assert!(a.typed::<u64>("jobs", 0).is_err());
    }

    #[test]
    fn help_text_lists_options() {
        let h = cli().help_text();
        assert!(h.contains("--jobs"));
        assert!(h.contains("default: 1024"));
        assert!(h.contains("--verbose"));
    }
}
