//! Minimal little-endian binary codec for deterministic snapshots.
//!
//! The serve-mode snapshot format (see [`serve::snapshot`](crate::serve::snapshot))
//! serializes the full scheduler state through these two types. Design
//! rules, shared with `runtime/checkpoint.rs`:
//!
//! * everything is little-endian and length-prefixed — no alignment, no
//!   padding, no platform dependence;
//! * floats travel as raw IEEE-754 bits ([`f64::to_bits`]), so a
//!   round-trip is bit-exact (including infinities and negative zero —
//!   the quantile sketch's `min`/`max` sentinels depend on this);
//! * the reader is fully bounds-checked and returns typed errors on
//!   truncation or corruption — it never panics and never allocates
//!   unbounded memory from a hostile length prefix.

use anyhow::{bail, Result};

/// Append-only little-endian byte sink.
#[derive(Debug, Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    /// An empty writer.
    pub fn new() -> Self {
        BinWriter::default()
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer, yielding the serialized bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Write one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Write a bool as one byte (`0`/`1`).
    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Write a `u32`, little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `u64`, little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `usize` as a `u64` (platform-independent width).
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Write an `f64` as its raw IEEE-754 bits (bit-exact round-trip).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Write an optional `u64` as a presence byte plus the value.
    pub fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.u64(x);
            }
            None => self.bool(false),
        }
    }

    /// Write a string as a `u64` byte length plus UTF-8 bytes.
    pub fn str(&mut self, s: &str) {
        self.usize(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Write a sequence length prefix (`u64`); follow with the elements.
    pub fn seq(&mut self, n: usize) {
        self.usize(n);
    }
}

/// Bounds-checked little-endian reader over a byte slice. Every accessor
/// returns a typed error on truncation instead of panicking.
#[derive(Debug)]
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    /// Read from `buf`, starting at offset 0.
    pub fn new(buf: &'a [u8]) -> Self {
        BinReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Fail unless every byte has been consumed (trailing garbage is
    /// corruption, not slack).
    pub fn expect_end(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!("snapshot payload has {} trailing bytes", self.remaining());
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "snapshot payload truncated: need {n} bytes at offset {}, {} remain",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a bool; any byte other than `0`/`1` is corruption.
    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("snapshot payload corrupt: bool byte {other}"),
        }
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Read a `usize` written by [`BinWriter::usize`].
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("snapshot length {v} exceeds usize"))
    }

    /// Read an `f64` from its raw bits.
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read an optional `u64` written by [`BinWriter::opt_u64`].
    pub fn opt_u64(&mut self) -> Result<Option<u64>> {
        if self.bool()? {
            Ok(Some(self.u64()?))
        } else {
            Ok(None)
        }
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.usize()?;
        if n > self.remaining() {
            bail!("snapshot string length {n} exceeds the {} remaining bytes", self.remaining());
        }
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("snapshot string is not valid UTF-8"))
    }

    /// Read a sequence length prefix, guarded so a corrupt prefix cannot
    /// trigger an absurd allocation (each element costs at least one
    /// byte, so the count can never exceed the remaining payload).
    pub fn seq(&mut self) -> Result<usize> {
        let n = self.usize()?;
        if n > self.remaining() {
            bail!("snapshot sequence length {n} exceeds the {} remaining bytes", self.remaining());
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_primitive() {
        let mut w = BinWriter::new();
        w.u8(7);
        w.bool(true);
        w.bool(false);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.usize(42);
        w.f64(-0.0);
        w.f64(f64::INFINITY);
        w.f64(f64::NEG_INFINITY);
        w.f64(1.5e-300);
        w.opt_u64(None);
        w.opt_u64(Some(9));
        w.str("héllo");
        w.seq(2);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.usize().unwrap(), 42);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.f64().unwrap(), f64::INFINITY);
        assert_eq!(r.f64().unwrap(), f64::NEG_INFINITY);
        assert_eq!(r.f64().unwrap(), 1.5e-300);
        assert_eq!(r.opt_u64().unwrap(), None);
        assert_eq!(r.opt_u64().unwrap(), Some(9));
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.seq().unwrap(), 2);
        r.expect_end().unwrap();
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = BinWriter::new();
        w.u64(123);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = BinReader::new(&bytes[..cut]);
            assert!(r.u64().is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn hostile_lengths_are_rejected() {
        let mut w = BinWriter::new();
        w.usize(usize::MAX / 2); // absurd sequence length
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(r.seq().is_err());
        let mut r = BinReader::new(&bytes);
        assert!(r.str().is_err());
    }

    #[test]
    fn bad_bool_is_corruption() {
        let mut r = BinReader::new(&[2]);
        assert!(r.bool().is_err());
    }

    #[test]
    fn trailing_bytes_are_detected() {
        let mut w = BinWriter::new();
        w.u8(1);
        w.u8(2);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        r.u8().unwrap();
        assert!(r.expect_end().is_err());
        r.u8().unwrap();
        r.expect_end().unwrap();
    }
}
