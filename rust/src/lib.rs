//! # fitgpp — low-latency job scheduling with preemption for DL clusters
//!
//! A reproduction of *"Low-latency job scheduling with preemption for the
//! development of deep learning"* (Yabuuchi, Taniwaki, Omura; 2019) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a FIFO cluster
//!   scheduler with the *FitGpp* preemption policy, plus the full evaluation
//!   substrate (discrete-time simulator, synthetic/trace workloads, metrics)
//!   and a *live* mode in which scheduled jobs execute real transformer
//!   training steps through PJRT.
//! * **Layer 2** — `python/compile/model.py`: a JAX transformer-LM train
//!   step, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels (fused causal
//!   attention, fused layernorm) called from the L2 graph.
//!
//! Python never runs on the request path; the rust binary is self-contained
//! once `make artifacts` has produced the HLO artifacts.
//!
//! ## Quick tour
//!
//! ```no_run
//! use fitgpp::prelude::*;
//!
//! let spec = ClusterSpec::pfn();                    // 84 nodes, 32C/256G/8GPU
//! let wl = SyntheticWorkload::paper_section_4_2(7). // §4.2 distributions
//!     with_num_jobs(4096).generate();
//! let cfg = SimConfig::new(spec, PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
//! let result = Simulator::new(cfg).run(&wl);
//! println!("{}", result.summary_table());
//! ```
//!
//! For evaluation campaigns — grids of policy × parameter × seed — use the
//! thread-parallel sweep harness instead of looping over `Simulator` by
//! hand:
//!
//! ```no_run
//! use fitgpp::prelude::*;
//!
//! let spec = SweepSpec::table1(4096, &[100, 101, 102, 103]);
//! let result = spec.run(); // all cells in parallel, workloads cached
//! println!("{}", result.table1("Table 1").to_text());
//! ```
//!
//! See `README.md` for the architecture and `EXPERIMENTS.md` for the exact
//! command reproducing every paper figure/table.

#![warn(missing_docs)]

pub mod benchkit;
pub mod cluster;
pub mod config;
pub mod job;
pub mod job_table;
pub mod live;
pub mod metrics;
pub mod queue;
pub mod resources;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod sweep;
pub mod testkit;
pub mod util;
pub mod workload;
pub mod xla;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::cluster::{Cluster, ClusterSpec, NodeAvailability, NodeId};
    pub use crate::job::{Job, JobClass, JobId, JobSpec, JobState, TenantId};
    pub use crate::job_table::JobTable;
    pub use crate::metrics::{Percentiles, SlowdownReport, StreamingMetrics, TenantMetrics};
    pub use crate::resources::ResourceVec;
    pub use crate::sched::admission::{DisciplineKind, QueueDiscipline, TenantDirectory};
    pub use crate::sched::control::{
        ClusterController, EventSubscriber, JsonlEventLog, SchedulerCommand, SchedulerEvent,
        SharedEventLog,
    };
    pub use crate::sched::policy::PolicyKind;
    pub use crate::sched::predict::{EstimatorKind, RuntimeEstimator, SharedEstimator};
    pub use crate::serve::{AttackConfig, AttackReport, ServeConfig, ServeOutcome, ServeStats};
    pub use crate::sim::scenario::ScenarioScript;
    pub use crate::sim::{SimConfig, SimEngine, SimResult, SimSession, Simulator};
    pub use crate::stats::rng::Pcg64;
    pub use crate::stats::sketch::QuantileSketch;
    pub use crate::sweep::{SweepResult, SweepSpec};
    pub use crate::workload::{
        source::{ArrivalSource, ClosedLoopSource, TenantAssigner, WorkloadSource},
        synthetic::{SyntheticSource, SyntheticWorkload},
        trace::{CsvStreamSource, InstitutionSource, Trace},
        Workload,
    };
}

/// Crate-wide time type: simulated minutes since epoch (the paper's
/// scheduler "decides resource allocation at every simulated minute").
pub type Minutes = u64;
