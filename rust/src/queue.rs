//! The FIFO job queue with the paper's re-insertion rule: *"Suspended BE
//! jobs are placed back on the top of the job queue"* (§2).
//!
//! New arrivals append at the tail; preempted jobs push at the head. The
//! scheduler only ever examines the head (FIFO admission — a blocked head
//! blocks everything behind it; that head-of-line blocking is precisely the
//! phenomenon FitGpp mitigates by preempting *small* BE jobs).
//!
//! `JobQueue` is the ordered backing store; *which queued job admission
//! tries next* is decided one layer up, by the pluggable
//! [`QueueDiscipline`](crate::sched::admission::QueueDiscipline) (the
//! default [`Fifo`](crate::sched::admission::Fifo) discipline reproduces
//! the head-only loop verbatim). The TE fast lane uses `JobQueue`
//! directly — it is per-arrival, so there is no head to discipline.

use crate::job::JobId;
use crate::util::bin::{BinReader, BinWriter};
use std::collections::VecDeque;

/// FIFO queue over job ids. Thin wrapper so the re-insertion semantics are
/// documented and testable in one place.
#[derive(Debug, Clone, Default)]
pub struct JobQueue {
    q: VecDeque<JobId>,
}

impl JobQueue {
    pub fn new() -> Self {
        JobQueue { q: VecDeque::new() }
    }

    /// New submission: tail of the queue.
    pub fn submit(&mut self, id: JobId) {
        self.q.push_back(id);
    }

    /// Preempted job returning: *top* of the queue, ahead of everything —
    /// including previously re-queued jobs (most recent preemption first;
    /// within one tick the simulator vacates in deterministic job order, so
    /// results are reproducible).
    pub fn reinsert_front(&mut self, id: JobId) {
        self.q.push_front(id);
    }

    /// Peek the head without removing it (FIFO admission examines only the
    /// head).
    pub fn head(&self) -> Option<JobId> {
        self.q.front().copied()
    }

    /// Pop the head (after a successful placement).
    pub fn pop_head(&mut self) -> Option<JobId> {
        self.q.pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Iterate in queue order (head first). Used by metrics/diagnostics, not
    /// by admission.
    pub fn iter(&self) -> impl Iterator<Item = JobId> + '_ {
        self.q.iter().copied()
    }

    /// Position of a job in the queue (0 = head), if queued.
    pub fn position(&self, id: JobId) -> Option<usize> {
        self.q.iter().position(|j| *j == id)
    }

    /// The job at position `i` (0 = head), if any. The quota-gate
    /// discipline's backfill scan walks the queue by index.
    pub fn get(&self, i: usize) -> Option<JobId> {
        self.q.get(i).copied()
    }

    /// Serialize the queue in order (head first) for a snapshot.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.seq(self.q.len());
        for id in &self.q {
            w.u32(id.0);
        }
    }

    /// Rebuild a queue written by [`JobQueue::snapshot_bin`], preserving
    /// order exactly (including jobs that were re-inserted at the head).
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let n = r.seq()?;
        let mut q = VecDeque::with_capacity(n);
        for _ in 0..n {
            q.push_back(JobId(r.u32()?));
        }
        Ok(JobQueue { q })
    }

    /// Remove a specific job (TE-lane admission is per-arrival: a TE job
    /// whose reservation lands may start while an earlier TE job is still
    /// waiting out a longer drain). Returns true if it was queued.
    pub fn remove(&mut self, id: JobId) -> bool {
        match self.position(id) {
            Some(i) => {
                self.q.remove(i);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_for_submissions() {
        let mut q = JobQueue::new();
        for i in 0..5 {
            q.submit(JobId(i));
        }
        for i in 0..5 {
            assert_eq!(q.pop_head(), Some(JobId(i)));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn preempted_jobs_jump_the_queue() {
        let mut q = JobQueue::new();
        q.submit(JobId(1));
        q.submit(JobId(2));
        q.reinsert_front(JobId(99)); // preempted job
        assert_eq!(q.head(), Some(JobId(99)));
        assert_eq!(q.position(JobId(1)), Some(1));
        assert_eq!(q.position(JobId(2)), Some(2));
    }

    #[test]
    fn multiple_reinserts_are_lifo_among_themselves() {
        let mut q = JobQueue::new();
        q.submit(JobId(1));
        q.reinsert_front(JobId(10));
        q.reinsert_front(JobId(11));
        assert_eq!(q.pop_head(), Some(JobId(11)));
        assert_eq!(q.pop_head(), Some(JobId(10)));
        assert_eq!(q.pop_head(), Some(JobId(1)));
    }

    #[test]
    fn head_does_not_consume() {
        let mut q = JobQueue::new();
        q.submit(JobId(7));
        assert_eq!(q.head(), Some(JobId(7)));
        assert_eq!(q.head(), Some(JobId(7)));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn remove_by_id() {
        let mut q = JobQueue::new();
        q.submit(JobId(1));
        q.submit(JobId(2));
        q.submit(JobId(3));
        assert!(q.remove(JobId(2)));
        assert!(!q.remove(JobId(2)));
        let order: Vec<u32> = q.iter().map(|j| j.0).collect();
        assert_eq!(order, vec![1, 3]);
    }

    #[test]
    fn iter_is_head_first() {
        let mut q = JobQueue::new();
        q.submit(JobId(1));
        q.submit(JobId(2));
        q.reinsert_front(JobId(0));
        let order: Vec<u32> = q.iter().map(|j| j.0).collect();
        assert_eq!(order, vec![0, 1, 2]);
    }
}
