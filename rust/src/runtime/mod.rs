//! The PJRT runtime: loads AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and executes them on the request path
//! — python is never involved at runtime.
//!
//! Interchange is HLO **text**: the image's xla_extension 0.5.1 rejects
//! serialized `HloModuleProto`s from jax ≥ 0.5 (64-bit instruction ids);
//! the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md).

pub mod checkpoint;
pub mod manifest;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use manifest::{Manifest, TensorSpec};
pub use trainer::Trainer;

use crate::xla;
use anyhow::{Context, Result};
use std::path::Path;

/// True when the binary was built against a real PJRT backend. The offline
/// image links the [`crate::xla`] stub instead, so live execution paths
/// report unavailability at runtime and tests skip.
pub fn backend_available() -> bool {
    xla::PjRtClient::cpu().is_ok()
}

/// A PJRT CPU client wrapper. One per thread in live mode (the underlying
/// handles are not `Sync`).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled computation ready to run.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple
    /// (artifacts are lowered with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("executing artifact")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("untupling result")
    }
}

/// Default artifacts directory: `$FITGPP_ARTIFACTS` or `artifacts/`
/// relative to the crate root (works from `cargo test`/`cargo bench`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("FITGPP_ARTIFACTS") {
        return p.into();
    }
    let here = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    here.join("artifacts")
}

/// True if the AOT artifacts have been built (`make artifacts`). Tests and
/// benches that need them skip gracefully otherwise.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_cpu_comes_up() {
        match Engine::cpu() {
            Ok(e) => {
                assert_eq!(e.platform(), "cpu");
                assert!(e.device_count() >= 1);
            }
            Err(e) => eprintln!("skipping: PJRT backend not available ({e:#})"),
        }
    }

    #[test]
    fn load_missing_artifact_errors() {
        let Ok(e) = Engine::cpu() else {
            eprintln!("skipping: PJRT backend not available");
            return;
        };
        assert!(e.load_hlo_text(Path::new("/nonexistent/foo.hlo.txt")).is_err());
    }

    #[test]
    fn backend_flag_matches_client_creation() {
        assert_eq!(backend_available(), Engine::cpu().is_ok());
    }
}
