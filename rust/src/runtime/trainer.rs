//! Training-job executor: owns the model parameters and drives the AOT
//! train-step artifact. This is what a "DL job" actually runs in live mode
//! — the compute the scheduler is scheduling.
//!
//! Calling convention (fixed by `python/compile/aot.py`):
//! inputs `(param_0, …, param_{n-1}, tokens)` →
//! outputs `(param_0', …, param_{n-1}', loss)`.

use super::manifest::{Manifest, ModelVariant};
use super::{Checkpoint, Engine, Executable};
use crate::stats::dist::{Normal, Sample};
use crate::stats::rng::Pcg64;
use crate::xla;
use anyhow::{bail, Context, Result};

/// A live training job: compiled step + resident parameters.
pub struct Trainer {
    pub variant: ModelVariant,
    exec: Executable,
    /// Current parameters, calling-convention order.
    params: Vec<xla::Literal>,
    /// Steps completed.
    pub step: u64,
    batch_rng: Pcg64,
}

impl Trainer {
    /// Fresh trainer with rust-side parameter init (normal, σ = 0.02 — the
    /// standard GPT-style init; python tests validate model numerics
    /// against the jnp reference separately).
    pub fn new(engine: &Engine, manifest: &Manifest, variant: &str, seed: u64) -> Result<Trainer> {
        let variant = manifest.variant(variant)?.clone();
        let exec = engine.load_hlo_text(&manifest.artifact_path(&variant.train_step))?;
        let mut rng = Pcg64::new(seed);
        let dist = Normal::new(0.0, 0.02);
        let params = variant
            .params
            .iter()
            .map(|spec| {
                if spec.dtype != "f32" {
                    bail!("only f32 params supported, got {}", spec.dtype);
                }
                // Mirror python's init_params: layernorm gains are ones,
                // shifts are zeros, weights are N(0, 0.02).
                let data: Vec<f32> = if spec.name.ends_with(".g") {
                    vec![1.0; spec.elements()]
                } else if spec.name.ends_with(".b") {
                    vec![0.0; spec.elements()]
                } else {
                    (0..spec.elements())
                        .map(|_| dist.sample(&mut rng) as f32)
                        .collect()
                };
                make_f32(&data, &spec.shape)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Trainer {
            batch_rng: rng.split(17),
            variant,
            exec,
            params,
            step: 0,
        })
    }

    /// Resume from a checkpoint (live-mode preemption recovery).
    pub fn from_checkpoint(
        engine: &Engine,
        manifest: &Manifest,
        variant: &str,
        ckpt: &Checkpoint,
        seed: u64,
    ) -> Result<Trainer> {
        let variant = manifest.variant(variant)?.clone();
        if ckpt.tensors.len() != variant.params.len() {
            bail!(
                "checkpoint has {} tensors, model {} expects {}",
                ckpt.tensors.len(),
                variant.name,
                variant.params.len()
            );
        }
        let exec = engine.load_hlo_text(&manifest.artifact_path(&variant.train_step))?;
        let params = ckpt
            .tensors
            .iter()
            .zip(&variant.params)
            .map(|((dims, data), spec)| {
                if dims != &spec.shape {
                    bail!("checkpoint tensor {dims:?} != manifest {:?}", spec.shape);
                }
                make_f32(data, dims)
            })
            .collect::<Result<Vec<_>>>()?;
        let mut rng = Pcg64::new(seed ^ ckpt.step);
        Ok(Trainer {
            batch_rng: rng.split(17),
            variant,
            exec,
            params,
            step: ckpt.step,
        })
    }

    /// Batch shape `[batch, seq]`.
    pub fn batch_shape(&self) -> (usize, usize) {
        (self.variant.tokens.shape[0], self.variant.tokens.shape[1])
    }

    /// One training step on explicit tokens (row-major `[batch*seq]`).
    pub fn step_with(&mut self, tokens: &[i32]) -> Result<f32> {
        let (b, s) = self.batch_shape();
        if tokens.len() != b * s {
            bail!("expected {}x{} tokens, got {}", b, s, tokens.len());
        }
        let tok = xla::Literal::vec1(tokens)
            .reshape(&[b as i64, s as i64])
            .context("reshaping tokens")?;
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&tok);
        let outputs = {
            let result = self
                .exec
                .run_refs(&inputs)
                .context("train step execution")?;
            result
        };
        let n = self.params.len();
        if outputs.len() != n + 1 {
            bail!("train step returned {} outputs, expected {}", outputs.len(), n + 1);
        }
        let mut outputs = outputs;
        let loss_lit = outputs.pop().unwrap();
        let loss: f32 = loss_lit.get_first_element().context("reading loss")?;
        self.params = outputs;
        self.step += 1;
        Ok(loss)
    }

    /// One training step on a synthetic-but-learnable batch: sequences from
    /// a fixed affine recurrence `x_{t+1} = (5 x_t + 3) mod V` with random
    /// starting symbol — a next-token structure a small LM learns quickly,
    /// so live-mode loss curves visibly decrease.
    pub fn step_synthetic(&mut self) -> Result<f32> {
        let (b, s) = self.batch_shape();
        let vocab = *self.variant.config.get("vocab").unwrap_or(&256.0) as i64;
        let mut toks = Vec::with_capacity(b * s);
        for _ in 0..b {
            let mut x = (self.batch_rng.below(vocab as u64)) as i64;
            for _ in 0..s {
                toks.push(x as i32);
                x = (5 * x + 3) % vocab;
            }
        }
        self.step_with(&toks)
    }

    /// Snapshot current parameters (the grace-period "suspension
    /// processing" of §2 — this is real serialization work).
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let tensors = self
            .params
            .iter()
            .zip(&self.variant.params)
            .map(|(lit, spec)| {
                let data = lit.to_vec::<f32>().context("param to host")?;
                Ok((spec.shape.clone(), data))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Checkpoint::new(self.step, tensors))
    }

    /// L2 norm of all parameters (diagnostics / tests).
    pub fn param_norm(&self) -> Result<f64> {
        let mut acc = 0.0f64;
        for lit in &self.params {
            for x in lit.to_vec::<f32>()? {
                acc += (x as f64) * (x as f64);
            }
        }
        Ok(acc.sqrt())
    }
}

fn make_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .context("reshaping parameter literal")
}

impl Executable {
    /// Like [`Executable::run`] but borrowing inputs (hot path: avoids
    /// cloning resident parameters every step).
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .context("executing artifact")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        lit.to_tuple().context("untupling result")
    }
}
