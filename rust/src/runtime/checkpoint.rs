//! Checkpoint serialization: the *real work* a live job performs during
//! its grace period (§2: "writing data back to persistent storage").
//!
//! Format (little-endian, versioned):
//!
//! ```text
//! magic   u32  = 0x46_49_54_47  ("FITG")
//! version u32  = 1
//! step    u64                      training step reached
//! ntensor u32
//! per tensor: rank u32, dims u32×rank, len u32, data f32×len
//! crc     u32  (FNV-1a over everything before it)
//! ```

use anyhow::{bail, Context, Result};

const MAGIC: u32 = 0x4649_5447;
const VERSION: u32 = 1;

/// A serialized training state: step counter + parameter tensors.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Training steps completed when the snapshot was taken.
    pub step: u64,
    /// `(shape, row-major data)` per parameter tensor, calling-convention
    /// order.
    pub tensors: Vec<(Vec<usize>, Vec<f32>)>,
}

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for b in bytes {
        h ^= *b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

impl Checkpoint {
    /// Build a checkpoint from raw tensors.
    pub fn new(step: u64, tensors: Vec<(Vec<usize>, Vec<f32>)>) -> Self {
        Checkpoint { step, tensors }
    }

    /// Total parameter count.
    pub fn elements(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.len()).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.elements() * 4);
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (dims, data) in &self.tensors {
            out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in dims {
                out.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            out.extend_from_slice(&(data.len() as u32).to_le_bytes());
            for x in data {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        let crc = fnv1a(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 24 {
            bail!("checkpoint too short ({} bytes)", bytes.len());
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 4);
        let crc = u32::from_le_bytes(crc_bytes.try_into().unwrap());
        if fnv1a(body) != crc {
            bail!("checkpoint CRC mismatch (corrupt suspension data)");
        }
        let mut r = Reader { b: body, pos: 0 };
        if r.u32()? != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = r.u64()?;
        let ntensor = r.u32()? as usize;
        let mut tensors = Vec::with_capacity(ntensor);
        for _ in 0..ntensor {
            let rank = r.u32()? as usize;
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(r.u32()? as usize);
            }
            let len = r.u32()? as usize;
            let expect: usize = dims.iter().product();
            if expect != len {
                bail!("tensor dims {dims:?} disagree with data length {len}");
            }
            let mut data = Vec::with_capacity(len);
            for _ in 0..len {
                data.push(f32::from_le_bytes(r.bytes(4)?.try_into().unwrap()));
            }
            tensors.push((dims, data));
        }
        if r.pos != body.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { step, tensors })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("checkpoint truncated at byte {}", self.pos);
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

/// Write a checkpoint to disk (used by live mode's grace-period work).
pub fn save(ckpt: &Checkpoint, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, ckpt.to_bytes()).with_context(|| format!("writing {}", path.display()))
}

/// Read a checkpoint from disk.
pub fn load(path: &std::path::Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    Checkpoint::from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint::new(
            42,
            vec![
                (vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
                (vec![4], vec![-1.0, 0.5, 0.25, 1e-7]),
            ],
        )
    }

    #[test]
    fn roundtrip() {
        let c = sample();
        let bytes = c.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.elements(), 10);
    }

    #[test]
    fn detects_corruption() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn detects_truncation() {
        let bytes = sample().to_bytes();
        assert!(Checkpoint::from_bytes(&bytes[..bytes.len() - 8]).is_err());
        assert!(Checkpoint::from_bytes(&[]).is_err());
    }

    #[test]
    fn rejects_dim_mismatch() {
        // Hand-craft: tensor claims dims [2,2] but 3 elements.
        let c = Checkpoint::new(0, vec![(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0])]);
        let mut bytes = c.to_bytes();
        // Patch the length field (rank=2 dims at offset 16+4+4=24.. len at 32).
        // Easier: build from parts — just check the valid case parses and a
        // mangled len fails CRC anyway (covered above). Here check version.
        bytes[4] = 99; // version byte
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn disk_roundtrip() {
        let dir = std::env::temp_dir().join("fitgpp-ckpt-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        save(&sample(), &path).unwrap();
        assert_eq!(load(&path).unwrap(), sample());
    }
}
