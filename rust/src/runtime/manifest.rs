//! The artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py`, tells the rust runtime what was lowered —
//! model variants, parameter tensor order/shapes/dtypes, and input specs —
//! so the two sides agree without sharing code.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor in the AOT calling convention.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Parameter name (python-side pytree path).
    pub name: String,
    /// Dimensions, row-major.
    pub shape: Vec<usize>,
    /// `"f32"` or `"s32"` (all the artifacts use).
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let name = v.get("name").as_str().context("tensor name")?.to_string();
        let shape = v
            .get("shape")
            .as_arr()
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_u64().map(|x| x as usize).context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v.get("dtype").as_str().unwrap_or("f32").to_string();
        Ok(TensorSpec { name, shape, dtype })
    }
}

/// One lowered model variant (e.g. `tiny`, `small`).
#[derive(Debug, Clone)]
pub struct ModelVariant {
    /// Variant name (`tiny`, `small`, ...).
    pub name: String,
    /// HLO-text file for the fused train step (params…, tokens) →
    /// (params…, loss).
    pub train_step: String,
    /// Parameter tensors, in calling-convention order.
    pub params: Vec<TensorSpec>,
    /// Token input spec `[batch, seq]`, dtype s32.
    pub tokens: TensorSpec,
    /// Model hyper-parameters (vocab, d_model, n_layer, …).
    pub config: BTreeMap<String, f64>,
}

impl ModelVariant {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the artifacts live in.
    pub dir: PathBuf,
    /// Lowered model variants by name.
    pub variants: BTreeMap<String, ModelVariant>,
    /// Stand-alone probe artifact for runtime smoke tests:
    /// `f(x, y) = (x·y + 2,)` over f32[2,2].
    pub probe: Option<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let mut variants = BTreeMap::new();
        let Some(models) = v.get("models").as_arr() else {
            bail!("manifest missing \"models\"");
        };
        for m in models {
            let name = m.get("name").as_str().context("model name")?.to_string();
            let train_step = m
                .get("train_step")
                .as_str()
                .context("train_step path")?
                .to_string();
            let params = m
                .get("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let tokens = TensorSpec::from_json(m.get("tokens")).context("tokens spec")?;
            let mut config = BTreeMap::new();
            if let Some(obj) = m.get("config").as_obj() {
                for (k, val) in obj {
                    if let Some(x) = val.as_f64() {
                        config.insert(k.clone(), x);
                    }
                }
            }
            variants.insert(
                name.clone(),
                ModelVariant { name, train_step, params, tokens, config },
            );
        }
        let probe = v.get("probe").as_str().map(|s| s.to_string());
        Ok(Manifest { dir: dir.to_path_buf(), variants, probe })
    }

    pub fn variant(&self, name: &str) -> Result<&ModelVariant> {
        self.variants
            .get(name)
            .with_context(|| format!("no model variant {name:?} in manifest"))
    }

    /// Absolute path of an artifact file.
    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "probe": "probe.hlo.txt",
      "models": [{
        "name": "tiny",
        "train_step": "train_step_tiny.hlo.txt",
        "tokens": {"name": "tokens", "shape": [8, 64], "dtype": "s32"},
        "params": [
          {"name": "wte", "shape": [256, 32], "dtype": "f32"},
          {"name": "w1", "shape": [32, 128], "dtype": "f32"}
        ],
        "config": {"vocab": 256, "d_model": 32, "lr": 0.001}
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.probe.as_deref(), Some("probe.hlo.txt"));
        let v = m.variant("tiny").unwrap();
        assert_eq!(v.params.len(), 2);
        assert_eq!(v.params[0].elements(), 256 * 32);
        assert_eq!(v.param_count(), 256 * 32 + 32 * 128);
        assert_eq!(v.tokens.shape, vec![8, 64]);
        assert_eq!(v.config["vocab"], 256.0);
        assert_eq!(
            m.artifact_path(&v.train_step),
            PathBuf::from("/tmp/artifacts/train_step_tiny.hlo.txt")
        );
    }

    #[test]
    fn missing_variant_errors() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m.variant("huge").is_err());
    }

    #[test]
    fn rejects_missing_models() {
        assert!(Manifest::parse("{}", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("not json", Path::new("/tmp")).is_err());
    }
}
