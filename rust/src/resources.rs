//! Resource vectors and the paper's scale-invariant `Size` measure (Eq. 1).
//!
//! The paper models three resource types — CPU cores, RAM, and GPUs — and
//! notes that "the extension of our theory to other types of resource should
//! be straightforward". We keep the three-axis vector as a fixed-size struct
//! (hot path: the FitGpp victim scan calls `size()` and `fits()` for every
//! running BE job on every preemption decision).

use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A demand or capacity vector `[C, R, G]`: CPU cores, RAM in GiB, GPUs.
///
/// Stored as `f64` so fractional requests (e.g. millicores, half-GiB) work;
/// the paper's workloads use integral values.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceVec {
    /// CPU cores requested / available.
    pub cpu: f64,
    /// RAM in GiB.
    pub ram_gb: f64,
    /// Number of GPUs.
    pub gpu: f64,
}

impl ResourceVec {
    pub const ZERO: ResourceVec = ResourceVec { cpu: 0.0, ram_gb: 0.0, gpu: 0.0 };

    pub fn new(cpu: f64, ram_gb: f64, gpu: f64) -> Self {
        ResourceVec { cpu, ram_gb, gpu }
    }

    /// The per-node capacity used throughout the paper's evaluation:
    /// 32 CPUs, 256 GB RAM, 8 GPUs.
    pub fn pfn_node() -> Self {
        ResourceVec::new(32.0, 256.0, 8.0)
    }

    /// Eq. 1: `Size([C,R,G]) = sqrt((C/C_cap)^2 + (R/R_cap)^2 + (G/G_cap)^2)`.
    ///
    /// Scale-invariant: measuring RAM in MB vs GB does not change the value
    /// as long as `capacity` uses the same unit. Axes with zero capacity are
    /// skipped (a cluster without GPUs simply drops the GPU term).
    pub fn size(&self, capacity: &ResourceVec) -> f64 {
        let mut acc = 0.0;
        if capacity.cpu > 0.0 {
            let t = self.cpu / capacity.cpu;
            acc += t * t;
        }
        if capacity.ram_gb > 0.0 {
            let t = self.ram_gb / capacity.ram_gb;
            acc += t * t;
        }
        if capacity.gpu > 0.0 {
            let t = self.gpu / capacity.gpu;
            acc += t * t;
        }
        acc.sqrt()
    }

    /// Element-wise `self <= other` — the fit test (and Eq. 2's comparison).
    pub fn fits_in(&self, other: &ResourceVec) -> bool {
        self.cpu <= other.cpu + EPS
            && self.ram_gb <= other.ram_gb + EPS
            && self.gpu <= other.gpu + EPS
    }

    /// True if any component is negative (used by invariant checks).
    pub fn any_negative(&self) -> bool {
        self.cpu < -EPS || self.ram_gb < -EPS || self.gpu < -EPS
    }

    /// True if all components are zero (within tolerance).
    pub fn is_zero(&self) -> bool {
        self.cpu.abs() <= EPS && self.ram_gb.abs() <= EPS && self.gpu.abs() <= EPS
    }

    /// Element-wise max.
    pub fn max(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec::new(
            self.cpu.max(other.cpu),
            self.ram_gb.max(other.ram_gb),
            self.gpu.max(other.gpu),
        )
    }

    /// Element-wise min.
    pub fn min(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec::new(
            self.cpu.min(other.cpu),
            self.ram_gb.min(other.ram_gb),
            self.gpu.min(other.gpu),
        )
    }

    /// Saturating subtraction: clamps each component at zero. Used when
    /// projecting hypothetical allocations.
    pub fn saturating_sub(&self, other: &ResourceVec) -> ResourceVec {
        ResourceVec::new(
            (self.cpu - other.cpu).max(0.0),
            (self.ram_gb - other.ram_gb).max(0.0),
            (self.gpu - other.gpu).max(0.0),
        )
    }

    /// Scale every component by `k`.
    pub fn scale(&self, k: f64) -> ResourceVec {
        ResourceVec::new(self.cpu * k, self.ram_gb * k, self.gpu * k)
    }

    /// Serialize as three raw-bit `f64`s for a snapshot. `Node::release`
    /// snaps `free` back to capacity within a tolerance, so free vectors
    /// must travel bit-exact rather than be recomputed on restore.
    pub fn snapshot_bin(&self, w: &mut crate::util::bin::BinWriter) {
        w.f64(self.cpu);
        w.f64(self.ram_gb);
        w.f64(self.gpu);
    }

    /// Rebuild a vector written by [`ResourceVec::snapshot_bin`].
    pub fn restore_bin(r: &mut crate::util::bin::BinReader) -> anyhow::Result<Self> {
        Ok(ResourceVec::new(r.f64()?, r.f64()?, r.f64()?))
    }

    /// The ratio `self / capacity` on the most-loaded axis — used for the
    /// cluster-load calibration in the workload generator (§4.2 keeps the
    /// FIFO load at 2.0).
    pub fn dominant_share(&self, capacity: &ResourceVec) -> f64 {
        let mut m: f64 = 0.0;
        if capacity.cpu > 0.0 {
            m = m.max(self.cpu / capacity.cpu);
        }
        if capacity.ram_gb > 0.0 {
            m = m.max(self.ram_gb / capacity.ram_gb);
        }
        if capacity.gpu > 0.0 {
            m = m.max(self.gpu / capacity.gpu);
        }
        m
    }
}

/// Comparison tolerance for f64 resource arithmetic (accumulated
/// allocate/release round-off must never flip a fit decision).
pub const EPS: f64 = 1e-9;

impl Add for ResourceVec {
    type Output = ResourceVec;
    fn add(self, rhs: ResourceVec) -> ResourceVec {
        ResourceVec::new(self.cpu + rhs.cpu, self.ram_gb + rhs.ram_gb, self.gpu + rhs.gpu)
    }
}

impl AddAssign for ResourceVec {
    fn add_assign(&mut self, rhs: ResourceVec) {
        self.cpu += rhs.cpu;
        self.ram_gb += rhs.ram_gb;
        self.gpu += rhs.gpu;
    }
}

impl Sub for ResourceVec {
    type Output = ResourceVec;
    fn sub(self, rhs: ResourceVec) -> ResourceVec {
        ResourceVec::new(self.cpu - rhs.cpu, self.ram_gb - rhs.ram_gb, self.gpu - rhs.gpu)
    }
}

impl SubAssign for ResourceVec {
    fn sub_assign(&mut self, rhs: ResourceVec) {
        self.cpu -= rhs.cpu;
        self.ram_gb -= rhs.ram_gb;
        self.gpu -= rhs.gpu;
    }
}

impl fmt::Display for ResourceVec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}C, {}G, {}GPU]", self.cpu, self.ram_gb, self.gpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_of_full_node_is_sqrt3() {
        let cap = ResourceVec::pfn_node();
        let d = cap;
        assert!((d.size(&cap) - 3f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn size_is_scale_invariant() {
        // Same demand expressed in GB vs MB must yield the same Size as long
        // as the capacity uses matching units (the paper's Eq. 1 remark).
        let cap_gb = ResourceVec::new(32.0, 256.0, 8.0);
        let d_gb = ResourceVec::new(4.0, 64.0, 2.0);
        let cap_mb = ResourceVec::new(32.0, 256.0 * 1024.0, 8.0);
        let d_mb = ResourceVec::new(4.0, 64.0 * 1024.0, 2.0);
        assert!((d_gb.size(&cap_gb) - d_mb.size(&cap_mb)).abs() < 1e-12);
    }

    #[test]
    fn size_zero_capacity_axis_is_skipped() {
        let cap = ResourceVec::new(32.0, 256.0, 0.0);
        let d = ResourceVec::new(32.0, 0.0, 0.0);
        assert!((d.size(&cap) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn size_monotone_in_each_axis() {
        let cap = ResourceVec::pfn_node();
        let base = ResourceVec::new(4.0, 32.0, 1.0);
        for bigger in [
            ResourceVec::new(5.0, 32.0, 1.0),
            ResourceVec::new(4.0, 33.0, 1.0),
            ResourceVec::new(4.0, 32.0, 2.0),
        ] {
            assert!(bigger.size(&cap) > base.size(&cap));
        }
    }

    #[test]
    fn fits_in_elementwise() {
        let a = ResourceVec::new(4.0, 64.0, 2.0);
        let b = ResourceVec::new(8.0, 64.0, 2.0);
        assert!(a.fits_in(&b));
        assert!(!b.fits_in(&a));
        // One axis over ⇒ no fit even if others are under.
        let c = ResourceVec::new(2.0, 128.0, 1.0);
        assert!(!c.fits_in(&a));
    }

    #[test]
    fn fits_in_tolerates_roundoff() {
        let mut free = ResourceVec::new(32.0, 256.0, 8.0);
        let d = ResourceVec::new(0.1, 0.3, 0.7);
        for _ in 0..1000 {
            free -= d;
            free += d;
        }
        assert!(ResourceVec::new(32.0, 256.0, 8.0).fits_in(&free));
    }

    #[test]
    fn arithmetic_roundtrip() {
        let a = ResourceVec::new(4.0, 64.0, 2.0);
        let b = ResourceVec::new(1.0, 16.0, 1.0);
        assert_eq!(a + b - b, a);
        let mut c = a;
        c += b;
        c -= b;
        assert_eq!(c, a);
    }

    #[test]
    fn saturating_sub_clamps() {
        let a = ResourceVec::new(1.0, 1.0, 1.0);
        let b = ResourceVec::new(2.0, 0.5, 3.0);
        let r = a.saturating_sub(&b);
        assert_eq!(r, ResourceVec::new(0.0, 0.5, 0.0));
        assert!(!r.any_negative());
    }

    #[test]
    fn dominant_share() {
        let cap = ResourceVec::pfn_node();
        let d = ResourceVec::new(8.0, 32.0, 4.0); // 0.25, 0.125, 0.5
        assert!((d.dominant_share(&cap) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn display_renders() {
        assert_eq!(
            ResourceVec::new(4.0, 64.0, 2.0).to_string(),
            "[4C, 64G, 2GPU]"
        );
    }
}
