//! Pull-based arrival sources: workloads as streams.
//!
//! The materialized [`Workload`] is a `Vec<JobSpec>` — fine for §4.2-scale
//! experiments, O(total jobs) memory for everything else. An
//! [`ArrivalSource`] instead *yields* jobs in submission order and is
//! pulled lazily by the streaming simulator
//! ([`Simulator::run_source`](crate::sim::Simulator::run_source)), so only
//! the live set is ever resident. Implementations:
//!
//! * [`WorkloadSource`] — back-compat adapter over a materialized
//!   [`Workload`] (what [`Simulator::run`](crate::sim::Simulator::run) and
//!   every sweep cell use).
//! * [`SyntheticSource`](crate::workload::synthetic::SyntheticSource) —
//!   the §4.2 generator, jobs drawn on the fly while its internal FIFO
//!   calibration sim advances.
//! * [`InstitutionSource`](crate::workload::trace::InstitutionSource) —
//!   the §4.4 institution-trace synthesizer as a stream.
//! * [`CsvStreamSource`](crate::workload::trace::CsvStreamSource) — a
//!   buffered-reader CSV trace streamer (replay traces bigger than RAM).
//! * [`ClosedLoopSource`] — the paper's actual trial-and-error scenario:
//!   users who resubmit their next job only after the previous one
//!   finishes plus think time. Arrival times *depend on scheduling
//!   decisions*, so no fixed trace (materialized or streamed) can express
//!   it — this is what the [`ArrivalSource::on_job_finished`] feedback
//!   channel exists for.
//!
//! ## Contract
//!
//! * Jobs are yielded in non-decreasing `submit` order with dense ids
//!   (`0..n` in yield order) — the simulator's clock breaks same-minute
//!   ties by id, so this keeps streamed runs byte-identical to
//!   materialized ones.
//! * `peek_submit` never returns a minute earlier than the last yielded
//!   job's `submit`.
//! * A source whose `peek_submit` is `None` but which is not [`done`]
//!   (a closed loop waiting on completions) must become ready again after
//!   some pending job finishes; the simulator keeps ticking (or
//!   fast-forwards to its internal events) until then.
//!
//! [`done`]: ArrivalSource::done

use super::Workload;
use crate::job::{JobClass, JobId, JobSpec, TenantId};
use crate::resources::ResourceVec;
use crate::stats::dist::{Exponential, Sample, TruncatedNormal};
use crate::stats::rng::Pcg64;
use crate::Minutes;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Deterministic tenant-assignment rule shared by the open (feed-forward)
/// sources: tenants are assigned round-robin by job sequence number, with
/// an optional *burst window* during which every arrival belongs to one
/// designated tenant — the "tenant storm" scenario family (one tenant
/// floods the queue on a schedule; the others ride out the burst).
///
/// Assignment is pure metadata: it never changes arrival times, demands,
/// or RNG draws, so a tenant-tagged workload is byte-identical to the
/// untagged one under the `fifo` discipline (pinned by
/// `rust/tests/streaming_equivalence.rs`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantAssigner {
    /// Number of tenants (≥ 1). One tenant ⇒ everything is
    /// [`TenantId::DEFAULT`].
    pub tenants: u32,
    /// Optional burst rule.
    pub burst: Option<TenantBurst>,
}

/// A periodic burst window: while `submit % period < len`, every arrival
/// belongs to `tenant`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantBurst {
    /// The bursting tenant.
    pub tenant: u32,
    /// Window period in minutes (> 0).
    pub period: Minutes,
    /// Window length in minutes (≤ period).
    pub len: Minutes,
}

impl TenantAssigner {
    /// Everything on the default tenant (the pre-tenant behaviour).
    pub fn single() -> Self {
        TenantAssigner { tenants: 1, burst: None }
    }

    /// Round-robin over `n` tenants by job sequence number (`n` ≥ 1).
    pub fn round_robin(n: u32) -> Self {
        TenantAssigner { tenants: n.max(1), burst: None }
    }

    /// Add a periodic burst window for `tenant` (must be one of the
    /// `0..tenants` ids — a typo'd out-of-range tenant would otherwise
    /// silently storm some other tenant).
    pub fn with_burst(mut self, tenant: u32, period: Minutes, len: Minutes) -> Self {
        assert!(period > 0, "burst period must be positive");
        assert!(
            tenant < self.tenants.max(1),
            "burst tenant {tenant} out of range (tenants: {})",
            self.tenants
        );
        self.burst = Some(TenantBurst { tenant, period, len: len.min(period) });
        self
    }

    /// The tenant for the job with sequence number `seq` submitting at
    /// `submit`.
    pub fn assign(&self, seq: u32, submit: Minutes) -> TenantId {
        let n = self.tenants.max(1);
        if let Some(b) = self.burst {
            if submit % b.period < b.len {
                return TenantId(b.tenant % n);
            }
        }
        TenantId(seq % n)
    }
}

impl Default for TenantAssigner {
    fn default() -> Self {
        TenantAssigner::single()
    }
}

/// A workload yielded one job at a time, in submission order. See the
/// module docs for the contract.
pub trait ArrivalSource {
    /// Submission minute of the next job, if one is currently known.
    /// Generative sources may need to advance internal state to answer
    /// (hence `&mut self`); the call must not consume the job.
    fn peek_submit(&mut self) -> Option<Minutes>;

    /// Yield the next job. `None` when no job is currently available
    /// (exhausted, or a closed loop waiting on completions).
    fn next_job(&mut self) -> Option<JobSpec>;

    /// Completion feedback: `id` left the system at tick `finished_at` —
    /// it completed, or the control plane cancelled it (a scenario kill).
    /// Open (feed-forward) sources ignore this; closed-loop sources use it
    /// to schedule the submitting user's next trial — a user whose job was
    /// killed resubmits exactly like one whose job finished, which is the
    /// paper's trial-and-error story.
    fn on_job_finished(&mut self, _id: JobId, _finished_at: Minutes) {}

    /// True when this source will never yield another job.
    fn done(&self) -> bool;

    /// True when future arrivals depend on completion feedback (closed
    /// loops). The simulator clamps its arrival lookahead to zero for
    /// such sources: pulling a known arrival early could ordering-race a
    /// not-yet-scheduled resubmission with an earlier submit minute,
    /// violating the monotone-submit/dense-id contract above.
    fn feedback_driven(&self) -> bool {
        false
    }

    /// Total jobs this source will yield, when known up front.
    fn size_hint(&self) -> Option<usize> {
        None
    }
}

/// Back-compat adapter: stream a materialized [`Workload`] (already sorted
/// with dense ids by `Workload::new`).
pub struct WorkloadSource<'a> {
    jobs: &'a [JobSpec],
    next: usize,
}

impl<'a> WorkloadSource<'a> {
    /// Stream `workload` in order.
    pub fn new(workload: &'a Workload) -> Self {
        debug_assert!(
            workload.jobs.windows(2).all(|w| w[0].submit <= w[1].submit),
            "Workload::new guarantees submit order"
        );
        WorkloadSource { jobs: &workload.jobs, next: 0 }
    }
}

impl ArrivalSource for WorkloadSource<'_> {
    fn peek_submit(&mut self) -> Option<Minutes> {
        self.jobs.get(self.next).map(|j| j.submit)
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        let spec = self.jobs.get(self.next)?.clone();
        self.next += 1;
        Some(spec)
    }

    fn done(&self) -> bool {
        self.next >= self.jobs.len()
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.jobs.len())
    }
}

/// Parameters of the closed-loop trial-and-error scenario.
#[derive(Debug, Clone)]
pub struct ClosedLoopParams {
    /// Concurrent users iterating on models.
    pub users: usize,
    /// Trials each user submits before stopping.
    pub trials_per_user: u32,
    /// Probability a trial is a TE job (users occasionally promote an
    /// experiment to a longer best-effort training run).
    pub te_fraction: f64,
    /// Mean think time between a job finishing and the user's next
    /// submission (exponential, minutes; at least 1 minute elapses).
    pub think_mean: f64,
    /// Users' first submissions are spread uniformly over this ramp-up
    /// window (minutes).
    pub ramp: Minutes,
    /// Per-job demands are capped at this vector so every job fits some
    /// node.
    pub node_cap: ResourceVec,
    /// Tenants the users map onto (`user % tenants`; 1 = single-tenant).
    /// Closed loops assign by *user*, not by job sequence — a user's whole
    /// trial history belongs to one tenant, the natural "team" mapping.
    pub tenants: u32,
}

impl ClosedLoopParams {
    /// A paper-flavoured default: TE-heavy iteration with ~10-minute think
    /// times on PFN-sized nodes.
    pub fn demo(users: usize, trials_per_user: u32) -> Self {
        ClosedLoopParams {
            users,
            trials_per_user,
            te_fraction: 0.85,
            think_mean: 10.0,
            ramp: 60,
            node_cap: ResourceVec::pfn_node(),
            tenants: 1,
        }
    }

    /// Map users onto `n` tenants (`user % n`).
    pub fn with_tenants(mut self, n: u32) -> Self {
        self.tenants = n.max(1);
        self
    }
}

/// One pending submission: `(ready minute, user)` — the heap orders by
/// time, then user index, so ids stay dense in submission order even when
/// several users' think timers expire out of completion order.
type PendingUser = Reverse<(Minutes, u32)>;

/// The closed-loop source. Each user runs `submit → wait for completion →
/// think → resubmit` for `trials_per_user` rounds; job bodies are drawn
/// from the §4.2 distributions.
pub struct ClosedLoopSource {
    params: ClosedLoopParams,
    exec_te: TruncatedNormal,
    exec_be: TruncatedNormal,
    cpu: TruncatedNormal,
    ram: TruncatedNormal,
    gpu: TruncatedNormal,
    gp: TruncatedNormal,
    think: Exponential,
    body_rng: Pcg64,
    think_rng: Pcg64,
    class_rng: Pcg64,
    /// Users whose next submission time is already known.
    ready: BinaryHeap<PendingUser>,
    /// Trials each user still has left to *submit*.
    trials_left: Vec<u32>,
    /// In-flight job id → user (removed on completion; O(live) entries).
    in_flight: std::collections::HashMap<u32, u32>,
    next_id: u32,
}

impl ClosedLoopSource {
    /// Build the source. Deterministic per `(params, seed)`.
    pub fn new(params: ClosedLoopParams, seed: u64) -> Self {
        assert!(params.users > 0 && params.trials_per_user > 0);
        let mut root = Pcg64::new(seed);
        let mut ramp_rng = root.split(1);
        let body_rng = root.split(2);
        let think_rng = root.split(3);
        let class_rng = root.split(4);
        let mut ready = BinaryHeap::with_capacity(params.users);
        for u in 0..params.users {
            ready.push(Reverse((ramp_rng.below(params.ramp.max(1)), u as u32)));
        }
        ClosedLoopSource {
            // §4.2 bodies: TE trials short (≤30 min), BE promotions long.
            exec_te: TruncatedNormal::new(5.0, 6.0, 1.0, 30.0),
            exec_be: TruncatedNormal::new(30.0, 60.0, 1.0, 1440.0),
            cpu: TruncatedNormal::new(8.0, 8.0, 1.0, 32.0),
            ram: TruncatedNormal::new(64.0, 64.0, 1.0, 256.0),
            gpu: TruncatedNormal::new(3.0, 2.5, 0.0, 8.0),
            gp: TruncatedNormal::new(3.0, 4.0, 0.0, 20.0),
            think: Exponential::new(1.0 / params.think_mean.max(1e-9)),
            body_rng,
            think_rng,
            class_rng,
            ready,
            trials_left: vec![params.trials_per_user; params.users],
            in_flight: std::collections::HashMap::new(),
            next_id: 0,
            params,
        }
    }

    /// Total jobs this source will yield over its lifetime.
    pub fn total_jobs(&self) -> usize {
        self.params.users * self.params.trials_per_user as usize
    }
}

impl ArrivalSource for ClosedLoopSource {
    fn peek_submit(&mut self) -> Option<Minutes> {
        self.ready.peek().map(|Reverse((at, _))| *at)
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        let Reverse((at, user)) = self.ready.pop()?;
        debug_assert!(self.trials_left[user as usize] > 0);
        self.trials_left[user as usize] -= 1;
        let class = if self.class_rng.chance(self.params.te_fraction) {
            JobClass::Te
        } else {
            JobClass::Be
        };
        let exec_dist = match class {
            JobClass::Te => &self.exec_te,
            JobClass::Be => &self.exec_be,
        };
        let exec = exec_dist.sample(&mut self.body_rng).round().max(1.0) as u64;
        let cpu = self.cpu.sample(&mut self.body_rng).round().max(1.0);
        let ram = self.ram.sample(&mut self.body_rng).round().max(1.0);
        let gpu = self.gpu.sample(&mut self.body_rng).round().max(0.0);
        let demand = ResourceVec::new(cpu, ram, gpu).min(&self.params.node_cap);
        let gp = self.gp.sample(&mut self.body_rng).round().max(0.0) as u64;
        let id = JobId(self.next_id);
        self.next_id += 1;
        self.in_flight.insert(id.0, user);
        Some(JobSpec {
            id,
            class,
            demand,
            submit: at,
            exec_time: exec,
            grace_period: gp,
            tenant: TenantId(user % self.params.tenants.max(1)),
        })
    }

    fn on_job_finished(&mut self, id: JobId, finished_at: Minutes) {
        let Some(user) = self.in_flight.remove(&id.0) else {
            return; // not ours (defensive; the simulator only reports ours)
        };
        if self.trials_left[user as usize] == 0 {
            return; // user is done iterating
        }
        // Think, then resubmit. At least one minute passes: the arrival
        // must land on a strictly later tick than the completion.
        let think = self.think.sample(&mut self.think_rng).round().max(1.0) as u64;
        self.ready.push(Reverse((finished_at.saturating_add(think), user)));
    }

    fn done(&self) -> bool {
        self.ready.is_empty() && self.in_flight.is_empty()
    }

    fn feedback_driven(&self) -> bool {
        true
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.total_jobs())
    }
}

/// Drain an arrival source into a materialized [`Workload`] (diagnostics
/// and tests; defeats the purpose for closed loops, which never yield
/// beyond their first wave without completion feedback).
pub fn collect_workload(source: &mut dyn ArrivalSource) -> Workload {
    let mut jobs = Vec::new();
    while let Some(spec) = source.next_job() {
        jobs.push(spec);
    }
    Workload::new(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_source_streams_in_order() {
        let wl = Workload::new(vec![
            JobSpec::new(0, JobClass::Be, ResourceVec::new(1.0, 1.0, 0.0), 5, 5, 0),
            JobSpec::new(1, JobClass::Te, ResourceVec::new(1.0, 1.0, 0.0), 2, 5, 0),
        ]);
        let mut src = WorkloadSource::new(&wl);
        assert_eq!(src.size_hint(), Some(2));
        assert_eq!(src.peek_submit(), Some(2));
        let a = src.next_job().unwrap();
        assert_eq!((a.id, a.submit), (JobId(0), 2));
        assert!(!src.done());
        let b = src.next_job().unwrap();
        assert_eq!((b.id, b.submit), (JobId(1), 5));
        assert!(src.done());
        assert_eq!(src.next_job(), None);
    }

    #[test]
    fn closed_loop_waits_for_completions() {
        let mut src = ClosedLoopSource::new(ClosedLoopParams::demo(2, 2), 7);
        assert_eq!(src.size_hint(), Some(4));
        // First wave: one job per user, no more until something finishes.
        let first = src.next_job().unwrap();
        let second = src.next_job().unwrap();
        assert_eq!(first.id, JobId(0));
        assert_eq!(second.id, JobId(1));
        assert!(first.submit <= second.submit, "ids dense in submit order");
        assert_eq!(src.peek_submit(), None, "closed loop is blocked");
        assert!(!src.done(), "users still mid-trial");

        // A completion wakes the corresponding user.
        src.on_job_finished(JobId(0), 100);
        let at = src.peek_submit().expect("user 0 resubmits");
        assert!(at > 100, "think time puts the arrival strictly later");
        let third = src.next_job().unwrap();
        assert_eq!(third.id, JobId(2));

        // Finishing the last trials closes the loop.
        src.on_job_finished(JobId(1), 120);
        let fourth = src.next_job().unwrap();
        assert_eq!(fourth.id, JobId(3));
        src.on_job_finished(JobId(2), 130);
        src.on_job_finished(JobId(3), 140);
        assert!(src.done(), "all trials submitted and finished");
        assert_eq!(src.next_job(), None);
    }

    #[test]
    fn tenant_assigner_round_robin_and_burst() {
        let a = TenantAssigner::round_robin(3);
        assert_eq!(a.assign(0, 10), TenantId(0));
        assert_eq!(a.assign(4, 10), TenantId(1));
        assert_eq!(TenantAssigner::single().assign(7, 99), TenantId::DEFAULT);
        // Burst window: minutes [0, 30) of every 120 belong to tenant 2.
        let b = TenantAssigner::round_robin(3).with_burst(2, 120, 30);
        assert_eq!(b.assign(0, 10), TenantId(2), "inside the window");
        assert_eq!(b.assign(0, 30), TenantId(0), "outside: round-robin");
        assert_eq!(b.assign(1, 125), TenantId(2), "window repeats");
    }

    #[test]
    fn closed_loop_maps_users_to_tenants() {
        let mut src = ClosedLoopSource::new(ClosedLoopParams::demo(4, 1).with_tenants(2), 3);
        let mut tenants = Vec::new();
        while let Some(s) = src.next_job() {
            tenants.push(s.tenant.0);
        }
        assert_eq!(tenants.len(), 4);
        assert!(tenants.iter().any(|t| *t == 0) && tenants.iter().any(|t| *t == 1));
        assert!(tenants.iter().all(|t| *t < 2));
    }

    #[test]
    fn closed_loop_is_deterministic() {
        let drive = || {
            let mut src = ClosedLoopSource::new(ClosedLoopParams::demo(3, 2), 11);
            let mut specs = Vec::new();
            // Deterministic completion schedule.
            let mut t = 50;
            while let Some(s) = src.next_job() {
                specs.push(s.clone());
                src.on_job_finished(s.id, t);
                t += 13;
            }
            specs
        };
        assert_eq!(drive(), drive());
    }
}
