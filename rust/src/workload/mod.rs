//! Workloads: job sequences fed to the simulator.
//!
//! * [`source`] — the pull-based [`ArrivalSource`](source::ArrivalSource)
//!   trait: workloads as streams, so the simulator's resident state is
//!   O(live jobs) instead of O(total jobs). Includes the closed-loop
//!   trial-and-error source, whose arrivals depend on completions.
//! * [`synthetic`] — the §4.2 generator: per-class truncated-normal
//!   execution times / demands / grace periods, with submission times
//!   calibrated so the FIFO cluster load stays at the target (2.0).
//!   Materializes via [`SyntheticWorkload::generate`](synthetic::SyntheticWorkload::generate)
//!   or streams via [`SyntheticSource`](synthetic::SyntheticSource).
//! * [`trace`] — CSV trace I/O (materialized and streamed) plus a
//!   synthesized "institution trace" (heavy-tailed, bursty) standing in
//!   for the private cluster trace of §4.4 (see DESIGN.md §3 for the
//!   substitution argument).

pub mod source;
pub mod synthetic;
pub mod trace;

pub use source::{ArrivalSource, WorkloadSource};

use crate::job::{JobClass, JobSpec};
use crate::resources::ResourceVec;

/// An ordered job sequence. Invariants (enforced by `new`): jobs sorted by
/// submission time, ids dense `0..n` in submission order (the simulator
/// indexes its job table by id).
#[derive(Debug, Clone)]
pub struct Workload {
    /// Job specs sorted by submission time with dense ids.
    pub jobs: Vec<JobSpec>,
}

impl Workload {
    /// Normalize: stable-sort by submit time and reassign dense ids.
    pub fn new(mut jobs: Vec<JobSpec>) -> Self {
        jobs.sort_by_key(|j| j.submit);
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = crate::job::JobId(i as u32);
        }
        Workload { jobs }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Fraction of TE jobs.
    pub fn te_fraction(&self) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        let te = self.jobs.iter().filter(|j| j.class == JobClass::Te).count();
        te as f64 / self.jobs.len() as f64
    }

    /// Total work = Σ demand · exec-time, as a resource-minutes vector.
    pub fn total_work(&self) -> ResourceVec {
        self.jobs.iter().fold(ResourceVec::ZERO, |acc, j| {
            acc + j.demand.scale(j.exec_time as f64)
        })
    }

    /// Span of submission times in minutes.
    pub fn submit_span(&self) -> u64 {
        match (self.jobs.first(), self.jobs.last()) {
            (Some(a), Some(b)) => b.submit - a.submit,
            _ => 0,
        }
    }

    /// Filter to a class (diagnostics).
    pub fn of_class(&self, class: JobClass) -> impl Iterator<Item = &JobSpec> {
        self.jobs.iter().filter(move |j| j.class == class)
    }

    /// Stream this workload through the pull-based [`ArrivalSource`]
    /// interface (the back-compat adapter the simulator and sweep use).
    pub fn source(&self) -> WorkloadSource<'_> {
        WorkloadSource::new(self)
    }

    /// Re-assign tenants with `assigner` (by the dense id, which equals
    /// the submission sequence number). Pure metadata: arrival times,
    /// demands, and ids are untouched, so a tenant-tagged workload runs
    /// byte-identically under the `fifo` discipline.
    pub fn assign_tenants(&mut self, assigner: &source::TenantAssigner) {
        for j in &mut self.jobs {
            j.tenant = assigner.assign(j.id.0, j.submit);
        }
    }

    /// Distinct tenants present in the workload.
    pub fn tenant_count(&self) -> usize {
        let mut seen: Vec<u32> = self.jobs.iter().map(|j| j.tenant.0).collect();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobId;

    #[test]
    fn new_sorts_and_renumbers() {
        let wl = Workload::new(vec![
            JobSpec::new(7, JobClass::Be, ResourceVec::new(1.0, 1.0, 0.0), 10, 5, 0),
            JobSpec::new(3, JobClass::Te, ResourceVec::new(1.0, 1.0, 0.0), 2, 5, 0),
        ]);
        assert_eq!(wl.jobs[0].submit, 2);
        assert_eq!(wl.jobs[0].id, JobId(0));
        assert_eq!(wl.jobs[1].id, JobId(1));
        assert_eq!(wl.te_fraction(), 0.5);
        assert_eq!(wl.submit_span(), 8);
    }

    #[test]
    fn total_work_accumulates() {
        let wl = Workload::new(vec![
            JobSpec::new(0, JobClass::Be, ResourceVec::new(2.0, 4.0, 1.0), 0, 10, 0),
            JobSpec::new(1, JobClass::Be, ResourceVec::new(1.0, 2.0, 0.0), 0, 20, 0),
        ]);
        assert_eq!(wl.total_work(), ResourceVec::new(40.0, 80.0, 10.0));
    }
}
