//! The §4.2 synthetic workload generator.
//!
//! The paper fits separate (truncated) normal distributions per job class
//! to the institution trace for (1) execution time, (2) CPU, (3) RAM, and
//! (4) GPU, then submits jobs "at such a rate that the cluster load (the
//! ratio of the total resource demand relative to the capacity) would be
//! kept at 2.0 if they were scheduled by FIFO".
//!
//! Published parameters: TE exec ~ N(5 min, ·) trunc 30 min; BE exec ~
//! N(30 min, ·) trunc 24 h; GP ~ N(3 min, ·) trunc 20 min. The standard
//! deviations and the Fig. 2 demand distributions are not printed in the
//! paper, so we choose values that reproduce its qualitative regime
//! (several jobs per node, GPU as the binding axis, a standing FIFO
//! backlog ≈ one cluster-capacity of demand) and document them here; all
//! are overridable via the builder.
//!
//! **Arrival calibration.** "Kept at 2.0 under FIFO" is implemented
//! literally: the generator runs an *internal FIFO simulation* and, at
//! every simulated minute, injects new jobs while the outstanding demand
//! (queued + running, dominant-axis share of total capacity) is below the
//! target. The resulting submission times are frozen into the workload,
//! and every policy replays the identical sequence.

use super::Workload;
use crate::cluster::ClusterSpec;
use crate::job::{Job, JobClass, JobId, JobSpec};
use crate::resources::ResourceVec;
use crate::sched::policy::PolicyKind;
use crate::sched::{SchedConfig, Scheduler};
use crate::stats::dist::{Sample, TruncatedNormal};
use crate::stats::rng::Pcg64;

/// Per-class demand/exec distribution bundle.
#[derive(Debug, Clone)]
pub struct ClassDists {
    pub exec_min: TruncatedNormal,
    pub cpu: TruncatedNormal,
    pub ram_gb: TruncatedNormal,
    pub gpu: TruncatedNormal,
}

/// Builder for §4.2 workloads.
#[derive(Debug, Clone)]
pub struct SyntheticWorkload {
    pub seed: u64,
    pub num_jobs: usize,
    pub te_fraction: f64,
    pub target_load: f64,
    pub cluster: ClusterSpec,
    pub te: ClassDists,
    pub be: ClassDists,
    pub gp: TruncatedNormal,
    /// Fraction of jobs that request zero GPUs (CPU-only preprocessing
    /// etc.; gives the GPU axis the bimodal shape of a real DL cluster).
    pub cpu_only_fraction: f64,
}

impl SyntheticWorkload {
    /// The paper's §4.2 configuration (with documented choices where the
    /// paper is silent — see module docs).
    pub fn paper_section_4_2(seed: u64) -> Self {
        SyntheticWorkload {
            seed,
            num_jobs: 1 << 16,
            te_fraction: 0.30,
            target_load: 2.0,
            cluster: ClusterSpec::pfn(),
            te: ClassDists {
                // Paper: mean 5 min, truncated at 30 min. Demands: TE jobs
                // are short-*duration* debugging runs of the same models
                // the BE jobs train (Fig. 2 shows similar per-class demand
                // marginals — debugging a 4-GPU model still needs 4 GPUs),
                // so the demand distributions match the BE ones. This is
                // also what makes preemption necessary at all: a TE job
                // rarely fits in the slack the blocked BE head left behind.
                exec_min: TruncatedNormal::new(5.0, 6.0, 1.0, 30.0),
                cpu: TruncatedNormal::new(8.0, 8.0, 1.0, 32.0),
                ram_gb: TruncatedNormal::new(64.0, 64.0, 1.0, 256.0),
                gpu: TruncatedNormal::new(3.0, 2.5, 0.0, 8.0),
            },
            be: ClassDists {
                // Paper: mean 30 min, truncated at 24 h.
                exec_min: TruncatedNormal::new(30.0, 60.0, 1.0, 1440.0),
                cpu: TruncatedNormal::new(8.0, 8.0, 1.0, 32.0),
                ram_gb: TruncatedNormal::new(64.0, 64.0, 1.0, 256.0),
                gpu: TruncatedNormal::new(3.0, 2.5, 0.0, 8.0),
            },
            // Paper: mean 3 min, truncated at 20 min (σ chosen so a
            // meaningful mass sits near zero — rewind-tolerant jobs).
            gp: TruncatedNormal::new(3.0, 4.0, 0.0, 20.0),
            cpu_only_fraction: 0.1,
        }
    }

    pub fn with_num_jobs(mut self, n: usize) -> Self {
        self.num_jobs = n;
        self
    }

    pub fn with_te_fraction(mut self, f: f64) -> Self {
        assert!((0.0..=1.0).contains(&f));
        self.te_fraction = f;
        self
    }

    pub fn with_target_load(mut self, l: f64) -> Self {
        assert!(l > 0.0);
        self.target_load = l;
        self
    }

    pub fn with_cluster(mut self, c: ClusterSpec) -> Self {
        self.cluster = c;
        self
    }

    /// Fig. 7: scale the whole GP distribution (mean, σ, truncation) by `k`.
    pub fn with_gp_scale(mut self, k: f64) -> Self {
        self.gp = self.gp.scaled(k);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Draw one job spec (id/submit filled by the calibration loop).
    fn draw_job(&self, rng: &mut Pcg64, gp_rng: &mut Pcg64, class_rng: &mut Pcg64) -> (JobClass, ResourceVec, u64, u64) {
        let class = if class_rng.chance(self.te_fraction) {
            JobClass::Te
        } else {
            JobClass::Be
        };
        let d = match class {
            JobClass::Te => &self.te,
            JobClass::Be => &self.be,
        };
        let cpu = d.cpu.sample(rng).round().max(1.0);
        let ram = d.ram_gb.sample(rng).round().max(1.0);
        let gpu = if rng.chance(self.cpu_only_fraction) {
            0.0
        } else {
            d.gpu.sample(rng).round().max(0.0)
        };
        let mut demand = ResourceVec::new(cpu, ram, gpu);
        // Cap at the largest node so every job is schedulable.
        let max_cap = self
            .cluster
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, c| acc.max(c));
        demand = demand.min(&max_cap);
        let exec = d.exec_min.sample(rng).round().max(1.0) as u64;
        let gp = self.gp.sample(gp_rng).round().max(0.0) as u64;
        (class, demand, exec, gp)
    }

    /// Generate the workload: run the internal FIFO calibration sim and
    /// freeze submission times.
    pub fn generate(&self) -> Workload {
        let mut root = Pcg64::new(self.seed);
        let mut demand_rng = root.split(1);
        let mut gp_rng = root.split(2);
        let mut class_rng = root.split(3);

        let total_cap = self.cluster.total_capacity();
        let mut sched = Scheduler::new(&self.cluster, SchedConfig::new(PolicyKind::Fifo));
        let mut jobs: Vec<Job> = Vec::with_capacity(self.num_jobs);
        let mut arrivals: Vec<JobId> = Vec::new();
        let mut now: u64 = 0;
        let mut drawn = 0usize;

        while drawn < self.num_jobs {
            // Inject while the FIFO outstanding load is below target.
            arrivals.clear();
            loop {
                let load = sched
                    .outstanding_demand(&jobs)
                    .dominant_share(&total_cap);
                if load >= self.target_load || drawn >= self.num_jobs {
                    break;
                }
                let (class, demand, exec, gp) = self.draw_job(&mut demand_rng, &mut gp_rng, &mut class_rng);
                let id = JobId(drawn as u32);
                let spec = JobSpec { id, class, demand, submit: now, exec_time: exec, grace_period: gp };
                jobs.push(Job::new(spec));
                arrivals.push(id);
                // The arrival immediately counts toward outstanding demand
                // once submitted below.
                sched.submit(&jobs[drawn]);
                drawn += 1;
            }
            // Tick FIFO (arrivals were already submitted above; pass none).
            sched.tick(now, &mut jobs, &[]);
            now += 1;
        }

        Workload::new(jobs.into_iter().map(|j| j.spec).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SyntheticWorkload {
        SyntheticWorkload::paper_section_4_2(42)
            .with_cluster(ClusterSpec::tiny(4))
            .with_num_jobs(512)
    }

    #[test]
    fn respects_published_truncations() {
        let wl = small().generate();
        for j in &wl.jobs {
            match j.class {
                JobClass::Te => assert!(j.exec_time <= 30, "TE exec trunc 30: {}", j.exec_time),
                JobClass::Be => assert!(j.exec_time <= 1440, "BE exec trunc 24h"),
            }
            assert!(j.grace_period <= 20, "GP trunc 20 min");
            assert!(j.exec_time >= 1);
        }
    }

    #[test]
    fn te_fraction_close_to_requested() {
        let wl = SyntheticWorkload::paper_section_4_2(7)
            .with_cluster(ClusterSpec::tiny(4))
            .with_num_jobs(4096)
            .generate();
        assert!((wl.te_fraction() - 0.30).abs() < 0.03, "{}", wl.te_fraction());
    }

    #[test]
    fn demands_fit_some_node() {
        let wl = small().generate();
        let cap = ResourceVec::pfn_node();
        for j in &wl.jobs {
            assert!(j.demand.fits_in(&cap), "{} exceeds node", j.demand);
            assert!(j.demand.cpu >= 1.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = small().generate();
        let b = small().generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x, y);
        }
        let c = small().with_seed(43).generate();
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| x != y));
    }

    #[test]
    fn load_calibration_builds_backlog() {
        // Under the FIFO calibration the submission span must be long
        // enough that arrivals are rate-limited (not all at t=0), and the
        // workload's outstanding load target implies a standing backlog.
        let wl = small().generate();
        assert!(wl.submit_span() > 10, "span={}", wl.submit_span());
        // Sorted ids == submit order.
        for w in wl.jobs.windows(2) {
            assert!(w[0].submit <= w[1].submit);
        }
    }

    #[test]
    fn gp_scale_scales_truncation() {
        let wl = small().with_gp_scale(8.0).generate();
        let max_gp = wl.jobs.iter().map(|j| j.grace_period).max().unwrap();
        assert!(max_gp > 20, "scaled GPs must exceed the 1.0-scale cap");
        assert!(max_gp <= 160);
    }

    #[test]
    fn zero_gpu_jobs_exist() {
        let wl = small().generate();
        assert!(wl.jobs.iter().any(|j| j.demand.gpu == 0.0));
        assert!(wl.jobs.iter().any(|j| j.demand.gpu > 0.0));
    }
}
