//! The §4.2 synthetic workload generator.
//!
//! The paper fits separate (truncated) normal distributions per job class
//! to the institution trace for (1) execution time, (2) CPU, (3) RAM, and
//! (4) GPU, then submits jobs "at such a rate that the cluster load (the
//! ratio of the total resource demand relative to the capacity) would be
//! kept at 2.0 if they were scheduled by FIFO".
//!
//! Published parameters: TE exec ~ N(5 min, ·) trunc 30 min; BE exec ~
//! N(30 min, ·) trunc 24 h; GP ~ N(3 min, ·) trunc 20 min. The standard
//! deviations and the Fig. 2 demand distributions are not printed in the
//! paper, so we choose values that reproduce its qualitative regime
//! (several jobs per node, GPU as the binding axis, a standing FIFO
//! backlog ≈ one cluster-capacity of demand) and document them here; all
//! are overridable via the builder.
//!
//! **Arrival calibration.** "Kept at 2.0 under FIFO" is implemented
//! literally: the generator runs an *internal FIFO simulation* and, at
//! every simulated minute, injects new jobs while the outstanding demand
//! (queued + running, dominant-axis share of total capacity) is below the
//! target. The resulting submission times are frozen into the workload,
//! and every policy replays the identical sequence.

use super::source::{ArrivalSource, TenantAssigner};
use super::Workload;
use crate::cluster::ClusterSpec;
use crate::job::{Job, JobClass, JobId, JobSpec};
use crate::job_table::JobTable;
use crate::resources::ResourceVec;
use crate::sched::policy::PolicyKind;
use crate::sched::{SchedConfig, Scheduler};
use crate::stats::dist::{Sample, TruncatedNormal};
use crate::stats::rng::Pcg64;
use crate::Minutes;
use std::collections::VecDeque;

/// Per-class demand/exec distribution bundle.
#[derive(Debug, Clone)]
pub struct ClassDists {
    pub exec_min: TruncatedNormal,
    pub cpu: TruncatedNormal,
    pub ram_gb: TruncatedNormal,
    pub gpu: TruncatedNormal,
}

/// Builder for §4.2 workloads.
#[derive(Debug, Clone)]
pub struct SyntheticWorkload {
    pub seed: u64,
    pub num_jobs: usize,
    pub te_fraction: f64,
    pub target_load: f64,
    pub cluster: ClusterSpec,
    pub te: ClassDists,
    pub be: ClassDists,
    pub gp: TruncatedNormal,
    /// Fraction of jobs that request zero GPUs (CPU-only preprocessing
    /// etc.; gives the GPU axis the bimodal shape of a real DL cluster).
    pub cpu_only_fraction: f64,
    /// Tenant-assignment rule (single-tenant by default; pure metadata —
    /// never changes arrival times or RNG draws).
    pub tenants: TenantAssigner,
}

impl SyntheticWorkload {
    /// The paper's §4.2 configuration (with documented choices where the
    /// paper is silent — see module docs).
    pub fn paper_section_4_2(seed: u64) -> Self {
        SyntheticWorkload {
            seed,
            num_jobs: 1 << 16,
            te_fraction: 0.30,
            target_load: 2.0,
            cluster: ClusterSpec::pfn(),
            te: ClassDists {
                // Paper: mean 5 min, truncated at 30 min. Demands: TE jobs
                // are short-*duration* debugging runs of the same models
                // the BE jobs train (Fig. 2 shows similar per-class demand
                // marginals — debugging a 4-GPU model still needs 4 GPUs),
                // so the demand distributions match the BE ones. This is
                // also what makes preemption necessary at all: a TE job
                // rarely fits in the slack the blocked BE head left behind.
                exec_min: TruncatedNormal::new(5.0, 6.0, 1.0, 30.0),
                cpu: TruncatedNormal::new(8.0, 8.0, 1.0, 32.0),
                ram_gb: TruncatedNormal::new(64.0, 64.0, 1.0, 256.0),
                gpu: TruncatedNormal::new(3.0, 2.5, 0.0, 8.0),
            },
            be: ClassDists {
                // Paper: mean 30 min, truncated at 24 h.
                exec_min: TruncatedNormal::new(30.0, 60.0, 1.0, 1440.0),
                cpu: TruncatedNormal::new(8.0, 8.0, 1.0, 32.0),
                ram_gb: TruncatedNormal::new(64.0, 64.0, 1.0, 256.0),
                gpu: TruncatedNormal::new(3.0, 2.5, 0.0, 8.0),
            },
            // Paper: mean 3 min, truncated at 20 min (σ chosen so a
            // meaningful mass sits near zero — rewind-tolerant jobs).
            gp: TruncatedNormal::new(3.0, 4.0, 0.0, 20.0),
            cpu_only_fraction: 0.1,
            tenants: TenantAssigner::single(),
        }
    }

    pub fn with_num_jobs(mut self, n: usize) -> Self {
        self.num_jobs = n;
        self
    }

    pub fn with_te_fraction(mut self, f: f64) -> Self {
        assert!((0.0..=1.0).contains(&f));
        self.te_fraction = f;
        self
    }

    pub fn with_target_load(mut self, l: f64) -> Self {
        assert!(l > 0.0);
        self.target_load = l;
        self
    }

    pub fn with_cluster(mut self, c: ClusterSpec) -> Self {
        self.cluster = c;
        self
    }

    /// Fig. 7: scale the whole GP distribution (mean, σ, truncation) by `k`.
    pub fn with_gp_scale(mut self, k: f64) -> Self {
        self.gp = self.gp.scaled(k);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the tenant-assignment rule (round-robin, bursty tenant, …).
    pub fn with_tenant_assigner(mut self, tenants: TenantAssigner) -> Self {
        self.tenants = tenants;
        self
    }

    /// Draw one job spec (id/submit filled by the calibration loop).
    fn draw_job(&self, rng: &mut Pcg64, gp_rng: &mut Pcg64, class_rng: &mut Pcg64) -> (JobClass, ResourceVec, u64, u64) {
        let class = if class_rng.chance(self.te_fraction) {
            JobClass::Te
        } else {
            JobClass::Be
        };
        let d = match class {
            JobClass::Te => &self.te,
            JobClass::Be => &self.be,
        };
        let cpu = d.cpu.sample(rng).round().max(1.0);
        let ram = d.ram_gb.sample(rng).round().max(1.0);
        let gpu = if rng.chance(self.cpu_only_fraction) {
            0.0
        } else {
            d.gpu.sample(rng).round().max(0.0)
        };
        let mut demand = ResourceVec::new(cpu, ram, gpu);
        // Cap at the largest node so every job is schedulable.
        let max_cap = self
            .cluster
            .nodes
            .iter()
            .fold(ResourceVec::ZERO, |acc, c| acc.max(c));
        demand = demand.min(&max_cap);
        let exec = d.exec_min.sample(rng).round().max(1.0) as u64;
        let gp = self.gp.sample(gp_rng).round().max(0.0) as u64;
        (class, demand, exec, gp)
    }

    /// Generate the workload: run the internal FIFO calibration sim and
    /// freeze submission times. Equivalent to draining a
    /// [`SyntheticSource`] — the streamed and materialized §4.2 workloads
    /// are byte-identical (pinned by `rust/tests/streaming_equivalence.rs`).
    pub fn generate(&self) -> Workload {
        let mut src = SyntheticSource::new(self.clone());
        let mut jobs = Vec::with_capacity(self.num_jobs);
        while let Some(spec) = src.next_job() {
            jobs.push(spec);
        }
        Workload::new(jobs)
    }

    /// Stream this generator (jobs drawn on the fly; O(live) memory).
    pub fn stream(&self) -> SyntheticSource {
        SyntheticSource::new(self.clone())
    }
}

/// The §4.2 generator as a pull-based [`ArrivalSource`]: jobs are drawn
/// while the internal FIFO calibration simulation advances, one simulated
/// minute at a time, and buffered only until the consumer pulls them. The
/// calibration sim itself retires completed jobs from its job table, so
/// generating an N-job workload is O(live jobs) resident — the workload is
/// never materialized.
pub struct SyntheticSource {
    params: SyntheticWorkload,
    demand_rng: Pcg64,
    gp_rng: Pcg64,
    class_rng: Pcg64,
    total_cap: ResourceVec,
    sched: Scheduler,
    table: JobTable,
    now: u64,
    drawn: usize,
    /// Jobs drawn but not yet pulled (at most one injection burst).
    buffer: VecDeque<JobSpec>,
}

impl SyntheticSource {
    /// Build the streaming generator (same RNG layout as `generate`, so
    /// the job sequence is identical).
    pub fn new(params: SyntheticWorkload) -> Self {
        let mut root = Pcg64::new(params.seed);
        let demand_rng = root.split(1);
        let gp_rng = root.split(2);
        let class_rng = root.split(3);
        let total_cap = params.cluster.total_capacity();
        let sched = Scheduler::new(&params.cluster, SchedConfig::new(PolicyKind::Fifo));
        SyntheticSource {
            demand_rng,
            gp_rng,
            class_rng,
            total_cap,
            sched,
            table: JobTable::new(),
            now: 0,
            drawn: 0,
            buffer: VecDeque::new(),
            params,
        }
    }

    /// Advance the calibration sim one simulated minute: inject while the
    /// FIFO outstanding load is below target (buffering each drawn spec),
    /// then tick and retire completions.
    fn advance_minute(&mut self) {
        loop {
            let load = self
                .sched
                .outstanding_demand(&self.table)
                .dominant_share(&self.total_cap);
            if load >= self.params.target_load || self.drawn >= self.params.num_jobs {
                break;
            }
            let (class, demand, exec, gp) =
                self.params
                    .draw_job(&mut self.demand_rng, &mut self.gp_rng, &mut self.class_rng);
            let id = JobId(self.drawn as u32);
            let spec = JobSpec {
                id,
                class,
                demand,
                submit: self.now,
                exec_time: exec,
                grace_period: gp,
                tenant: self.params.tenants.assign(id.0, self.now),
            };
            self.table.insert(Job::new(spec.clone()));
            // The arrival immediately counts toward outstanding demand.
            self.sched.submit(&self.table[id]);
            self.buffer.push_back(spec);
            self.drawn += 1;
        }
        // Tick FIFO (arrivals were already submitted above; pass none).
        let out = self.sched.tick(self.now, &mut self.table, &[]);
        for id in &out.completed {
            self.table.remove(*id);
        }
        self.now += 1;
    }
}

impl ArrivalSource for SyntheticSource {
    fn peek_submit(&mut self) -> Option<Minutes> {
        loop {
            if let Some(spec) = self.buffer.front() {
                return Some(spec.submit);
            }
            if self.drawn >= self.params.num_jobs {
                return None;
            }
            self.advance_minute();
        }
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        self.peek_submit()?;
        self.buffer.pop_front()
    }

    fn done(&self) -> bool {
        self.buffer.is_empty() && self.drawn >= self.params.num_jobs
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.params.num_jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SyntheticWorkload {
        SyntheticWorkload::paper_section_4_2(42)
            .with_cluster(ClusterSpec::tiny(4))
            .with_num_jobs(512)
    }

    #[test]
    fn respects_published_truncations() {
        let wl = small().generate();
        for j in &wl.jobs {
            match j.class {
                JobClass::Te => assert!(j.exec_time <= 30, "TE exec trunc 30: {}", j.exec_time),
                JobClass::Be => assert!(j.exec_time <= 1440, "BE exec trunc 24h"),
            }
            assert!(j.grace_period <= 20, "GP trunc 20 min");
            assert!(j.exec_time >= 1);
        }
    }

    #[test]
    fn te_fraction_close_to_requested() {
        let wl = SyntheticWorkload::paper_section_4_2(7)
            .with_cluster(ClusterSpec::tiny(4))
            .with_num_jobs(4096)
            .generate();
        assert!((wl.te_fraction() - 0.30).abs() < 0.03, "{}", wl.te_fraction());
    }

    #[test]
    fn demands_fit_some_node() {
        let wl = small().generate();
        let cap = ResourceVec::pfn_node();
        for j in &wl.jobs {
            assert!(j.demand.fits_in(&cap), "{} exceeds node", j.demand);
            assert!(j.demand.cpu >= 1.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = small().generate();
        let b = small().generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x, y);
        }
        let c = small().with_seed(43).generate();
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| x != y));
    }

    #[test]
    fn load_calibration_builds_backlog() {
        // Under the FIFO calibration the submission span must be long
        // enough that arrivals are rate-limited (not all at t=0), and the
        // workload's outstanding load target implies a standing backlog.
        let wl = small().generate();
        assert!(wl.submit_span() > 10, "span={}", wl.submit_span());
        // Sorted ids == submit order.
        for w in wl.jobs.windows(2) {
            assert!(w[0].submit <= w[1].submit);
        }
    }

    #[test]
    fn gp_scale_scales_truncation() {
        let wl = small().with_gp_scale(8.0).generate();
        let max_gp = wl.jobs.iter().map(|j| j.grace_period).max().unwrap();
        assert!(max_gp > 20, "scaled GPs must exceed the 1.0-scale cap");
        assert!(max_gp <= 160);
    }

    #[test]
    fn stream_matches_generate_byte_for_byte() {
        let params = small();
        let wl = params.generate();
        let mut src = params.stream();
        let mut streamed = Vec::new();
        while let Some(s) = src.next_job() {
            streamed.push(s);
        }
        assert!(src.done());
        assert_eq!(wl.jobs, streamed, "streamed §4.2 jobs must equal the materialized ones");
    }

    #[test]
    fn streaming_generator_retires_calibration_jobs() {
        let mut src = small().stream();
        while src.next_job().is_some() {}
        // The internal calibration sim must not have materialized the
        // whole workload: its job table holds only the live backlog.
        assert!(
            src.table.peak_live() < 512,
            "calibration table peaked at {} of 512 jobs",
            src.table.peak_live()
        );
    }

    #[test]
    fn zero_gpu_jobs_exist() {
        let wl = small().generate();
        assert!(wl.jobs.iter().any(|j| j.demand.gpu == 0.0));
        assert!(wl.jobs.iter().any(|j| j.demand.gpu > 0.0));
    }
}
