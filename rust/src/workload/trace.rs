//! Trace I/O and the synthesized institution trace (§4.4).
//!
//! The paper replays a six-month trace (~50k jobs > 180 s) of the private
//! cluster at the authors' institution. That trace is not public, so —
//! per the substitution rule in DESIGN.md §3 — `synthesize_institution`
//! builds a statistically similar stand-in: heavy-tailed (lognormal)
//! execution times, a diurnal arrival rate with bursts, per-class demand
//! marginals, and GP lengths sampled from the §4.2 distribution (the paper
//! itself had to synthesize GPs for the trace experiment too).
//!
//! The CSV format lets a *real* trace be replayed instead:
//!
//! ```csv
//! id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu
//! 0,TE,0,12,3,2,16,1
//! ```

use super::Workload;
use crate::job::{JobClass, JobSpec};
use crate::resources::ResourceVec;
use crate::stats::dist::{LogNormal, Sample, TruncatedNormal};
use crate::stats::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Trace I/O entry points.
pub struct Trace;

impl Trace {
    /// Serialize a workload to the CSV trace format.
    pub fn to_csv(workload: &Workload) -> String {
        let mut out = String::from("id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu\n");
        for j in &workload.jobs {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                j.id.0,
                j.class.as_str(),
                j.submit,
                j.exec_time,
                j.grace_period,
                j.demand.cpu,
                j.demand.ram_gb,
                j.demand.gpu
            ));
        }
        out
    }

    /// Parse the CSV trace format (header required).
    pub fn from_csv(text: &str) -> Result<Workload> {
        let mut lines = text.lines().enumerate();
        let (_, header) = lines.next().context("empty trace")?;
        let expect = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu";
        if header.trim() != expect {
            bail!("bad trace header: {header:?} (expected {expect:?})");
        }
        let mut jobs = Vec::new();
        for (lineno, line) in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != 8 {
                bail!("line {}: expected 8 columns, got {}", lineno + 1, cols.len());
            }
            let class = match cols[1] {
                "TE" | "te" => JobClass::Te,
                "BE" | "be" => JobClass::Be,
                other => bail!("line {}: bad class {other:?}", lineno + 1),
            };
            let parse_u64 = |i: usize| -> Result<u64> {
                cols[i]
                    .parse::<u64>()
                    .with_context(|| format!("line {}: column {}", lineno + 1, i))
            };
            let parse_f64 = |i: usize| -> Result<f64> {
                cols[i]
                    .parse::<f64>()
                    .with_context(|| format!("line {}: column {}", lineno + 1, i))
            };
            jobs.push(JobSpec {
                id: crate::job::JobId(cols[0].parse().with_context(|| format!("line {}: id", lineno + 1))?),
                class,
                submit: parse_u64(2)?,
                exec_time: parse_u64(3)?.max(1),
                grace_period: parse_u64(4)?,
                demand: ResourceVec::new(parse_f64(5)?, parse_f64(6)?, parse_f64(7)?),
            });
        }
        Ok(Workload::new(jobs))
    }

    pub fn write_csv(workload: &Workload, path: &Path) -> Result<()> {
        std::fs::write(path, Self::to_csv(workload))
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn read_csv(path: &Path) -> Result<Workload> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_csv(&text)
    }

    /// Synthesize the institution-trace stand-in (§4.4). `days` of
    /// submissions; ~`jobs_per_day` arrivals per day with diurnal +
    /// bursty modulation; heavy-tailed exec times.
    pub fn synthesize_institution(seed: u64, num_jobs: usize) -> Workload {
        let mut root = Pcg64::new(seed);
        let mut arrival_rng = root.split(1);
        let mut body_rng = root.split(2);
        let mut gp_rng = root.split(3);

        // Heavy-tailed execution times (minutes). TE: median 5, p95 25
        // (capped at 30 per the TE definition). BE: median 25, p95 600,
        // capped at 24 h — the long tail that makes FIFO head-of-line
        // blocking catastrophic in Table 5.
        let te_exec = LogNormal::from_median_p95(5.0, 25.0);
        let be_exec = LogNormal::from_median_p95(25.0, 600.0);
        // Demands: same marginals as §4.2 (Fig. 2 is the common source).
        let params = super::synthetic::SyntheticWorkload::paper_section_4_2(seed);
        let gp_dist = TruncatedNormal::new(3.0, 4.0, 0.0, 20.0);

        let mut jobs = Vec::with_capacity(num_jobs);
        let mut now_f = 0.0f64;
        // Base rate: ~2.0 jobs/min daytime, ~0.3 nighttime, occasional
        // 30-minute bursts at 6× (paper-style "everyone debugging at once").
        let mut burst_until = 0.0f64;
        for i in 0..num_jobs {
            let minute_of_day = (now_f as u64) % 1440;
            let day_phase = (minute_of_day as f64 / 1440.0) * std::f64::consts::TAU;
            // Diurnal: peak early afternoon, trough at night.
            let diurnal = 1.15 - (day_phase - 0.6).cos();
            let mut rate = 0.25 + 1.75 * (diurnal / 2.15).clamp(0.0, 1.0);
            if now_f < burst_until {
                rate *= 6.0;
            } else if arrival_rng.chance(0.0005) {
                burst_until = now_f + 30.0;
            }
            let gap = -(1.0 - arrival_rng.next_f64()).ln() / rate;
            now_f += gap;

            let class = if body_rng.chance(0.30) { JobClass::Te } else { JobClass::Be };
            let (dists, exec_dist, cap): (_, &LogNormal, f64) = match class {
                JobClass::Te => (&params.te, &te_exec, 30.0),
                JobClass::Be => (&params.be, &be_exec, 1440.0),
            };
            let exec = exec_dist.sample(&mut body_rng).min(cap).max(1.0).round() as u64;
            let cpu = dists.cpu.sample(&mut body_rng).round().max(1.0);
            let ram = dists.ram_gb.sample(&mut body_rng).round().max(1.0);
            let gpu = if body_rng.chance(params.cpu_only_fraction) {
                0.0
            } else {
                dists.gpu.sample(&mut body_rng).round().max(0.0)
            };
            let demand = ResourceVec::new(cpu, ram, gpu).min(&ResourceVec::pfn_node());
            let gp = gp_dist.sample(&mut gp_rng).round().max(0.0) as u64;
            jobs.push(JobSpec {
                id: crate::job::JobId(i as u32),
                class,
                submit: now_f as u64,
                exec_time: exec,
                grace_period: gp,
                demand,
            });
        }
        Workload::new(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let wl = Trace::synthesize_institution(1, 200);
        let csv = Trace::to_csv(&wl);
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(back.len(), wl.len());
        for (a, b) in wl.jobs.iter().zip(&back.jobs) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rejects_bad_header_and_rows() {
        assert!(Trace::from_csv("nope\n1,2,3").is_err());
        let good_header = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu";
        assert!(Trace::from_csv(&format!("{good_header}\n0,XX,0,5,0,1,1,0")).is_err());
        assert!(Trace::from_csv(&format!("{good_header}\n0,TE,0,5,0,1,1")).is_err());
        assert!(Trace::from_csv(&format!("{good_header}\n0,TE,zero,5,0,1,1,0")).is_err());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let text = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu\n\n# c\n0,TE,0,5,0,1,1,0\n";
        let wl = Trace::from_csv(text).unwrap();
        assert_eq!(wl.len(), 1);
    }

    #[test]
    fn institution_trace_is_heavy_tailed() {
        let wl = Trace::synthesize_institution(3, 5000);
        let mut be: Vec<f64> = wl
            .of_class(JobClass::Be)
            .map(|j| j.exec_time as f64)
            .collect();
        be.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = be[be.len() / 2];
        let p95 = be[(be.len() as f64 * 0.95) as usize];
        assert!(p95 / med > 10.0, "heavy tail: median {med}, p95 {p95}");
    }

    #[test]
    fn institution_trace_has_te_mix_and_monotone_submits() {
        let wl = Trace::synthesize_institution(4, 3000);
        assert!((wl.te_fraction() - 0.30).abs() < 0.05);
        for w in wl.jobs.windows(2) {
            assert!(w[0].submit <= w[1].submit);
        }
        assert!(wl.submit_span() > 1000, "multi-day span");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Trace::synthesize_institution(9, 300);
        let b = Trace::synthesize_institution(9, 300);
        assert_eq!(a.jobs, b.jobs);
    }
}
