//! Trace I/O and the synthesized institution trace (§4.4).
//!
//! The paper replays a six-month trace (~50k jobs > 180 s) of the private
//! cluster at the authors' institution. That trace is not public, so —
//! per the substitution rule in DESIGN.md §3 — [`InstitutionSource`]
//! synthesizes a statistically similar stand-in: heavy-tailed (lognormal)
//! execution times, a diurnal arrival rate with bursts, per-class demand
//! marginals, and GP lengths sampled from the §4.2 distribution (the paper
//! itself had to synthesize GPs for the trace experiment too).
//! [`Trace::synthesize_institution`] materializes it;
//! [`InstitutionSource`] streams it one job at a time, which is how the
//! million-job `scale` bench runs it.
//!
//! The CSV format lets a *real* trace be replayed instead:
//!
//! ```csv
//! id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu
//! 0,TE,0,12,3,2,16,1
//! ```
//!
//! [`Trace::from_csv`] materializes a whole file;
//! [`CsvStreamSource`] streams it through a buffered reader (`fitgpp
//! replay --stream`), never holding more than one row. Both accept CRLF
//! line endings and whitespace around fields, and both reject non-monotone
//! `submit_min` — an unsorted trace would otherwise break the simulator's
//! submission-order invariants at a distance. Duplicate job ids are
//! rejected by `from_csv` only: the streamer *reassigns* ids densely in
//! row order (it cannot hold a seen-id set in O(1) memory), so the CSV id
//! column is echo data on that path.

use super::source::{ArrivalSource, TenantAssigner};
use super::Workload;
use crate::job::{JobClass, JobId, JobSpec};
use crate::resources::ResourceVec;
use crate::stats::dist::{LogNormal, Sample, TruncatedNormal};
use crate::stats::rng::Pcg64;
use crate::Minutes;
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::io::BufRead;
use std::path::Path;

/// The required CSV header.
const HEADER: &str = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu";

/// One parsed CSV data row (before id/order validation).
struct Row {
    id: u32,
    class: JobClass,
    submit: Minutes,
    exec: Minutes,
    grace: Minutes,
    demand: ResourceVec,
}

/// Parse one line. `Ok(None)` for blank lines and `#` comments. Tolerates
/// CRLF endings and spaces around fields.
fn parse_row(lineno: usize, line: &str) -> Result<Option<Row>> {
    let line = line.trim_end_matches('\r').trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let cols: Vec<&str> = line.split(',').map(str::trim).collect();
    if cols.len() != 8 {
        bail!("line {lineno}: expected 8 columns, got {}", cols.len());
    }
    let class = match cols[1] {
        "TE" | "te" => JobClass::Te,
        "BE" | "be" => JobClass::Be,
        other => bail!("line {lineno}: bad class {other:?}"),
    };
    let parse_u64 = |i: usize| -> Result<u64> {
        cols[i]
            .parse::<u64>()
            .with_context(|| format!("line {lineno}: column {i}"))
    };
    let parse_f64 = |i: usize| -> Result<f64> {
        cols[i]
            .parse::<f64>()
            .with_context(|| format!("line {lineno}: column {i}"))
    };
    Ok(Some(Row {
        id: cols[0]
            .parse()
            .with_context(|| format!("line {lineno}: id"))?,
        class,
        submit: parse_u64(2)?,
        exec: parse_u64(3)?.max(1),
        grace: parse_u64(4)?,
        demand: ResourceVec::new(parse_f64(5)?, parse_f64(6)?, parse_f64(7)?),
    }))
}

/// Check a header line (CRLF/whitespace tolerant).
fn check_header(header: &str) -> Result<()> {
    if header.trim_end_matches('\r').trim() != HEADER {
        bail!("bad trace header: {header:?} (expected {HEADER:?})");
    }
    Ok(())
}

/// Trace I/O entry points.
pub struct Trace;

impl Trace {
    /// Serialize a workload to the CSV trace format.
    pub fn to_csv(workload: &Workload) -> String {
        let mut out = String::from(HEADER);
        out.push('\n');
        for j in &workload.jobs {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                j.id.0,
                j.class.as_str(),
                j.submit,
                j.exec_time,
                j.grace_period,
                j.demand.cpu,
                j.demand.ram_gb,
                j.demand.gpu
            ));
        }
        out
    }

    /// Parse the CSV trace format (header required). Rejects duplicate job
    /// ids and rows whose `submit_min` decreases — both would silently
    /// corrupt the simulator's submission-order invariants after the
    /// workload's ids are renumbered.
    pub fn from_csv(text: &str) -> Result<Workload> {
        let mut lines = text.lines().enumerate();
        let (_, header) = lines.next().context("empty trace")?;
        check_header(header)?;
        let mut jobs = Vec::new();
        let mut seen_ids: HashSet<u32> = HashSet::new();
        let mut last_submit: Option<Minutes> = None;
        for (lineno, line) in lines {
            let Some(row) = parse_row(lineno + 1, line)? else {
                continue;
            };
            if !seen_ids.insert(row.id) {
                bail!("line {}: duplicate job id {}", lineno + 1, row.id);
            }
            if let Some(prev) = last_submit {
                if row.submit < prev {
                    bail!(
                        "line {}: submit_min {} decreases (previous row was {prev}); traces must be sorted by submission time",
                        lineno + 1,
                        row.submit
                    );
                }
            }
            last_submit = Some(row.submit);
            jobs.push(JobSpec {
                id: JobId(row.id),
                class: row.class,
                submit: row.submit,
                exec_time: row.exec,
                grace_period: row.grace,
                demand: row.demand,
                tenant: crate::job::TenantId::DEFAULT,
            });
        }
        Ok(Workload::new(jobs))
    }

    pub fn write_csv(workload: &Workload, path: &Path) -> Result<()> {
        std::fs::write(path, Self::to_csv(workload))
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn read_csv(path: &Path) -> Result<Workload> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_csv(&text)
    }

    /// Materialize the institution-trace stand-in (§4.4) by draining
    /// [`InstitutionSource`] — the streamed and materialized traces are
    /// byte-identical.
    pub fn synthesize_institution(seed: u64, num_jobs: usize) -> Workload {
        let mut src = InstitutionSource::new(seed, num_jobs);
        let mut jobs = Vec::with_capacity(num_jobs);
        while let Some(spec) = src.next_job() {
            jobs.push(spec);
        }
        Workload::new(jobs)
    }
}

/// The §4.4 institution-trace synthesizer as a pull-based stream: one job
/// generated per pull, O(1) resident state. `days` worth of submissions
/// with diurnal + bursty arrival modulation and heavy-tailed (lognormal)
/// execution times — the long BE tail that makes FIFO head-of-line
/// blocking catastrophic in Table 5.
pub struct InstitutionSource {
    arrival_rng: Pcg64,
    body_rng: Pcg64,
    gp_rng: Pcg64,
    te_exec: LogNormal,
    be_exec: LogNormal,
    params: super::synthetic::SyntheticWorkload,
    gp_dist: TruncatedNormal,
    num_jobs: usize,
    generated: usize,
    now_f: f64,
    burst_until: f64,
    pending: Option<JobSpec>,
    assigner: TenantAssigner,
}

impl InstitutionSource {
    /// Assign tenants with `assigner` (pure metadata — the job stream's
    /// times, demands, and RNG draws are unchanged).
    pub fn with_tenants(mut self, assigner: TenantAssigner) -> Self {
        self.assigner = assigner;
        self
    }

    /// Build the stream. Deterministic per `(seed, num_jobs)` and
    /// prefix-stable: the first `k` jobs do not depend on `num_jobs`.
    pub fn new(seed: u64, num_jobs: usize) -> Self {
        let mut root = Pcg64::new(seed);
        let arrival_rng = root.split(1);
        let body_rng = root.split(2);
        let gp_rng = root.split(3);
        InstitutionSource {
            arrival_rng,
            body_rng,
            gp_rng,
            // Heavy-tailed execution times (minutes). TE: median 5, p95 25
            // (capped at 30 per the TE definition). BE: median 25, p95
            // 600, capped at 24 h.
            te_exec: LogNormal::from_median_p95(5.0, 25.0),
            be_exec: LogNormal::from_median_p95(25.0, 600.0),
            // Demands: same marginals as §4.2 (Fig. 2 is the common source).
            params: super::synthetic::SyntheticWorkload::paper_section_4_2(seed),
            gp_dist: TruncatedNormal::new(3.0, 4.0, 0.0, 20.0),
            num_jobs,
            generated: 0,
            now_f: 0.0,
            burst_until: 0.0,
            pending: None,
            assigner: TenantAssigner::single(),
        }
    }

    /// Generate the next job into `pending` if any remain.
    fn refill(&mut self) {
        if self.pending.is_some() || self.generated >= self.num_jobs {
            return;
        }
        let minute_of_day = (self.now_f as u64) % 1440;
        let day_phase = (minute_of_day as f64 / 1440.0) * std::f64::consts::TAU;
        // Diurnal: peak early afternoon, trough at night. Base rate ~2.0
        // jobs/min daytime, ~0.3 nighttime, occasional 30-minute bursts at
        // 6x (paper-style "everyone debugging at once").
        let diurnal = 1.15 - (day_phase - 0.6).cos();
        let mut rate = 0.25 + 1.75 * (diurnal / 2.15).clamp(0.0, 1.0);
        if self.now_f < self.burst_until {
            rate *= 6.0;
        } else if self.arrival_rng.chance(0.0005) {
            self.burst_until = self.now_f + 30.0;
        }
        let gap = -(1.0 - self.arrival_rng.next_f64()).ln() / rate;
        self.now_f += gap;

        let class = if self.body_rng.chance(0.30) { JobClass::Te } else { JobClass::Be };
        let (dists, exec_dist, cap): (_, &LogNormal, f64) = match class {
            JobClass::Te => (&self.params.te, &self.te_exec, 30.0),
            JobClass::Be => (&self.params.be, &self.be_exec, 1440.0),
        };
        let exec = exec_dist.sample(&mut self.body_rng).min(cap).max(1.0).round() as u64;
        let cpu = dists.cpu.sample(&mut self.body_rng).round().max(1.0);
        let ram = dists.ram_gb.sample(&mut self.body_rng).round().max(1.0);
        let gpu = if self.body_rng.chance(self.params.cpu_only_fraction) {
            0.0
        } else {
            dists.gpu.sample(&mut self.body_rng).round().max(0.0)
        };
        let demand = ResourceVec::new(cpu, ram, gpu).min(&ResourceVec::pfn_node());
        // GP from its own RNG stream, so the demand draws stay aligned
        // whatever the GP distribution does.
        let gp = self.gp_dist.sample(&mut self.gp_rng).round().max(0.0) as u64;
        let submit = self.now_f as u64;
        let spec = JobSpec {
            id: JobId(self.generated as u32),
            class,
            submit,
            exec_time: exec,
            grace_period: gp,
            demand,
            tenant: self.assigner.assign(self.generated as u32, submit),
        };
        self.generated += 1;
        self.pending = Some(spec);
    }
}

impl ArrivalSource for InstitutionSource {
    fn peek_submit(&mut self) -> Option<Minutes> {
        self.refill();
        self.pending.as_ref().map(|s| s.submit)
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        self.refill();
        self.pending.take()
    }

    fn done(&self) -> bool {
        self.pending.is_none() && self.generated >= self.num_jobs
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.num_jobs)
    }
}

/// Stream a CSV trace through a buffered reader: at most one row resident.
///
/// Ids are re-assigned densely in row order (matching what
/// `Workload::new` does for the materialized path), so the CSV id column
/// is not validated here — duplicate-id rejection needs the whole file
/// and lives in [`Trace::from_csv`]. Rows must be sorted by `submit_min`:
/// a decreasing submit aborts the stream with an error surfaced via
/// [`CsvStreamSource::error`], since a pull-based source cannot sort what
/// it has not read.
pub struct CsvStreamSource<R: BufRead> {
    reader: R,
    pending: Option<JobSpec>,
    next_id: u32,
    last_submit: Minutes,
    lineno: usize,
    eof: bool,
    error: Option<anyhow::Error>,
    assigner: TenantAssigner,
}

impl CsvStreamSource<std::io::BufReader<std::fs::File>> {
    /// Open a CSV trace file for streaming (header validated eagerly).
    pub fn open(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::from_reader(std::io::BufReader::new(file))
    }
}

impl<R: BufRead> CsvStreamSource<R> {
    /// Stream from any buffered reader (header validated eagerly).
    pub fn from_reader(mut reader: R) -> Result<Self> {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            bail!("empty trace");
        }
        check_header(&header)?;
        Ok(CsvStreamSource {
            reader,
            pending: None,
            next_id: 0,
            last_submit: 0,
            lineno: 1,
            eof: false,
            error: None,
            assigner: TenantAssigner::single(),
        })
    }

    /// Assign tenants to streamed rows with `assigner` (the CSV format
    /// carries no tenant column; replay-time rules — round-robin, bursty
    /// tenant — are applied here).
    pub fn with_tenants(mut self, assigner: TenantAssigner) -> Self {
        self.assigner = assigner;
        self
    }

    /// The error that aborted the stream, if any. Callers should check
    /// this after the run: a mid-stream parse error ends the stream early
    /// rather than panicking inside the simulator.
    pub fn error(&self) -> Option<&anyhow::Error> {
        self.error.as_ref()
    }

    /// Rows successfully streamed so far.
    pub fn rows_yielded(&self) -> u32 {
        self.next_id
    }

    fn refill(&mut self) {
        if self.pending.is_some() || self.eof || self.error.is_some() {
            return;
        }
        let mut line = String::new();
        loop {
            line.clear();
            self.lineno += 1;
            match self.reader.read_line(&mut line) {
                Ok(0) => {
                    self.eof = true;
                    return;
                }
                Ok(_) => {}
                Err(e) => {
                    self.error = Some(
                        anyhow::Error::from(e)
                            .context(format!("reading trace line {}", self.lineno)),
                    );
                    return;
                }
            }
            match parse_row(self.lineno, &line) {
                Ok(None) => continue, // blank/comment
                Ok(Some(row)) => {
                    if row.submit < self.last_submit {
                        self.error = Some(anyhow::anyhow!(
                            "line {}: submit_min {} decreases (previous row was {}); streamed traces must be sorted",
                            self.lineno,
                            row.submit,
                            self.last_submit
                        ));
                        return;
                    }
                    self.last_submit = row.submit;
                    let id = JobId(self.next_id);
                    self.next_id += 1;
                    self.pending = Some(JobSpec {
                        id,
                        class: row.class,
                        submit: row.submit,
                        exec_time: row.exec,
                        grace_period: row.grace,
                        demand: row.demand,
                        tenant: self.assigner.assign(id.0, row.submit),
                    });
                    return;
                }
                Err(e) => {
                    self.error = Some(e);
                    return;
                }
            }
        }
    }
}

impl<R: BufRead> ArrivalSource for CsvStreamSource<R> {
    fn peek_submit(&mut self) -> Option<Minutes> {
        self.refill();
        self.pending.as_ref().map(|s| s.submit)
    }

    fn next_job(&mut self) -> Option<JobSpec> {
        self.refill();
        self.pending.take()
    }

    fn done(&self) -> bool {
        self.pending.is_none() && (self.eof || self.error.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let wl = Trace::synthesize_institution(1, 200);
        let csv = Trace::to_csv(&wl);
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(back.len(), wl.len());
        for (a, b) in wl.jobs.iter().zip(&back.jobs) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rejects_bad_header_and_rows() {
        assert!(Trace::from_csv("nope\n1,2,3").is_err());
        let good_header = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu";
        assert!(Trace::from_csv(&format!("{good_header}\n0,XX,0,5,0,1,1,0")).is_err());
        assert!(Trace::from_csv(&format!("{good_header}\n0,TE,0,5,0,1,1")).is_err());
        assert!(Trace::from_csv(&format!("{good_header}\n0,TE,zero,5,0,1,1,0")).is_err());
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let text = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu\n\n# c\n0,TE,0,5,0,1,1,0\n";
        let wl = Trace::from_csv(text).unwrap();
        assert_eq!(wl.len(), 1);
    }

    #[test]
    fn accepts_crlf_and_spaces() {
        let text = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu\r\n 0 , TE , 0 , 5 , 0 , 1 , 1 , 0 \r\n1,be,3,7,2,2,8,1\r\n";
        let wl = Trace::from_csv(text).unwrap();
        assert_eq!(wl.len(), 2);
        assert_eq!(wl.jobs[0].class, JobClass::Te);
        assert_eq!(wl.jobs[1].submit, 3);
    }

    #[test]
    fn rejects_duplicate_ids_and_non_monotone_submits() {
        let h = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu";
        let dup = format!("{h}\n0,TE,0,5,0,1,1,0\n0,BE,1,5,0,1,1,0\n");
        let err = Trace::from_csv(&dup).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate job id"), "{err:#}");
        let unsorted = format!("{h}\n0,TE,5,5,0,1,1,0\n1,BE,2,5,0,1,1,0\n");
        let err = Trace::from_csv(&unsorted).unwrap_err();
        assert!(format!("{err:#}").contains("decreases"), "{err:#}");
    }

    #[test]
    fn stream_source_matches_from_csv() {
        let wl = Trace::synthesize_institution(5, 300);
        let csv = Trace::to_csv(&wl);
        let mut src = CsvStreamSource::from_reader(std::io::Cursor::new(csv.as_bytes())).unwrap();
        let mut streamed = Vec::new();
        while let Some(s) = src.next_job() {
            streamed.push(s);
        }
        assert!(src.error().is_none());
        assert!(src.done());
        assert_eq!(streamed, wl.jobs);
    }

    #[test]
    fn stream_source_surfaces_mid_stream_errors() {
        let h = "id,class,submit_min,exec_min,grace_min,cpu,ram_gb,gpu";
        let text = format!("{h}\n0,TE,5,5,0,1,1,0\n1,BE,2,5,0,1,1,0\n");
        let mut src = CsvStreamSource::from_reader(std::io::Cursor::new(text.into_bytes())).unwrap();
        assert!(src.next_job().is_some(), "first row is fine");
        assert!(src.next_job().is_none(), "stream stops at the bad row");
        assert!(src.done());
        assert!(format!("{:#}", src.error().unwrap()).contains("decreases"));
        assert_eq!(src.rows_yielded(), 1);
    }

    #[test]
    fn institution_stream_matches_materialized() {
        let wl = Trace::synthesize_institution(7, 400);
        let mut src = InstitutionSource::new(7, 400);
        let mut streamed = Vec::new();
        while let Some(s) = src.next_job() {
            streamed.push(s);
        }
        assert!(src.done());
        assert_eq!(streamed, wl.jobs);
    }

    #[test]
    fn institution_trace_is_heavy_tailed() {
        let wl = Trace::synthesize_institution(3, 5000);
        let mut be: Vec<f64> = wl
            .of_class(JobClass::Be)
            .map(|j| j.exec_time as f64)
            .collect();
        be.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = be[be.len() / 2];
        let p95 = be[(be.len() as f64 * 0.95) as usize];
        assert!(p95 / med > 10.0, "heavy tail: median {med}, p95 {p95}");
    }

    #[test]
    fn institution_trace_has_te_mix_and_monotone_submits() {
        let wl = Trace::synthesize_institution(4, 3000);
        assert!((wl.te_fraction() - 0.30).abs() < 0.05);
        for w in wl.jobs.windows(2) {
            assert!(w[0].submit <= w[1].submit);
        }
        assert!(wl.submit_span() > 1000, "multi-day span");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = Trace::synthesize_institution(9, 300);
        let b = Trace::synthesize_institution(9, 300);
        assert_eq!(a.jobs, b.jobs);
    }
}
