//! Live mode: the scheduler drives *real* training jobs through PJRT.
//!
//! This is the end-to-end proof that the three layers compose: the L3
//! coordinator makes the same decisions as in simulation (one tick = one
//! scheduled minute, scaled to `tick_ms` wall milliseconds), but every
//! running job is a worker thread executing the AOT-compiled transformer
//! train step (L2 + L1) on the CPU PJRT client, and a preemption's grace
//! period performs *real* suspension work — serializing the model
//! parameters to a checkpoint — exactly the §2 story.
//!
//! The coordinator speaks the same
//! [`ClusterController`](crate::sched::control::ClusterController)
//! command/event protocol the simulator drives, so both execution paths
//! are provably one API: the live report carries the run's
//! [`SchedulerEvent`](crate::sched::control::SchedulerEvent) stream, and
//! worker threads are spawned/checkpointed/stopped off the same
//! [`StepOutcome`](crate::sched::control::StepOutcome)s a simulated round
//! produces.
//!
//! Per-thread PJRT clients: the xla handles are not `Sync`, so each worker
//! owns an `Engine` and compiles the artifact at spawn (compile time is
//! reported so the overhead is visible).

use crate::cluster::ClusterSpec;
use crate::job::{JobClass, JobId};
use crate::runtime::{self, Checkpoint, Engine, Manifest, Trainer};
use crate::sched::control::{ClusterController, SchedulerEvent, SharedEventLog};
use crate::sched::policy::PolicyKind;
use crate::sched::SchedConfig;
use crate::util::json::Json;
use crate::workload::Workload;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Live-run configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Cluster shape for the live scheduler.
    pub cluster: ClusterSpec,
    /// Scheduling policy.
    pub policy: PolicyKind,
    /// Wall milliseconds per simulated minute.
    pub tick_ms: u64,
    /// Model variant from the manifest (e.g. "tiny").
    pub variant: String,
    /// RNG seed for parameter init.
    pub seed: u64,
}

impl LiveConfig {
    /// The demo configuration: the [`ClusterSpec::live_demo`] preset at
    /// its default two nodes. Resize with [`LiveConfig::with_nodes`]
    /// (`fitgpp live --nodes N`).
    pub fn demo(policy: PolicyKind) -> Self {
        LiveConfig {
            cluster: ClusterSpec::live_demo(2),
            policy,
            tick_ms: 150,
            variant: "tiny".to_string(),
            seed: 7,
        }
    }

    /// Rebuild the cluster from the [`ClusterSpec::live_demo`] preset with
    /// `n` nodes.
    pub fn with_nodes(mut self, n: usize) -> Self {
        assert!(n > 0, "a live cluster needs at least one node");
        self.cluster = ClusterSpec::live_demo(n);
        self
    }
}

/// One recorded training-loss sample.
#[derive(Debug, Clone)]
pub struct LossPoint {
    /// Which job logged the sample.
    pub job: JobId,
    /// Training step the loss belongs to.
    pub step: u64,
    /// Loss value.
    pub loss: f32,
}

/// Worker lifecycle events (for the report).
#[derive(Debug, Clone)]
pub enum LiveEvent {
    /// A worker thread came up (fresh or resumed from a checkpoint).
    Spawned { job: JobId, compile_ms: f64, resumed_at_step: u64 },
    /// A worker received the preemption signal and serialized a checkpoint.
    Suspended { job: JobId, at_step: u64, checkpoint_ms: f64, checkpoint_bytes: usize },
    /// A worker finished its job.
    Finished { job: JobId, steps: u64 },
}

#[derive(Default)]
struct SharedLog {
    losses: Vec<LossPoint>,
    events: Vec<LiveEvent>,
    checkpoints: HashMap<JobId, Checkpoint>,
}

enum Cmd {
    Preempt,
    Stop,
}

struct WorkerHandle {
    tx: Sender<Cmd>,
    join: std::thread::JoinHandle<()>,
}

/// Outcome of a live run.
#[derive(Debug)]
pub struct LiveReport {
    /// Policy that ran.
    pub policy: PolicyKind,
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// End-to-end wall clock.
    pub wall: Duration,
    /// All loss samples, in log order.
    pub losses: Vec<LossPoint>,
    /// Worker lifecycle events.
    pub events: Vec<LiveEvent>,
    /// The scheduler's control-plane event stream (the same
    /// [`SchedulerEvent`]s a simulated run emits — the proof both drivers
    /// speak one protocol).
    pub sched_events: Vec<SchedulerEvent>,
    /// Final job records (same record type the simulator produces), in
    /// job-id order.
    pub records: Vec<crate::sim::JobRecord>,
    /// Total train steps across all jobs.
    pub total_steps: u64,
}

impl LiveReport {
    /// Mean loss of the first/last quartile of a job's samples — used to
    /// verify training progress ("the loss curve went down").
    pub fn loss_drop(&self, job: JobId) -> Option<(f32, f32)> {
        let pts: Vec<&LossPoint> = self.losses.iter().filter(|p| p.job == job).collect();
        if pts.len() < 8 {
            return None;
        }
        let q = pts.len() / 4;
        let head: f32 = pts[..q].iter().map(|p| p.loss).sum::<f32>() / q as f32;
        let tail: f32 = pts[pts.len() - q..].iter().map(|p| p.loss).sum::<f32>() / q as f32;
        Some((head, tail))
    }

    /// The run's scheduler events in the exact JSONL line format the
    /// wire service ([`crate::serve`]) streams to subscribers and
    /// `--events-out` writes to disk — one line per event. A live run, a
    /// batch simulation, and a `serve` session of the same workload can
    /// therefore be diffed line by line.
    pub fn events_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in &self.sched_events {
            out.push_str(&crate::sched::control::event_jsonl_line(ev));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut per_job: Vec<Json> = Vec::new();
        for r in &self.records {
            let steps = self
                .losses
                .iter()
                .filter(|p| p.job == r.id)
                .map(|p| p.step)
                .max()
                .unwrap_or(0);
            let drop = self.loss_drop(r.id);
            per_job.push(Json::obj(vec![
                ("id", Json::num(r.id.0 as f64)),
                ("class", Json::str(r.class.as_str())),
                ("slowdown", Json::num(r.slowdown)),
                ("preemptions", Json::num(r.preemptions as f64)),
                ("steps", Json::num(steps as f64)),
                (
                    "loss_first_quartile",
                    drop.map(|d| Json::num(d.0 as f64)).unwrap_or(Json::Null),
                ),
                (
                    "loss_last_quartile",
                    drop.map(|d| Json::num(d.1 as f64)).unwrap_or(Json::Null),
                ),
            ]));
        }
        Json::obj(vec![
            ("policy", Json::str(&self.policy.name())),
            ("ticks", Json::num(self.ticks as f64)),
            ("wall_sec", Json::num(self.wall.as_secs_f64())),
            ("total_steps", Json::num(self.total_steps as f64)),
            ("sched_events", Json::num(self.sched_events.len() as f64)),
            ("jobs", Json::Arr(per_job)),
        ])
    }
}

/// The live coordinator.
pub struct LiveCluster {
    cfg: LiveConfig,
    manifest: Manifest,
}

impl LiveCluster {
    /// Load the manifest from the artifacts dir (requires `make artifacts`).
    pub fn new(cfg: LiveConfig) -> Result<LiveCluster> {
        let manifest = Manifest::load(&runtime::artifacts_dir())
            .context("loading artifact manifest — run `make artifacts` first")?;
        manifest.variant(&cfg.variant)?;
        Ok(LiveCluster { cfg, manifest })
    }

    /// Run `workload` live. Returns when every job has completed.
    ///
    /// The coordinator drives the same [`ClusterController`]
    /// command/event protocol the simulator does — every scheduling round
    /// is one [`step`](ClusterController::step), and the worker threads
    /// are controlled off the round's outcome (preempt → checkpoint,
    /// finish → stop, start/resume → spawn). The run's
    /// [`SchedulerEvent`] stream is captured in the report, so a live run
    /// and a simulated run of the same workload can be diffed event by
    /// event.
    pub fn run(&self, workload: &Workload) -> Result<LiveReport> {
        let wall0 = Instant::now();
        let mut ctl =
            ClusterController::new(&self.cfg.cluster, SchedConfig::new(self.cfg.policy));
        let sched_log = SharedEventLog::new();
        ctl.subscribe(Box::new(sched_log.clone()));
        // Live workloads are small: stage every arrival up front (the
        // clock pops each at its submit minute).
        for spec in &workload.jobs {
            ctl.stage_arrival(spec.clone());
        }
        let log: Arc<Mutex<SharedLog>> = Arc::new(Mutex::new(SharedLog::default()));
        let mut workers: HashMap<JobId, WorkerHandle> = HashMap::new();
        let mut records: Vec<crate::sim::JobRecord> = Vec::new();

        let mut now = 0u64;
        loop {
            let tick_start = Instant::now();
            let out = ctl.step(now);

            // Preemption signals → tell workers to checkpoint.
            for id in &out.tick.preempted {
                if let Some(w) = workers.get(id) {
                    let _ = w.tx.send(Cmd::Preempt);
                }
            }
            // Completions (scheduler is the source of truth for timing).
            for rec in out.finished {
                if let Some(w) = workers.remove(&rec.id) {
                    let _ = w.tx.send(Cmd::Stop);
                    let _ = w.join.join();
                }
                records.push(rec);
            }
            // Cancelled jobs' workers stop without a checkpoint (the run
            // is dead; nobody resumes it).
            for rec in out.cancelled {
                if let Some(w) = workers.remove(&rec.id) {
                    let _ = w.tx.send(Cmd::Stop);
                    let _ = w.join.join();
                }
                records.push(rec);
            }
            // Vacated jobs' workers are already checkpointing; join so the
            // checkpoint is durable before any restart.
            for id in &out.tick.vacated {
                if let Some(w) = workers.remove(id) {
                    let _ = w.tx.send(Cmd::Preempt); // idempotent nudge
                    let _ = w.join.join();
                }
            }
            // Starts (fresh or resumed).
            for id in &out.tick.started {
                let handle = self.spawn_worker(*id, Arc::clone(&log))?;
                workers.insert(*id, handle);
            }

            now += 1;
            if !ctl.sched.clock.arrivals_pending() && ctl.idle() {
                break;
            }
            if now > 1_000_000 {
                anyhow::bail!("live run did not converge");
            }
            // Pace to wall clock.
            let elapsed = tick_start.elapsed();
            let budget = Duration::from_millis(self.cfg.tick_ms);
            if elapsed < budget {
                std::thread::sleep(budget - elapsed);
            }
        }
        // Drain any stragglers.
        for (_, w) in workers.drain() {
            let _ = w.tx.send(Cmd::Stop);
            let _ = w.join.join();
        }

        records.sort_by_key(|r| r.id);
        debug_assert_eq!(records.len(), workload.jobs.len(), "every job retired");
        let log = Arc::try_unwrap(log)
            .map_err(|_| anyhow::anyhow!("worker still holds log"))?
            .into_inner()
            .unwrap();
        let total_steps = log
            .events
            .iter()
            .map(|e| match e {
                LiveEvent::Finished { steps, .. } => *steps,
                _ => 0,
            })
            .sum();
        Ok(LiveReport {
            policy: self.cfg.policy,
            ticks: now,
            wall: wall0.elapsed(),
            losses: log.losses,
            events: log.events,
            sched_events: sched_log.events(),
            records,
            total_steps,
        })
    }

    fn spawn_worker(&self, id: JobId, log: Arc<Mutex<SharedLog>>) -> Result<WorkerHandle> {
        let (tx, rx): (Sender<Cmd>, Receiver<Cmd>) = std::sync::mpsc::channel();
        let manifest = self.manifest.clone();
        let variant = self.cfg.variant.clone();
        let seed = self.cfg.seed ^ (id.0 as u64);
        let resume = log.lock().unwrap().checkpoints.remove(&id);
        let join = std::thread::spawn(move || {
            if let Err(e) = worker_main(id, rx, log, manifest, variant, seed, resume) {
                eprintln!("[live] worker {id} failed: {e:#}");
            }
        });
        Ok(WorkerHandle { tx, join })
    }
}

fn worker_main(
    id: JobId,
    rx: Receiver<Cmd>,
    log: Arc<Mutex<SharedLog>>,
    manifest: Manifest,
    variant: String,
    seed: u64,
    resume: Option<Checkpoint>,
) -> Result<()> {
    let t0 = Instant::now();
    let engine = Engine::cpu()?;
    let mut trainer = match &resume {
        Some(ckpt) => Trainer::from_checkpoint(&engine, &manifest, &variant, ckpt, seed)?,
        None => Trainer::new(&engine, &manifest, &variant, seed)?,
    };
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    log.lock().unwrap().events.push(LiveEvent::Spawned {
        job: id,
        compile_ms,
        resumed_at_step: trainer.step,
    });

    loop {
        match rx.try_recv() {
            Ok(Cmd::Preempt) => {
                // Grace-period work: serialize parameters (real bytes).
                let c0 = Instant::now();
                let ckpt = trainer.checkpoint()?;
                let bytes = ckpt.to_bytes().len();
                let checkpoint_ms = c0.elapsed().as_secs_f64() * 1e3;
                let mut l = log.lock().unwrap();
                l.events.push(LiveEvent::Suspended {
                    job: id,
                    at_step: trainer.step,
                    checkpoint_ms,
                    checkpoint_bytes: bytes,
                });
                l.checkpoints.insert(id, ckpt);
                return Ok(());
            }
            Ok(Cmd::Stop) | Err(TryRecvError::Disconnected) => {
                log.lock().unwrap().events.push(LiveEvent::Finished {
                    job: id,
                    steps: trainer.step,
                });
                return Ok(());
            }
            Err(TryRecvError::Empty) => {
                let loss = trainer.step_synthetic()?;
                log.lock().unwrap().losses.push(LossPoint {
                    job: id,
                    step: trainer.step,
                    loss,
                });
            }
        }
    }
}

/// A small live workload sized for the demo cluster: a saturating mix of
/// BE training jobs with staggered TE arrivals to force preemptions.
pub fn demo_workload(n: usize, seed: u64) -> Workload {
    use crate::job::JobSpec;
    use crate::resources::ResourceVec;
    let mut rng = crate::stats::rng::Pcg64::new(seed);
    let mut specs = Vec::with_capacity(n);
    for i in 0..n {
        let te = i % 3 == 2; // every third job is trial-and-error
        let class = if te { JobClass::Te } else { JobClass::Be };
        let demand = if te {
            ResourceVec::new(2.0, 16.0, 1.0)
        } else {
            ResourceVec::new(4.0, 32.0, 2.0)
        };
        let submit = if te { 2 + (i as u64) } else { (i as u64) / 2 };
        let exec = if te { 2 + rng.below(3) } else { 5 + rng.below(6) };
        let gp = if te { 0 } else { rng.below(3) };
        specs.push(JobSpec::new(i as u32, class, demand, submit, exec, gp));
    }
    Workload::new(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_workload_shape() {
        let wl = demo_workload(9, 1);
        assert_eq!(wl.len(), 9);
        assert!(wl.te_fraction() > 0.2 && wl.te_fraction() < 0.5);
    }

    #[test]
    fn demo_config_is_sane() {
        let c = LiveConfig::demo(PolicyKind::FitGpp { s: 4.0, p_max: Some(1) });
        assert_eq!(c.cluster.nodes.len(), 2);
        assert_eq!(c.cluster, ClusterSpec::live_demo(2), "demo routes through the preset");
        assert!(c.tick_ms > 0);
    }

    #[test]
    fn with_nodes_resizes_the_preset() {
        let c = LiveConfig::demo(PolicyKind::Fifo).with_nodes(5);
        assert_eq!(c.cluster, ClusterSpec::live_demo(5));
        assert_eq!(c.cluster.nodes.len(), 5);
    }

    #[test]
    fn live_cluster_requires_artifacts() {
        if runtime::artifacts_available() {
            // With artifacts present construction must succeed.
            assert!(LiveCluster::new(LiveConfig::demo(PolicyKind::Fifo)).is_ok());
        } else {
            assert!(LiveCluster::new(LiveConfig::demo(PolicyKind::Fifo)).is_err());
        }
    }
}
