//! Job model: TE/BE classes, demands, grace periods, and the lifecycle
//! state machine (§2 of the paper).
//!
//! Users declare, per job: the class (`TE` or `BE`), the demand vector, and
//! — because suspension processing (checkpointing) takes time — a *grace
//! period* (GP). The scheduler may suspend BE jobs; a suspended job is
//! re-queued at the *top* of the FIFO queue and later resumed with its
//! completed work intact. TE jobs are never preempted.

use crate::resources::ResourceVec;
use crate::Minutes;
use std::fmt;

/// Opaque job identifier (dense, assigned by the workload generator in
/// submission order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Opaque tenant (user / team / account) identifier. Every job belongs to
/// exactly one tenant; single-tenant workloads use [`TenantId::DEFAULT`].
///
/// Tenancy is *admission-layer* identity: the
/// [`QueueDiscipline`](crate::sched::admission::QueueDiscipline) uses it
/// for fair sharing and quota gating, and the metrics sink keys per-tenant
/// percentiles by it. The preemption policies (§3) never read it — fairness
/// composes with FitGpp orthogonally, at the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct TenantId(pub u32);

impl TenantId {
    /// The tenant every job belongs to unless a workload source assigns
    /// one (single-tenant runs are byte-identical to the pre-tenant code).
    pub const DEFAULT: TenantId = TenantId(0);
}

impl fmt::Display for TenantId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tenant-{}", self.0)
    }
}

/// The paper's two job classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobClass {
    /// Trial-and-error: small, interactive, latency-sensitive. The scheduler
    /// may preempt BE jobs to start a TE job immediately.
    Te,
    /// Best-effort: throughput-oriented; preemptible up to `P` times.
    Be,
}

impl JobClass {
    /// `"TE"` / `"BE"` (table rendering, traces).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobClass::Te => "TE",
            JobClass::Be => "BE",
        }
    }
}

impl fmt::Display for JobClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Immutable submission-time description of a job — everything the
/// scheduler is allowed to know (FitGpp deliberately does *not* get the
/// execution time; the LRTP baseline receives it as an oracle).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Dense identifier (submission order).
    pub id: JobId,
    /// TE or BE.
    pub class: JobClass,
    /// Demand vector `[C, R, G]`.
    pub demand: ResourceVec,
    /// Submission time (minutes since simulation start).
    pub submit: Minutes,
    /// Total execution time needed (minutes of actual progress).
    pub exec_time: Minutes,
    /// User-declared grace period: how long the job needs to checkpoint
    /// before vacating. Zero means "rewind is fine" (§2).
    pub grace_period: Minutes,
    /// The tenant this job belongs to ([`TenantId::DEFAULT`] unless the
    /// workload source assigned one). Read by the admission layer only.
    pub tenant: TenantId,
}

impl JobSpec {
    /// Builder-style constructor for tests and examples (tenant =
    /// [`TenantId::DEFAULT`]; chain [`JobSpec::with_tenant`] to set one).
    pub fn new(id: u32, class: JobClass, demand: ResourceVec, submit: Minutes, exec_time: Minutes, grace_period: Minutes) -> Self {
        JobSpec {
            id: JobId(id),
            class,
            demand,
            submit,
            exec_time: exec_time.max(1),
            grace_period,
            tenant: TenantId::DEFAULT,
        }
    }

    /// Builder: assign the job to `tenant`.
    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }
}

/// Lifecycle states. Transitions (enforced by `Job` methods):
///
/// ```text
/// Pending ──start──▶ Running ──preempt──▶ Draining ──vacate──▶ Pending(top)
///    ▲                  │                     │
///    └──────────────────┴──────complete───────┘   (Draining jobs complete
///  Running ──complete──▶ Done                      too if their remaining
///                                                  work hits 0 first)
/// ```
///
/// Two further transitions come from the control plane
/// ([`sched::control`](crate::sched::control)) rather than the scheduler's
/// own decisions:
///
/// * `Pending | Running | Draining ──cancel──▶ Cancelled` — the user (or a
///   [`ScenarioScript`](crate::sim::scenario::ScenarioScript) standing in
///   for one) kills the job; it never completes and is excluded from
///   slowdown statistics.
/// * `Running | Draining ──fail_over──▶ Pending(top)` — the hosting node
///   failed; the job is re-queued with priority. Unlike [`Job::vacate`]
///   this does **not** count as a policy preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// In the queue (either never started, or suspended and re-queued).
    Pending,
    /// Occupying resources on a node and making progress.
    Running,
    /// Signalled for preemption; still occupying resources for the grace
    /// period while it checkpoints. Makes **no** progress on its own work
    /// (suspension processing is pure overhead — conservative reading of §2).
    Draining,
    /// Finished.
    Done,
    /// Killed by a control-plane cancellation before completing.
    Cancelled,
}

/// A job's full runtime record. The simulator owns one `Job` per `JobSpec`;
/// scheduling policies see `&Job` views.
#[derive(Debug, Clone)]
pub struct Job {
    /// The immutable submission-time spec.
    pub spec: JobSpec,
    /// Current lifecycle state.
    pub state: JobState,
    /// Remaining execution time (minutes). `spec.exec_time` at submission;
    /// preserved across suspend/resume (no rewind).
    pub remaining: Minutes,
    /// Remaining grace period while `Draining`.
    pub grace_left: Minutes,
    /// Node currently hosting the job (`Running` or `Draining`).
    pub node: Option<crate::cluster::NodeId>,
    /// How many times this job has been preempted (the paper's
    /// `PreemptionCount_j`, capped by the policy parameter `P`).
    pub preemptions: u32,
    /// Cumulative minutes spent waiting in the queue (drives Eq. 5).
    pub waiting: Minutes,
    /// Tick at which the job most recently vacated a node due to preemption
    /// (start of a re-scheduling interval, Table 2).
    pub last_vacated: Option<Minutes>,
    /// Completed re-scheduling intervals (vacate → restart), Table 2.
    pub resched_intervals: Vec<Minutes>,
    /// First time the job started running (for time-to-first-schedule).
    pub first_start: Option<Minutes>,
    /// Completion time.
    pub finished_at: Option<Minutes>,
    /// Cancellation time (control plane). Mutually exclusive with
    /// `finished_at`.
    pub cancelled_at: Option<Minutes>,
    /// Node-failure evictions suffered (control plane; *not* counted as
    /// preemptions — the `P` starvation cap only reads `preemptions`).
    pub evictions: u32,
    /// Lifecycle-transition counter: bumped on every start / preemption
    /// signal / vacate / complete. The [`EventClock`](crate::sched::clock)
    /// stamps scheduled events with the epoch they were predicted under, so
    /// a later transition invalidates them lazily (no heap surgery).
    pub epoch: u64,
}

impl Job {
    pub fn new(spec: JobSpec) -> Self {
        let remaining = spec.exec_time;
        Job {
            spec,
            state: JobState::Pending,
            remaining,
            grace_left: 0,
            node: None,
            preemptions: 0,
            waiting: 0,
            last_vacated: None,
            resched_intervals: Vec::new(),
            first_start: None,
            finished_at: None,
            cancelled_at: None,
            evictions: 0,
            epoch: 0,
        }
    }

    pub fn id(&self) -> JobId {
        self.spec.id
    }

    pub fn is_te(&self) -> bool {
        self.spec.class == JobClass::Te
    }

    pub fn is_be(&self) -> bool {
        self.spec.class == JobClass::Be
    }

    /// The tenant this job belongs to.
    pub fn tenant(&self) -> TenantId {
        self.spec.tenant
    }

    /// Transition Pending → Running on `node` at time `now`.
    pub fn start(&mut self, node: crate::cluster::NodeId, now: Minutes) {
        debug_assert_eq!(self.state, JobState::Pending, "{} start from {:?}", self.id(), self.state);
        self.state = JobState::Running;
        self.epoch += 1;
        self.node = Some(node);
        if self.first_start.is_none() {
            self.first_start = Some(now);
        }
        if let Some(v) = self.last_vacated.take() {
            self.resched_intervals.push(now.saturating_sub(v));
        }
    }

    /// Transition Running → Draining: the preemption signal. The job keeps
    /// its resources for `grace_period` minutes (possibly 0 ⇒ it vacates on
    /// the same tick's GP-expiry pass).
    pub fn signal_preemption(&mut self) {
        debug_assert_eq!(self.state, JobState::Running, "{} preempt from {:?}", self.id(), self.state);
        debug_assert!(self.is_be(), "TE jobs are never preempted");
        self.state = JobState::Draining;
        self.epoch += 1;
        self.grace_left = self.spec.grace_period;
    }

    /// Transition Draining → Pending: the grace period elapsed and the job
    /// vacated its node. Returns to the *top* of the queue (caller's job).
    pub fn vacate(&mut self, now: Minutes) {
        debug_assert_eq!(self.state, JobState::Draining);
        self.state = JobState::Pending;
        self.epoch += 1;
        self.node = None;
        self.grace_left = 0;
        self.preemptions += 1;
        self.last_vacated = Some(now);
    }

    /// Transition Running/Draining → Done.
    pub fn complete(&mut self, now: Minutes) {
        debug_assert!(matches!(self.state, JobState::Running | JobState::Draining));
        self.state = JobState::Done;
        self.epoch += 1;
        self.node = None;
        self.finished_at = Some(now);
    }

    /// Control-plane cancellation: Pending/Running/Draining → Cancelled.
    /// The job never completes (`finished_at` stays `None`, so cancelled
    /// jobs fall out of every slowdown percentile) and is retired
    /// immediately by the caller.
    pub fn cancel(&mut self, now: Minutes) {
        debug_assert!(
            matches!(
                self.state,
                JobState::Pending | JobState::Running | JobState::Draining
            ),
            "{} cancelled from {:?}",
            self.id(),
            self.state
        );
        self.state = JobState::Cancelled;
        self.epoch += 1;
        self.node = None;
        self.grace_left = 0;
        self.cancelled_at = Some(now);
    }

    /// Node-failure eviction: Running/Draining → Pending. The hosting node
    /// disappeared, so there is no grace period — the job vacates at once
    /// and is re-queued at the top. Completed work is preserved (the live
    /// executor restores from the last checkpoint; the simulator models the
    /// optimistic no-rewind case, matching [`Job::vacate`]). Unlike a
    /// vacate this is *not* a policy preemption: `preemptions` (the paper's
    /// `PreemptionCount_j`, which the `P` cap reads) stays untouched and
    /// the interruption is tallied in `evictions` instead.
    pub fn fail_over(&mut self, _now: Minutes) {
        debug_assert!(matches!(self.state, JobState::Running | JobState::Draining));
        self.state = JobState::Pending;
        self.epoch += 1;
        self.node = None;
        self.grace_left = 0;
        self.evictions += 1;
    }

    /// Eq. 5: `slowdown = 1 + WaitingTime / ExecutionTime`.
    ///
    /// We take `WaitingTime = FlowTime - ExecutionTime` (every non-progress
    /// minute: queueing *and* grace-period limbo), which makes Eq. 5 the
    /// classic `slowdown = FlowTime / ExecutionTime`. For a never-preempted
    /// job this is exactly `1 + queue-wait / exec`. For a job still
    /// unfinished when the simulation is cut off, the accrued queue wait is
    /// used as a lower bound (the default simulations drain the backlog, so
    /// this only applies to custom horizons).
    pub fn slowdown(&self) -> f64 {
        match self.finished_at {
            Some(fin) => (fin - self.spec.submit) as f64 / self.spec.exec_time as f64,
            None => 1.0 + self.waiting as f64 / self.spec.exec_time as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;

    fn spec(class: JobClass) -> JobSpec {
        JobSpec::new(1, class, ResourceVec::new(4.0, 32.0, 1.0), 0, 30, 3)
    }

    #[test]
    fn fresh_job_is_pending_with_full_remaining() {
        let j = Job::new(spec(JobClass::Be));
        assert_eq!(j.state, JobState::Pending);
        assert_eq!(j.remaining, 30);
        assert_eq!(j.preemptions, 0);
        assert_eq!(j.slowdown(), 1.0);
    }

    #[test]
    fn exec_time_clamped_to_one_minute() {
        let s = JobSpec::new(1, JobClass::Te, ResourceVec::ZERO, 0, 0, 0);
        assert_eq!(s.exec_time, 1);
    }

    #[test]
    fn start_records_first_start_once() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 5);
        assert_eq!(j.first_start, Some(5));
        assert_eq!(j.state, JobState::Running);
        j.signal_preemption();
        j.vacate(10);
        j.start(NodeId(1), 12);
        assert_eq!(j.first_start, Some(5), "first_start must not move");
    }

    #[test]
    fn preemption_cycle_updates_count_and_interval() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 0);
        j.signal_preemption();
        assert_eq!(j.state, JobState::Draining);
        assert_eq!(j.grace_left, 3);
        j.vacate(4);
        assert_eq!(j.state, JobState::Pending);
        assert_eq!(j.preemptions, 1);
        assert!(j.node.is_none());
        j.start(NodeId(2), 9);
        assert_eq!(j.resched_intervals, vec![5]);
    }

    #[test]
    fn slowdown_eq5_unfinished_uses_accrued_wait() {
        let mut j = Job::new(spec(JobClass::Te));
        j.waiting = 15; // waited half its 30-minute runtime so far
        assert!((j.slowdown() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn slowdown_eq5_finished_is_flow_over_exec() {
        let mut j = Job::new(spec(JobClass::Te)); // submit=0, exec=30
        j.start(NodeId(0), 15);
        j.complete(45); // flow = 45, exec = 30 ⇒ slowdown = 1.5 = 1 + 15/30
        assert!((j.slowdown() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn complete_from_running_and_draining() {
        let mut a = Job::new(spec(JobClass::Be));
        a.start(NodeId(0), 0);
        a.complete(30);
        assert_eq!(a.state, JobState::Done);
        assert_eq!(a.finished_at, Some(30));

        let mut b = Job::new(spec(JobClass::Be));
        b.start(NodeId(0), 0);
        b.signal_preemption();
        b.complete(3); // finished while draining
        assert_eq!(b.state, JobState::Done);
    }

    #[test]
    fn cancel_from_each_live_state() {
        // Pending.
        let mut a = Job::new(spec(JobClass::Te));
        a.cancel(4);
        assert_eq!(a.state, JobState::Cancelled);
        assert_eq!(a.cancelled_at, Some(4));
        assert_eq!(a.finished_at, None, "cancelled jobs never finish");

        // Running.
        let mut b = Job::new(spec(JobClass::Be));
        b.start(NodeId(0), 0);
        let epoch = b.epoch;
        b.cancel(7);
        assert_eq!(b.state, JobState::Cancelled);
        assert!(b.node.is_none());
        assert_eq!(b.epoch, epoch + 1, "cancel invalidates clock predictions");

        // Draining.
        let mut c = Job::new(spec(JobClass::Be));
        c.start(NodeId(0), 0);
        c.signal_preemption();
        c.cancel(2);
        assert_eq!(c.state, JobState::Cancelled);
        assert_eq!(c.grace_left, 0);
    }

    #[test]
    fn fail_over_requeues_without_counting_a_preemption() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 0);
        j.fail_over(5);
        assert_eq!(j.state, JobState::Pending);
        assert_eq!(j.preemptions, 0, "node failure is not a policy preemption");
        assert_eq!(j.evictions, 1);
        assert!(j.node.is_none());
        // The job restarts like any pending job; no resched interval is
        // recorded (Table 2 measures preemption intervals only).
        j.start(NodeId(1), 9);
        assert!(j.resched_intervals.is_empty());
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn te_jobs_cannot_be_preempted() {
        let mut j = Job::new(spec(JobClass::Te));
        j.start(NodeId(0), 0);
        j.signal_preemption();
    }
}
