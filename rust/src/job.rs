//! Job model: TE/BE classes, demands, grace periods, and the lifecycle
//! state machine (§2 of the paper).
//!
//! Users declare, per job: the class (`TE` or `BE`), the demand vector, and
//! — because suspension processing (checkpointing) takes time — a *grace
//! period* (GP). The scheduler may suspend BE jobs; a suspended job is
//! re-queued at the *top* of the FIFO queue and later resumed with its
//! completed work intact. TE jobs are never preempted.
//!
//! ## Lazy (virtual-time) accounting
//!
//! The time-indexed counters (`remaining`, `grace_left`, `waiting`) are
//! **not** burned down minute by minute. Each job records the minute its
//! counters were last settled (`synced_at`); [`Job::sync`] applies the
//! whole elapsed span in one arithmetic step, and every lifecycle
//! transition syncs first. Between transitions the stored values are
//! intentionally stale — readers that need the live value at minute `now`
//! use [`Job::remaining_at`] / [`Job::grace_left_at`] /
//! [`Job::waiting_at`]. This is what makes the scheduler's steady-state
//! rounds O(events) instead of O(active + queued) per minute, and makes a
//! quiescent fast-forward ([`Scheduler::burn_many`](crate::sched::Scheduler::burn_many))
//! O(1): nothing needs touching until the next transition settles it.

use crate::resources::ResourceVec;
use crate::util::bin::{BinReader, BinWriter};
use crate::Minutes;
use anyhow::bail;
use std::fmt;

/// Opaque job identifier (dense, assigned by the workload generator in
/// submission order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Opaque tenant (user / team / account) identifier. Every job belongs to
/// exactly one tenant; single-tenant workloads use [`TenantId::DEFAULT`].
///
/// Tenancy is *admission-layer* identity: the
/// [`QueueDiscipline`](crate::sched::admission::QueueDiscipline) uses it
/// for fair sharing and quota gating, and the metrics sink keys per-tenant
/// percentiles by it. The preemption policies (§3) never read it — fairness
/// composes with FitGpp orthogonally, at the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct TenantId(pub u32);

impl TenantId {
    /// The tenant every job belongs to unless a workload source assigns
    /// one (single-tenant runs are byte-identical to the pre-tenant code).
    pub const DEFAULT: TenantId = TenantId(0);
}

impl fmt::Display for TenantId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "tenant-{}", self.0)
    }
}

/// The paper's two job classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobClass {
    /// Trial-and-error: small, interactive, latency-sensitive. The scheduler
    /// may preempt BE jobs to start a TE job immediately.
    Te,
    /// Best-effort: throughput-oriented; preemptible up to `P` times.
    Be,
}

impl JobClass {
    /// `"TE"` / `"BE"` (table rendering, traces).
    pub fn as_str(&self) -> &'static str {
        match self {
            JobClass::Te => "TE",
            JobClass::Be => "BE",
        }
    }
}

impl JobClass {
    /// Stable one-byte snapshot tag.
    pub(crate) fn tag(self) -> u8 {
        match self {
            JobClass::Te => 0,
            JobClass::Be => 1,
        }
    }

    /// Inverse of [`JobClass::tag`]; any other byte is corruption.
    pub(crate) fn from_tag(t: u8) -> anyhow::Result<Self> {
        match t {
            0 => Ok(JobClass::Te),
            1 => Ok(JobClass::Be),
            other => bail!("snapshot corrupt: job class tag {other}"),
        }
    }
}

impl fmt::Display for JobClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Immutable submission-time description of a job — everything the
/// scheduler is allowed to know (FitGpp deliberately does *not* get the
/// execution time; the LRTP baseline receives it as an oracle).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Dense identifier (submission order).
    pub id: JobId,
    /// TE or BE.
    pub class: JobClass,
    /// Demand vector `[C, R, G]`.
    pub demand: ResourceVec,
    /// Submission time (minutes since simulation start).
    pub submit: Minutes,
    /// Total execution time needed (minutes of actual progress).
    pub exec_time: Minutes,
    /// User-declared grace period: how long the job needs to checkpoint
    /// before vacating. Zero means "rewind is fine" (§2).
    pub grace_period: Minutes,
    /// The tenant this job belongs to ([`TenantId::DEFAULT`] unless the
    /// workload source assigned one). Read by the admission layer only.
    pub tenant: TenantId,
}

impl JobSpec {
    /// Builder-style constructor for tests and examples (tenant =
    /// [`TenantId::DEFAULT`]; chain [`JobSpec::with_tenant`] to set one).
    pub fn new(id: u32, class: JobClass, demand: ResourceVec, submit: Minutes, exec_time: Minutes, grace_period: Minutes) -> Self {
        JobSpec {
            id: JobId(id),
            class,
            demand,
            submit,
            exec_time: exec_time.max(1),
            grace_period,
            tenant: TenantId::DEFAULT,
        }
    }

    /// Builder: assign the job to `tenant`.
    pub fn with_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    /// Serialize for a snapshot.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        w.u32(self.id.0);
        w.u8(self.class.tag());
        self.demand.snapshot_bin(w);
        w.u64(self.submit);
        w.u64(self.exec_time);
        w.u64(self.grace_period);
        w.u32(self.tenant.0);
    }

    /// Rebuild a spec written by [`JobSpec::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        Ok(JobSpec {
            id: JobId(r.u32()?),
            class: JobClass::from_tag(r.u8()?)?,
            demand: ResourceVec::restore_bin(r)?,
            submit: r.u64()?,
            exec_time: r.u64()?,
            grace_period: r.u64()?,
            tenant: TenantId(r.u32()?),
        })
    }
}

/// Lifecycle states. Transitions (enforced by `Job` methods):
///
/// ```text
/// Pending ──start──▶ Running ──preempt──▶ Draining ──vacate──▶ Pending(top)
///    ▲                  │                     │
///    └──────────────────┴──────complete───────┘   (Draining jobs complete
///  Running ──complete──▶ Done                      too if their remaining
///                                                  work hits 0 first)
/// ```
///
/// Two further transitions come from the control plane
/// ([`sched::control`](crate::sched::control)) rather than the scheduler's
/// own decisions:
///
/// * `Pending | Running | Draining ──cancel──▶ Cancelled` — the user (or a
///   [`ScenarioScript`](crate::sim::scenario::ScenarioScript) standing in
///   for one) kills the job; it never completes and is excluded from
///   slowdown statistics.
/// * `Running | Draining ──fail_over──▶ Pending(top)` — the hosting node
///   failed; the job is re-queued with priority. Unlike [`Job::vacate`]
///   this does **not** count as a policy preemption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// In the queue (either never started, or suspended and re-queued).
    Pending,
    /// Occupying resources on a node and making progress.
    Running,
    /// Signalled for preemption; still occupying resources for the grace
    /// period while it checkpoints. Makes **no** progress on its own work
    /// (suspension processing is pure overhead — conservative reading of §2)
    /// unless the §2 ablation (`progress_during_grace`) is on.
    Draining,
    /// Finished.
    Done,
    /// Killed by a control-plane cancellation before completing.
    Cancelled,
}

/// A job's full runtime record. The simulator owns one `Job` per `JobSpec`;
/// scheduling policies see `&Job` views.
///
/// The time-indexed counters are lazily accounted — see the module docs.
/// `remaining`, `grace_left`, and `waiting` are exact *as of* `synced_at`;
/// use the `*_at(now)` accessors for live reads between transitions.
#[derive(Debug, Clone)]
pub struct Job {
    /// The immutable submission-time spec.
    pub spec: JobSpec,
    /// Current lifecycle state.
    pub state: JobState,
    /// Remaining execution time (minutes) as of `synced_at`.
    /// `spec.exec_time` at submission; preserved across suspend/resume
    /// (no rewind).
    pub remaining: Minutes,
    /// Remaining grace period while `Draining`, as of `synced_at`.
    pub grace_left: Minutes,
    /// Node currently hosting the job (`Running` or `Draining`).
    pub node: Option<crate::cluster::NodeId>,
    /// How many times this job has been preempted (the paper's
    /// `PreemptionCount_j`, capped by the policy parameter `P`).
    pub preemptions: u32,
    /// Cumulative minutes spent waiting in the queue as of `synced_at`
    /// (drives Eq. 5).
    pub waiting: Minutes,
    /// Tick at which the job most recently vacated a node due to preemption
    /// (start of a re-scheduling interval, Table 2).
    pub last_vacated: Option<Minutes>,
    /// Completed re-scheduling intervals (vacate → restart), Table 2.
    pub resched_intervals: Vec<Minutes>,
    /// First time the job started running (for time-to-first-schedule).
    pub first_start: Option<Minutes>,
    /// Completion time.
    pub finished_at: Option<Minutes>,
    /// Cancellation time (control plane). Mutually exclusive with
    /// `finished_at`.
    pub cancelled_at: Option<Minutes>,
    /// Node-failure evictions suffered (control plane; *not* counted as
    /// preemptions — the `P` starvation cap only reads `preemptions`).
    pub evictions: u32,
    /// The minute up to which `remaining` / `grace_left` / `waiting` are
    /// settled. Starts at `spec.submit` (a staged-but-unarrived job accrues
    /// nothing); every [`Job::sync`] moves it forward.
    pub synced_at: Minutes,
    /// Whether this drain makes progress on the job's own work (the §2
    /// `progress_during_grace` ablation, captured at signal time so
    /// [`Job::sync`] needs no config access).
    pub drain_progress: bool,
}

impl Job {
    pub fn new(spec: JobSpec) -> Self {
        let remaining = spec.exec_time;
        let synced_at = spec.submit;
        Job {
            spec,
            state: JobState::Pending,
            remaining,
            grace_left: 0,
            node: None,
            preemptions: 0,
            waiting: 0,
            last_vacated: None,
            resched_intervals: Vec::new(),
            first_start: None,
            finished_at: None,
            cancelled_at: None,
            evictions: 0,
            synced_at,
            drain_progress: false,
        }
    }

    pub fn id(&self) -> JobId {
        self.spec.id
    }

    pub fn is_te(&self) -> bool {
        self.spec.class == JobClass::Te
    }

    pub fn is_be(&self) -> bool {
        self.spec.class == JobClass::Be
    }

    /// The tenant this job belongs to.
    pub fn tenant(&self) -> TenantId {
        self.spec.tenant
    }

    /// Settle the lazily-accounted counters up to `now`: one arithmetic
    /// step applies the whole `now - synced_at` span to whichever counter
    /// the current state accrues (queue wait while `Pending`, progress
    /// while `Running`, grace burn-down — plus progress when
    /// `drain_progress` — while `Draining`). Idempotent within a minute;
    /// every lifecycle transition calls it first.
    pub fn sync(&mut self, now: Minutes) {
        let elapsed = now.saturating_sub(self.synced_at);
        if elapsed == 0 {
            return;
        }
        match self.state {
            JobState::Pending => self.waiting += elapsed,
            JobState::Running => {
                debug_assert!(
                    elapsed <= self.remaining,
                    "{} ran past its completion minute ({elapsed} > {})",
                    self.id(),
                    self.remaining
                );
                self.remaining = self.remaining.saturating_sub(elapsed);
            }
            JobState::Draining => {
                debug_assert!(
                    elapsed <= self.grace_left,
                    "{} drained past its grace expiry ({elapsed} > {})",
                    self.id(),
                    self.grace_left
                );
                self.grace_left = self.grace_left.saturating_sub(elapsed);
                if self.drain_progress {
                    // Saturating: progress stops at zero while the grace
                    // period keeps burning (the job completes at the next
                    // event application).
                    self.remaining = self.remaining.saturating_sub(elapsed);
                }
            }
            JobState::Done | JobState::Cancelled => {}
        }
        self.synced_at = now;
    }

    /// `remaining` as it stands at minute `now`, without mutating the job.
    pub fn remaining_at(&self, now: Minutes) -> Minutes {
        let elapsed = now.saturating_sub(self.synced_at);
        match self.state {
            JobState::Running => self.remaining.saturating_sub(elapsed),
            JobState::Draining if self.drain_progress => self.remaining.saturating_sub(elapsed),
            _ => self.remaining,
        }
    }

    /// `grace_left` as it stands at minute `now`, without mutating the job.
    pub fn grace_left_at(&self, now: Minutes) -> Minutes {
        match self.state {
            JobState::Draining => self
                .grace_left
                .saturating_sub(now.saturating_sub(self.synced_at)),
            _ => self.grace_left,
        }
    }

    /// `waiting` as it stands at minute `now`, without mutating the job.
    pub fn waiting_at(&self, now: Minutes) -> Minutes {
        match self.state {
            JobState::Pending => self.waiting + now.saturating_sub(self.synced_at),
            _ => self.waiting,
        }
    }

    /// Transition Pending → Running on `node` at time `now`.
    pub fn start(&mut self, node: crate::cluster::NodeId, now: Minutes) {
        self.sync(now);
        debug_assert_eq!(self.state, JobState::Pending, "{} start from {:?}", self.id(), self.state);
        self.state = JobState::Running;
        self.node = Some(node);
        if self.first_start.is_none() {
            self.first_start = Some(now);
        }
        if let Some(v) = self.last_vacated.take() {
            self.resched_intervals.push(now.saturating_sub(v));
        }
    }

    /// Transition Running → Draining: the preemption signal at minute
    /// `now`. The job keeps its resources for `grace_period` minutes
    /// (possibly 0 ⇒ it vacates on the same tick's GP-expiry pass);
    /// `drain_progress` records whether this drain advances the job's own
    /// work (the scheduler's `progress_during_grace` setting).
    pub fn signal_preemption(&mut self, now: Minutes, drain_progress: bool) {
        self.sync(now);
        debug_assert_eq!(self.state, JobState::Running, "{} preempt from {:?}", self.id(), self.state);
        debug_assert!(self.is_be(), "TE jobs are never preempted");
        self.state = JobState::Draining;
        self.grace_left = self.spec.grace_period;
        self.drain_progress = drain_progress;
    }

    /// Transition Draining → Pending: the grace period elapsed and the job
    /// vacated its node. Returns to the *top* of the queue (caller's job).
    pub fn vacate(&mut self, now: Minutes) {
        self.sync(now);
        debug_assert_eq!(self.state, JobState::Draining);
        self.state = JobState::Pending;
        self.node = None;
        self.grace_left = 0;
        self.preemptions += 1;
        self.last_vacated = Some(now);
    }

    /// Transition Running/Draining → Done.
    pub fn complete(&mut self, now: Minutes) {
        self.sync(now);
        debug_assert!(matches!(self.state, JobState::Running | JobState::Draining));
        self.state = JobState::Done;
        self.node = None;
        self.finished_at = Some(now);
    }

    /// Control-plane cancellation: Pending/Running/Draining → Cancelled.
    /// The job never completes (`finished_at` stays `None`, so cancelled
    /// jobs fall out of every slowdown percentile) and is retired
    /// immediately by the caller. Syncs first, so the accrued-wait
    /// slowdown lower bound in the final record is exact.
    pub fn cancel(&mut self, now: Minutes) {
        self.sync(now);
        debug_assert!(
            matches!(
                self.state,
                JobState::Pending | JobState::Running | JobState::Draining
            ),
            "{} cancelled from {:?}",
            self.id(),
            self.state
        );
        self.state = JobState::Cancelled;
        self.node = None;
        self.grace_left = 0;
        self.cancelled_at = Some(now);
    }

    /// Node-failure eviction: Running/Draining → Pending. The hosting node
    /// disappeared, so there is no grace period — the job vacates at once
    /// and is re-queued at the top. Completed work is preserved (the live
    /// executor restores from the last checkpoint; the simulator models the
    /// optimistic no-rewind case, matching [`Job::vacate`]). Unlike a
    /// vacate this is *not* a policy preemption: `preemptions` (the paper's
    /// `PreemptionCount_j`, which the `P` cap reads) stays untouched and
    /// the interruption is tallied in `evictions` instead.
    pub fn fail_over(&mut self, now: Minutes) {
        self.sync(now);
        debug_assert!(matches!(self.state, JobState::Running | JobState::Draining));
        self.state = JobState::Pending;
        self.node = None;
        self.grace_left = 0;
        self.evictions += 1;
    }

    /// Eq. 5: `slowdown = 1 + WaitingTime / ExecutionTime`.
    ///
    /// We take `WaitingTime = FlowTime - ExecutionTime` (every non-progress
    /// minute: queueing *and* grace-period limbo), which makes Eq. 5 the
    /// classic `slowdown = FlowTime / ExecutionTime`. For a never-preempted
    /// job this is exactly `1 + queue-wait / exec`. For a job still
    /// unfinished when the simulation is cut off, the accrued queue wait is
    /// used as a lower bound (the default simulations drain the backlog, so
    /// this only applies to custom horizons). Readers of the unfinished
    /// branch must settle the job first ([`Job::sync`] or
    /// [`JobTable::settle_all`](crate::job_table::JobTable::settle_all));
    /// the simulator's cut-off path does.
    pub fn slowdown(&self) -> f64 {
        match self.finished_at {
            Some(fin) => (fin - self.spec.submit) as f64 / self.spec.exec_time as f64,
            None => 1.0 + self.waiting as f64 / self.spec.exec_time as f64,
        }
    }

    /// Serialize the full runtime record (spec + lifecycle counters) for a
    /// snapshot. The lazily-accounted counters travel exactly as stored —
    /// deliberately stale relative to `synced_at`, like the live struct.
    pub fn snapshot_bin(&self, w: &mut BinWriter) {
        self.spec.snapshot_bin(w);
        w.u8(match self.state {
            JobState::Pending => 0,
            JobState::Running => 1,
            JobState::Draining => 2,
            JobState::Done => 3,
            JobState::Cancelled => 4,
        });
        w.u64(self.remaining);
        w.u64(self.grace_left);
        match self.node {
            Some(n) => {
                w.bool(true);
                w.u32(n.0);
            }
            None => w.bool(false),
        }
        w.u32(self.preemptions);
        w.u64(self.waiting);
        w.opt_u64(self.last_vacated);
        w.seq(self.resched_intervals.len());
        for &iv in &self.resched_intervals {
            w.u64(iv);
        }
        w.opt_u64(self.first_start);
        w.opt_u64(self.finished_at);
        w.opt_u64(self.cancelled_at);
        w.u32(self.evictions);
        w.u64(self.synced_at);
        w.bool(self.drain_progress);
    }

    /// Rebuild a job written by [`Job::snapshot_bin`].
    pub fn restore_bin(r: &mut BinReader) -> anyhow::Result<Self> {
        let spec = JobSpec::restore_bin(r)?;
        let state = match r.u8()? {
            0 => JobState::Pending,
            1 => JobState::Running,
            2 => JobState::Draining,
            3 => JobState::Done,
            4 => JobState::Cancelled,
            other => bail!("snapshot corrupt: job state tag {other}"),
        };
        let remaining = r.u64()?;
        let grace_left = r.u64()?;
        let node = if r.bool()? { Some(crate::cluster::NodeId(r.u32()?)) } else { None };
        let preemptions = r.u32()?;
        let waiting = r.u64()?;
        let last_vacated = r.opt_u64()?;
        let n = r.seq()?;
        let mut resched_intervals = Vec::with_capacity(n);
        for _ in 0..n {
            resched_intervals.push(r.u64()?);
        }
        Ok(Job {
            spec,
            state,
            remaining,
            grace_left,
            node,
            preemptions,
            waiting,
            last_vacated,
            resched_intervals,
            first_start: r.opt_u64()?,
            finished_at: r.opt_u64()?,
            cancelled_at: r.opt_u64()?,
            evictions: r.u32()?,
            synced_at: r.u64()?,
            drain_progress: r.bool()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeId;

    fn spec(class: JobClass) -> JobSpec {
        JobSpec::new(1, class, ResourceVec::new(4.0, 32.0, 1.0), 0, 30, 3)
    }

    #[test]
    fn fresh_job_is_pending_with_full_remaining() {
        let j = Job::new(spec(JobClass::Be));
        assert_eq!(j.state, JobState::Pending);
        assert_eq!(j.remaining, 30);
        assert_eq!(j.preemptions, 0);
        assert_eq!(j.slowdown(), 1.0);
        assert_eq!(j.synced_at, 0, "settled from the submit minute");
    }

    #[test]
    fn exec_time_clamped_to_one_minute() {
        let s = JobSpec::new(1, JobClass::Te, ResourceVec::ZERO, 0, 0, 0);
        assert_eq!(s.exec_time, 1);
    }

    #[test]
    fn start_records_first_start_once() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 5);
        assert_eq!(j.first_start, Some(5));
        assert_eq!(j.state, JobState::Running);
        j.signal_preemption(5, false);
        j.vacate(8);
        j.start(NodeId(1), 12);
        assert_eq!(j.first_start, Some(5), "first_start must not move");
    }

    #[test]
    fn preemption_cycle_updates_count_and_interval() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 0);
        j.signal_preemption(0, false);
        assert_eq!(j.state, JobState::Draining);
        assert_eq!(j.grace_left, 3);
        j.vacate(3);
        assert_eq!(j.state, JobState::Pending);
        assert_eq!(j.preemptions, 1);
        assert!(j.node.is_none());
        j.start(NodeId(2), 9);
        assert_eq!(j.resched_intervals, vec![6]);
    }

    #[test]
    fn sync_settles_lazily_accrued_time() {
        let mut j = Job::new(spec(JobClass::Be)); // submit 0, exec 30, GP 3
        assert_eq!(j.waiting_at(7), 7);
        assert_eq!(j.waiting, 0, "reads do not mutate");
        j.start(NodeId(0), 7);
        assert_eq!(j.waiting, 7, "start settles the queue wait");
        assert_eq!(j.remaining_at(12), 25);
        assert_eq!(j.remaining, 30, "stored value is stale until a sync");
        j.signal_preemption(12, true);
        assert_eq!(j.remaining, 25, "signal settles the running span");
        assert_eq!(j.grace_left_at(14), 1);
        assert_eq!(j.remaining_at(14), 23, "progress during grace");
        j.vacate(15);
        assert_eq!(j.remaining, 22);
        assert_eq!(j.grace_left, 0);
        assert_eq!(j.waiting_at(20), 7 + 5, "pending again accrues wait");
    }

    #[test]
    fn sync_is_idempotent_within_a_minute() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 4);
        j.sync(10);
        j.sync(10);
        assert_eq!(j.remaining, 24);
        assert_eq!(j.waiting, 4);
        // A sync at an earlier minute is a no-op, not a rewind.
        j.sync(8);
        assert_eq!(j.remaining, 24);
        assert_eq!(j.synced_at, 10);
    }

    #[test]
    fn draining_without_progress_keeps_remaining() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 0);
        j.signal_preemption(10, false);
        assert_eq!(j.remaining, 20);
        j.sync(13);
        assert_eq!(j.grace_left, 0);
        assert_eq!(j.remaining, 20, "no progress during grace by default");
        assert_eq!(j.remaining_at(13), 20);
    }

    #[test]
    fn slowdown_eq5_unfinished_uses_accrued_wait() {
        let mut j = Job::new(spec(JobClass::Te));
        j.waiting = 15; // waited half its 30-minute runtime so far
        assert!((j.slowdown() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn slowdown_eq5_finished_is_flow_over_exec() {
        let mut j = Job::new(spec(JobClass::Te)); // submit=0, exec=30
        j.start(NodeId(0), 15);
        j.complete(45); // flow = 45, exec = 30 ⇒ slowdown = 1.5 = 1 + 15/30
        assert!((j.slowdown() - 1.5).abs() < 1e-12);
        assert_eq!(j.remaining, 0, "complete settled the whole running span");
    }

    #[test]
    fn complete_from_running_and_draining() {
        let mut a = Job::new(spec(JobClass::Be));
        a.start(NodeId(0), 0);
        a.complete(30);
        assert_eq!(a.state, JobState::Done);
        assert_eq!(a.finished_at, Some(30));

        let mut b = Job::new(spec(JobClass::Be));
        b.start(NodeId(0), 0);
        b.signal_preemption(0, true);
        b.complete(3); // finished while draining
        assert_eq!(b.state, JobState::Done);
    }

    #[test]
    fn cancel_from_each_live_state() {
        // Pending.
        let mut a = Job::new(spec(JobClass::Te));
        a.cancel(4);
        assert_eq!(a.state, JobState::Cancelled);
        assert_eq!(a.cancelled_at, Some(4));
        assert_eq!(a.finished_at, None, "cancelled jobs never finish");
        assert_eq!(a.waiting, 4, "cancel settles the accrued wait");

        // Running.
        let mut b = Job::new(spec(JobClass::Be));
        b.start(NodeId(0), 0);
        b.cancel(7);
        assert_eq!(b.state, JobState::Cancelled);
        assert!(b.node.is_none());
        assert_eq!(b.remaining, 23, "cancel settles the running span");

        // Draining.
        let mut c = Job::new(spec(JobClass::Be));
        c.start(NodeId(0), 0);
        c.signal_preemption(0, false);
        c.cancel(2);
        assert_eq!(c.state, JobState::Cancelled);
        assert_eq!(c.grace_left, 0);
    }

    #[test]
    fn fail_over_requeues_without_counting_a_preemption() {
        let mut j = Job::new(spec(JobClass::Be));
        j.start(NodeId(0), 0);
        j.fail_over(5);
        assert_eq!(j.state, JobState::Pending);
        assert_eq!(j.preemptions, 0, "node failure is not a policy preemption");
        assert_eq!(j.evictions, 1);
        assert!(j.node.is_none());
        assert_eq!(j.remaining, 25, "completed work preserved (no rewind)");
        // The job restarts like any pending job; no resched interval is
        // recorded (Table 2 measures preemption intervals only).
        j.start(NodeId(1), 9);
        assert!(j.resched_intervals.is_empty());
        assert_eq!(j.waiting, 4, "re-queued wait settled at restart");
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn te_jobs_cannot_be_preempted() {
        let mut j = Job::new(spec(JobClass::Te));
        j.start(NodeId(0), 0);
        j.signal_preemption(0, false);
    }
}
