//! The JSONL wire protocol: requests in, responses and events out.
//!
//! Every message is one JSON object per line. Requests carry a `"cmd"`
//! key; command shapes match the scenario-script format
//! ([`ScenarioScript::parse`](crate::sim::scenario::ScenarioScript::parse))
//! with two differences: there is no `"at"` (wire commands apply at the
//! session's current minute) and `"submit"` *is* allowed (live arrivals
//! come over the wire; a submit minute in the past is clamped to the
//! current minute server-side). Any request may carry an integer
//! `"seq"`, echoed back in an `{"type":"ack","seq":…}` response once the
//! command has been applied — closed-loop clients use it to pipeline.
//!
//! Outbound lines are typed by their `"type"` key:
//!
//! * scheduler events — exactly the [`JsonlEventLog`] line format
//!   ([`event_jsonl_line`]), sent to connections that issued
//!   `{"cmd":"subscribe"}`;
//! * `{"type":"lagged","dropped":N}` — the backpressure notice: this
//!   connection's bounded event queue overflowed and `N` events were
//!   dropped rather than buffered without bound (see
//!   [`crate::serve::server`]);
//! * `{"type":"ack"|"error"|"pong"|"hello"|"snapshot",…}` — request
//!   responses.
//!
//! [`JsonlEventLog`]: crate::sched::control::JsonlEventLog
//! [`event_jsonl_line`]: crate::sched::control::event_jsonl_line

use crate::cluster::NodeId;
use crate::job::{JobClass, JobId, JobSpec, TenantId};
use crate::resources::ResourceVec;
use crate::sched::control::SchedulerCommand;
use crate::util::json::Json;
use crate::Minutes;
use anyhow::{bail, Context, Result};

/// A parsed request line.
#[derive(Debug)]
pub enum WireRequest {
    /// Apply a scheduler command at the current minute.
    Command {
        /// The command to apply.
        cmd: SchedulerCommand,
        /// Echoed back in the ack, when present.
        seq: Option<u64>,
    },
    /// Start streaming scheduler events to this connection.
    Subscribe {
        /// Echoed back in the ack, when present.
        seq: Option<u64>,
    },
    /// Save a snapshot now (the session is always at a round boundary
    /// when requests are handled).
    Snapshot {
        /// Echoed back in the response, when present.
        seq: Option<u64>,
    },
    /// Liveness probe; answered with the current virtual minute.
    Ping {
        /// Echoed back in the pong, when present.
        seq: Option<u64>,
    },
    /// Stop the server gracefully — same path as SIGTERM: a final
    /// snapshot (when a snapshot directory is configured), then exit.
    Shutdown {
        /// Echoed back in the ack, when present.
        seq: Option<u64>,
    },
}

/// Parse one request line. Errors are protocol errors to report back to
/// the client; they never tear down the session.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("request json: {e}"))?;
    let kind = v.get("cmd").as_str().context("missing 'cmd'")?.to_string();
    let seq = v.get("seq").as_u64();
    let id32 = |key: &str| -> Result<u32> {
        let x = v
            .get(key)
            .as_u64()
            .with_context(|| format!("{kind}: missing integer '{key}'"))?;
        u32::try_from(x).map_err(|_| anyhow::anyhow!("{kind}: '{key}' {x} exceeds u32 range"))
    };
    let node = || -> Result<NodeId> { Ok(NodeId(id32("node")?)) };
    let class = || -> Result<JobClass> {
        match v.get("class").as_str() {
            Some("TE") | Some("te") => Ok(JobClass::Te),
            Some("BE") | Some("be") => Ok(JobClass::Be),
            _ => bail!("{kind}: 'class' must be \"TE\" or \"BE\""),
        }
    };
    let cmd = match kind.as_str() {
        "subscribe" => return Ok(WireRequest::Subscribe { seq }),
        "snapshot" => return Ok(WireRequest::Snapshot { seq }),
        "ping" => return Ok(WireRequest::Ping { seq }),
        "shutdown" => return Ok(WireRequest::Shutdown { seq }),
        "submit" => {
            let axis = |key: &str| -> Result<f64> {
                let x = v
                    .get(key)
                    .as_f64()
                    .with_context(|| format!("submit: missing number '{key}'"))?;
                if !x.is_finite() || x < 0.0 {
                    bail!("submit: '{key}' must be finite and non-negative");
                }
                Ok(x)
            };
            let exec_time: Minutes = v
                .get("exec_time")
                .as_u64()
                .context("submit: missing integer 'exec_time'")?;
            // Absent "submit" means "as soon as possible": 0 is always in
            // the past once the session has started, and the server clamps
            // past minutes up to the current one.
            let submit: Minutes = v.get("submit").as_u64().unwrap_or(0);
            let grace: Minutes = v.get("grace_period").as_u64().unwrap_or(0);
            let mut spec = JobSpec::new(
                id32("id")?,
                class()?,
                ResourceVec::new(axis("cpu")?, axis("ram_gb")?, axis("gpu")?),
                submit,
                exec_time,
                grace,
            );
            if !matches!(v.get("tenant"), Json::Null) {
                spec = spec.with_tenant(TenantId(id32("tenant")?));
            }
            SchedulerCommand::Submit(spec)
        }
        "cancel" => SchedulerCommand::Cancel { job: JobId(id32("job")?) },
        "reclassify" => SchedulerCommand::Reclassify {
            job: JobId(id32("job")?),
            class: class()?,
        },
        "node_down" => SchedulerCommand::NodeDown { node: node()? },
        "node_up" => SchedulerCommand::NodeUp { node: node()? },
        "drain" => SchedulerCommand::Drain { node: node()? },
        "resize" => {
            let axis = |key: &str| -> Result<f64> {
                v.get(key)
                    .as_f64()
                    .with_context(|| format!("resize: missing number '{key}'"))
            };
            SchedulerCommand::Resize {
                node: node()?,
                capacity: ResourceVec::new(axis("cpu")?, axis("ram_gb")?, axis("gpu")?),
            }
        }
        "set_quota" => {
            let size = v
                .get("size")
                .as_f64()
                .context("set_quota: missing number 'size'")?;
            SchedulerCommand::SetQuota { tenant: TenantId(id32("tenant")?), size }
        }
        "set_weight" => {
            let weight = id32("weight")?;
            SchedulerCommand::SetWeight { tenant: TenantId(id32("tenant")?), weight }
        }
        other => bail!("unknown command {other:?}"),
    };
    Ok(WireRequest::Command { cmd, seq })
}

/// Append `seq` when the request carried one.
fn with_seq(mut fields: Vec<(&str, Json)>, seq: Option<u64>) -> Json {
    if let Some(s) = seq {
        fields.push(("seq", Json::num(s as f64)));
    }
    Json::obj(fields)
}

/// `{"type":"hello",…}` — sent once per connection; announces the
/// protocol version and the session's current virtual minute.
pub fn hello_line(now: Minutes) -> String {
    Json::obj(vec![
        ("type", Json::str("hello")),
        ("protocol", Json::num(1.0)),
        ("now", Json::num(now as f64)),
    ])
    .to_string()
}

/// `{"type":"ack",…}` — the command was applied (acceptance or rejection
/// is reported separately, as a scheduler event).
pub fn ack_line(seq: Option<u64>, now: Minutes) -> String {
    with_seq(
        vec![("type", Json::str("ack")), ("now", Json::num(now as f64))],
        seq,
    )
    .to_string()
}

/// `{"type":"error",…}` — the request could not be parsed or served.
pub fn error_line(seq: Option<u64>, message: &str) -> String {
    with_seq(
        vec![("type", Json::str("error")), ("error", Json::str(message))],
        seq,
    )
    .to_string()
}

/// `{"type":"pong",…}` — answer to a ping.
pub fn pong_line(seq: Option<u64>, now: Minutes) -> String {
    with_seq(
        vec![("type", Json::str("pong")), ("now", Json::num(now as f64))],
        seq,
    )
    .to_string()
}

/// `{"type":"snapshot",…}` — a snapshot was saved at `minute`.
pub fn snapshot_line(seq: Option<u64>, minute: Minutes, path: &str) -> String {
    with_seq(
        vec![
            ("type", Json::str("snapshot")),
            ("minute", Json::num(minute as f64)),
            ("path", Json::str(path)),
        ],
        seq,
    )
    .to_string()
}

/// `{"type":"lagged","dropped":N}` — the backpressure notice: `N` events
/// were dropped for this connection since its last successfully queued
/// line.
pub fn lagged_line(dropped: u64) -> String {
    Json::obj(vec![
        ("type", Json::str("lagged")),
        ("dropped", Json::num(dropped as f64)),
    ])
    .to_string()
}

/// Direct single-pass encoder for the response lines above: serializes
/// each response straight into a reusable scratch buffer, skipping the
/// [`Json`] value tree. Byte-identical to the `*_line` builders (keys in
/// the sorted order the value tree's `BTreeMap` would produce, numbers
/// and strings through the same [`crate::util::json`] formatting) and
/// allocation-free in steady state — the serve hot path's counterpart to
/// [`crate::sched::control::JsonLineEncoder`].
#[derive(Default)]
pub struct ResponseEncoder {
    buf: String,
}

impl ResponseEncoder {
    /// A fresh encoder with a line-sized scratch buffer.
    pub fn new() -> Self {
        ResponseEncoder { buf: String::with_capacity(128) }
    }

    fn seq_then_type(&mut self, seq: Option<u64>, kind: &str) -> &str {
        use crate::util::json::write_num as num;
        let b = &mut self.buf;
        if let Some(s) = seq {
            b.push_str(",\"seq\":");
            num(b, s as f64);
        }
        b.push_str(",\"type\":\"");
        b.push_str(kind);
        b.push_str("\"}");
        &self.buf
    }

    /// `{"now":…,"protocol":1,"type":"hello"}`.
    pub fn hello(&mut self, now: Minutes) -> &str {
        use crate::util::json::write_num as num;
        self.buf.clear();
        self.buf.push_str("{\"now\":");
        num(&mut self.buf, now as f64);
        self.buf.push_str(",\"protocol\":1,\"type\":\"hello\"}");
        &self.buf
    }

    /// `{"now":…[,"seq":…],"type":"ack"}`.
    pub fn ack(&mut self, seq: Option<u64>, now: Minutes) -> &str {
        use crate::util::json::write_num as num;
        self.buf.clear();
        self.buf.push_str("{\"now\":");
        num(&mut self.buf, now as f64);
        self.seq_then_type(seq, "ack")
    }

    /// `{"error":…[,"seq":…],"type":"error"}`.
    pub fn error(&mut self, seq: Option<u64>, message: &str) -> &str {
        use crate::util::json::write_escaped as esc;
        self.buf.clear();
        self.buf.push_str("{\"error\":");
        esc(&mut self.buf, message);
        self.seq_then_type(seq, "error")
    }

    /// `{"now":…[,"seq":…],"type":"pong"}`.
    pub fn pong(&mut self, seq: Option<u64>, now: Minutes) -> &str {
        use crate::util::json::write_num as num;
        self.buf.clear();
        self.buf.push_str("{\"now\":");
        num(&mut self.buf, now as f64);
        self.seq_then_type(seq, "pong")
    }

    /// `{"minute":…,"path":…[,"seq":…],"type":"snapshot"}`.
    pub fn snapshot(&mut self, seq: Option<u64>, minute: Minutes, path: &str) -> &str {
        use crate::util::json::{write_escaped as esc, write_num as num};
        self.buf.clear();
        self.buf.push_str("{\"minute\":");
        num(&mut self.buf, minute as f64);
        self.buf.push_str(",\"path\":");
        esc(&mut self.buf, path);
        self.seq_then_type(seq, "snapshot")
    }

    /// `{"dropped":…,"type":"lagged"}`.
    pub fn lagged(&mut self, dropped: u64) -> &str {
        use crate::util::json::write_num as num;
        self.buf.clear();
        self.buf.push_str("{\"dropped\":");
        num(&mut self.buf, dropped as f64);
        self.buf.push_str(",\"type\":\"lagged\"}");
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_command_shape() {
        let ok = |line: &str| parse_request(line).unwrap();
        match ok(r#"{"cmd":"submit","id":7,"class":"TE","cpu":4,"ram_gb":32,"gpu":1,"exec_time":90,"grace_period":2,"tenant":3,"seq":11}"#)
        {
            WireRequest::Command { cmd: SchedulerCommand::Submit(spec), seq: Some(11) } => {
                assert_eq!(spec.id, JobId(7));
                assert_eq!(spec.class, JobClass::Te);
                assert_eq!(spec.exec_time, 90);
                assert_eq!(spec.tenant, TenantId(3));
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            ok(r#"{"cmd":"cancel","job":4}"#),
            WireRequest::Command { cmd: SchedulerCommand::Cancel { job: JobId(4) }, seq: None }
        ));
        assert!(matches!(
            ok(r#"{"cmd":"node_down","node":1}"#),
            WireRequest::Command { cmd: SchedulerCommand::NodeDown { .. }, .. }
        ));
        assert!(matches!(
            ok(r#"{"cmd":"resize","node":0,"cpu":64,"ram_gb":512,"gpu":16}"#),
            WireRequest::Command { cmd: SchedulerCommand::Resize { .. }, .. }
        ));
        assert!(matches!(
            ok(r#"{"cmd":"set_quota","tenant":2,"size":128.5}"#),
            WireRequest::Command { cmd: SchedulerCommand::SetQuota { .. }, .. }
        ));
        assert!(matches!(
            ok(r#"{"cmd":"set_weight","tenant":2,"weight":4}"#),
            WireRequest::Command { cmd: SchedulerCommand::SetWeight { .. }, .. }
        ));
        assert!(matches!(
            ok(r#"{"cmd":"reclassify","job":3,"class":"BE"}"#),
            WireRequest::Command { cmd: SchedulerCommand::Reclassify { .. }, .. }
        ));
        assert!(matches!(ok(r#"{"cmd":"subscribe"}"#), WireRequest::Subscribe { seq: None }));
        assert!(matches!(ok(r#"{"cmd":"snapshot","seq":5}"#), WireRequest::Snapshot { seq: Some(5) }));
        assert!(matches!(ok(r#"{"cmd":"ping"}"#), WireRequest::Ping { .. }));
        assert!(matches!(ok(r#"{"cmd":"shutdown"}"#), WireRequest::Shutdown { .. }));
    }

    #[test]
    fn bad_requests_are_errors_not_panics() {
        for line in [
            "",
            "not json",
            "{}",
            r#"{"cmd":"launch_missiles"}"#,
            r#"{"cmd":"submit","id":7}"#,
            r#"{"cmd":"submit","id":99999999999,"class":"TE","cpu":1,"ram_gb":1,"gpu":0,"exec_time":5}"#,
            r#"{"cmd":"cancel"}"#,
            r#"{"cmd":"submit","id":1,"class":"XX","cpu":1,"ram_gb":1,"gpu":0,"exec_time":5}"#,
            r#"{"cmd":"submit","id":1,"class":"TE","cpu":-1,"ram_gb":1,"gpu":0,"exec_time":5}"#,
        ] {
            assert!(parse_request(line).is_err(), "{line:?} must be rejected");
        }
    }

    #[test]
    fn direct_response_encoder_matches_value_tree_builders() {
        let mut enc = ResponseEncoder::new();
        for now in [0u64, 7, 123_456_789] {
            for seq in [None, Some(0u64), Some(42), Some(u64::from(u32::MAX) + 1)] {
                assert_eq!(enc.ack(seq, now), ack_line(seq, now));
                assert_eq!(enc.pong(seq, now), pong_line(seq, now));
                assert_eq!(
                    enc.snapshot(seq, now, "/tmp/a b/auto-000000000042-000007.snap"),
                    snapshot_line(seq, now, "/tmp/a b/auto-000000000042-000007.snap")
                );
            }
            assert_eq!(enc.hello(now), hello_line(now));
        }
        for msg in ["", "plain", "with \"quotes\" and \\slash", "ctrl\u{1}\n\t", "üñíçødé"] {
            assert_eq!(enc.error(None, msg), error_line(None, msg));
            assert_eq!(enc.error(Some(9), msg), error_line(Some(9), msg));
        }
        for dropped in [1u64, 250, 1 << 40] {
            assert_eq!(enc.lagged(dropped), lagged_line(dropped));
        }
    }

    #[test]
    fn response_lines_are_single_line_json() {
        for line in [
            hello_line(3),
            ack_line(Some(7), 12),
            error_line(None, "nope"),
            pong_line(Some(1), 0),
            snapshot_line(None, 44, "/tmp/x.snap"),
            lagged_line(250),
        ] {
            assert!(!line.contains('\n'));
            Json::parse(&line).unwrap();
        }
    }
}
