//! Live service mode: the simulator's control plane exposed on a wire,
//! with deterministic snapshot/restore underneath it.
//!
//! Everything the batch simulator can do through
//! [`SchedulerCommand`](crate::sched::control::SchedulerCommand) /
//! [`SchedulerEvent`](crate::sched::control::SchedulerEvent) is served
//! here as JSONL over TCP and Unix-domain sockets, around one
//! [`SimSession`](crate::sim::SimSession) that owns all scheduler state:
//!
//! * [`wire`] — the request/response line protocol, its parser, and the
//!   reusable-buffer direct response encoder;
//! * [`server`] — listeners, per-connection threads, the session loop,
//!   batched zero-alloc fan-out with explicit `lagged` backpressure,
//!   pacing of virtual minutes against the wall clock, background
//!   auto-snapshots, and SIGTERM-triggered final snapshots;
//! * [`snapshot`] — the versioned, checksummed snapshot envelope, its
//!   file lifecycle (atomic save, load, latest-in-directory), and the
//!   background writer that keeps disk I/O off the session thread;
//! * [`attack`] — the closed-loop traffic frontend that replays any
//!   [`ArrivalSource`](crate::workload::source::ArrivalSource) against a
//!   live server from many concurrent wire clients.
//!
//! The determinism contract: snapshot at minute *T*, kill the process,
//! restore, continue — and the event stream and final records are
//! byte-identical to the uninterrupted run, across both engines and all
//! policies. `rust/tests/serve_snapshot.rs` pins exactly that under
//! chaos scenarios.

pub mod attack;
pub mod server;
pub mod snapshot;
pub mod wire;

pub use attack::{AttackConfig, AttackReport};
pub use server::{conservation_line, ServeConfig, ServeOutcome, ServeStats};
pub use snapshot::SnapshotFormatError;
